"""Adaptive model cascades on AI_FILTER (paper §5.2 / §6.2).

    PYTHONPATH=src python examples/cascade_filter.py

Runs the same semantic filter three ways — oracle-only, SUPG-IT cascade,
proxy-only — and prints the speed/quality trade-off plus the cascade's
learned thresholds and delegation report (what Snowflake surfaces to the
user after each query).
"""
import numpy as np

from repro.core import AisqlEngine, Catalog, CascadeConfig, ExecConfig
from repro.data import datasets as D
from repro.inference.api import make_simulated_client


def main():
    dataset = "NQ"
    t = D.cascade_table(dataset)
    catalog = Catalog({"ds": t})
    sql = ("SELECT * FROM ds AS d WHERE "
           f"AI_FILTER(PROMPT('{D.CASCADE_PREDICATES[dataset]}', d.text))")

    results = {}
    for mode in ("oracle", "cascade", "proxy"):
        client = make_simulated_client()
        ec = ExecConfig()
        if mode == "cascade":
            ec = ExecConfig(use_cascade=True, cascade=CascadeConfig(
                recall_target=0.9, precision_target=0.9))
        if mode == "proxy":
            client.default_model = "proxy-8b"
        engine = AisqlEngine(catalog, client, executor=ec)
        out = engine.sql(sql)
        ids = set(out.column("d.id").tolist())
        pred = np.array([i in ids for i in t.column("id")])
        m = D.binary_metrics(pred, t.column("_truth"))
        clock = sum(r.clock_s for reps in client.scheduler._replicas.values()
                    for r in {id(x): x for x in reps}.values()) / 2
        results[mode] = (clock, m)
        print(f"{mode:8s}: {clock:7.2f}s modelled | F1={m['f1']:.3f} "
              f"P={m['precision']:.3f} R={m['recall']:.3f} | "
              f"calls={dict(client.calls_by_model)}")
        if mode == "cascade":
            casc = list(engine.cascades.values())[0]
            s = casc.stats
            print(f"          delegation report: {s.delegation_rate:.1%} of "
                  f"{s.rows} rows escalated | thresholds "
                  f"tau_low={s.tau_low:.3f} tau_high={s.tau_high:.3f} | "
                  f"accept={s.accepted_by_proxy} reject={s.rejected_by_proxy} "
                  f"uncertain->oracle={s.uncertain_to_oracle}")
    speed = results["oracle"][0] / results["cascade"][0]
    keep = results["cascade"][1]["f1"] / results["oracle"][1]["f1"]
    print(f"\ncascade: {speed:.2f}x faster at {keep:.1%} of oracle F1 "
          f"(paper band: 1.2-5.9x at ~95.7%)")


if __name__ == "__main__":
    main()
