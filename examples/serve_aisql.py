"""End-to-end driver: serve AISQL over REAL JAX inference engines.

    PYTHONPATH=src python examples/serve_aisql.py

This is the paper-shaped end-to-end path (the paper is a serving system):
stand up the Cortex-platform analogue — two smoke-size model replicas per
tier behind the scheduler — and push batched AISQL queries through parse
-> AI-aware optimize -> execute, with every AI operator landing on real
model forward passes (prefill scoring, label-likelihood classification,
greedy decode).  Also demonstrates fault tolerance: one replica injects
failures and the scheduler retries transparently.
"""
import time

from repro.core import AisqlEngine, Catalog, ExecConfig
from repro.data import datasets as D
from repro.inference.api import CortexClient
from repro.inference.engine import JaxInferenceEngine
from repro.inference.scheduler import Scheduler


def main():
    # --- the Cortex platform: engines + scheduler + API service ---
    sched = Scheduler(max_retries=2)
    sched.register(JaxInferenceEngine("proxy-8b", engine_id="proxy#0"))
    sched.register(JaxInferenceEngine("proxy-8b", engine_id="proxy#1",
                                      failure_rate=0.3, seed=7))  # flaky
    sched.register(JaxInferenceEngine("oracle-70b", engine_id="oracle#0"))
    client = CortexClient(sched, default_model="oracle-70b",
                          proxy_model="proxy-8b")

    catalog = Catalog({
        "reviews": D.cascade_table("IMDB", rows=24),
        "articles": D.nyt_articles(24),
    })
    engine = AisqlEngine(catalog, client)

    queries = [
        "SELECT * FROM reviews AS r WHERE "
        "AI_FILTER(PROMPT('positive? {0}', r.text), model => 'proxy-8b') "
        "LIMIT 4",
        "SELECT AI_CLASSIFY(PROMPT('topic {0}', a.body), "
        "['politics','sports','tech'], model => 'proxy-8b') AS topic, "
        "COUNT(*) FROM articles AS a GROUP BY topic",
        "SELECT AI_COMPLETE(PROMPT('summarize: {0}', r.text), "
        "model => 'proxy-8b', max_tokens => 8) FROM reviews AS r LIMIT 2",
    ]
    for sql in queries:
        t0 = time.perf_counter()
        out = engine.sql(sql)
        dt = time.perf_counter() - t0
        rep = engine.last_report
        print(f"\n>>> {sql[:78]}...")
        for i in range(min(out.num_rows, 4)):
            print("   ", {k: str(v)[:56] for k, v in out.row(i).items()})
        print(f"    {out.num_rows} rows | {rep.ai_calls} real LLM calls | "
              f"{rep.ai_credits:.6f} credits | {dt:.2f}s wall")
    print(f"\nscheduler fault tolerance: {sched.retries} retries absorbed "
          f"(one replica injects failures at rate 0.3)")
    for model, reps in sched._replicas.items():
        for r in {id(x): x for x in reps}.values():
            if hasattr(r, "total_requests"):
                print(f"  {r.engine_id}: {r.total_requests} requests, "
                      f"{r.total_tokens} tokens, "
                      f"{r.total_credits:.6f} credits")


if __name__ == "__main__":
    main()
