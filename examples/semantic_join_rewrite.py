"""Semantic-join -> multi-label classification rewrite (paper §5.3 / §6.3).

    PYTHONPATH=src python examples/semantic_join_rewrite.py [dataset]

Shows the same AI_FILTER join executed as (a) the naive O(|L|x|R|) cross
join and (b) the AI_CLASSIFY rewrite the optimizer's rewrite-oracle
chooses, with call counts, modelled time, and pair-level quality.
"""
import sys

import numpy as np

from repro.core import AisqlEngine, Catalog, OptimizerConfig
from repro.data import datasets as D
from repro.inference.api import make_simulated_client


def model_clock(client) -> float:
    seen, total = set(), 0.0
    for reps in client.scheduler._replicas.values():
        for r in reps:
            if id(r) not in seen and hasattr(r, "clock_s"):
                total += r.clock_s
                seen.add(id(r))
    return total


def main(dataset: str = "CNN"):
    left, right, spec = D.join_tables(dataset)
    catalog = Catalog({"docs": left, "cats": right})
    sql = ("SELECT * FROM docs AS l JOIN cats AS r ON "
           f"AI_FILTER(PROMPT('{D.JOIN_PROMPTS[dataset]}', "
           "l.content, r.label))")
    truth = D.true_pairs_of(left, right)
    print(f"dataset={dataset}: |L|={spec.left_rows} |R|={spec.right_rows} "
          f"({spec.left_rows * spec.right_rows} candidate pairs)\n")

    stats = {}
    for mode, label in (("none", "cross join + AI_FILTER"),
                        ("ai_aware", "AI_CLASSIFY rewrite")):
        client = make_simulated_client()
        engine = AisqlEngine(catalog, client,
                             optimizer=OptimizerConfig(mode=mode))
        print(f"--- {label} ---")
        print(engine.explain(sql))
        out = engine.sql(sql)
        pairs = set(zip((int(x) for x in out.column("l.id")),
                        (str(x) for x in out.column("r.label"))))
        m = D.pair_metrics(pairs, truth)
        stats[mode] = (engine.last_report.ai_calls, model_clock(client), m)
        print(f"  {engine.last_report.ai_calls} LLM calls | "
              f"{model_clock(client):.1f}s modelled | "
              f"P={m['precision']:.3f} R={m['recall']:.3f} F1={m['f1']:.3f}\n")
    calls0, t0, m0 = stats["none"]
    calls1, t1, m1 = stats["ai_aware"]
    print(f"rewrite: {calls0}->{calls1} calls, {t0 / t1:.1f}x faster, "
          f"F1 {m0['f1']:.3f}->{m1['f1']:.3f} "
          f"(paper CNN: 69.5x, 0.840->0.887)")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "CNN")
