"""Quickstart: AISQL in five queries.

    PYTHONPATH=src python examples/quickstart.py

Builds a small catalog (product reviews + the paper's arXiv example
schema), stands up a simulated Cortex client, and runs the paper's six
semantic operators end-to-end with AI-aware optimization, printing the
optimized plans and the LLM-call telemetry.
"""
from repro.core import AisqlEngine, Catalog
from repro.data import datasets as D
from repro.inference.api import make_simulated_client


def main():
    papers, paper_images = D.papers_tables(n_papers=120, images_per_paper=3)
    catalog = Catalog({
        "product_reviews": D.cascade_table("IMDB", rows=80),
        "papers": papers,
        "paper_images": paper_images,
    })
    engine = AisqlEngine(catalog, make_simulated_client())

    queries = [
        # AI_COMPLETE — map/projection (§3.1)
        ("AI_COMPLETE",
         "SELECT AI_COMPLETE(PROMPT('Evaluate satisfaction: {0}', r.text)) "
         "FROM product_reviews AS r LIMIT 3"),
        # AI_FILTER — semantic predicate (§3.2)
        ("AI_FILTER",
         "SELECT * FROM product_reviews AS r WHERE "
         "AI_FILTER(PROMPT('does {0} express positive sentiment?', r.text)) "
         "LIMIT 5"),
        # AI_CLASSIFY + GROUP BY (§3.4)
        ("AI_CLASSIFY",
         "SELECT AI_CLASSIFY(PROMPT('sentiment {0}', r.text), "
         "['positive','negative']) AS sentiment, COUNT(*) "
         "FROM product_reviews AS r GROUP BY sentiment"),
        # AI_SUMMARIZE_AGG (§3.5)
        ("AI_SUMMARIZE_AGG",
         "SELECT AI_SUMMARIZE_AGG(r.text) FROM product_reviews AS r"),
        # the paper's §5.1 example: relational + text + multimodal filters
        ("paper §5.1 example",
         "SELECT AI_SUMMARIZE_AGG(p.abstract) "
         "FROM papers p JOIN paper_images i ON p.id = i.id "
         "WHERE p.date BETWEEN 2010 AND 2015 AND "
         "AI_FILTER(PROMPT('{0} discusses energy efficiency', p.abstract)) "
         "AND AI_FILTER(PROMPT('{0} shows TPC-H results', i.image_file))"),
    ]
    for name, sql in queries:
        print(f"\n=== {name} ===")
        print(engine.explain(sql))
        out = engine.sql(sql)
        for i in range(min(out.num_rows, 3)):
            print("  ", {k: str(v)[:64] for k, v in out.row(i).items()})
        rep = engine.last_report
        print(f"  -> {out.num_rows} rows | {rep.ai_calls} LLM calls | "
              f"{rep.ai_credits:.6f} credits | {rep.wall_seconds:.2f}s")


if __name__ == "__main__":
    main()
