"""Continuous-batching decode backend: paged KV cache, per-step admission,
parity with the static path (results must be byte-identical), and the
satellite regressions (latency attribution, decode jit bucketing, result
ordering for duplicate / unknown request ids)."""
import copy

import numpy as np
import pytest

from repro.inference import tokenizer as tok
from repro.inference.api import make_engine_client
from repro.inference.backend import (COMPLETE, SCORE, EngineFailure, Request,
                                     Result)
from repro.inference.continuous import ContinuousBatcher, _Seq, supports
from repro.inference.engine import JaxInferenceEngine
from repro.inference.paged_kv import OutOfBlocks, PagedKVCache
from repro.configs import base as cfgs


@pytest.fixture(scope="module")
def static_engine():
    return JaxInferenceEngine("proxy-8b", smoke=True, max_seq=192,
                              backend="static", seed=0)


@pytest.fixture(scope="module")
def cont_engine():
    return JaxInferenceEngine("proxy-8b", smoke=True, max_seq=192,
                              backend="continuous", seed=0)


def _row(r: Result):
    return (r.request_id, r.kind, r.text, r.score, r.tokens_in,
            r.tokens_out, r.credits)


def _serve(engine, reqs):
    return [_row(r) for r in engine.submit_batch(copy.deepcopy(reqs))]


# ---------------------------------------------------------------------------
# paged KV allocator
# ---------------------------------------------------------------------------


def test_paged_kv_allocator(cont_engine):
    kv = PagedKVCache(cont_engine.model, block_size=16, num_blocks=8)
    assert kv.max_seq_blocks == 7          # block 0 is scratch
    assert kv.free_count == 7
    assert kv.blocks_for(1) == 1 and kv.blocks_for(16) == 1
    assert kv.blocks_for(17) == 2
    got = kv.alloc(3)
    assert len(got) == 3 and 0 not in got
    assert kv.free_count == 4
    assert kv.can_alloc(4) and not kv.can_alloc(5)
    with pytest.raises(OutOfBlocks):
        kv.alloc(5)
    kv.free_blocks(got)
    assert kv.free_count == 7
    with pytest.raises(ValueError):
        kv.free_blocks(got)                # double free
    with pytest.raises(ValueError):
        kv.free_blocks([0])                # scratch block is not allocable


def test_paged_kv_scatter_gather_roundtrip(cont_engine):
    import jax
    import jax.numpy as jnp
    kv = PagedKVCache(cont_engine.model, block_size=8, num_blocks=6)
    b0, b1 = kv.alloc(2), kv.alloc(1)
    tables = jnp.asarray(np.array([[b0[0], b0[1]], [b1[0], 0]], np.int32))
    zero = jnp.zeros((2,), jnp.int32)
    counts = np.array([5, 3], np.int32)
    dense = kv.gather(kv.pool, tables, zero)
    rng = np.random.default_rng(0)

    def fill(x):
        return jnp.asarray(rng.standard_normal(x.shape)).astype(x.dtype)

    fake = {k: jax.tree.map(fill, dense[k]) for k in kv.pool}
    pool2 = kv.scatter(kv.pool, fake, tables, zero, jnp.asarray(counts), 8)
    got = kv.gather(pool2, tables, jnp.asarray(counts))
    for k in kv.pool:
        for g, f, a in zip(jax.tree.leaves(got[k]), jax.tree.leaves(fake[k]),
                           jax.tree.leaves(kv._axes[k])):
            g = np.moveaxis(np.asarray(g, np.float32), (a, a + 1), (0, 1))
            f = np.moveaxis(np.asarray(f, np.float32), (a, a + 1), (0, 1))
            for row, cnt in enumerate(counts):
                # written prefix persisted exactly; tails and the scratch
                # block stayed zero
                assert (g[row, :cnt] == f[row, :cnt]).all()
                assert (g[row, cnt:] == 0).all()


# ---------------------------------------------------------------------------
# parity: continuous == static, byte-identical
# ---------------------------------------------------------------------------


def _ragged_workload():
    reqs = []
    rid = 0
    for i, mt in enumerate([40, 4, 9, 2, 17, 4, 1, 6]):
        rid += 1
        reqs.append(Request(
            "w" * (3 + 11 * i) + f" complete case {i}", "proxy-8b", COMPLETE,
            max_tokens=mt, request_id=rid))
    for i in range(5):
        rid += 1
        reqs.append(Request(f"score this ragged row {i}" + "?" * (7 * i),
                            "proxy-8b", SCORE, request_id=rid))
    return reqs


def test_parity_ragged_lengths(static_engine, cont_engine):
    reqs = _ragged_workload()
    assert _serve(static_engine, reqs) == _serve(cont_engine, reqs)


def test_parity_midstream_admission(static_engine, cont_engine):
    # 3x more requests than slots: admission happens mid-stream as
    # earlier sequences retire, never at batch boundaries
    reqs = []
    for i in range(3 * cont_engine.max_batch):
        reqs.append(Request(f"queued request number {i} says hello",
                            "proxy-8b", COMPLETE,
                            max_tokens=24 if i % 5 == 0 else 3,
                            request_id=i + 1))
    before = cont_engine._batcher.admitted
    assert _serve(static_engine, reqs) == _serve(cont_engine, reqs)
    assert cont_engine._batcher.admitted - before == len(reqs)


def test_parity_chunked_prefill_long_prompt(static_engine, cont_engine):
    # prompts several chunks long: chunked decode-mode prefill must equal
    # the static one-shot prefill bitwise
    long = "the quick brown fox jumps over the lazy dog " * 4
    reqs = [Request(long + f"[{i}]", "proxy-8b",
                    SCORE if i % 2 else COMPLETE, max_tokens=6,
                    request_id=i + 1) for i in range(4)]
    assert _serve(static_engine, reqs) == _serve(cont_engine, reqs)


def test_parity_repeated_waves_reuse_pool(static_engine, cont_engine):
    # the paged pool is reused across serve() waves; stale KV from an
    # earlier wave must never leak into a later one
    reqs = _ragged_workload()[:6]
    first = _serve(cont_engine, reqs)
    kv = cont_engine._batcher.kv
    assert kv.free_count == kv.num_blocks - 1   # all blocks recycled
    assert first == _serve(cont_engine, reqs)
    assert first == _serve(static_engine, reqs)


def test_parity_through_client_eager_and_pipelined():
    outs = {}
    for backend in ("static", "continuous"):
        for pipelined in (False, True):
            client = make_engine_client(("proxy-8b",), seed=0,
                                        pipelined=pipelined, backend=backend)
            scores = client.filter_scores(
                [f"is item {i} in stock?" for i in range(5)],
                model="proxy-8b")
            texts = client.complete(
                [f"describe item {i}" for i in range(3)],
                model="proxy-8b", max_tokens=5)
            outs[(backend, pipelined)] = (scores.tolist(), texts)
    assert outs[("static", False)] == outs[("continuous", False)]
    assert outs[("static", True)] == outs[("continuous", True)]
    assert outs[("static", False)] == outs[("static", True)]


# ---------------------------------------------------------------------------
# retirement / admission mechanics
# ---------------------------------------------------------------------------


def test_eos_retires_before_max_tokens(cont_engine):
    b = ContinuousBatcher(cont_engine, block_size=16)
    blocks = b.kv.alloc(1)
    seq = _Seq(req=Request("x", "proxy-8b", COMPLETE, max_tokens=64,
                           request_id=1),
               index=0, enc=[tok.BOS_ID, 5, 6], slot=0, blocks=blocks,
               state="decode", cur=tok.EOS_ID)
    active = [seq] + [None] * (b.slots - 1)
    results = [None]
    free_before = b.kv.free_count
    b._consume(seq, active, results, t0=0.0)
    assert results[0] is not None and results[0].tokens_out == 1
    assert active[0] is None                       # slot freed
    assert b.retired_eos == 1
    assert b.kv.free_count == free_before + 1      # blocks recycled


def test_oversized_request_raises(cont_engine):
    b = cont_engine._batcher
    need = (b.kv.max_seq_blocks + 1) * b.block_size
    reqs = [Request("p", "proxy-8b", COMPLETE, max_tokens=need,
                    request_id=1)]
    with pytest.raises(EngineFailure):
        cont_engine.submit_batch(reqs)


def test_unsupported_arch_falls_back_to_static():
    cfg = cfgs.get_smoke_config("recurrentgemma-9b")
    assert not supports(cfg)
    eng = JaxInferenceEngine("recurrentgemma-9b", smoke=True, backend="auto")
    assert eng.backend == "static"
    with pytest.raises(ValueError):
        JaxInferenceEngine("recurrentgemma-9b", smoke=True,
                           backend="continuous")


def test_supported_arch_defaults_to_continuous(cont_engine):
    assert supports(cont_engine.cfg)
    eng = JaxInferenceEngine("proxy-8b", smoke=True, backend="auto")
    assert eng.backend == "continuous"


# ---------------------------------------------------------------------------
# satellite regressions
# ---------------------------------------------------------------------------


def test_latency_attributed_per_request(cont_engine):
    # one long tail + many short completions: the shorts retire early and
    # must not inherit the batch-drain latency (no smearing)
    reqs = [Request(f"req {i}", "proxy-8b", COMPLETE,
                    max_tokens=64 if i == 0 else 2, request_id=i + 1)
            for i in range(6)]
    res = cont_engine.submit_batch(copy.deepcopy(reqs))
    lats = [r.latency_s for r in res]
    assert len(set(lats)) > 1, "per-request latency is smeared"
    assert res[0].latency_s == max(lats)   # the long tail finishes last
    assert all(l <= res[0].latency_s for l in lats)


def test_static_latency_not_smeared(static_engine):
    reqs = [Request(f"req {i}", "proxy-8b", COMPLETE,
                    max_tokens=48 if i == 0 else 2, request_id=i + 1)
            for i in range(4)]
    res = static_engine.submit_batch(copy.deepcopy(reqs))
    lats = [r.latency_s for r in res]
    assert res[0].latency_s == max(lats)
    assert min(lats) < max(lats)


def test_decode_jit_cache_bucketed(static_engine):
    # decode step functions are keyed on the bucketed batch, so serving
    # B=3 then B=4 compiles exactly one decode entry
    def decode_keys():
        return {k for k in static_engine._jit_cache if k[0] == "decode"}

    counts = []
    for B in (3, 4):
        reqs = [Request("same prompt here", "proxy-8b", COMPLETE,
                        max_tokens=3, request_id=i + 1) for i in range(B)]
        static_engine.submit_batch(reqs)
        counts.append(len(decode_keys()))
    assert counts[0] == counts[1], "B=3 and B=4 must share one decode key"


def test_duplicate_request_ids_stable_order(static_engine, cont_engine):
    for eng in (static_engine, cont_engine):
        reqs = [Request("first of a duplicated id", "proxy-8b", SCORE,
                        request_id=9),
                Request("second of a duplicated id", "proxy-8b", SCORE,
                        request_id=9)]
        res = eng.submit_batch(copy.deepcopy(reqs))
        solo = [eng.submit_batch([copy.deepcopy(r)])[0].score for r in reqs]
        assert [r.score for r in res] == solo  # submission order kept


def test_unknown_request_id_raises(static_engine):
    reqs = [Request("p", "proxy-8b", SCORE, request_id=1)]
    bogus = [Result(99, "proxy-8b", SCORE, score=0.5)]
    with pytest.raises(EngineFailure):
        static_engine._restore_order(reqs, bogus)


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------


def test_backend_stats_and_roofline(cont_engine):
    reqs = _ragged_workload()[:4]
    cont_engine.submit_batch(copy.deepcopy(reqs))
    stats = cont_engine.backend_stats()
    assert stats["backend"] == "continuous"
    assert stats["prefill_steps"] > 0 and stats["decode_steps"] > 0
    assert stats["kv_peak_blocks"] > 0
    rep = cont_engine.backend_roofline()
    assert set(rep) == {"prefill", "decode"}
    for kind in rep.values():
        assert kind["tokens_per_step"] > 0
        assert 0.0 <= kind["mfu_bound"] <= 1.0

    # the static backend reports too, without batcher telemetry
    st = JaxInferenceEngine("proxy-8b", smoke=True, backend="static")
    assert st.backend_stats()["backend"] == "static"
    assert st.backend_roofline() == {}
