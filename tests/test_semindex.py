"""Semantic index subsystem: store, IVF index, manager, and the two
optimizer integrations (index-assisted join blocking, top-k pruning)."""
import os

import numpy as np
import pytest

from repro.core import (AisqlEngine, Catalog, ExecConfig, SemIndexConfig,
                        ServingEngine)
from repro.data import datasets as D
from repro.inference.api import make_simulated_client
from repro.semindex import (EmbeddingStore, IvfConfig, IvfFlatIndex,
                            SemanticIndexManager)
from repro.tables.table import Table


def _clustered_vectors(n_clusters=8, per_cluster=40, dim=32, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((n_clusters, dim))
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    vecs = np.repeat(centers, per_cluster, axis=0)
    vecs = vecs + 0.15 * rng.standard_normal(vecs.shape)
    return vecs.astype(np.float32)


# ---------------------------------------------------------------------------
# EmbeddingStore
# ---------------------------------------------------------------------------


def test_store_content_hash_roundtrip(tmp_path):
    store = EmbeddingStore()
    texts = [f"doc {i}" for i in range(10)]
    vecs = [np.full(4, i, np.float32) for i in range(10)]
    store.put("m", texts, vecs)
    got = store.get("m", ["doc 3", "doc 99", "doc 0"])
    assert got[1] is None
    np.testing.assert_array_equal(got[0], vecs[3])
    np.testing.assert_array_equal(got[2], vecs[0])
    assert store.coverage("m", texts) == 1.0
    assert store.coverage("other-model", texts) == 0.0  # model in the key
    store.register_column("t.body", "m", texts)
    path = os.path.join(tmp_path, "emb")
    store.save(path)
    re = EmbeddingStore(path)
    assert len(re) == len(store)
    mat, keys = re.column_matrix("t.body")
    assert mat.shape == (10, 4)
    np.testing.assert_array_equal(mat[7], vecs[7])


def test_store_column_signature_tracks_content():
    texts = [f"x{i}" for i in range(5)]
    s1 = EmbeddingStore.column_signature("m", texts)
    s2 = EmbeddingStore.column_signature("m", texts)
    s3 = EmbeddingStore.column_signature("m", texts[:-1] + ["changed"])
    assert s1 == s2 and s1 != s3


# ---------------------------------------------------------------------------
# IvfFlatIndex
# ---------------------------------------------------------------------------


def test_ivf_exact_when_probing_all_cells():
    vecs = _clustered_vectors()
    ix = IvfFlatIndex(vecs, IvfConfig(nlist=8, nprobe=8, impl="reference"))
    q = vecs[::37] + 0.01
    v_flat, i_flat = ix.search_flat(q, 7)
    v_ivf, i_ivf = ix.search(q, 7)
    np.testing.assert_array_equal(i_flat, i_ivf)
    np.testing.assert_allclose(v_flat, v_ivf, rtol=1e-5)


def test_ivf_recall_on_clustered_data():
    vecs = _clustered_vectors()
    ix = IvfFlatIndex(vecs, IvfConfig(nlist=8, nprobe=2, impl="reference"))
    q = vecs[::11]
    assert ix.measure_recall(q, 5) > 0.9   # clustered data: 2 probes enough
    assert ix.measure_recall(q, 5, nprobe=8) == 1.0


def test_ivf_self_query_returns_self():
    vecs = _clustered_vectors(per_cluster=10)
    ix = IvfFlatIndex(vecs, IvfConfig(nlist=4, nprobe=4, impl="reference"))
    _, idx = ix.search(vecs[17:18], 1)
    assert int(idx[0][0]) == 17


# ---------------------------------------------------------------------------
# SemanticIndexManager
# ---------------------------------------------------------------------------


def test_manager_embeds_misses_once_and_rebuilds_on_drift():
    client = make_simulated_client()
    mgr = SemanticIndexManager(SemIndexConfig(impl="reference", nlist=4,
                                              min_index_rows=4))
    texts = [f"text number {i}" for i in range(30)]
    ix1 = mgr.ensure_index(client, "t.c", texts)
    calls = client.ai_calls
    assert calls == 30
    assert mgr.ensure_index(client, "t.c", texts) is ix1   # cached
    assert client.ai_calls == calls
    # one changed row: re-embed exactly the new text
    ix2 = mgr.ensure_index(client, "t.c", texts[:-1] + ["fresh text"])
    assert ix2 is not ix1
    assert client.ai_calls == calls + 1


def test_manager_search_and_coverage():
    client = make_simulated_client()
    mgr = SemanticIndexManager(SemIndexConfig(impl="reference", nlist=2,
                                              min_index_rows=2))
    texts = [f"alpha topic {i}" for i in range(12)]
    mgr.ensure_index(client, "t.c", texts)
    assert mgr.coverage(client, texts) == 1.0
    q = mgr.embed_texts(client, [texts[5]])
    vals, ids = mgr.search("t.c", q, 3)
    assert int(ids[0][0]) == 5
    with pytest.raises(KeyError):
        mgr.search("t.unknown", q, 3)


# ---------------------------------------------------------------------------
# EMBED pricing (satellite: per-kind table, legacy kinds unchanged)
# ---------------------------------------------------------------------------


def test_embed_priced_per_input_token_and_cheaper():
    from repro.inference.backend import (EMBED, EMBED_CREDITS_PER_MTOK,
                                         credits_for)
    for model, rate in EMBED_CREDITS_PER_MTOK.items():
        assert credits_for(model, 1000, EMBED) == pytest.approx(
            rate * 1000 / 1e6)
    # an embedding over the same tokens costs far below any LLM tier
    assert credits_for("arctic-embed-m", 1000, EMBED) < \
        0.2 * credits_for("proxy-8b", 1000)


def test_generative_kinds_price_unchanged_by_kind_table():
    """Regression: SCORE/CLASSIFY/COMPLETE (and the legacy two-argument
    call) still price exactly ``CREDITS_PER_MTOK[model] * toks / 1e6``."""
    from repro.inference.backend import (CLASSIFY, COMPLETE,
                                         CREDITS_PER_MTOK, SCORE,
                                         credits_for)
    for model, rate in CREDITS_PER_MTOK.items():
        legacy = rate * 777 / 1e6
        assert credits_for(model, 777) == pytest.approx(legacy)
        for kind in (SCORE, CLASSIFY, COMPLETE, None):
            assert credits_for(model, 777, kind) == pytest.approx(legacy)
    assert credits_for("unknown-model", 100) == pytest.approx(0.5 * 100 / 1e6)


def test_simulated_embeddings_deterministic_and_topic_correlated():
    c1 = make_simulated_client()
    c2 = make_simulated_client()
    texts = ["database engine storage query",
             "query engine for database storage",
             "soccer final tonight"]
    v1 = c1.embed(texts)
    v2 = c2.embed(texts)
    np.testing.assert_array_equal(v1, v2)           # seed-deterministic
    np.testing.assert_allclose(np.linalg.norm(v1, axis=1), 1.0, atol=1e-6)
    assert v1[0] @ v1[1] > 0.5                      # shared vocabulary
    assert v1[0] @ v1[2] < 0.4                      # disjoint topics


def test_embed_faults_injected_before_billing():
    from repro.inference.backend import EngineFailure, Request, EMBED
    from repro.inference.simulator import SimulatedBackend
    be = SimulatedBackend(seed=0, fault_rate=1.0)
    with pytest.raises(EngineFailure):
        be.submit_batch([Request("text", "arctic-embed-m", EMBED)])
    assert be.total_credits == 0.0


# ---------------------------------------------------------------------------
# SQL surface: AI_EMBED / AI_SIMILARITY
# ---------------------------------------------------------------------------


def _text_catalog(n=90, seed=0):
    rng = np.random.default_rng(seed)
    words = ["database engine", "vector index", "soccer match",
             "film review"]
    return Catalog({"t": Table({
        "id": np.arange(n),
        "val": rng.random(n),
        "text": [f"[t:{i}] {words[i % 4]} body {i}" for i in range(n)],
    }, name="t")})


def test_parse_ai_embed_and_similarity():
    from repro.core import sqlparse
    from repro.core import expr as E
    q = sqlparse.parse("SELECT AI_EMBED(t.text) FROM t "
                       "WHERE AI_SIMILARITY(t.text, 'query') > 0.5")
    assert isinstance(q.select[0].expr, E.AIEmbed)
    assert isinstance(q.where.left, E.AISimilarity)
    with pytest.raises(SyntaxError):
        sqlparse.parse("SELECT AI_SIMILARITY(t.text) FROM t")


def test_similarity_projection_and_threshold_filter():
    cat = _text_catalog()
    eng = AisqlEngine(cat, make_simulated_client())
    out = eng.sql("SELECT t.id, AI_SIMILARITY(t.text, 'database engine') "
                  "AS sim FROM t")
    sims = out.column("sim")
    ids = out.column("t.id")
    on_topic = sims[ids % 4 == 0]
    off_topic = sims[ids % 4 == 2]
    assert on_topic.min() > off_topic.max()    # topics separate cleanly
    flt = eng.sql("SELECT t.id FROM t "
                  "WHERE AI_SIMILARITY(t.text, 'database engine') > 0.5")
    assert set(flt.column("t.id").tolist()) == \
        set(ids[sims > 0.5].tolist())


def test_embed_projection_returns_unit_vectors():
    cat = _text_catalog(12)
    eng = AisqlEngine(cat, make_simulated_client())
    out = eng.sql("SELECT t.id, AI_EMBED(t.text) AS v FROM t")
    first = np.asarray(out.column("v")[0])
    assert len(first) == 64
    assert np.linalg.norm(first) == pytest.approx(1.0, abs=1e-6)


def test_similarity_topk_index_on_off_identical_and_warm_free():
    cat = _text_catalog()
    sql = ("SELECT t.id FROM t "
           "ORDER BY AI_SIMILARITY(t.text, 'database engine') DESC "
           "LIMIT 7")
    off = AisqlEngine(cat, make_simulated_client())
    rows_off = list(off.sql(sql).column("t.id"))
    on = AisqlEngine(cat, make_simulated_client(),
                     semindex=SemIndexConfig(impl="reference"))
    rows_on = list(on.sql(sql).column("t.id"))
    assert rows_on == rows_off
    assert on.last_report.ai_credits <= off.last_report.ai_credits + 1e-12
    assert on.last_report.semindex["index_topk"] == 1
    # warm repeat: the store answers everything, zero EMBED dispatches
    rows_warm = list(on.sql(sql).column("t.id"))
    assert rows_warm == rows_off
    assert on.last_report.ai_calls == 0


def test_similarity_topk_ivf_probing_path():
    """exact_topk=False routes the top-k through IVF probing; with
    nprobe == nlist the probe set covers every cell, so the result is
    still exact — the machinery is exercised without a recall gamble."""
    cat = _text_catalog()
    sql = ("SELECT t.id FROM t "
           "ORDER BY AI_SIMILARITY(t.text, 'database engine') DESC "
           "LIMIT 6")
    off = AisqlEngine(cat, make_simulated_client())
    on = AisqlEngine(cat, make_simulated_client(),
                     semindex=SemIndexConfig(impl="reference",
                                             exact_topk=False, nlist=6,
                                             nprobe=6, min_index_rows=8))
    assert list(on.sql(sql).column("t.id")) == \
        list(off.sql(sql).column("t.id"))
    assert on.semindex.index_builds == 1     # the managed IVF index ran


def test_similarity_order_by_asc_matches_host_sort():
    cat = _text_catalog()
    sql = ("SELECT t.id FROM t "
           "ORDER BY AI_SIMILARITY(t.text, 'database engine') ASC LIMIT 5")
    off = AisqlEngine(cat, make_simulated_client())
    on = AisqlEngine(cat, make_simulated_client(),
                     semindex=SemIndexConfig(impl="reference"))
    assert list(on.sql(sql).column("t.id")) == \
        list(off.sql(sql).column("t.id"))


# ---------------------------------------------------------------------------
# index-assisted semantic join
# ---------------------------------------------------------------------------


def _race(cat, sql, *, semindex=None, seed=0):
    eng = AisqlEngine(cat, make_simulated_client(seed=seed),
                      semindex=semindex)
    out = eng.sql(sql)
    pairs = set(zip((int(x) for x in out.column("l.id")),
                    (str(x) for x in out.column("r.label"))))
    return eng, pairs


def test_index_join_wins_race_and_pairs_subset():
    left, right, _ = D.join_tables("EURLEX")
    cat = Catalog({"l": left, "r": right})
    sql = ("SELECT * FROM l JOIN r ON "
           f"AI_FILTER(PROMPT('{D.JOIN_PROMPTS['EURLEX']}', "
           "l.content, r.label))")
    eng_c, pairs_c = _race(cat, sql)
    eng_i, pairs_i = _race(cat, sql,
                           semindex=SemIndexConfig(impl="reference",
                                                   join_k=16))
    assert any("rewrite-winner: index" in t
               for t in eng_i.last_report.optimizer_trace)
    assert "SemanticJoinIndex" in eng_i.last_report.plan
    # per-label decisions are composition-independent: the index's
    # verified pairs are the rewrite's selections restricted to the
    # candidate set — never new pairs (EURLEX averages 4 true labels
    # per row, which dilutes the anchors; recall is bounded, not exact)
    assert pairs_i <= pairs_c
    assert len(pairs_i) >= 0.7 * len(pairs_c)      # candidate recall
    assert eng_i.last_report.ai_credits < 0.5 * eng_c.last_report.ai_credits
    tel = eng_i.last_report.semindex
    assert tel["index_joins"] == 1 and tel["probes"] == left.num_rows


def test_index_join_identical_rows_without_add_noise():
    """With the add-noise knob at zero, candidate pruning cannot lose a
    selected pair (selections ⊆ true labels ⊆ candidates) — result rows
    must be identical to the full classification rewrite."""
    left, right, _ = D.join_tables("AGNEWS_100")
    left = left.with_column("_add_frac", np.zeros(left.num_rows))
    cat = Catalog({"l": left, "r": right})
    sql = ("SELECT * FROM l JOIN r ON "
           f"AI_FILTER(PROMPT('{D.JOIN_PROMPTS['AGNEWS_100']}', "
           "l.content, r.label))")
    _, pairs_c = _race(cat, sql)
    eng_i, pairs_i = _race(cat, sql,
                           semindex=SemIndexConfig(impl="reference",
                                                   join_k=8))
    assert pairs_i == pairs_c
    assert eng_i.last_report.semindex["verify_calls"] == left.num_rows


def test_index_join_multipass_matches_hybrid_rewrite():
    """classify_passes applies to the index join's verification too:
    with zero add-noise the 2-pass index join equals the 2-pass hybrid
    rewrite (pass-tagged prompts draw identically on both paths)."""
    left, right, _ = D.join_tables("AGNEWS_100")
    left = left.with_column("_add_frac", np.zeros(left.num_rows))
    cat = Catalog({"l": left, "r": right})
    sql = ("SELECT * FROM l JOIN r ON "
           f"AI_FILTER(PROMPT('{D.JOIN_PROMPTS['AGNEWS_100']}', "
           "l.content, r.label))")
    def run(semindex):
        eng = AisqlEngine(cat, make_simulated_client(), semindex=semindex,
                          executor=ExecConfig(classify_passes=2))
        out = eng.sql(sql)
        return eng, set(zip((int(x) for x in out.column("l.id")),
                            (str(x) for x in out.column("r.label"))))
    _, pairs_c = run(None)
    eng_i, pairs_i = run(SemIndexConfig(impl="reference", join_k=8))
    assert pairs_i == pairs_c
    assert eng_i.last_report.semindex["verify_calls"] == 2 * left.num_rows


def test_index_join_learns_candidate_rate():
    from repro.core.stats import index_join_fingerprint
    left, right, _ = D.join_tables("AGNEWS_100")
    cat = Catalog({"l": left, "r": right})
    sql = ("SELECT * FROM l JOIN r ON "
           f"AI_FILTER(PROMPT('{D.JOIN_PROMPTS['AGNEWS_100']}', "
           "l.content, r.label))")
    eng, _ = _race(cat, sql, semindex=SemIndexConfig(impl="reference",
                                                     join_k=6))
    keys = [k for k in eng.stats.keys() if k.startswith("INDEX_JOIN|")]
    assert keys
    obs = eng.stats.get(keys[0])
    assert obs.index_probes == left.num_rows
    assert 0 < obs.candidates_per_probe <= 6


def test_topk_index_score_escalates_candidates_only():
    rng = np.random.default_rng(3)
    n = 160
    topic = rng.random(n) < 0.25
    t = Table({
        "id": np.arange(n),
        "text": [f"[r:{i}] " + ("database query engine index"
                                if topic[i] else "travel food films")
                 + f" tail {i}" for i in range(n)],
        "_truth": topic,
        "_difficulty": np.full(n, 0.05),
    }, name="t")
    eng = AisqlEngine(Catalog({"t": t}), make_simulated_client(),
                      semindex=SemIndexConfig(impl="reference"),
                      executor=ExecConfig(topk_index_score=True))
    out = eng.sql("SELECT t.id FROM t ORDER BY AI_SCORE(PROMPT("
                  "'is this about database systems? {0}', t.text)) DESC "
                  "LIMIT 5")
    assert out.num_rows == 5
    assert any("topk-index" in ev
               for ev in eng.last_report.reoptimizations)
    # the oracle only saw the escalated candidates, not all n rows
    oracle_ops = [op for op in eng.last_report.operators
                  if "AI_SCORE" in op.operator and "oracle" in op.operator]
    assert oracle_ops and oracle_ops[0].actual_rows_in < n
    assert all(bool(t.column("_truth")[i]) for i in out.column("t.id"))


# ---------------------------------------------------------------------------
# serving: one index shared across tenant sessions
# ---------------------------------------------------------------------------


def test_serving_shares_index_across_tenants():
    cat = _text_catalog()
    sql = ("SELECT t.id FROM t "
           "ORDER BY AI_SIMILARITY(t.text, 'database engine') DESC "
           "LIMIT 5")
    serial = AisqlEngine(cat, make_simulated_client(),
                         semindex=SemIndexConfig(impl="reference"))
    rows_serial = list(serial.sql(sql).column("t.id"))
    with ServingEngine.simulated(
            cat, semindex=SemIndexConfig(impl="reference")) as srv:
        t_a = srv.submit("tenant-a", sql)
        t_a.result()
        srv.drain()
        embeds_after_a = srv.semindex.embed_llm_calls
        t_b = srv.submit("tenant-b", sql)
        t_b.result()
        srv.drain()
        # tenant B's query was answered from tenant A's embeddings:
        # the shared store dispatched no new EMBED work
        assert srv.semindex.embed_llm_calls == embeds_after_a
        assert list(t_a.result().column("t.id")) == rows_serial
        assert list(t_b.result().column("t.id")) == rows_serial


def test_persisted_store_warm_starts_new_engine(tmp_path):
    cat = _text_catalog(40)
    sql = ("SELECT t.id FROM t "
           "ORDER BY AI_SIMILARITY(t.text, 'database engine') DESC "
           "LIMIT 4")
    path = os.path.join(tmp_path, "semidx")
    e1 = AisqlEngine(cat, make_simulated_client(),
                     semindex=SemIndexConfig(impl="reference"),
                     semindex_path=path)
    rows1 = list(e1.sql(sql).column("t.id"))
    assert e1.last_report.ai_calls > 0
    # a brand-new engine (new client, new manager) loads the store from
    # disk: same rows, zero EMBED dispatches
    e2 = AisqlEngine(cat, make_simulated_client(),
                     semindex=SemIndexConfig(impl="reference"),
                     semindex_path=path)
    rows2 = list(e2.sql(sql).column("t.id"))
    assert rows2 == rows1
    assert e2.last_report.ai_calls == 0
