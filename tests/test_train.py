"""Training substrate: determinism, checkpoints, crash-restart, data."""
import json
import os

import jax
import numpy as np
import pytest

from repro.models import model_zoo
from repro.train.checkpoint import CheckpointCorrupt, CheckpointManager
from repro.train.data import TokenPipeline
from repro.train.loop import (FailureInjector, LoopConfig, Trainer,
                              run_with_restarts)
from repro.train.optim import AdamWConfig, adamw_init, adamw_update, schedule
from repro.train.train_step import init_state, make_train_step


@pytest.fixture(scope="module")
def model():
    return model_zoo.build("rwkv6-1.6b", smoke=True)


def test_pipeline_deterministic_and_sharded():
    p = TokenPipeline(1000, seq_len=16, global_batch=8, seed=0)
    b1 = p.batch_at(3)
    b2 = p.batch_at(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # shards partition the global batch
    shards = [TokenPipeline(1000, 16, 8, seed=0, num_shards=2, shard_id=i)
              for i in range(2)]
    got = np.concatenate([s.batch_at(3)["tokens"] for s in shards])
    np.testing.assert_array_equal(got, b1["tokens"])
    # labels are next-token shifted
    full = TokenPipeline(1000, 16, 8, seed=0)
    b = full.batch_at(0)
    assert b["tokens"].shape == (8, 16) and b["labels"].shape == (8, 16)


def test_adamw_schedule_and_clip():
    cfg = AdamWConfig(lr=1e-2, warmup_steps=10, total_steps=100)
    assert float(schedule(cfg, 0)) == 0.0
    assert abs(float(schedule(cfg, 10)) - 1e-2) < 1e-9
    assert float(schedule(cfg, 100)) <= 1e-2 * cfg.min_lr_frac + 1e-9
    params = {"w": np.ones((4,), np.float32)}
    state = adamw_init(params)
    grads = {"w": np.full((4,), 100.0, np.float32)}   # must be clipped
    newp, newstate, m = adamw_update(cfg, grads, state, params)
    assert float(m["grad_norm"]) == pytest.approx(200.0)
    assert np.isfinite(np.asarray(newp["w"])).all()


def test_checkpoint_roundtrip(tmp_path, model):
    state = init_state(model, jax.random.PRNGKey(0)).tree()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, state)
    restored = mgr.restore(5, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_integrity_rejects_corruption(tmp_path, model):
    state = init_state(model, jax.random.PRNGKey(0)).tree()
    mgr = CheckpointManager(str(tmp_path))
    path = mgr.save(1, state)
    # flip a byte in some array file
    victim = next(f for f in sorted(os.listdir(path)) if f.endswith(".npy"))
    fp = os.path.join(path, victim)
    data = bytearray(open(fp, "rb").read())
    data[-1] ^= 0xFF
    open(fp, "wb").write(bytes(data))
    with pytest.raises(CheckpointCorrupt):
        mgr.restore(1, state)
    assert mgr.restore_latest(state) is None   # nothing valid left


def test_restore_latest_skips_corrupt(tmp_path, model):
    state = init_state(model, jax.random.PRNGKey(0)).tree()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, state)
    p2 = mgr.save(2, state)
    victim = next(f for f in sorted(os.listdir(p2)) if f.endswith(".npy"))
    open(os.path.join(p2, victim), "wb").write(b"garbage")
    step, _ = mgr.restore_latest(state)
    assert step == 1                            # fell back past corrupt 2


def test_crash_restart_resumes_and_matches_uninterrupted(tmp_path, model):
    pipe = TokenPipeline(model.cfg.vocab_size, seq_len=32, global_batch=2,
                         seed=0)
    loop = LoopConfig(total_steps=8, checkpoint_every=2)
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=8)

    # uninterrupted reference
    ref_dir = tmp_path / "ref"
    ref = Trainer(model, pipe, CheckpointManager(str(ref_dir)), loop=loop,
                  opt=opt).run()

    # crash at step 5, restart
    crash_dir = tmp_path / "crash"
    inj = FailureInjector(fail_at_steps=(5,))
    mgr = CheckpointManager(str(crash_dir))
    out = run_with_restarts(
        lambda: Trainer(model, pipe, mgr, loop=loop, opt=opt, injector=inj))
    assert out["restarts"] == 1
    assert out["resumed_from"] == 4
    # final states identical (deterministic data + resume)
    for a, b in zip(jax.tree.leaves(ref["state"]),
                    jax.tree.leaves(out["state"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-6, atol=1e-6)


def test_checkpoint_retention(tmp_path, model):
    state = init_state(model, jax.random.PRNGKey(0)).tree()
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]
