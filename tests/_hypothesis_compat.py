"""Import indirection for hypothesis-based property tests.

The tier-1 environment does not ship `hypothesis`; importing it at module
scope used to kill collection of every test in the importing file.  This
shim degrades gracefully: with hypothesis installed (see
requirements-dev.txt) the real API is re-exported; without it, ``@given``
turns the property test into a clean skip and the example-based tests in
the same file keep running.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                                           # pragma: no cover
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategies:
        """Accept any strategy construction; values are never drawn."""

        def __getattr__(self, name):
            def _strategy(*args, **kwargs):
                return None
            return _strategy

    st = _Strategies()

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (see requirements-dev.txt)"
            )(fn)
        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco
