"""Semantic-operator runtime: RequestPipeline coalescing / dedup / flush,
load-aware scheduling, and eager-vs-pipelined end-to-end equivalence."""
import numpy as np
import pytest

from repro.core import AisqlEngine, Catalog, CascadeConfig, ExecConfig
from repro.data import datasets as D
from repro.inference.api import CortexClient, make_simulated_client
from repro.inference.backend import CLASSIFY, COMPLETE, SCORE, Request
from repro.inference.pipeline import (PipelineConfig, RequestPipeline,
                                      ResultFuture)
from repro.inference.scheduler import Scheduler
from repro.inference.simulator import SimulatedBackend


def make_pipeline(max_batch=512, dedup=True, seed=0, models=None):
    sched = Scheduler()
    sched.register(SimulatedBackend(models=models, seed=seed))
    pipe = RequestPipeline(sched, PipelineConfig(max_batch=max_batch,
                                                 dedup=dedup))
    return sched, pipe


def score_reqs(n, model="proxy-8b", prefix="row"):
    return [Request(f"{prefix} {i}", model, SCORE) for i in range(n)]


# ---------------------------------------------------------------------------
# RequestPipeline unit tests
# ---------------------------------------------------------------------------


def test_micro_batches_coalesce_into_one_submit():
    sched, pipe = make_pipeline()
    futures = []
    for lo in range(0, 100, 10):          # ten 10-row micro-batches
        futures.extend(pipe.submit_many(score_reqs(10, prefix=f"b{lo}")))
    assert sched.submits == 0             # nothing dispatched yet
    assert not futures[0].done()
    first = futures[0].result()           # barrier flush
    assert 0.0 <= first.score <= 1.0
    assert sched.submits == 1             # all 100 in one engine batch
    assert all(f.done() for f in futures)
    assert pipe.stats.batch_size_hist == {100: 1}
    assert pipe.stats.flushes_on_barrier == 1
    assert pipe.stats.queue_wait_s >= 0.0


def test_flush_on_size_threshold():
    sched, pipe = make_pipeline(max_batch=32)
    futs = pipe.submit_many(score_reqs(80))
    # 80 enqueued at once: the size flush drains the whole queue in
    # right-sized batches of <= 32
    assert sched.submits == 3
    assert all(f.done() for f in futs)
    assert pipe.stats.flushes_on_size == 1
    assert sorted(pipe.stats.batch_size_hist) == [16, 32]


def test_per_model_queues_dispatch_separately():
    sched, pipe = make_pipeline()
    fa = pipe.submit_many(score_reqs(5, model="proxy-8b"))
    fb = pipe.submit_many(score_reqs(5, model="oracle-70b"))
    fa[0].result()
    # the barrier is scoped to the demanded future's model queue: the
    # other model's queue keeps coalescing until its own barrier
    assert sched.submits == 1
    assert all(f.done() for f in fa)
    assert not any(f.done() for f in fb)
    fb[0].result()
    assert sched.submits == 2             # one model-pure batch each
    assert all(f.done() for f in fa + fb)


def test_dedup_inflight_and_memo_cache():
    sched, pipe = make_pipeline()
    f1 = pipe.submit(Request("same prompt", "proxy-8b", SCORE))
    f2 = pipe.submit(Request("same prompt", "proxy-8b", SCORE))
    r1, r2 = f1.result(), f2.result()
    assert r1.score == r2.score
    assert pipe.stats.dispatched == 1     # one engine execution
    assert pipe.stats.inflight_hits == 1
    # a third arrival after completion is served from the memo cache
    f3 = pipe.submit(Request("same prompt", "proxy-8b", SCORE))
    assert f3.done()                      # resolved without any dispatch
    assert pipe.stats.cache_hits == 1
    assert pipe.stats.dedup_hits == 2
    assert sched.submits == 1


def test_lru_hot_key_survives_cache_pressure():
    """Regression: FIFO eviction used to drop the oldest *half* of the
    cache including hot keys — eviction is LRU now (hits move a key to
    the recent end), so a constantly-hit key outlives churn."""
    sched, pipe = make_pipeline()
    pipe.cfg.cache_size = 4
    pipe.submit(Request("HOT", "proxy-8b", SCORE)).result()
    for i in range(8):                     # 2x the capacity in cold keys
        pipe.submit(Request("HOT", "proxy-8b", SCORE))     # keep it hot
        pipe.submit(Request(f"cold {i}", "proxy-8b", SCORE)).result()
    dispatched = pipe.stats.dispatched
    f = pipe.submit(Request("HOT", "proxy-8b", SCORE))
    assert f.done()                        # still a cache hit
    assert pipe.stats.dispatched == dispatched
    # the cache never exceeds its cap and the hot key is the freshest
    assert len(pipe.cache_keys()) <= 4


def test_lru_evicts_the_least_recently_used_key():
    sched, pipe = make_pipeline()
    pipe.cfg.cache_size = 2
    pipe.submit(Request("a", "proxy-8b", SCORE)).result()
    pipe.submit(Request("b", "proxy-8b", SCORE)).result()
    pipe.submit(Request("a", "proxy-8b", SCORE))       # refresh a
    pipe.submit(Request("c", "proxy-8b", SCORE)).result()  # evicts b
    d0 = pipe.stats.dispatched
    assert pipe.submit(Request("a", "proxy-8b", SCORE)).done()
    assert pipe.stats.dispatched == d0                 # a survived
    pipe.submit(Request("b", "proxy-8b", SCORE)).result()
    assert pipe.stats.dispatched == d0 + 1             # b was evicted


def test_cache_ttl_expires_memoized_results():
    import time as _time
    sched, pipe = make_pipeline()
    pipe.cfg.cache_ttl_s = 0.03
    pipe.submit(Request("p", "proxy-8b", SCORE)).result()
    assert pipe.submit(Request("p", "proxy-8b", SCORE)).done()  # fresh hit
    _time.sleep(0.04)
    f = pipe.submit(Request("p", "proxy-8b", SCORE))
    assert not f.done()                    # expired: goes back to the queue
    f.result()
    assert pipe.stats.cache_expired == 1
    assert pipe.stats.dispatched == 2


def test_dedup_respects_fingerprint_fields():
    sched, pipe = make_pipeline()
    futs = [pipe.submit(Request("p", "proxy-8b", SCORE)),
            pipe.submit(Request("p", "oracle-70b", SCORE)),      # model
            pipe.submit(Request("p", "proxy-8b", COMPLETE)),     # kind
            pipe.submit(Request("p", "proxy-8b", CLASSIFY,
                                labels=("a", "b")))]             # labels
    [f.result() for f in futs]
    assert pipe.stats.dedup_hits == 0
    assert pipe.stats.dispatched == 4


def test_client_meters_only_dispatched_work():
    sched = Scheduler()
    sched.register(SimulatedBackend(seed=0))
    client = CortexClient(sched, pipeline=PipelineConfig())
    s = client.filter_scores(["dup", "dup", "dup"], model="oracle-70b")
    assert s.shape == (3,) and len(set(s.tolist())) == 1
    assert client.ai_calls == 1           # two were deduplicated
    assert client.pipeline.stats.dedup_hits == 2


def test_sync_wrappers_match_eager_results():
    prompts = [f"is row {i} good?" for i in range(40)]
    eager = make_simulated_client()
    piped = make_simulated_client(pipelined=True)
    np.testing.assert_allclose(eager.filter_scores(prompts),
                               piped.filter_scores(prompts))
    assert piped.scheduler.submits == 1


# ---------------------------------------------------------------------------
# Scheduler: least-loaded routing, batch splitting, id collisions
# ---------------------------------------------------------------------------


def test_scheduler_least_loaded_balances_replicas():
    sched = Scheduler()
    a = SimulatedBackend(models=["proxy-8b"], seed=0)
    b = SimulatedBackend(models=["proxy-8b"], seed=1)
    sched.register(a)
    sched.register(b)
    for i in range(6):
        sched.submit([Request(f"q{i}", "proxy-8b", SCORE, request_id=1)])
    served = [sum(e.calls_by_model.values()) for e in (a, b)]
    assert min(served) > 0                # both replicas took traffic
    # artificially load one replica: new work routes to its peer
    sched._busy_s[id(a)] += 100.0
    before = sum(b.calls_by_model.values())
    sched.submit([Request("q-extra", "proxy-8b", SCORE, request_id=1)])
    assert sum(b.calls_by_model.values()) == before + 1


def test_scheduler_splits_oversized_batch_across_replicas():
    sched = Scheduler()
    a = SimulatedBackend(models=["proxy-8b"], seed=0, batch_parallelism=2)
    b = SimulatedBackend(models=["proxy-8b"], seed=0, batch_parallelism=2)
    sched.register(a)
    sched.register(b)
    # capacity hint per replica = 2 * 32 = 64; 200 requests -> split in two
    reqs = [Request(f"r{i}", "proxy-8b", SCORE, request_id=i + 1)
            for i in range(200)]
    res = sched.submit(reqs)
    assert len(res) == 200
    assert [r.request_id for r in res] == [q.request_id for q in reqs]
    assert sched.splits >= 1
    assert sum(a.calls_by_model.values()) > 0
    assert sum(b.calls_by_model.values()) > 0


def test_scheduler_handles_request_id_collisions():
    sched = Scheduler()
    sched.register(SimulatedBackend(seed=0))
    reqs = [Request(f"prompt {i}", "proxy-8b", SCORE) for i in range(5)]
    assert all(r.request_id == 0 for r in reqs)    # the all-zero default
    res = sched.submit(reqs)
    assert len(res) == 5                  # nothing silently dropped
    assert all(r.request_id == 0 for r in reqs)    # caller ids restored
    assert all(r.request_id == 0 for r in res)
    scores = [r.score for r in res]
    assert len(set(scores)) > 1           # distinct per-prompt results


def test_engine_classify_empty_labels_metered():
    pytest.importorskip("jax")
    from repro.inference.engine import JaxInferenceEngine
    eng = JaxInferenceEngine("proxy-8b", smoke=True, max_seq=64)
    res = eng.submit_batch([Request("no labels here", "proxy-8b", CLASSIFY,
                                    labels=(), request_id=3)])
    assert res[0].label is None
    assert res[0].engine_id == eng.engine_id
    assert res[0].tokens_in > 0
    assert res[0].credits > 0
    assert eng.total_credits > 0
    # a coalesced batch mixing labeled and label-less classify requests
    mixed = eng.submit_batch([
        Request("pick one", "proxy-8b", CLASSIFY, labels=("a", "b"),
                request_id=1),
        Request("nothing to pick", "proxy-8b", CLASSIFY, labels=(),
                request_id=2)])
    assert mixed[0].label in ("a", "b")
    assert mixed[1].label is None and mixed[1].credits > 0


# ---------------------------------------------------------------------------
# End-to-end: eager vs pipelined equivalence + fewer scheduler submits
# ---------------------------------------------------------------------------

_SQL = ("SELECT r.id, AI_CLASSIFY(PROMPT('sentiment of {0}', r.text), "
        "['positive','negative']) AS sentiment "
        "FROM reviews AS r WHERE "
        "AI_FILTER(PROMPT('does {0} express positive sentiment?', r.text)) "
        "AND AI_FILTER(PROMPT('is {0} about a movie?', r.text))")


def _run(pipelined: bool):
    cat = Catalog({"reviews": D.cascade_table("IMDB", rows=600)})
    client = make_simulated_client(pipelined=pipelined)
    eng = AisqlEngine(cat, client)
    out = eng.sql(_SQL)
    rows = sorted(zip(out.column("r.id").tolist(),
                      out.column("sentiment").tolist()))
    return rows, client, eng


def test_pipelined_query_identical_rows_fewer_submits():
    rows_e, client_e, _ = _run(pipelined=False)
    rows_p, client_p, eng_p = _run(pipelined=True)
    assert rows_e == rows_p               # identical result set
    assert len(rows_p) > 0
    assert client_p.scheduler.submits < client_e.scheduler.submits
    rep = eng_p.last_report
    assert rep.pipeline is not None
    assert rep.pipeline["batches"] == client_p.scheduler.submits
    assert rep.pipeline["dispatched"] == rep.ai_calls


def test_repeated_cascade_query_hits_dedup_cache():
    cat = Catalog({"ds": D.cascade_table("NQ", rows=600)})
    client = make_simulated_client(pipelined=True)
    eng = AisqlEngine(cat, client,
                      executor=ExecConfig(use_cascade=True,
                                          cascade=CascadeConfig(seed=0)))
    sql = ("SELECT * FROM ds AS d WHERE "
           "AI_FILTER(PROMPT('answers? {0}', d.text))")
    out1 = eng.sql(sql)
    first = eng.last_report
    assert first.ai_calls > 0
    out2 = eng.sql(sql)                   # the production repeat-query case
    second = eng.last_report
    assert sorted(out1.column("d.id").tolist()) == \
        sorted(out2.column("d.id").tolist())
    assert second.pipeline["dedup_hits"] > 0
    assert second.pipeline["cache_hits"] > 0
    assert second.ai_calls == 0           # fully served from the memo cache
    assert second.ai_credits == 0.0
