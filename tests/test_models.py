"""Per-architecture smoke tests (reduced configs, CPU).

For every assigned arch: forward/train shapes + finiteness, one real train
step, and the strongest cache-correctness check we have — teacher-forced
prefill+decode must reproduce the train-mode forward logits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfgs
from repro.models import model_zoo
from repro.train.optim import AdamWConfig
from repro.train.train_step import init_state, make_train_step

ARCHS = list(cfgs.ARCH_IDS)


def _batch_for(cfg, B, S, key):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 4, cfg.vocab_size)}
    if cfg.frontend == "frames":
        batch["frames"] = jax.random.normal(
            ks[1], (B, cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.1
    if cfg.frontend == "patches":
        P = cfg.num_patches
        batch["patches"] = jax.random.normal(
            ks[1], (B, P, cfg.d_model), jnp.float32) * 0.1
        pos = jnp.zeros((B, P + S, 3), jnp.int32)
        pos = pos.at[:, P:, :].set(
            jnp.arange(S, dtype=jnp.int32)[None, :, None] + 1)
        side = max(int(np.sqrt(P)), 1)
        ar = jnp.arange(P, dtype=jnp.int32)
        pos = pos.at[:, :P, 1].set(ar // side)
        pos = pos.at[:, :P, 2].set(ar % side)
        batch["positions"] = pos
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    model = model_zoo.build(arch, smoke=True)
    cfg = model.cfg
    B, S = 2, 32
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, B, S, jax.random.PRNGKey(1))
    out = model.apply(params, batch, mode="train", remat=False)
    h = out["hidden"]
    S_out = S + (cfg.num_patches if cfg.frontend == "patches" else 0)
    assert h.shape == (B, S_out, cfg.d_model)
    assert bool(jnp.isfinite(h.astype(jnp.float32)).all())
    logits = model.logits_of(params, h[:, -1])
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_loss_and_no_nans(arch):
    model = model_zoo.build(arch, smoke=True)
    cfg = model.cfg
    B, S = 2, 32
    state = init_state(model, jax.random.PRNGKey(0)).tree()
    step = jax.jit(make_train_step(model, AdamWConfig(lr=5e-3,
                                                      warmup_steps=1,
                                                      total_steps=10)))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 4,
                                min(cfg.vocab_size, 260))
    batch = _batch_for(cfg, B, S, jax.random.PRNGKey(1))
    batch["tokens"] = tokens[:, :-1]
    S_out = S + (cfg.num_patches if cfg.frontend == "patches" else 0)
    labels = jnp.zeros((B, S_out), jnp.int32)
    labels = labels.at[:, -S:].set(tokens[:, 1:])
    mask = jnp.zeros((B, S_out), jnp.float32).at[:, -S:].set(1.0)
    batch["labels"] = labels
    batch["loss_mask"] = mask
    losses = []
    for _ in range(3):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all(), losses
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    """Teacher-forced decode against the cache == train-mode forward."""
    import dataclasses
    cfg = cfgs.get_smoke_config(arch)
    if cfg.moe is not None:
        # capacity-based token dropping is mode-dependent (decode is
        # dropless); compare the routing-consistent dropless configuration
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=100.0))
    model = model_zoo.build(cfg)
    if cfg.frontend == "patches":
        pytest.skip("vlm decode positions use M-RoPE streams; covered below")
    B, S = 2, 24
    prefix = 16
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, B, S, jax.random.PRNGKey(1))
    full = model.apply(params, batch, mode="train", remat=False)
    full_h = full["hidden"]

    cache = model.init_cache(B, S + 4)
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :prefix]
    pre_batch["lengths"] = jnp.full((B,), prefix, jnp.int32)
    pre = model.apply(params, pre_batch, mode="prefill", cache=cache)
    np.testing.assert_allclose(
        np.asarray(pre["last_hidden"], np.float32),
        np.asarray(full_h[:, prefix - 1], np.float32), rtol=2e-2, atol=2e-2)

    cache = pre["cache"]
    for t in range(prefix, S):
        dec = model.apply(params, {"tokens": batch["tokens"][:, t:t + 1]},
                          mode="decode", cache=cache)
        cache = dec["cache"]
        np.testing.assert_allclose(
            np.asarray(dec["hidden"][:, 0], np.float32),
            np.asarray(full_h[:, t], np.float32), rtol=3e-2, atol=3e-2)


def test_param_count_analytic_close_to_actual():
    for arch in ARCHS:
        model = model_zoo.build(arch, smoke=True)
        params = model.init_params(jax.random.PRNGKey(0))
        actual = sum(np.prod(p.shape) for p in jax.tree.leaves(params))
        analytic = model.cfg.param_count()
        assert abs(actual - analytic) / actual < 0.15, (
            arch, actual, analytic)


def test_full_configs_match_assignment():
    """The full (non-smoke) configs carry the assigned geometry."""
    spec = {
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "rwkv6-1.6b": (24, 2048, 0, 0, 7168, 65536),
    }
    for arch, (L, d, H, KV, ff, V) in spec.items():
        cfg = cfgs.get_config(arch)
        assert cfg.num_layers == L, arch
        assert cfg.d_model == d, arch
        if H:
            assert cfg.num_heads == H, arch
            assert cfg.num_kv_heads == KV, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == V, arch


def test_moe_configs():
    phi = cfgs.get_config("phi3.5-moe-42b-a6.6b")
    assert phi.moe.num_experts == 16 and phi.moe.num_experts_per_tok == 2
    qw = cfgs.get_config("qwen2-moe-a2.7b")
    assert qw.moe.num_experts == 60 and qw.moe.num_experts_per_tok == 4
    assert qw.moe.num_shared_experts == 4
    assert qw.moe.padded_num_experts == 64   # even 16-way EP


def test_long_context_eligibility():
    subq = {a for a in ARCHS if cfgs.get_config(a).sub_quadratic}
    assert subq == {"recurrentgemma-9b", "rwkv6-1.6b"}
    for a in ARCHS:
        shape_names = {s.name for s in cfgs.cells(a)}
        if a in subq:
            assert "long_500k" in shape_names
        else:
            assert "long_500k" not in shape_names
