"""Docs stay honest: files exist, links resolve, documented fields exist."""
import dataclasses
import os
import re
import subprocess
import sys

from repro.core import OperatorReport, QueryReport
from repro.inference.pipeline import PipelineStats

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = os.path.join(REPO, "docs")


def _read(name):
    with open(os.path.join(REPO, name)) as f:
        return f.read()


def test_docs_exist_and_linked_from_readme():
    assert os.path.exists(os.path.join(DOCS, "architecture.md"))
    assert os.path.exists(os.path.join(DOCS, "query-reference.md"))
    assert os.path.exists(os.path.join(DOCS, "serving.md"))
    readme = _read("README.md")
    assert "docs/architecture.md" in readme
    assert "docs/query-reference.md" in readme
    assert "docs/serving.md" in readme
    # the architecture walkthrough cross-links the serving doc
    assert "serving.md" in _read("docs/architecture.md")


def test_docs_links_resolve():
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "check_docs_links.py")],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr


def _table_fields(text, heading):
    """First-column backticked names of the markdown table under a
    heading."""
    section = text.split(heading, 1)[1]
    fields = []
    for line in section.splitlines():
        m = re.match(r"\|\s*`([A-Za-z_][A-Za-z0-9_]*)`\s*\|", line)
        if m:
            fields.append(m.group(1))
        elif fields and not line.startswith("|"):
            break
    return fields


def test_every_documented_queryreport_field_exists():
    text = _read("docs/query-reference.md")
    documented = _table_fields(text, "## QueryReport")
    actual = {f.name for f in dataclasses.fields(QueryReport)}
    assert documented, "QueryReport table not found in query-reference.md"
    missing = set(documented) - actual
    assert not missing, f"documented but not in code: {missing}"
    undocumented = actual - set(documented)
    assert not undocumented, f"in code but not documented: {undocumented}"


def test_every_documented_operatorreport_field_exists():
    text = _read("docs/query-reference.md")
    documented = _table_fields(text, "estimated vs actual")
    actual = {f.name for f in dataclasses.fields(OperatorReport)}
    assert set(documented) == actual, (set(documented), actual)


def test_documented_pipeline_keys_exist():
    text = _read("docs/query-reference.md")
    documented = _table_fields(text, "### `QueryReport.pipeline`")
    stat_fields = {f.name for f in dataclasses.fields(PipelineStats)}
    # delta() adds the derived dedup_hit_rate key
    valid = stat_fields | {"dedup_hit_rate"}
    unknown = set(documented) - valid
    assert documented and not unknown, (documented, unknown)
    assert valid - set(documented) == set(), \
        f"pipeline keys missing from docs: {valid - set(documented)}"


def test_every_documented_servingreport_field_exists():
    from repro.core import ServingReport
    text = _read("docs/serving.md")
    documented = _table_fields(text, "## ServingReport")
    actual = {f.name for f in dataclasses.fields(ServingReport)}
    assert documented, "ServingReport table not found in serving.md"
    assert set(documented) == actual, \
        (set(documented) - actual, actual - set(documented))


def test_every_documented_tenantreport_field_exists():
    from repro.core import TenantReport
    text = _read("docs/serving.md")
    documented = _table_fields(text, "### TenantReport")
    actual = {f.name for f in dataclasses.fields(TenantReport)}
    assert documented, "TenantReport table not found in serving.md"
    assert set(documented) == actual, \
        (set(documented) - actual, actual - set(documented))


def test_semantic_index_doc_exists_and_linked():
    assert os.path.exists(os.path.join(DOCS, "semantic-index.md"))
    assert "docs/semantic-index.md" in _read("README.md")
    assert "semantic-index.md" in _read("docs/architecture.md")
    assert "semantic-index.md" in _read("docs/query-reference.md")
    assert "semantic-index.md" in _read("docs/serving.md")


def test_documented_recall_knobs_exist_in_code():
    """Every knob in semantic-index.md's recall table is a real config
    attribute (``Class.field`` first column)."""
    import dataclasses as dc
    from repro.core import ExecConfig, SemIndexConfig
    text = _read("docs/semantic-index.md")
    section = text.split("## Recall knobs", 1)[1]
    knobs = re.findall(r"\|\s*`([A-Za-z_]+)\.([A-Za-z_]+)`\s*\|", section)
    assert knobs, "recall-knob table not found in semantic-index.md"
    classes = {"SemIndexConfig": SemIndexConfig, "ExecConfig": ExecConfig}
    for cls_name, field in knobs:
        cls = classes[cls_name]
        names = {f.name for f in dc.fields(cls)}
        assert field in names, f"{cls_name}.{field} documented but missing"


def test_documented_semindex_telemetry_keys_match_runtime():
    from repro.core import AisqlEngine, Catalog, SemIndexConfig
    from repro.inference.api import make_simulated_client
    from repro.tables.table import Table
    import numpy as np

    t = Table({"id": np.arange(30),
               "text": [f"[d:{i}] body words {i}" for i in range(30)]},
              name="t")
    eng = AisqlEngine(Catalog({"t": t}), make_simulated_client(),
                      semindex=SemIndexConfig(impl="reference"))
    eng.sql("SELECT t.id FROM t "
            "ORDER BY AI_SIMILARITY(t.text, 'body words') DESC LIMIT 3")
    tel = eng.last_report.semindex
    assert tel is not None
    doc_row = [ln for ln in _read("docs/query-reference.md").splitlines()
               if ln.startswith("| `semindex`")]
    assert doc_row, "QueryReport.semindex row missing from docs"
    # every runtime key's concept is named in the doc row
    assert {"index_joins", "index_topk", "probes", "candidates",
            "verify_calls", "embed_texts", "embed_llm_calls"} == \
        set(tel.keys())


def test_documented_pilot_keys_match_runtime():
    from repro.core import AisqlEngine, Catalog, ExecConfig
    from repro.data import datasets as D
    from repro.inference.api import make_simulated_client

    text = _read("docs/query-reference.md")
    documented = _table_fields(text, "### `QueryReport.pilot`")
    cat = Catalog({"articles": D.skewed_articles(240)})
    eng = AisqlEngine(cat, make_simulated_client(),
                      executor=ExecConfig(min_rows_for_pilot=64))
    eng.sql("SELECT * FROM articles AS a WHERE "
            "AI_FILTER(PROMPT('broad? {0}', a.headline)) AND "
            "AI_FILTER(PROMPT('narrow topic? {0}', a.summary))")
    pilot = eng.last_report.pilot
    assert pilot is not None
    assert set(documented) == set(pilot.keys()), (documented,
                                                  sorted(pilot.keys()))


def test_storage_doc_exists_and_linked():
    assert os.path.exists(os.path.join(DOCS, "storage.md"))
    assert "docs/storage.md" in _read("README.md")
    assert "storage.md" in _read("docs/architecture.md")
    assert "storage.md" in _read("docs/serving.md")


def test_documented_storage_knobs_exist_in_code():
    """Every `Class.field` knob in storage.md's tables is a real
    constructor parameter / dataclass field."""
    import inspect
    import sys as _sys
    from repro.core import SemIndexConfig
    from repro.tables.chunked import ChunkedTable
    from repro.tables.spill import SpillManager
    _sys.path.insert(0, os.path.join(REPO, "tools"))
    from replay import TraceConfig
    text = _read("docs/storage.md")
    knobs = re.findall(r"\|\s*`([A-Za-z_]+)\.([A-Za-z_]+)`\s*\|", text)
    assert knobs, "knob tables not found in storage.md"
    classes = {
        "ChunkedTable": set(
            inspect.signature(ChunkedTable.__init__).parameters),
        "SpillManager": set(
            inspect.signature(SpillManager.__init__).parameters),
        "SemIndexConfig": {f.name for f in
                           dataclasses.fields(SemIndexConfig)},
        "TraceConfig": {f.name for f in dataclasses.fields(TraceConfig)},
    }
    for cls_name, field in knobs:
        assert field in classes[cls_name], \
            f"{cls_name}.{field} documented but missing in code"
    # every TraceConfig field is documented (the trace format is the
    # replay harness's public contract)
    documented = {f for c, f in knobs if c == "TraceConfig"}
    assert documented == classes["TraceConfig"], \
        f"TraceConfig fields missing from docs: " \
        f"{classes['TraceConfig'] - documented}"


def test_backend_doc_exists_and_linked():
    assert os.path.exists(os.path.join(DOCS, "backend-serving.md"))
    assert "docs/backend-serving.md" in _read("README.md")
    assert "backend-serving.md" in _read("docs/architecture.md")
    assert "backend-serving.md" in _read("docs/serving.md")


def test_documented_backend_knobs_exist_in_code():
    """Every knob in backend-serving.md's table is a real
    JaxInferenceEngine constructor parameter."""
    import inspect
    from repro.inference.engine import JaxInferenceEngine
    text = _read("docs/backend-serving.md")
    knobs = _table_fields(text, "## Knobs")
    assert knobs, "knob table not found in backend-serving.md"
    params = set(inspect.signature(JaxInferenceEngine.__init__).parameters)
    unknown = set(knobs) - params
    assert not unknown, f"documented but not a constructor param: {unknown}"


def test_learned_optimizer_doc_exists_and_linked():
    assert os.path.exists(os.path.join(DOCS, "learned-optimizer.md"))
    assert "docs/learned-optimizer.md" in _read("README.md")
    assert "learned-optimizer.md" in _read("docs/architecture.md")
    assert "learned-optimizer.md" in _read("docs/query-reference.md")
    assert "learned-optimizer.md" in _read("docs/serving.md")


def test_documented_learned_knobs_exist_in_code():
    """Every ``Class.field`` knob in learned-optimizer.md is a real
    config attribute, and the core v2 switches are all documented."""
    import dataclasses as dc
    from repro.core import (CostDefaults, ExecConfig, OptimizerConfig,
                            ServingConfig)
    text = _read("docs/learned-optimizer.md")
    knobs = re.findall(r"\|\s*`([A-Za-z_]+)\.([A-Za-z_]+)`\s*\|", text)
    assert knobs, "knob tables not found in learned-optimizer.md"
    classes = {"CostDefaults": CostDefaults, "ExecConfig": ExecConfig,
               "OptimizerConfig": OptimizerConfig,
               "ServingConfig": ServingConfig}
    for cls_name, field in knobs:
        names = {f.name for f in dc.fields(classes[cls_name])}
        assert field in names, f"{cls_name}.{field} documented but missing"
    documented = {f"{c}.{f}" for c, f in knobs}
    for required in ("CostDefaults.enable_stat_transfer",
                     "OptimizerConfig.enable_plan_memo",
                     "ServingConfig.stat_sharing",
                     "ExecConfig.pilot_trust_transfer"):
        assert required in documented, f"{required} not documented"


def test_http_api_doc_exists_and_linked():
    assert os.path.exists(os.path.join(DOCS, "http-api.md"))
    assert "docs/http-api.md" in _read("README.md")
    assert "http-api.md" in _read("docs/architecture.md")
    assert "http-api.md" in _read("docs/serving.md")


def test_http_api_error_table_matches_contract():
    """The docs' error-contract table is exactly the server's exception
    map: same codes, same HTTP statuses, nothing extra or missing."""
    from repro.serve.http import ERROR_CONTRACT
    text = _read("docs/http-api.md")
    section = text.split("## Error contract", 1)[1]
    section = section.split("## ", 1)[0]
    rows = re.findall(r"\|\s*`([a-z0-9_]+)`\s*\|\s*(\d{3})\s*\|", section)
    documented = {code: int(status) for code, status in rows}
    actual = {code: status for code, (status, _) in ERROR_CONTRACT.items()}
    assert documented, "error-contract table not found in http-api.md"
    assert documented == actual, (
        f"docs/http-api.md error table out of sync with ERROR_CONTRACT: "
        f"doc-only {set(documented) - set(actual)}, "
        f"code-only {set(actual) - set(documented)}, "
        f"status mismatches "
        f"{ {c for c in documented.keys() & actual.keys() if documented[c] != actual[c]} }")


def test_observability_doc_exists_and_linked():
    assert os.path.exists(os.path.join(DOCS, "observability.md"))
    assert "docs/observability.md" in _read("README.md")
    assert "observability.md" in _read("docs/architecture.md")
    assert "observability.md" in _read("docs/serving.md")
    assert "observability.md" in _read("docs/http-api.md")
    assert "observability.md" in _read("docs/query-reference.md")


def _catalog_rows(text, heading):
    """First-column backticked names (dots allowed) of the table under a
    heading — span kinds, event kinds and metric families use dotted /
    prefixed names the stricter ``_table_fields`` regex rejects."""
    section = text.split(heading, 1)[1].split("\n## ", 1)[0]
    return [m.group(1) for m in
            re.finditer(r"^\|\s*`([A-Za-z_][A-Za-z0-9_.]*)`\s*\|",
                        section, re.M)]


def test_documented_span_kinds_match_catalog():
    from repro.obs import SPAN_KINDS
    rows = _catalog_rows(_read("docs/observability.md"), "## Span taxonomy")
    assert rows, "span taxonomy table not found in observability.md"
    assert set(rows) == set(SPAN_KINDS), (
        set(rows) - set(SPAN_KINDS), set(SPAN_KINDS) - set(rows))


def test_documented_event_kinds_match_catalog():
    from repro.obs import EVENT_KINDS
    rows = _catalog_rows(_read("docs/observability.md"), "## Event kinds")
    assert rows, "event kinds table not found in observability.md"
    assert set(rows) == set(EVENT_KINDS), (
        set(rows) - set(EVENT_KINDS), set(EVENT_KINDS) - set(rows))


def test_documented_metric_families_match_catalog():
    """Name, type and label set of every documented family must match
    the code catalog exactly."""
    from repro.obs import METRIC_FAMILIES
    text = _read("docs/observability.md")
    section = text.split("## Metric families", 1)[1].split("\n### ", 1)[0]
    rows = re.findall(
        r"^\|\s*`([a-z_]+)`\s*\|\s*(\w+)\s*\|\s*([^|]*)\|", section, re.M)
    documented = {}
    for name, mtype, labels in rows:
        if name == "family":
            continue
        labelset = tuple(
            s.strip() for s in labels.split(",") if s.strip() not in ("", "—"))
        documented[name] = (mtype, labelset)
    actual = {name: (mtype, tuple(labels))
              for name, (mtype, _help, labels) in METRIC_FAMILIES.items()}
    assert documented, "metric family table not found in observability.md"
    assert documented == actual, (
        f"doc-only {set(documented) - set(actual)}, "
        f"code-only {set(actual) - set(documented)}, "
        f"mismatched { {n for n in documented.keys() & actual.keys() if documented[n] != actual[n]} }")


def test_documented_quantile_error_bound_matches_code():
    from repro.obs.metrics import BUCKET_FACTOR, QUANTILE_REL_ERROR
    text = _read("docs/observability.md")
    assert "17%" in text  # (sqrt(2)-1)/(sqrt(2)+1) ~= 0.1716
    assert abs(QUANTILE_REL_ERROR
               - (BUCKET_FACTOR - 1.0) / (BUCKET_FACTOR + 1.0)) < 1e-12
    assert round(QUANTILE_REL_ERROR * 100) == 17
