"""Cortex-platform analogue: engines, scheduler fault tolerance, metering."""
import numpy as np
import pytest

from repro.inference.api import CortexClient, make_engine_client
from repro.inference.backend import (CLASSIFY, COMPLETE, SCORE, EngineFailure,
                                     Request, credits_for)
from repro.inference.engine import JaxInferenceEngine
from repro.inference.scheduler import Scheduler, SchedulerError
from repro.inference.simulator import SimulatedBackend


@pytest.fixture(scope="module")
def engine():
    return JaxInferenceEngine("proxy-8b", smoke=True, max_seq=192)


def test_engine_score_batch(engine):
    reqs = [Request(f"is row {i} positive?", "proxy-8b", SCORE,
                    request_id=i) for i in range(3)]
    res = engine.submit_batch(reqs)
    assert len(res) == 3
    for r in res:
        assert 0.0 <= r.score <= 1.0
        assert r.credits > 0 and r.tokens_in > 0


def test_engine_complete_batch(engine):
    reqs = [Request("hello", "proxy-8b", COMPLETE, max_tokens=4,
                    request_id=7)]
    res = engine.submit_batch(reqs)
    assert res[0].tokens_out <= 4
    assert isinstance(res[0].text, str)


def test_engine_classify_batch(engine):
    reqs = [Request("pick a label", "proxy-8b", CLASSIFY,
                    labels=("alpha", "beta"), request_id=1)]
    res = engine.submit_batch(reqs)
    assert res[0].label in ("alpha", "beta")


def test_engine_determinism(engine):
    reqs = [Request("same prompt", "proxy-8b", SCORE, request_id=1)]
    s1 = engine.submit_batch(reqs)[0].score
    s2 = engine.submit_batch(reqs)[0].score
    assert s1 == s2


def test_scheduler_retries_on_failure():
    sched = Scheduler(max_retries=2)
    flaky = SimulatedBackend(seed=0)
    # wrap with a failure-injecting proxy
    class Flaky:
        def __init__(self, inner, fail_times):
            self.inner = inner
            self.fails = fail_times
        def submit_batch(self, reqs):
            if self.fails > 0:
                self.fails -= 1
                raise EngineFailure("boom")
            return self.inner.submit_batch(reqs)
        def hosted_models(self):
            return self.inner.hosted_models()
    sched.register(Flaky(flaky, fail_times=1))
    sched.register(SimulatedBackend(seed=1))
    res = sched.submit([Request("x", "proxy-8b", SCORE, request_id=1)])
    assert len(res) == 1
    assert sched.retries == 1


def test_scheduler_exhausts_retries():
    sched = Scheduler(max_retries=1)
    class AlwaysDown:
        def submit_batch(self, reqs):
            raise EngineFailure("down")
        def hosted_models(self):
            return ["proxy-8b"]
    sched.register(AlwaysDown())
    with pytest.raises(SchedulerError):
        sched.submit([Request("x", "proxy-8b", SCORE, request_id=1)])


def test_scheduler_unknown_model():
    sched = Scheduler()
    sched.register(SimulatedBackend(models=["proxy-8b"]))
    with pytest.raises(SchedulerError):
        sched.submit([Request("x", "no-such-model", SCORE, request_id=1)])


def test_elastic_register_deregister():
    sched = Scheduler()
    a, b = SimulatedBackend(seed=0), SimulatedBackend(seed=1)
    sched.register(a)
    sched.register(b)
    assert len(sched.replicas("proxy-8b")) == 2
    sched.deregister(a)
    assert len(sched.replicas("proxy-8b")) == 1


def test_client_metering():
    sched = Scheduler()
    sched.register(SimulatedBackend(seed=0))
    client = CortexClient(sched)
    before = client.snapshot()
    client.filter_scores(["a", "b", "c"], model="oracle-70b")
    delta = client.meter_delta(before)
    assert delta["ai_calls"] == 3
    assert delta["ai_credits"] > 0


def test_credits_scale_with_model():
    assert credits_for("oracle-70b", 1000) > credits_for("proxy-8b", 1000)


def test_engine_client_end_to_end():
    client = make_engine_client(("proxy-8b",), replicas=2)
    scores = client.filter_scores(["row one", "row two"], model="proxy-8b")
    assert scores.shape == (2,)
    assert client.ai_calls == 2
