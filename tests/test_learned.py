"""Learned cost model v2: kNN prior transfer, plan memo, cross-tenant
stat sharing, and the stats-layer crash-safety / fingerprint bugfixes."""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core import (AisqlEngine, Catalog, CostDefaults, CostModel,
                        ExecConfig, OptimizerConfig, PlanMemo,
                        PredObservation, ServingConfig, ServingEngine,
                        StatsStore, TenantStatsStore, plan_fingerprint,
                        predicate_fingerprint, predicate_prompt_text)
from repro.core import expr as E
from repro.core import plan as P
from repro.core import sqlparse
from repro.core.stats import wilson_interval
from repro.data import datasets as D
from repro.inference.api import make_simulated_client
from repro.semindex import SemanticIndexManager, SemIndexConfig


def _ai(template="p {0}", col="a.summary", model=None):
    return E.AIFilter(E.Prompt(template, (E.Column(col),)), model=model)


def _catalog(n=400, seed=0):
    return Catalog({"articles": D.skewed_articles(n, seed=seed)})


# ---------------------------------------------------------------------------
# bugfix: crash-safe StatsStore persistence
# ---------------------------------------------------------------------------


def test_truncated_stats_file_warns_and_starts_empty(tmp_path):
    """A kill-9-truncated stats file must never poison engine startup."""
    path = str(tmp_path / "stats.json")
    store = StatsStore(path)
    key = predicate_fingerprint(_ai())
    store.observe_predicate(key, evaluated=80, passed=20)
    store.save()
    blob = open(path).read()
    with open(path, "w") as f:
        f.write(blob[:len(blob) // 2])        # mid-write truncation
    with pytest.warns(UserWarning, match="unreadable"):
        loaded = StatsStore(path)
    assert len(loaded) == 0
    # the engine constructs (and can re-save) over the same path
    with pytest.warns(UserWarning, match="unreadable"):
        eng = AisqlEngine(_catalog(50), make_simulated_client(),
                          stats_path=path)
    assert len(eng.stats) == 0


def test_interrupted_save_preserves_previous_file(tmp_path, monkeypatch):
    """A crash mid-save leaves the previous complete file, not a
    truncated one: the write lands in a temp file, os.replace is the
    only mutation of the real path."""
    path = str(tmp_path / "stats.json")
    key = predicate_fingerprint(_ai())
    store = StatsStore(path)
    store.observe_predicate(key, evaluated=80, passed=20)
    store.save()
    store.observe_predicate(key, evaluated=800, passed=200)

    def boom(*a, **kw):
        raise OSError("disk full mid-write")

    monkeypatch.setattr("repro.core.stats.json.dump", boom)
    with pytest.raises(OSError):
        store.save()
    monkeypatch.undo()
    loaded = StatsStore(path)                 # previous file still whole
    assert loaded.get(key).evaluated == 80
    assert os.listdir(tmp_path) == ["stats.json"]   # no temp litter


def test_malformed_entry_is_skipped_not_fatal(tmp_path):
    path = str(tmp_path / "stats.json")
    good = PredObservation(evaluated=10, passed=5).to_dict()
    payload = {"format": 2,
               "observations": {"GOOD": good, "BAD": "not-a-dict"},
               "prompts": {}}
    with open(path, "w") as f:
        json.dump(payload, f)
    with pytest.warns(UserWarning, match="malformed"):
        store = StatsStore(path)
    assert store.get("GOOD").evaluated == 10
    assert store.get("BAD") is None


def test_embedding_store_sidecar_is_crash_safe(tmp_path, monkeypatch):
    from repro.semindex.store import EmbeddingStore
    path = str(tmp_path / "emb")
    store = EmbeddingStore(path)
    store.put("m", ["hello"], [np.ones(8, np.float32)])
    store.save()
    # corrupt sidecar: load warns and starts empty instead of raising
    with open(path + ".json", "w") as f:
        f.write('{"keys": [')
    with pytest.warns(UserWarning, match="unreadable"):
        reloaded = EmbeddingStore(path)
    assert len(reloaded) == 0
    # interrupted save never destroys the (restored) previous pair
    store.save()
    store.put("m", ["world"], [np.ones(8, np.float32)])

    def boom(*a, **kw):
        raise OSError("disk full mid-write")

    monkeypatch.setattr("repro.semindex.store.json.dump", boom)
    with pytest.raises(OSError):
        store.save()
    monkeypatch.undo()
    survivor = EmbeddingStore(path)
    assert len(survivor) == 1
    assert sorted(os.listdir(tmp_path)) == ["emb.json", "emb.npz"]


# ---------------------------------------------------------------------------
# bugfix: symmetric AI_SIMILARITY fingerprints + legacy twin-key merge
# ---------------------------------------------------------------------------


def test_similarity_fingerprint_is_symmetric():
    ab = E.AISimilarity(E.Column("t.x"), E.Column("t.y"))
    ba = E.AISimilarity(E.Column("t.y"), E.Column("t.x"))
    assert predicate_fingerprint(ab) == predicate_fingerprint(ba)
    # wrapped in a comparison (the REL fingerprint path) too
    cmp_ab = E.BinOp(">", ab, E.Literal(0.8))
    cmp_ba = E.BinOp(">", ba, E.Literal(0.8))
    assert predicate_fingerprint(cmp_ab) == predicate_fingerprint(cmp_ba)
    # different models stay distinct
    other = E.AISimilarity(E.Column("t.x"), E.Column("t.y"), model="e5")
    assert predicate_fingerprint(ab) != predicate_fingerprint(other)


def test_legacy_twin_similarity_keys_merge_on_load(tmp_path):
    """Stores written before the symmetry fix hold split evidence under
    both argument orders; load folds both into the canonical key."""
    path = str(tmp_path / "stats.json")
    legacy = {           # pre-format-2 flat payload with asymmetric twins
        "AI_SIMILARITY|m|x|y": PredObservation(
            evaluated=10, passed=4).to_dict(),
        "AI_SIMILARITY|m|y|x": PredObservation(
            evaluated=30, passed=12).to_dict(),
    }
    with open(path, "w") as f:
        json.dump(legacy, f)
    store = StatsStore(path)
    merged = store.get("AI_SIMILARITY|m|x|y")
    assert merged is not None and merged.evaluated == 40
    assert merged.passed == 16
    assert store.get("AI_SIMILARITY|m|y|x") is None
    assert len(store) == 1


# ---------------------------------------------------------------------------
# kNN prior transfer (cost model v2)
# ---------------------------------------------------------------------------

DONOR = _ai("does this text concern database systems research? {0}")
COLD = _ai("is this article about research on database systems? {0}")


def _transfer_cost(store=None, **default_overrides):
    cat = _catalog()
    defaults = dataclasses.replace(CostDefaults(), **default_overrides)
    cost = CostModel(cat, stats=store if store is not None else StatsStore(),
                     defaults=defaults)
    cost.semindex = SemanticIndexManager(SemIndexConfig(impl="reference"))
    cost.embed_client = make_simulated_client()
    return cost


def _observe_donor(store, pred=DONOR, *, evaluated=200, passed=10,
                   credits=0.02):
    fp = predicate_fingerprint(pred)
    store.observe_predicate(fp, evaluated=evaluated, passed=passed,
                            credits=credits)
    store.register_prompt(fp, predicate_prompt_text(pred))


def test_transferred_prior_from_nearest_donor():
    store = StatsStore()
    _observe_donor(store)
    cost = _transfer_cost(store, transfer_min_sim=0.0)
    tp = cost.transferred_prior(COLD)
    assert tp is not None
    assert predicate_fingerprint(DONOR) in [d for d, _ in tp.donors]
    # estimates pulled toward the donor's observed numbers
    assert tp.selectivity == pytest.approx(0.05, abs=1e-6)
    assert tp.cost_per_row == pytest.approx(0.02 / 200, rel=1e-6)
    assert cost.estimate_source(COLD) == "transferred"
    # blended selectivity sits strictly between donor and static prior
    sel = cost.predicate_selectivity(COLD)
    assert tp.selectivity < sel < cost.defaults.ai_selectivity


def test_transferred_never_outranks_direct_observation():
    """Property: at equal n, a transferred prior is always visibly less
    confident than a direct observation — smaller pseudo-row mass than
    the trust threshold, a wider CI, and never the 'observed' tier."""
    store = StatsStore()
    _observe_donor(store)
    cost = _transfer_cost(store, transfer_min_sim=0.0)
    tp = cost.transferred_prior(COLD)
    min_rows = cost.defaults.stats_min_rows
    assert tp.n_eff < min_rows
    assert not store.confident(predicate_fingerprint(COLD),
                               min_rows=min_rows)
    # CI is wider than a direct observation of the same pseudo-size
    n = max(1, int(round(tp.n_eff)))
    direct = wilson_interval(int(round(tp.selectivity * n)), n)
    assert (tp.ci[1] - tp.ci[0]) >= (direct[1] - direct[0])
    # once the predicate is observed directly, the observation wins raw
    store.observe_predicate(predicate_fingerprint(COLD),
                            evaluated=min_rows, passed=min_rows // 2,
                            credits=0.01)
    assert cost.estimate_source(COLD) == "observed"
    assert cost.predicate_selectivity(COLD) == pytest.approx(0.5)


def test_transfer_disabled_cleanly_without_stack():
    store = StatsStore()
    _observe_donor(store)
    # no semindex / no embed client -> no transfer, default tier
    bare = CostModel(_catalog(), stats=store)
    assert bare.transferred_prior(COLD) is None
    assert bare.estimate_source(COLD) == "default"
    # stack present but no donors registered any prompt text
    empty = StatsStore()
    empty.observe_predicate(predicate_fingerprint(DONOR),
                            evaluated=200, passed=10)
    cost = _transfer_cost(empty, transfer_min_sim=0.0)
    assert cost.transferred_prior(COLD) is None
    assert cost.estimate_source(COLD) == "default"
    # explicit kill switch
    off = _transfer_cost(store, enable_stat_transfer=False)
    assert off.transferred_prior(COLD) is None
    # dissimilar-only donors fall below the cosine floor
    far = StatsStore()
    _observe_donor(far, _ai("zq xv qq ww ee rr {0}"))
    high = _transfer_cost(far, transfer_min_sim=0.999)
    assert high.transferred_prior(COLD) is None


def test_transfer_cache_invalidated_by_store_writes():
    store = StatsStore()
    _observe_donor(store)
    cost = _transfer_cost(store, transfer_min_sim=0.0)
    tp1 = cost.transferred_prior(COLD)
    assert cost.transferred_prior(COLD) is tp1          # cached
    store.observe_predicate(predicate_fingerprint(DONOR),
                            evaluated=1000, passed=900)
    tp2 = cost.transferred_prior(COLD)
    assert tp2 is not tp1 and tp2.selectivity > tp1.selectivity


def test_transfer_skips_pilot_and_saves_calls():
    """An engine whose store knows a paraphrased neighbour skips the
    pilot for the unseen predicate (counted as transferred) and still
    returns the same rows."""
    sql = ("SELECT * FROM articles AS a WHERE "
           "AI_FILTER(PROMPT('broad? {0}', a.headline)) AND "
           "AI_FILTER(PROMPT('is this article about research on "
           "database systems? {0}', a.summary))")

    def run(store, semindex, trust):
        eng = AisqlEngine(
            _catalog(), make_simulated_client(pipelined=True),
            executor=ExecConfig(min_rows_for_pilot=64,
                                pilot_trust_transfer=trust),
            optimizer=OptimizerConfig(cost_defaults=dataclasses.replace(
                CostDefaults(), transfer_min_sim=0.0)),
            stats=store, semindex=semindex)
        out = eng.sql(sql)
        return eng.last_report, sorted(out.column("a.id").tolist())

    # train a different-but-related workload, then run the paraphrase
    def trained():
        store = StatsStore()
        semindex = SemanticIndexManager(SemIndexConfig(impl="reference"))
        eng = AisqlEngine(_catalog(), make_simulated_client(pipelined=True),
                          executor=ExecConfig(min_rows_for_pilot=64),
                          stats=store, semindex=semindex)
        eng.sql("SELECT * FROM articles AS a WHERE "
                "AI_FILTER(PROMPT('broad? {0}', a.headline)) AND "
                "AI_FILTER(PROMPT('does this text concern database "
                "systems research? {0}', a.summary))")
        return store, semindex

    store, semindex = trained()
    warm_rep, warm_ids = run(store, semindex, trust=True)
    assert warm_rep.pilot is not None
    assert warm_rep.pilot["transferred_predicates"] >= 1
    cold_rep, cold_ids = run(StatsStore(), None, trust=False)
    assert warm_ids == cold_ids            # identical result rows
    assert warm_rep.ai_calls < cold_rep.ai_calls


# ---------------------------------------------------------------------------
# plan memo
# ---------------------------------------------------------------------------

MEMO_SQL = ("SELECT * FROM articles AS a WHERE "
            "AI_FILTER(PROMPT('broad? {0}', a.headline)) AND "
            "AI_FILTER(PROMPT('does this text concern database "
            "research? {0}', a.summary))")


def test_plan_fingerprint_stable_and_discriminating():
    node = P.build_plan(sqlparse.parse(MEMO_SQL))
    again = P.build_plan(sqlparse.parse(MEMO_SQL))
    assert plan_fingerprint(node) == plan_fingerprint(again)
    other = P.build_plan(sqlparse.parse(
        "SELECT * FROM articles AS a WHERE a.id < 10"))
    assert plan_fingerprint(node) != plan_fingerprint(other)


def test_plan_memo_hit_runs_zero_cost_races():
    eng = AisqlEngine(_catalog(n=300), make_simulated_client(),
                      executor=ExecConfig(pilot_rows=0))
    eng.sql(MEMO_SQL)
    first = eng.last_report.memo
    assert first is not None and not first["hit"]
    assert first["cost_races"] > 0          # real optimization ran
    # run 2 re-optimizes (stats moved from cold defaults: drift);
    # run 3 repeats run 2's stats-informed choice from the memo
    eng.sql(MEMO_SQL)
    eng.sql(MEMO_SQL)
    rep = eng.last_report
    assert rep.memo["hit"]
    assert rep.memo["cost_races"] == 0
    assert rep.memo["entries"] >= 1
    assert any("plan-memo: hit" in ln for ln in rep.optimizer_trace)
    assert "plan-memo: hit" in rep.explain_analyze()


def test_plan_memo_invalidates_on_stats_drift():
    eng = AisqlEngine(_catalog(n=300), make_simulated_client(),
                      executor=ExecConfig(pilot_rows=0))
    eng.sql(MEMO_SQL)
    eng.sql(MEMO_SQL)
    eng.sql(MEMO_SQL)
    assert eng.last_report.memo["hit"]
    # shove the narrow predicate's selectivity far from the snapshot
    fp = predicate_fingerprint(_ai(
        "does this text concern database research? {0}"))
    eng.stats.observe_predicate(fp, evaluated=100000, passed=99000,
                                credits=5.0)
    inv_before = eng.opt.memo.invalidations
    eng.sql(MEMO_SQL)
    assert not eng.last_report.memo["hit"]
    assert eng.opt.memo.invalidations == inv_before + 1


def test_plan_memo_disabled_and_lru_bounded():
    eng = AisqlEngine(_catalog(n=300), make_simulated_client(),
                      executor=ExecConfig(pilot_rows=0),
                      optimizer=OptimizerConfig(enable_plan_memo=False))
    eng.sql(MEMO_SQL)
    assert eng.last_report.memo is None
    memo = PlanMemo(max_entries=2)
    from repro.core.optimizer import MemoEntry
    for i in range(5):
        memo.store(f"k{i}", MemoEntry(plan=None, trace=[], snapshot=[]))
    assert len(memo) == 2


# ---------------------------------------------------------------------------
# cross-tenant stat sharing with isolation
# ---------------------------------------------------------------------------


def test_tenant_store_shares_priors_with_isolation():
    shared = StatsStore()
    a = TenantStatsStore(shared, prior_rows=48)
    b = TenantStatsStore(shared, prior_rows=48)
    fp = predicate_fingerprint(_ai())
    a.observe_predicate(fp, evaluated=1000, passed=100, credits=1.0)
    a.register_prompt(fp, "p summary")
    # tenant B sees a capped shared_prior copy, never A's raw history
    view = b.get(fp)
    assert view is not None and getattr(view, "shared_prior", False)
    assert view.evaluated == 48
    assert view.selectivity == pytest.approx(0.1, abs=0.02)
    assert b.confident(fp, min_rows=24)
    assert b.prompt_text(fp) == "p summary"
    # B's own evidence, once it exists, wins over the pool view
    b.observe_predicate(fp, evaluated=10, passed=9)
    own = b.get(fp)
    assert not getattr(own, "shared_prior", False)
    assert own.evaluated == 10 and own.passed == 9
    # ...and A's raw counters were never scaled or mutated
    assert a.get(fp).evaluated == 1000
    assert shared.get(fp).evaluated == 1010


def test_shared_prior_reads_as_transferred_tier():
    shared = StatsStore()
    donor_tenant = TenantStatsStore(shared, prior_rows=48)
    fp = predicate_fingerprint(_ai())
    donor_tenant.observe_predicate(fp, evaluated=500, passed=50,
                                   credits=0.5)
    fresh_tenant = TenantStatsStore(shared, prior_rows=48)
    cost = CostModel(_catalog(), stats=fresh_tenant)
    assert cost.estimate_source(_ai()) == "transferred"
    # blended, not trusted raw: pulled toward the static prior
    sel = cost.predicate_selectivity(_ai())
    assert 0.1 < sel < cost.defaults.ai_selectivity


def test_serving_stat_sharing_modes():
    cat = _catalog(n=120)
    sql = ("SELECT * FROM articles AS a WHERE "
           "AI_FILTER(PROMPT('broad? {0}', a.headline))")
    fp = predicate_fingerprint(E.AIFilter(E.Prompt(
        "broad? {0}", (E.Column("a.headline"),))))
    for mode in ("full", "priors", "none"):
        with ServingEngine.simulated(
                cat, cfg=ServingConfig(workers=2, stat_sharing=mode,
                                       executor=ExecConfig(pilot_rows=0)),
                ) as srv:
            srv.run_all([("acme", sql)])
            acme = srv.tenant_stats("acme")
            globex = srv.tenant_stats("globex")
            assert acme.get(fp).evaluated > 0
            if mode == "full":
                assert globex is acme is srv.stats
            elif mode == "priors":
                assert globex is not acme
                view = globex.get(fp)
                assert view is not None and view.shared_prior
                # billing isolation: globex ran nothing, spent nothing
                assert "globex" not in srv.report().tenants
            else:
                assert globex.get(fp) is None
    with pytest.raises(ValueError, match="stat_sharing"):
        ServingEngine.simulated(
            cat, cfg=ServingConfig(stat_sharing="everything"))


def test_tenant_store_version_tracks_shared_writes():
    shared = StatsStore()
    a = TenantStatsStore(shared, prior_rows=48)
    v0 = a.version
    # another tenant's pool write must invalidate A's transfer caches
    shared.observe_predicate("X", evaluated=10, passed=5)
    assert a.version > v0
