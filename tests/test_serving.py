"""Concurrency-correctness harness for the multi-tenant serving runtime.

Invariants under N worker threads over a seeded query corpus:

  * every query's result rows are identical to a serial, isolated
    execution of the same SQL (same simulator seed);
  * credits are conserved: the sum of per-tenant meters equals the
    backends' own spend meter — dedup/cache/cancel can only make a
    tenant cheaper, never shift spend onto another;
  * no futures are lost or duplicated: every ticket resolves, the shared
    pipeline drains to zero, and submitted == dispatched + dedup hits +
    cancelled + failed;
  * the shared `StatsStore` loses no observations (per-query row counts
    add up exactly; a two-writer hammer loses nothing);
  * admission control: credit budgets reject, token buckets delay.
"""
import threading

import pytest

from _serving_corpus import ROWS, SEED, canon_rows, make_catalog
from repro.core import (AdmissionError, AisqlEngine, ServingConfig,
                        ServingEngine, StatsStore, TenantPolicy)
from repro.core.serving import TokenBucket
from repro.inference.api import CortexClient, make_simulated_client
from repro.inference.backend import SCORE, Request
from repro.inference.pipeline import PipelineConfig, RequestPipeline
from repro.inference.scheduler import Scheduler
from repro.inference.simulator import SimulatedBackend

# a workload with deliberately repeated predicates (also under different
# aliases) — the production shape where cross-query reuse pays
CORPUS = [
    ("acme", "SELECT * FROM articles AS a WHERE "
             "AI_FILTER(PROMPT('broad topic? {0}', a.headline))"),
    ("acme", "SELECT a.id FROM articles AS a WHERE "
             "AI_FILTER(PROMPT('narrow topic? {0}', a.summary))"),
    ("beta", "SELECT * FROM articles AS b WHERE "
             "AI_FILTER(PROMPT('broad topic? {0}', b.headline))"),
    ("beta", "SELECT r.id, AI_CLASSIFY(PROMPT('sentiment of {0}', r.text), "
             "['positive','negative']) AS sentiment FROM reviews AS r "
             "WHERE AI_FILTER(PROMPT('positive? {0}', r.text))"),
    ("gamma", "SELECT * FROM reviews AS r WHERE "
              "AI_FILTER(PROMPT('positive? {0}', r.text)) AND r.id < 120"),
    ("gamma", "SELECT * FROM articles AS a WHERE "
              "AI_FILTER(PROMPT('broad topic? {0}', a.headline)) LIMIT 10"),
]


def serial_reference(corpus):
    """Each query on a fresh, isolated engine (the serial baseline)."""
    out = []
    for _tenant, sql in corpus:
        eng = AisqlEngine(make_catalog(),
                          make_simulated_client(seed=SEED, pipelined=True))
        out.append(canon_rows(eng.sql(sql)))
    return out


# ---------------------------------------------------------------------------
# correctness under concurrency
# ---------------------------------------------------------------------------


def test_concurrent_rows_identical_to_serial():
    reference = serial_reference(CORPUS)
    with ServingEngine.simulated(make_catalog(), seed=SEED,
                                 cfg=ServingConfig(workers=8)) as srv:
        tickets = srv.run_all(CORPUS * 2)     # every query twice
    for i, t in enumerate(tickets):
        assert t.exception() is None, (i, t.exception())
        assert canon_rows(t.result()) == reference[i % len(CORPUS)], \
            f"query {i} diverged from serial execution"


def test_credits_conserved_and_no_lost_futures():
    with ServingEngine.simulated(make_catalog(), seed=SEED,
                                 cfg=ServingConfig(workers=6)) as srv:
        tickets = srv.run_all(CORPUS)
        rep = srv.report()
        assert srv.pipeline.pending == 0          # fully drained
    assert all(t.done() for t in tickets)
    assert all(t.result() is not None for t in tickets)
    # conservation: per-tenant meters sum to the backends' own meter
    assert rep.backend_credits is not None
    assert rep.total_credits == pytest.approx(rep.backend_credits, abs=1e-9)
    # no request lost or duplicated
    ps = srv.pipeline.stats
    assert ps.submitted == (ps.dispatched + ps.dedup_hits + ps.cancelled
                            + ps.failures)
    assert rep.queries == len(CORPUS)
    assert sum(t.completed for t in rep.tenants.values()) == len(CORPUS)


def test_cross_query_cache_reuse_across_tenants():
    sql = CORPUS[0][1]
    with ServingEngine.simulated(make_catalog(), seed=SEED,
                                 cfg=ServingConfig(workers=2)) as srv:
        first = srv.submit("acme", sql)
        srv.drain()                                # serialize for determinism
        second = srv.submit("beta", sql)
        srv.drain()
        rep = srv.report()
        assert canon_rows(first.result()) == canon_rows(second.result())
    assert rep.cross_query_hits > 0
    # the hitting tenant paid nothing: spend stays on the dispatching one
    assert rep.tenants["beta"].credits_spent == 0.0
    assert rep.tenants["acme"].credits_spent == pytest.approx(
        rep.total_credits)


def test_statsstore_counts_match_row_counts():
    sql = ("SELECT * FROM reviews AS r WHERE "
           "AI_FILTER(PROMPT('positive? {0}', r.text))")
    stats = StatsStore()
    with ServingEngine.simulated(make_catalog(), seed=SEED, stats=stats,
                                 cfg=ServingConfig(workers=4)) as srv:
        srv.run_all([("t0", sql), ("t1", sql), ("t2", sql)])
    obs = stats.get("AI_FILTER|positive? {0}||text")
    assert obs is not None
    # every query records its full row count, cache hits included
    assert obs.evaluated == 3 * ROWS
    assert 0 < obs.passed < obs.evaluated


def test_sessions_are_reused_not_leaked():
    sql = CORPUS[0][1]
    with ServingEngine.simulated(make_catalog(), seed=SEED,
                                 cfg=ServingConfig(workers=2)) as srv:
        for _ in range(3):
            srv.submit("acme", sql)
            srv.drain()                  # sequential: one session suffices
        assert srv.sessions_created == 1
        srv.run_all([("acme", sql)] * 6)
        # concurrent bursts may add sessions, bounded by the worker count
        assert srv.sessions_created <= 2


# ---------------------------------------------------------------------------
# admission control: budgets + token buckets
# ---------------------------------------------------------------------------


def test_credit_budget_rejects_after_exhaustion():
    sql = CORPUS[0][1]
    tenants = {"capped": TenantPolicy(credit_budget=1e-6),
               "free": TenantPolicy()}
    with ServingEngine.simulated(
            make_catalog(), seed=SEED, tenants=tenants,
            cfg=ServingConfig(workers=1)) as srv:      # deterministic order
        t1 = srv.submit("capped", sql)
        t2 = srv.submit("capped", sql)
        t3 = srv.submit("free", sql)
        srv.drain()
        rep = srv.report()
    assert t1.result().num_rows > 0                # admitted at zero spend
    with pytest.raises(AdmissionError):
        t2.result()
    assert t3.exception() is None                  # other tenants unaffected
    cap = rep.tenants["capped"]
    assert cap.completed == 1 and cap.rejected == 1 and cap.failed == 0
    assert cap.credits_spent >= 1e-6               # why it got rejected


def test_zero_rate_tenant_rejects_instead_of_hanging_drain():
    # a paused tenant (queries_per_s=0) must not spin in the requeue
    # loop forever: past its burst, queries are rejected cleanly
    sql = "SELECT * FROM articles AS a WHERE a.id < 10"
    tenants = {"paused": TenantPolicy(queries_per_s=0.0, burst=1)}
    with ServingEngine.simulated(
            make_catalog(), seed=SEED, tenants=tenants,
            cfg=ServingConfig(workers=1)) as srv:
        first = srv.submit("paused", sql)
        second = srv.submit("paused", sql)
        srv.drain()                       # must return, not hang
        rep = srv.report()
    assert first.result().num_rows == 10  # burst token admitted it
    with pytest.raises(AdmissionError):
        second.result()
    assert rep.tenants["paused"].rejected == 1


def test_token_bucket_paces_admission():
    bucket = TokenBucket(rate=50.0, burst=1)
    assert bucket.acquire() == pytest.approx(0.0, abs=0.01)
    waited = bucket.acquire() + bucket.acquire()
    assert waited >= 0.02                          # 2 refills at 50/s


def test_rate_limited_tenant_reports_queue_waits():
    sql = ("SELECT * FROM articles AS a WHERE a.id < 40")
    tenants = {"slow": TenantPolicy(queries_per_s=25.0, burst=1)}
    with ServingEngine.simulated(
            make_catalog(), seed=SEED, tenants=tenants,
            cfg=ServingConfig(workers=4)) as srv:
        tickets = srv.run_all([("slow", sql)] * 4)
        rep = srv.report()
    assert all(t.exception() is None for t in tickets)
    waits = sorted(t.queue_wait_s for t in tickets)
    assert waits[-1] >= 0.05                       # 4 queries at 25/s
    assert rep.tenants["slow"].queue_wait_p95_s >= rep.queue_wait_p50_s


# ---------------------------------------------------------------------------
# shared-pipeline semantics: owner scoping
# ---------------------------------------------------------------------------


def shared_pipeline_pair(**cfg_kw):
    sched = Scheduler()
    sched.register(SimulatedBackend(seed=SEED))
    pipe = RequestPipeline(sched, PipelineConfig(**cfg_kw))
    a = CortexClient(sched, pipeline=pipe, owner="a")
    b = CortexClient(sched, pipeline=pipe, owner="b")
    return pipe, a, b


def test_owner_scoped_flush_leaves_other_sessions_queued():
    pipe, a, b = shared_pipeline_pair()
    fa = a.submit_async([Request(f"pa {i}", "proxy-8b", SCORE)
                         for i in range(3)])
    fb = b.submit_async([Request(f"pb {i}", "proxy-8b", SCORE)
                         for i in range(3)])
    a.flush()
    assert all(f.done() for f in fa)
    assert not any(f.done() for f in fb)           # b's work kept coalescing
    assert pipe.pending == 3
    assert a.ai_calls == 3 and b.ai_calls == 0     # billing followed dispatch
    b.flush()
    assert all(f.done() for f in fb)
    assert b.ai_calls == 3


def test_dispatch_bills_the_owner_that_queued_the_request():
    pipe, a, b = shared_pipeline_pair()
    fa = a.submit_async([Request("shared prompt", "proxy-8b", SCORE)])
    fb = b.submit_async([Request("shared prompt", "proxy-8b", SCORE)])
    # b dedup-attached to a's queued request; demanding b's result is a
    # global barrier that dispatches it — but the bill lands on a, the
    # owner whose submission caused the dispatch
    assert fb[0].result().score is not None
    assert fa[0].done() and fb[0].done()
    assert a.ai_calls == 1 and b.ai_calls == 0
    assert pipe.stats.inflight_hits == 1
    assert pipe.stats.cross_query_hits == 1


def test_cancel_owner_only_touches_exclusive_items():
    pipe, a, b = shared_pipeline_pair()
    a.submit_async([Request("only-a", "proxy-8b", SCORE)])
    fa = a.submit_async([Request("both", "proxy-8b", SCORE)])
    fb = b.submit_async([Request("both", "proxy-8b", SCORE)])
    assert a.cancel_queued() == 1                  # "both" survives: b waits
    assert pipe.pending == 1
    assert fb[0].result().score is not None
    assert fa[0].result().score == fb[0].result().score
    # the failed owner's billing tag moved with the cancellation: the
    # surviving dispatch is billed to b, never to the query that died
    assert a.ai_calls == 0 and a.ai_credits == 0.0
    assert b.ai_calls == 1


def test_rate_limited_tenant_does_not_starve_others():
    # one worker, a heavily rate-limited tenant first in the queue: the
    # unlimited tenant's query must not wait behind the bucket (tokens
    # arrive 0.5 s apart; generous margins keep loaded CI runners green)
    sql = "SELECT * FROM articles AS a WHERE a.id < 20"
    tenants = {"slow": TenantPolicy(queries_per_s=2.0, burst=1)}
    with ServingEngine.simulated(
            make_catalog(), seed=SEED, tenants=tenants,
            cfg=ServingConfig(workers=1)) as srv:
        slow = [srv.submit("slow", sql) for _ in range(3)]
        fast = srv.submit("fast", sql)
        fast.result(timeout=30.0)
        # the fast query finished while slow's 2nd/3rd still wait for
        # tokens — workers re-enqueue instead of sleeping on the bucket
        assert fast.queue_wait_s < 0.4
        assert not all(t.done() for t in slow)
        srv.drain()
    assert all(t.exception() is None for t in slow)


def test_cancel_owner_of_attached_owner_keeps_item_cancellable():
    # b dedup-attaches to a's item, then BOTH queries fail: b's cancel
    # removes b from the ownership set (even as a secondary), so a's
    # later cancel sees itself as sole owner and fully withdraws the
    # item — nothing is left queued, and no dead query is ever billed
    pipe, a, b = shared_pipeline_pair()
    a.submit_async([Request("shared", "proxy-8b", SCORE)])
    b.submit_async([Request("shared", "proxy-8b", SCORE)])
    assert b.cancel_queued() == 0                  # a still awaits it
    assert pipe.pending == 1
    assert a.cancel_queued() == 1                  # now exclusively a's
    assert pipe.pending == 0
    pipe.flush()
    assert a.ai_calls == 0 and b.ai_calls == 0     # post-mortem bill: none


# ---------------------------------------------------------------------------
# StatsStore under concurrent writers (the hammer)
# ---------------------------------------------------------------------------


def test_statsstore_concurrent_writers_lose_nothing():
    store = StatsStore()
    writers, iters = 8, 400

    def hammer(i):
        for k in range(iters):
            store.observe_predicate("shared-fp", evaluated=2, passed=1,
                                    credits=0.5, seconds=0.001)
            store.observe_cascade("shared-fp", rows=1,
                                  oracle_calls=k % 2)
            store.observe_pipeline(submitted=3, dedup_hits=1)

    threads = [threading.Thread(target=hammer, args=(i,))
               for i in range(writers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    obs = store.get("shared-fp")
    assert obs.evaluated == writers * iters * 2
    assert obs.passed == writers * iters
    assert obs.credits == pytest.approx(writers * iters * 0.5)
    assert obs.cascade_rows == writers * iters
    pipe = store.get("__pipeline__")
    assert pipe.dedup_submitted == writers * iters * 3
    assert pipe.dedup_hits == writers * iters
