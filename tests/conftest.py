import os

# Tests must see the single real CPU device (the dry-run sets its own
# XLA_FLAGS in-process; never here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="run scale/replay tests marked @pytest.mark.slow")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: scale/replay test, excluded from tier-1; "
        "run with --runslow (CI `scale` job)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip = pytest.mark.skip(reason="needs --runslow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
