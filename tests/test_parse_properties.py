"""Parser round-trip / precedence properties.

Hypothesis-based property tests (clean skips when hypothesis is absent,
see tests/_hypothesis_compat.py) plus example-based anchors that always
run: `parse()` must give AND/OR/NOT and + - * the standard precedence
and associativity (validated against Python, whose rules coincide), and
must reject malformed ORDER BY / LIMIT clauses outright.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import expr as E
from repro.core import sqlparse
from repro.tables.table import Table

# one row per boolean assignment of four variables
_BOOLS = Table({f"b{i}": [(bit >> i) & 1 == 1 for bit in range(16)]
                for i in range(4)})


def _eval_where(sql_expr: str) -> list:
    q = sqlparse.parse(f"SELECT * FROM t WHERE {sql_expr}")
    mask = E.eval_expr(q.where, _BOOLS, np.arange(_BOOLS.num_rows))
    return [bool(v) for v in mask]


def _python_truth(py_expr: str) -> list:
    out = []
    for bit in range(16):
        env = {f"b{i}": (bit >> i) & 1 == 1 for i in range(4)}
        out.append(bool(eval(py_expr, {}, env)))
    return out


def _eval_scalar(sql_expr: str):
    q = sqlparse.parse(f"SELECT {sql_expr} FROM t")
    one = Table({"x": [0]})
    return E.eval_expr(q.select[0].expr, one, np.arange(1))[0]


# ---------------------------------------------------------------------------
# example-based anchors (always run, even without hypothesis)
# ---------------------------------------------------------------------------


def test_and_binds_tighter_than_or():
    assert _eval_where("b0 OR b1 AND b2") == _python_truth("b0 or (b1 and b2)")
    assert _eval_where("b0 AND b1 OR b2") == _python_truth("(b0 and b1) or b2")


def test_not_binds_tighter_than_and():
    assert _eval_where("NOT b0 AND b1") == _python_truth("(not b0) and b1")
    assert _eval_where("NOT b0 OR b1") == _python_truth("(not b0) or b1")


def test_parens_override_precedence():
    assert _eval_where("(b0 OR b1) AND b2") == \
        _python_truth("(b0 or b1) and b2")
    assert _eval_where("NOT (b0 AND b1)") == _python_truth("not (b0 and b1)")


def test_mul_binds_tighter_than_add_and_left_assoc():
    assert _eval_scalar("1 + 2 * 3") == 7
    assert _eval_scalar("2 * 3 + 1") == 7
    assert _eval_scalar("10 - 3 - 2") == 5          # left associative
    assert _eval_scalar("2 * 3 * 4") == 24


def test_comparison_binds_looser_than_arithmetic():
    q = sqlparse.parse("SELECT * FROM t WHERE 1 + 2 * 3 < 8 AND b0")
    conj = E.split_conjuncts(q.where)
    assert len(conj) == 2 and isinstance(conj[0], E.BinOp)
    assert conj[0].op == "<"


def test_order_by_roundtrips_keys_and_directions():
    q = sqlparse.parse("SELECT t.id FROM t ORDER BY t.a DESC, t.b, "
                       "t.c ASC LIMIT 4")
    assert [(o.expr.name, o.desc) for o in q.order_by] == \
        [("t.a", True), ("t.b", False), ("t.c", False)]
    assert q.limit == 4


@pytest.mark.parametrize("bad", [
    "SELECT * FROM t ORDER t.id",            # missing BY
    "SELECT * FROM t ORDER BY",              # missing key
    "SELECT * FROM t ORDER BY ,t.id",        # leading comma
    "SELECT * FROM t ORDER BY t.id,",        # trailing comma
    "SELECT * FROM t ORDER BY t.id DESC ASC",  # duplicate direction
    "SELECT * FROM t LIMIT",                 # missing count
    "SELECT * FROM t LIMIT 'x'",             # non-numeric
    "SELECT * FROM t LIMIT 2.5",             # fractional
    "SELECT * FROM t LIMIT -3",              # negative
    "SELECT * FROM t LIMIT 3 ORDER BY t.id",  # clauses out of order
    "SELECT * FROM t LIMIT 3 4",             # trailing garbage
])
def test_rejects_malformed_order_by_and_limit(bad):
    with pytest.raises(SyntaxError):
        sqlparse.parse(bad)


# ---------------------------------------------------------------------------
# hypothesis properties
# ---------------------------------------------------------------------------

_TERMS = [f"b{i}" for i in range(4)]


def _render_bool(tokens) -> str:
    """Flatten [(negate, term, op), ...] into a parenthesis-free boolean
    expression; the parser must recover the NOT > AND > OR precedence."""
    parts = []
    for i, (neg, term, op) in enumerate(tokens):
        if i:
            parts.append(op)
        parts.append(f"NOT {term}" if neg else term)
    return " ".join(parts)


@given(st.lists(st.tuples(st.booleans(), st.sampled_from(_TERMS),
                          st.sampled_from(["AND", "OR"])),
                min_size=1, max_size=6))
@settings(max_examples=60, deadline=None)
def test_boolean_precedence_matches_python(tokens):
    sql = _render_bool(tokens)
    py = sql.replace("AND", "and").replace("OR", "or").replace("NOT", "not")
    assert _eval_where(sql) == _python_truth(py)


@given(st.lists(st.tuples(st.sampled_from(["+", "-", "*"]),
                          st.integers(0, 9)),
                min_size=1, max_size=7),
       st.integers(0, 9))
@settings(max_examples=60, deadline=None)
def test_arithmetic_precedence_matches_python(pairs, first):
    expr = str(first) + "".join(f" {op} {num}" for op, num in pairs)
    assert _eval_scalar(expr) == eval(expr)


@given(st.lists(st.tuples(st.sampled_from(_TERMS),
                          st.sampled_from(["ASC", "DESC", ""])),
                min_size=1, max_size=5),
       st.integers(0, 99))
@settings(max_examples=60, deadline=None)
def test_order_by_limit_roundtrip(keys, n):
    clause = ", ".join(f"{k} {d}".strip() for k, d in keys)
    q = sqlparse.parse(f"SELECT * FROM t ORDER BY {clause} LIMIT {n}")
    assert q.limit == n
    assert [(o.expr.name, o.desc) for o in q.order_by] == \
        [(k, d == "DESC") for k, d in keys]
