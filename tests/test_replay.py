"""Workload-replay determinism (slow suite — run with ``--runslow``).

Pins the `tools/replay.py` determinism contract:

  * same trace seed ⇒ byte-identical per-tenant row digests, billing
    and retry counters across two serial runs — with fault bursts on;
  * per-tenant rows and billing identical across worker counts (1 vs 8)
    on a tenant-salted, billing-pure, fault-free trace; total credits
    identical across worker counts even unsalted;
  * a tiny spill byte-budget forces eviction (``spill_events > 0``)
    yet changes nothing observable: identical rows and billing.
"""
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))

from replay import (TraceConfig, build_catalog, generate_trace,  # noqa: E402
                    replay)

pytestmark = pytest.mark.slow


def _tenant_rows(rep):
    return {t: o.rows_sha256 for t, o in rep.per_tenant.items()}


def _tenant_billing(rep):
    return {t: (o.rows_sha256, round(o.credits, 12), o.dispatched_calls)
            for t, o in rep.per_tenant.items()}


def test_same_seed_same_everything_serial():
    """Two serial replays of one trace — fault bursts active — agree on
    rows, billing AND retry counters (serial mode sees the same batch
    sequence, so even the fault die lands identically)."""
    cfg = TraceConfig(seed=11, sessions=60, tenants=4, rows=256,
                      chunk_rows=64)
    trace = generate_trace(cfg)
    runs = [replay(trace, build_catalog(cfg), workers=1, seed=11,
                   fault_rate=0.05, fault_burst_every=40,
                   fault_burst_len=6)
            for _ in range(2)]
    a, b = runs
    assert a.faults_injected > 0          # the burst process actually fired
    assert _tenant_billing(a) == _tenant_billing(b)
    assert a.failed_queries == b.failed_queries == 0
    assert (a.retries, a.scheduler_retries, a.faults_injected,
            a.timeouts_injected) == \
           (b.retries, b.scheduler_retries, b.faults_injected,
            b.timeouts_injected)
    assert abs(a.total_credits - b.total_credits) < 1e-9


def test_worker_count_invariance():
    """1-worker and 8-worker replays of a tenant-salted billing-pure
    trace agree on per-tenant rows and billing; an unsalted trace still
    agrees on rows and on *total* credits (attribution of shared embed
    requests is schedule-dependent by design)."""
    cfg = TraceConfig(seed=5, sessions=80, tenants=4, rows=256,
                      chunk_rows=64, tenant_salt=True, billing_pure=True)
    trace = generate_trace(cfg)
    w1 = replay(trace, build_catalog(cfg), workers=1, seed=5)
    w8 = replay(trace, build_catalog(cfg), workers=8, seed=5)
    assert _tenant_billing(w1) == _tenant_billing(w8)
    assert abs(w1.total_credits - w8.total_credits) < 1e-9
    assert w1.failed_queries == w8.failed_queries == 0

    plain = TraceConfig(seed=5, sessions=80, tenants=4, rows=256,
                        chunk_rows=64)
    trace2 = generate_trace(plain)
    p1 = replay(trace2, build_catalog(plain), workers=1, seed=5)
    p8 = replay(trace2, build_catalog(plain), workers=8, seed=5)
    assert _tenant_rows(p1) == _tenant_rows(p8)
    assert abs(p1.total_credits - p8.total_credits) < 1e-9


def test_spill_budget_is_observationally_invisible():
    """A byte budget small enough to force constant chunk eviction must
    not change a single result row or billed credit."""
    cfg = TraceConfig(seed=11, sessions=60, tenants=4, rows=256,
                      chunk_rows=64)
    trace = generate_trace(cfg)
    free = replay(trace, build_catalog(cfg), workers=2, seed=11)
    tight = replay(trace, build_catalog(cfg, budget_bytes=4096),
                   workers=2, seed=11)
    assert tight.storage is not None
    assert tight.storage["spill_events"] > 0
    assert tight.storage["reload_events"] > 0
    assert _tenant_rows(free) == _tenant_rows(tight)
    assert abs(free.total_credits - tight.total_credits) < 1e-9
    assert free.failed_queries == tight.failed_queries == 0


def test_trace_generator_is_pure():
    """generate_trace is a pure function of its config."""
    cfg = TraceConfig(seed=42, sessions=50, tenants=6)
    t1, t2 = generate_trace(cfg), generate_trace(cfg)
    assert t1 == t2
    # skew sanity: the trace exercises both kinds and shared templates
    kinds = {e.kind for e in t1}
    assert kinds == {"dashboard", "adhoc"}
    assert any("shared" in e.sql for e in t1)
    # distinct seeds diverge
    assert generate_trace(TraceConfig(seed=43, sessions=50, tenants=6)) != t1


def test_replay_report_shape():
    """The report carries the headline serving metrics the bench gates
    read: QPS, p95, cache-hit rates, storage counters."""
    cfg = TraceConfig(seed=2, sessions=30, tenants=3, rows=256,
                      chunk_rows=64)
    trace = generate_trace(cfg)
    rep = replay(trace, build_catalog(cfg), workers=4, seed=2)
    assert rep.queries == len(trace)
    assert rep.qps > 0 and rep.wall_s > 0
    assert rep.latency_p95_s >= rep.latency_p50_s >= 0
    assert 0.0 <= rep.dedup_hit_rate <= 1.0
    assert 0.0 <= rep.cross_query_hit_rate <= rep.dedup_hit_rate + 1e-9
    # Zipf-hot + shared templates must actually produce sharing
    assert rep.cross_query_hit_rate > 0.1
    assert rep.storage is not None and rep.storage["peak_bytes"] > 0
    assert sum(o.queries for o in rep.per_tenant.values()) == rep.queries
    assert abs(sum(o.credits for o in rep.per_tenant.values())
               - rep.total_credits) < 1e-9
    # conservation against the backends' own meter
    assert rep.backend_credits is not None
    assert abs(rep.total_credits - rep.backend_credits) < 1e-9
    text = rep.render()
    assert "qps" in text and "p95" in text and "storage" in text
