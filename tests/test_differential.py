"""Differential execution-mode suite.

A seeded randomized query corpus (filters × joins × aggregates × order
× limits) is executed under every execution mode — eager, pipelined,
partitioned (and partitioned over a pipelined client) — on both storage
backends — the monolithic numpy `Table` and the chunk-backed
`ChunkedTable` — and the modes must agree:

  * identical result rows, always;
  * across stores (same mode, chunks aligned with ``partition_rows``):
    identical rows, credits, and `StatsStore` observations — the
    storage refactor must be observationally invisible;
  * identical total credits billed on unbounded queries (no mode may
    silently buy more — or less — inference than another);
  * on LIMIT-bounded queries the partitioned mode may only ever spend
    *less* than materialize-then-truncate, never more;
  * with pilot sampling on, no predicate is ever billed for more rows
    than the table holds (no double billing across partition/pilot
    paths) and per-operator credits sum to the metered total;
  * the semantic index is an *accelerator*, never an answer-changer:
    for every embedding/similarity query in the corpus, index-on and
    index-off configurations return identical rows, and the index may
    only ever reduce credits.
"""
import numpy as np
import pytest

from repro.core import AisqlEngine, Catalog, ExecConfig, SemIndexConfig
from repro.data import datasets as D
from repro.inference.api import make_simulated_client
from repro.tables.chunked import ChunkedTable
from repro.tables.table import Table

SEED = 20260731
N_QUERIES = 24

# (pipelined client, partitioned executor)
MODES = {
    "eager": (False, False),
    "pipelined": (True, False),
    "partitioned": (False, True),
    "partitioned-pipelined": (True, True),
}

STORES = ("monolithic", "chunked")


def _catalog(seed=SEED, store="monolithic", chunk_rows=48):
    rng = np.random.default_rng(seed)
    n = 120
    t_cols = {
        "id": np.arange(n),
        "gid": np.arange(n) % 30,
        "val": rng.random(n),
        "cat": rng.choice(["a", "b", "c"], n),
        "text": [f"[t:{i}] document body {i}" for i in range(n)],
        "_truth": rng.random(n) < 0.45,
        "_difficulty": np.full(n, 0.05),
    }
    u_cols = {
        "k": np.arange(30),
        "w": rng.random(30),
    }
    if store == "chunked":
        t = ChunkedTable(t_cols, name="t", chunk_rows=chunk_rows)
        u = ChunkedTable(u_cols, name="u", chunk_rows=chunk_rows)
    else:
        t = Table(t_cols, name="t")
        u = Table(u_cols, name="u")
    return Catalog({"t": t, "u": u})


FILTERS = (
    "t.val < 0.6",
    "t.gid >= 9",
    "t.cat IN ('a', 'b')",
    "t.val BETWEEN 0.1 AND 0.9",
    "AI_FILTER(PROMPT('is this row relevant? {0}', t.text))",
    "AI_FILTER(PROMPT('does this mention databases? {0}', t.text))",
    "AI_SIMILARITY(t.text, 'document body') > 0.4",
)


def _gen_query(rng: np.random.Generator) -> str:
    join = rng.random() < 0.4
    agg = rng.random() < 0.3
    n_filters = int(rng.integers(0, 4))
    picks = list(rng.choice(len(FILTERS), size=n_filters, replace=False))
    where = " AND ".join(FILTERS[i] for i in picks)
    frm = "FROM t"
    if join:
        frm += " JOIN u ON t.gid = u.k"
    if agg:
        sql = f"SELECT t.cat, COUNT(*), AVG(t.val) {frm}"
        if where:
            sql += f" WHERE {where}"
        sql += " GROUP BY t.cat"
    else:
        cols = "t.id, t.val, t.cat" + (", u.w" if join else "")
        sql = f"SELECT {cols} {frm}"
        if where:
            sql += f" WHERE {where}"
        if rng.random() < 0.4:
            sql += " ORDER BY t.val DESC, t.id ASC"
    if rng.random() < 0.5:
        sql += f" LIMIT {int(rng.choice([3, 7, 17]))}"
    return sql


def _corpus():
    rng = np.random.default_rng(SEED)
    return [_gen_query(rng) for _ in range(N_QUERIES)]


def _run(cat, sql, *, pipelined, partitioned):
    client = make_simulated_client(pipelined=pipelined)
    # reorder/pilot off so every mode commits to the same static
    # evaluation order — the per-row work sets are then identical and
    # credit totals must match to the last dispatch
    eng = AisqlEngine(cat, client, executor=ExecConfig(
        partitioned=partitioned, partition_rows=48, chunk_rows=48,
        adaptive_reorder=False, pilot_rows=0))
    out = eng.sql(sql)
    return out, eng.last_report, _observations(eng)


def _observations(eng):
    """StatsStore content minus wall-clock timing (never comparable)."""
    out = {}
    for key in eng.stats.keys():
        d = eng.stats.get(key).to_dict()
        out[key] = {k: v for k, v in d.items() if k != "seconds"}
    return out


def _canon_rows(table: Table):
    cols = sorted(table.column_names)
    return sorted(tuple(str(table.column(c)[i]) for c in cols)
                  for i in range(table.num_rows))


@pytest.mark.parametrize("sql", _corpus())
def test_modes_agree_on_rows_and_credits(sql):
    cats = {store: _catalog(store=store) for store in STORES}
    results = {(store, name): _run(cats[store], sql,
                                   pipelined=p, partitioned=q)
               for store in STORES for name, (p, q) in MODES.items()}
    base_out, base_rep, _ = results[("monolithic", "eager")]
    base_rows = _canon_rows(base_out)
    bounded = "LIMIT" in sql
    for (store, name), (out, rep, _) in results.items():
        assert _canon_rows(out) == base_rows, \
            f"{store}/{name} changed the result set for: {sql}"
        if bounded and "partitioned" in name:
            # early termination may only ever reduce spend
            assert rep.ai_credits <= base_rep.ai_credits + 1e-12, \
                f"{store}/{name} overspent on: {sql}"
            assert rep.ai_calls <= base_rep.ai_calls, \
                f"{store}/{name} issued more calls on: {sql}"
        else:
            assert rep.ai_credits == pytest.approx(
                base_rep.ai_credits, abs=1e-12), \
                f"{store}/{name} billed differently for: {sql}"
            assert rep.ai_calls == base_rep.ai_calls, \
                f"{store}/{name} call count diverged for: {sql}"
    # chunked-vs-monolithic, same mode: chunks are aligned with
    # partition_rows, so the storage backend must be observationally
    # invisible — byte-identical credits, calls, and StatsStore content
    for name in MODES:
        _, rep_m, obs_m = results[("monolithic", name)]
        _, rep_c, obs_c = results[("chunked", name)]
        assert rep_c.ai_credits == pytest.approx(
            rep_m.ai_credits, abs=1e-12), \
            f"chunked store changed billing under {name} for: {sql}"
        assert rep_c.ai_calls == rep_m.ai_calls, \
            f"chunked store changed call count under {name} for: {sql}"
        assert obs_c == obs_m, \
            f"chunked store changed StatsStore content under {name}: {sql}"


@pytest.mark.parametrize("sql", [q for q in _corpus()
                                 if "LIMIT" not in q][:6])
def test_chunk_misalignment_rows_identical(sql):
    """Chunk boundaries that do NOT line up with ``partition_rows``
    still return identical rows and — on unbounded queries — identical
    credits: per-request pricing makes partition shape billing-neutral
    when reordering and pilot sampling are off."""
    cat_m = _catalog()
    cat_c = _catalog(store="chunked", chunk_rows=37)
    for name, (p, q) in MODES.items():
        out_m, rep_m, _ = _run(cat_m, sql, pipelined=p, partitioned=q)
        out_c, rep_c, _ = _run(cat_c, sql, pipelined=p, partitioned=q)
        assert _canon_rows(out_c) == _canon_rows(out_m), \
            f"misaligned chunks changed rows under {name} for: {sql}"
        assert rep_c.ai_credits == pytest.approx(
            rep_m.ai_credits, abs=1e-12), \
            f"misaligned chunks changed billing under {name} for: {sql}"


def test_corpus_is_meaningful():
    """The generated corpus must actually cover the operator space."""
    corpus = _corpus()
    assert any("JOIN" in q for q in corpus)
    assert any("GROUP BY" in q for q in corpus)
    assert any("LIMIT" in q for q in corpus)
    assert any("AI_FILTER" in q for q in corpus)
    assert any("ORDER BY" in q for q in corpus)
    assert any("LIMIT" not in q for q in corpus)
    assert any("AI_SIMILARITY" in q for q in corpus)


# ---------------------------------------------------------------------------
# semantic index on/off differential
# ---------------------------------------------------------------------------

# embedding/similarity queries: projections, threshold filters, semantic
# ORDER BY with and without LIMIT, mixed with relational predicates
INDEX_QUERIES = (
    "SELECT t.id, AI_SIMILARITY(t.text, 'document body') AS sim FROM t",
    "SELECT t.id FROM t WHERE AI_SIMILARITY(t.text, 'document body') > 0.4",
    "SELECT t.id FROM t WHERE t.val < 0.7 AND "
    "AI_SIMILARITY(t.text, 'document body') > 0.35",
    "SELECT t.id FROM t ORDER BY AI_SIMILARITY(t.text, 'document body') "
    "DESC LIMIT 9",
    "SELECT t.id FROM t ORDER BY AI_SIMILARITY(t.text, 'document body') "
    "ASC LIMIT 4",
    "SELECT t.id, t.cat FROM t "
    "WHERE AI_SIMILARITY(t.text, 'irrelevant topic') > 0.9",   # empty set
    "SELECT t.cat, COUNT(*) FROM t "
    "WHERE AI_SIMILARITY(t.text, 'document body') > 0.4 GROUP BY t.cat",
)


@pytest.mark.parametrize("sql", INDEX_QUERIES)
def test_index_on_off_rows_identical_credits_reduced(sql):
    """The semantic index must never change results: index-on and
    index-off return identical rows for every embedding query, the
    index may only reduce credits, and a warm second run is free."""
    cat = _catalog()
    off = AisqlEngine(cat, make_simulated_client())
    rows_off = _canon_rows(off.sql(sql))
    on = AisqlEngine(cat, make_simulated_client(),
                     semindex=SemIndexConfig(impl="reference"))
    rows_on = _canon_rows(on.sql(sql))
    cold_calls = on.last_report.ai_calls
    assert rows_on == rows_off, f"index changed the result set for: {sql}"
    assert on.last_report.ai_credits <= \
        off.last_report.ai_credits + 1e-12, f"index overspent on: {sql}"
    # second run: the store answers every previously-embedded text (a
    # reordered predicate chain may touch rows the first run skipped,
    # so "free" is guaranteed only for single-predicate full scans)
    rows_warm = _canon_rows(on.sql(sql))
    assert rows_warm == rows_off
    assert on.last_report.ai_calls <= cold_calls
    if "AND" not in sql:
        assert on.last_report.ai_calls == 0, \
            f"warm store still dispatched EMBED work for: {sql}"


def test_pilot_accounting_consistent_across_modes():
    """With pilot sampling on, every mode returns the same rows, never
    evaluates a predicate on more rows than the table holds, and
    attributes every credit (pilot rows are billed exactly once)."""
    cat = Catalog({"articles": D.skewed_articles(360)})
    sql = ("SELECT * FROM articles AS a WHERE "
           "AI_FILTER(PROMPT('broad appeal? {0}', a.headline)) AND "
           "AI_FILTER(PROMPT('narrowly about databases? {0}', a.summary))")
    rows_by_mode = {}
    for name, (pipelined, partitioned) in MODES.items():
        client = make_simulated_client(pipelined=pipelined)
        eng = AisqlEngine(cat, client, executor=ExecConfig(
            partitioned=partitioned, partition_rows=90, chunk_rows=90,
            pilot_rows=24, min_rows_for_pilot=64))
        out = eng.sql(sql)
        rep = eng.last_report
        rows_by_mode[name] = _canon_rows(out)
        assert rep.pilot is not None and rep.pilot["sampled_rows"] > 0
        for op in rep.operators:
            if op.actual_rows_in is not None:
                assert op.actual_rows_in <= 360, \
                    f"{name}: {op.operator} double-billed rows"
        total = sum(op.actual_credits for op in rep.operators
                    if op.actual_credits is not None)
        assert total == pytest.approx(rep.ai_credits, rel=1e-9), name
    base = rows_by_mode["eager"]
    for name, rows in rows_by_mode.items():
        assert rows == base, f"{name} changed the result set"
