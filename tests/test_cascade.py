"""SUPG-IT cascade: budget, quality, threshold and streaming invariants."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.cascade import (CalibratedCascade, CascadeConfig,
                                SupgItCascade)


def synth(n=2000, pos=0.4, sep=4.0, seed=0):
    """Rows with ground truth + a proxy that scores via a logistic noise."""
    rng = np.random.default_rng(seed)
    truth = rng.random(n) < pos
    z = np.where(truth, sep / 2, -sep / 2) + rng.normal(0, 1.2, n)
    scores = 1.0 / (1.0 + np.exp(-z))
    return list(range(n)), truth, scores


def run_cascade(cfg, rows, truth, scores, oracle_err=0.0, seed=0):
    rng = np.random.default_rng(seed + 1)
    calls = {"proxy": 0, "oracle": 0}

    def proxy(batch):
        calls["proxy"] += len(batch)
        return scores[np.asarray(batch)]

    def oracle(batch):
        calls["oracle"] += len(batch)
        t = truth[np.asarray(batch)]
        flip = rng.random(len(batch)) < oracle_err
        return np.where(flip, ~t, t)

    cascade = SupgItCascade(cfg)
    pred = cascade.run(rows, proxy, oracle)
    return pred, calls, cascade


def f1(pred, truth):
    tp = (pred & truth).sum()
    fp = (pred & ~truth).sum()
    fn = (~pred & truth).sum()
    return 2 * tp / max(2 * tp + fp + fn, 1)


def test_oracle_budget_respected():
    rows, truth, scores = synth()
    cfg = CascadeConfig(oracle_budget_frac=0.2, seed=0)
    pred, calls, _ = run_cascade(cfg, rows, truth, scores)
    assert calls["oracle"] <= int(np.ceil(0.2 * len(rows))) + cfg.batch_size


def test_cascade_beats_raw_proxy_quality():
    rows, truth, scores = synth(sep=2.5)
    cfg = CascadeConfig(seed=0)
    pred, calls, _ = run_cascade(cfg, rows, truth, scores)
    proxy_pred = scores >= 0.5
    assert f1(pred, truth) > f1(proxy_pred, truth)
    assert calls["oracle"] < len(rows) * 0.6   # and it used far fewer calls


def test_thresholds_ordered_and_narrowing():
    rows, truth, scores = synth()
    _, _, cascade = run_cascade(CascadeConfig(seed=1), rows, truth, scores)
    assert cascade.tau_low <= cascade.tau_high


def test_streaming_state_accumulates_across_runs():
    rows, truth, scores = synth()
    cfg = CascadeConfig(seed=2)
    cascade = SupgItCascade(cfg)

    def proxy(batch):
        return scores[np.asarray(batch)]

    def oracle(batch):
        return truth[np.asarray(batch)]

    half = len(rows) // 2
    cascade.run(rows[:half], proxy, oracle)
    samples_after_first = len(cascade._s)
    cascade.run(rows[half:], proxy, oracle)
    assert cascade.stats.rows == len(rows)
    assert len(cascade._s) >= samples_after_first
    # budget accounting must be streaming (vs rows seen), not per-call
    assert cascade.stats.oracle_calls <= int(np.ceil(
        cfg.oracle_budget_frac * len(rows))) + cfg.batch_size


def test_easy_data_mostly_proxy():
    rows, truth, scores = synth(sep=8.0)    # near-separable
    pred, calls, cascade = run_cascade(CascadeConfig(seed=3), rows, truth,
                                       scores)
    assert cascade.stats.delegation_rate < 0.35
    assert f1(pred, truth) > 0.93


def test_noisy_oracle_still_bounded():
    rows, truth, scores = synth(sep=3.0)
    pred, calls, _ = run_cascade(CascadeConfig(seed=4), rows, truth, scores,
                                 oracle_err=0.1)
    assert f1(pred, truth) > 0.7


@given(st.integers(0, 1000), st.floats(0.1, 0.9), st.floats(0.05, 0.5))
@settings(max_examples=15, deadline=None)
def test_property_budget_and_predictions_total(seed, pos, budget):
    rows, truth, scores = synth(n=400, pos=pos, seed=seed)
    cfg = CascadeConfig(oracle_budget_frac=budget, batch_size=128,
                        seed=seed)
    pred, calls, cascade = run_cascade(cfg, rows, truth, scores, seed=seed)
    # every row got a prediction; oracle calls within (streamed) budget
    assert len(pred) == len(rows)
    assert calls["oracle"] <= int(np.ceil(budget * len(rows))) + cfg.batch_size
    st_ = cascade.stats
    assert (st_.accepted_by_proxy + st_.rejected_by_proxy
            + st_.uncertain_to_oracle + st_.uncertain_fallback
            + st_.sampled_for_learning) >= len(rows)


def test_calibrated_cascade_runs():
    rows, truth, scores = synth()
    cc = CalibratedCascade(CascadeConfig(seed=5))
    pred = cc.run(rows, lambda b: scores[np.asarray(b)],
                  lambda b: truth[np.asarray(b)])
    assert f1(pred, truth) > 0.85


def test_pava_isotonic():
    y = np.array([0.1, 0.5, 0.3, 0.8, 0.2, 0.9])
    w = np.ones(6)
    out = CalibratedCascade._pava(y, w)
    assert (np.diff(out) >= -1e-12).all()
    np.testing.assert_allclose(out.sum(), y.sum(), rtol=1e-9)
