"""Chunk-backed columnar store: observational equivalence + spill.

Property tests (hypothesis, skipped cleanly when it is not installed)
assert that `ChunkedTable` is observationally identical to the
monolithic `Table` over random column types, chunk sizes, slices,
filters, joins and group-bys — and that morsel views are genuinely
zero-copy (`np.shares_memory` with the chunk's own arrays).

Example-based tests cover the spill manager (byte budget, LRU
eviction, transparent reload, counters) and the `Table.__init__`
unknown-type regression (a `ValueError` naming the column, not a bare
assert that vanishes under ``python -O``).
"""
import numpy as np
import pytest

from repro.tables.chunked import ChunkedTable
from repro.tables.spill import SpillManager, array_bytes
from repro.tables.table import FileRef, Table

from tests._hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st


# ---------------------------------------------------------------------------
# random table generation
# ---------------------------------------------------------------------------

def _make_columns(rng: np.random.Generator, n_rows: int, col_types):
    cols, types = {}, {}
    for i, t in enumerate(col_types):
        name = f"c{i}_{t}"
        types[name] = t
        if t == "int":
            cols[name] = rng.integers(-5, 6, n_rows)
        elif t == "float":
            cols[name] = rng.random(n_rows)
        elif t == "bool":
            cols[name] = rng.random(n_rows) < 0.5
        elif t == "file":
            cols[name] = [FileRef(f"s3://b/{int(k)}.png", "image/png")
                          for k in rng.integers(0, 4, n_rows)]
        else:
            cols[name] = [f"w{int(k)} body" for k in rng.integers(0, 7,
                                                                  n_rows)]
    return cols, types


def _pair(seed: int, n_rows: int, chunk_rows: int, col_types,
          budget=None):
    rng = np.random.default_rng(seed)
    cols, types = _make_columns(rng, n_rows, col_types)
    mono = Table(cols, types, name="t")
    spill = SpillManager(budget_bytes=budget)
    chunked = ChunkedTable(cols, types, name="t",
                           chunk_rows=chunk_rows, spill=spill)
    return mono, chunked, rng


def _rows_of(table: Table):
    cols = sorted(table.column_names)
    return [tuple(str(table.column(c)[i]) for c in cols)
            for i in range(table.num_rows)]


TYPE_ST = st.sampled_from(["int", "float", "str", "bool", "file"])


# ---------------------------------------------------------------------------
# hypothesis property tests
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10_000), n_rows=st.integers(1, 120),
       chunk_rows=st.integers(1, 50),
       col_types=st.lists(TYPE_ST, min_size=1, max_size=4))
def test_gather_take_slice_equivalence(seed, n_rows, chunk_rows,
                                       col_types):
    mono, chunked, rng = _pair(seed, n_rows, chunk_rows, col_types)
    assert chunked.num_rows == mono.num_rows
    assert chunked.column_names == mono.column_names
    assert chunked.types == mono.types
    # full column assembly
    for c in mono.column_names:
        assert np.array_equal(chunked.column(c), mono.column(c))
    # random gather: unsorted, with duplicates
    idx = rng.integers(0, n_rows, size=int(rng.integers(0, 2 * n_rows)))
    for c in mono.column_names:
        assert np.array_equal(chunked.gather(c, idx), mono.gather(c, idx))
    # take / head / row
    assert _rows_of(chunked.take(idx)) == _rows_of(mono.take(idx))
    k = int(rng.integers(0, n_rows + 2))
    assert _rows_of(chunked.head(k)) == _rows_of(mono.head(k))
    i = int(rng.integers(0, n_rows))
    assert {k_: str(v) for k_, v in chunked.row(i).items()} == \
        {k_: str(v) for k_, v in mono.row(i).items()}


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10_000), n_rows=st.integers(1, 100),
       chunk_rows=st.integers(1, 40))
def test_filter_join_groupby_equivalence(seed, n_rows, chunk_rows):
    mono, chunked, rng = _pair(seed, n_rows, chunk_rows,
                               ["int", "float", "str"])
    key = chunked.column_names[0]          # the int column
    # filter
    mask = rng.random(n_rows) < 0.4
    assert _rows_of(chunked.filter_mask(mask)) == \
        _rows_of(mono.filter_mask(mask))
    # group-by
    g_c = {k: v.tolist() for k, v in chunked.group_indices(key).items()}
    g_m = {k: v.tolist() for k, v in mono.group_indices(key).items()}
    assert g_c == g_m
    # hash join against a small dimension table
    dim = Table({"k": np.arange(-5, 6), "lab": [f"L{i}" for i in range(11)]},
                name="dim")
    assert _rows_of(chunked.hash_join(dim, key, "k")) == \
        _rows_of(mono.hash_join(dim, key, "k"))
    # rename / prefixed / select stay equivalent (and are O(1) views:
    # constructing one materializes nothing)
    pc, pm = chunked.prefixed("t"), mono.prefixed("t")
    assert pc.column_names == pm.column_names
    assert pc.materializations == 0
    assert _rows_of(pc) == _rows_of(pm)
    sel = chunked.column_names[:2]
    assert _rows_of(chunked.select(sel)) == _rows_of(mono.select(sel))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n_rows=st.integers(1, 90),
       chunk_rows=st.integers(1, 40),
       col_types=st.lists(TYPE_ST, min_size=1, max_size=3))
def test_morsels_are_zero_copy_views(seed, n_rows, chunk_rows, col_types):
    _, chunked, _ = _pair(seed, n_rows, chunk_rows, col_types)
    bounds = chunked.segment_bounds()
    assert bounds[0][0] == 0 and bounds[-1][1] == n_rows
    assert all(hi - lo <= chunk_rows for lo, hi in bounds)
    for si, (lo, hi) in enumerate(bounds):
        m = chunked.morsel(si)
        assert m.num_rows == hi - lo
        seg = chunked._segments[si].arrays()
        for pub, internal in chunked._colmap.items():
            assert np.shares_memory(m.column(pub), seg[internal]), \
                f"morsel {si} copied column {pub}"


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n_rows=st.integers(20, 100),
       chunk_rows=st.integers(1, 16))
def test_spilled_table_stays_equivalent(seed, n_rows, chunk_rows):
    """A byte budget small enough to force eviction changes nothing
    observable: gathers and filters reload segments transparently."""
    mono, chunked, rng = _pair(seed, n_rows, chunk_rows,
                               ["int", "str", "float"],
                               budget=600)
    sp = chunked.spill
    assert sp.spill_events > 0, "budget too large to exercise spill"
    idx = rng.integers(0, n_rows, size=n_rows)
    for c in mono.column_names:
        assert np.array_equal(chunked.gather(c, idx), mono.gather(c, idx))
    assert sp.reload_events > 0
    mask = rng.random(n_rows) < 0.5
    assert _rows_of(chunked.filter_mask(mask)) == \
        _rows_of(mono.filter_mask(mask))


# ---------------------------------------------------------------------------
# example-based: spill manager mechanics
# ---------------------------------------------------------------------------

def test_spill_manager_budget_and_counters(tmp_path):
    sp = SpillManager(budget_bytes=3000, spill_dir=str(tmp_path))
    cols = {"x": np.arange(1000), "s": [f"string number {i}" for i
                                        in range(1000)]}
    ct = ChunkedTable(cols, name="big", chunk_rows=100, spill=sp)
    stats = sp.stats()
    assert stats["spill_events"] > 0
    assert stats["tracked_bytes"] <= 3000 + max(
        s.nbytes for s in ct._segments)
    assert stats["peak_bytes"] >= stats["tracked_bytes"]
    # every row still reachable; reloads counted
    assert np.array_equal(ct.column("x"), np.arange(1000))
    assert sp.reload_events > 0
    # spill files live under the requested directory
    spilled = list(tmp_path.glob("seg*.npz"))
    assert spilled, "no segment files written"


def test_spill_untracked_by_default():
    """Without a budget the manager only accounts — nothing is evicted
    and nothing touches disk."""
    ct = ChunkedTable({"x": np.arange(500)}, chunk_rows=64)
    assert ct.spill.spill_events == 0
    assert ct.spill.tracked_bytes > 0
    assert ct.spill.peak_bytes >= ct.spill.tracked_bytes
    assert all(s.resident for s in ct._segments)


def test_wide_take_registers_with_same_manager():
    sp = SpillManager()
    ct = ChunkedTable({"x": np.arange(400)}, chunk_rows=50, spill=sp)
    wide = ct.take(np.arange(399, -1, -1))
    assert isinstance(wide, ChunkedTable)
    assert wide.spill is sp
    assert np.array_equal(wide.column("x"), np.arange(399, -1, -1))
    narrow = ct.take(np.arange(10))
    assert type(narrow) is Table


def test_array_bytes_counts_object_payload():
    fixed = np.arange(10, dtype=np.int64)
    assert array_bytes(fixed) == fixed.nbytes
    objs = np.empty(2, dtype=object)
    objs[0], objs[1] = "abc", "defgh"
    assert array_bytes(objs) == objs.nbytes + 8


# ---------------------------------------------------------------------------
# example-based: constructor validation (regression)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("factory", [Table, ChunkedTable])
def test_unknown_column_type_raises_value_error(factory):
    """`assert t in _COLUMN_TYPES` vanished under ``python -O``; it is
    now a ValueError naming the offending column and type."""
    with pytest.raises(ValueError) as exc:
        factory({"good": [1], "payload": ["x"]},
                types={"payload": "blob"})
    msg = str(exc.value)
    assert "'payload'" in msg and "'blob'" in msg


def test_unknown_type_survives_optimized_mode():
    import subprocess, sys, os
    code = ("from repro.tables.table import Table\n"
            "try:\n"
            "    Table({'c': [1]}, types={'c': 'nope'})\n"
            "except ValueError:\n"
            "    print('OK')\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-O", "-c", code],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.stdout.strip() == "OK", out.stderr
