"""Partitioned streaming execution: LIMIT-aware early termination,
prefetch cancellation, semantic ORDER BY / fused TopK, and the
accounting invariants (no phantom calls, no double billing)."""
import numpy as np
import pytest

from repro.core import AisqlEngine, Catalog, ExecConfig, OptimizerConfig
from repro.core import expr as E
from repro.core import plan as P
from repro.core import sqlparse
from repro.data import datasets as D
from repro.inference.api import make_simulated_client
from repro.tables.table import Table


def _alternating_table(n=128, name="t"):
    """Deterministic workload: _truth alternates True/False so each
    partition of 2k rows yields exactly k survivors (difficulty ~0 keeps
    the simulated oracle essentially exact)."""
    return Table({
        "id": np.arange(n),
        "text": [f"[{name}:{i}] row text {i}" for i in range(n)],
        "_truth": np.arange(n) % 2 == 0,
        "_difficulty": np.full(n, 0.01),
    }, name=name)


AI_SQL = ("SELECT * FROM t WHERE "
          "AI_FILTER(PROMPT('keep this row? {0}', t.text)) LIMIT 12")


def _engine(cat, *, pipelined=False, **exec_kw):
    return AisqlEngine(cat, make_simulated_client(pipelined=pipelined),
                       executor=ExecConfig(**exec_kw))


# ---------------------------------------------------------------------------
# early termination
# ---------------------------------------------------------------------------


def test_streaming_limit_matches_eager_rows_with_fewer_calls():
    cat = Catalog({"t": _alternating_table(256)})
    eager = _engine(cat)
    base = eager.sql(AI_SQL)
    part = _engine(cat, partitioned=True, partition_rows=32)
    out = part.sql(AI_SQL)
    assert out.column("t.id").tolist() == base.column("t.id").tolist()
    assert part.last_report.ai_calls < eager.last_report.ai_calls / 2
    assert part.last_report.ai_credits < eager.last_report.ai_credits / 2


def test_partition_telemetry_and_explain_analyze_render():
    cat = Catalog({"t": _alternating_table(256)})
    eng = _engine(cat, partitioned=True, partition_rows=32)
    eng.sql(AI_SQL)
    rep = eng.last_report
    p = rep.partitions
    assert p is not None
    assert p["partitions_total"] == 8
    assert p["early_terminated"]
    assert p["partitions_executed"] < p["partitions_total"]
    assert (p["partitions_executed"] + p["partitions_cancelled"]
            == p["partitions_total"])
    assert p["rows_emitted"] == 12
    assert p["rows_scanned"] == 32 * p["partitions_executed"]
    text = rep.explain_analyze()
    assert "partitions:" in text and "early termination" in text


def test_no_phantom_calls_or_credits_on_early_termination():
    """Operators' actual accounting must agree with the client meter even
    when most partitions were cancelled mid-query."""
    cat = Catalog({"t": _alternating_table(256)})
    eng = _engine(cat, partitioned=True, partition_rows=32)
    eng.sql(AI_SQL)
    rep = eng.last_report
    ai_ops = [op for op in rep.operators if op.actual_rows_in is not None]
    assert ai_ops, "expected the AI predicate in QueryReport.operators"
    # every dispatched call is attributed: per-operator credits sum to
    # the metered total, evaluated rows match what was actually scanned
    total = sum(op.actual_credits for op in ai_ops)
    assert total == pytest.approx(rep.ai_credits, rel=1e-9)
    assert sum(op.actual_rows_in for op in ai_ops) == rep.ai_calls
    assert all(op.actual_rows_in <= op.est_rows_in for op in ai_ops)


def test_partitioned_no_limit_matches_eager_exactly():
    """Without a LIMIT the partition-pull loop must evaluate exactly the
    eager chunked work (same rows, same credits) when sizes align."""
    cat = Catalog({"t": _alternating_table(200)})
    sql = ("SELECT * FROM t WHERE t.id < 150 AND "
           "AI_FILTER(PROMPT('keep this row? {0}', t.text))")
    eager = _engine(cat, chunk_rows=64, pilot_rows=0)
    base = eager.sql(sql)
    part = _engine(cat, partitioned=True, partition_rows=64, pilot_rows=0)
    out = part.sql(sql)
    assert out.column("t.id").tolist() == base.column("t.id").tolist()
    assert part.last_report.ai_calls == eager.last_report.ai_calls
    assert part.last_report.ai_credits == pytest.approx(
        eager.last_report.ai_credits, rel=1e-9)


def test_streaming_bounds_ai_projection():
    """Limit(Project) with an AI item: the projection runs only on the
    surviving k rows in partitioned mode."""
    cat = Catalog({"t": _alternating_table(192)})
    sql = ("SELECT t.id, AI_COMPLETE(PROMPT('summarize {0}', t.text)) "
           "FROM t LIMIT 4")
    eager = _engine(cat)
    base = eager.sql(sql)
    part = _engine(cat, partitioned=True, partition_rows=32)
    out = part.sql(sql)
    assert out.column("t.id").tolist() == base.column("t.id").tolist()
    assert part.last_report.ai_calls == 4
    assert eager.last_report.ai_calls == 192


def test_prefetch_cancellation_never_bills_cancelled_requests():
    """With lookahead, a partition queued speculatively but never
    dispatched is withdrawn on early termination at zero cost."""
    cat = Catalog({"t": _alternating_table(128)})
    eng = _engine(cat, pipelined=True, partitioned=True,
                  partition_rows=16, partition_lookahead=3)
    out = eng.sql(AI_SQL)
    assert out.num_rows == 12
    rep = eng.last_report
    # partitions 0-2 dispatched together (lookahead window), the limit
    # is met at partition 1, and the partition queued while processing
    # it (start 48) is cancelled before dispatch
    assert rep.partitions["partitions_executed"] == 2
    assert rep.partitions["early_terminated"]
    assert rep.partitions["cancelled_requests"] == 16
    assert rep.pipeline["cancelled"] == 16
    # dispatched = 3 prefetched partitions; the cancelled one is not billed
    assert rep.ai_calls == 48
    ai_ops = [op for op in rep.operators if op.actual_credits is not None]
    assert sum(op.actual_credits for op in ai_ops) == pytest.approx(
        rep.ai_credits, rel=1e-9)


def test_pilot_rows_not_rebilled_in_partitioned_mode():
    cat = Catalog({"articles": D.skewed_articles(400)})
    sql = ("SELECT * FROM articles AS a WHERE "
           "AI_FILTER(PROMPT('broad? {0}', a.headline)) AND "
           "AI_FILTER(PROMPT('narrow? {0}', a.summary)) LIMIT 10")
    eng = _engine(cat, partitioned=True, partition_rows=100,
                  pilot_rows=32, min_rows_for_pilot=64)
    out = eng.sql(sql)
    assert out.num_rows == 10
    rep = eng.last_report
    assert rep.pilot is not None and rep.pilot["sampled_rows"] == 32
    for op in rep.operators:
        if op.actual_rows_in is not None:
            assert op.actual_rows_in <= 400      # never double-counted
    total = sum(op.actual_credits for op in rep.operators
                if op.actual_credits is not None)
    assert total == pytest.approx(rep.ai_credits, rel=1e-9)


def test_cascade_flows_through_partition_pull():
    cat = Catalog({"t": _alternating_table(256)})
    eng = _engine(cat, partitioned=True, partition_rows=64,
                  use_cascade=True)
    out = eng.sql(AI_SQL)
    assert out.num_rows == 12
    assert eng.cascades, "cascade should have run inside the pull loop"
    assert eng.last_report.partitions["early_terminated"]


# ---------------------------------------------------------------------------
# ORDER BY: structured, alias, aggregate output, semantic top-k
# ---------------------------------------------------------------------------


def test_order_by_structured_multi_key():
    rng = np.random.default_rng(7)
    t = Table({"id": np.arange(40), "g": rng.integers(0, 4, 40),
               "v": rng.random(40)})
    eng = _engine(Catalog({"t": t}))
    out = eng.sql("SELECT t.id, t.g, t.v FROM t ORDER BY t.g ASC, t.v DESC")
    expect = sorted(range(40), key=lambda i: (t["g"][i], -t["v"][i]))
    assert out.column("t.id").tolist() == [int(t["id"][i]) for i in expect]


def test_order_by_select_alias_and_limit():
    t = Table({"id": np.arange(10), "v": np.arange(10)[::-1].astype(float)})
    eng = _engine(Catalog({"t": t}))
    out = eng.sql("SELECT t.id AS ident, t.v AS score FROM t "
                  "ORDER BY score ASC LIMIT 3")
    assert out.column("ident").tolist() == [9, 8, 7]


def test_order_by_aggregate_output():
    t = Table({"id": np.arange(30),
               "cat": np.repeat(["a", "b", "c"], [14, 10, 6])})
    eng = _engine(Catalog({"t": t}))
    out = eng.sql("SELECT t.cat, COUNT(*) FROM t GROUP BY t.cat "
                  "ORDER BY count DESC")
    assert out.column("count").tolist() == [14, 10, 6]
    assert out.column("t.cat").tolist() == ["a", "b", "c"]


def test_semantic_order_by_fuses_topk_and_prefilters():
    cat = Catalog({"t": _alternating_table(200)})
    eng = _engine(cat)
    sql = ("SELECT t.id FROM t ORDER BY "
           "AI_SCORE(PROMPT('is this row relevant? {0}', t.text)) DESC "
           "LIMIT 8")
    plan = eng.plan(sql)

    def has_topk(n):
        return isinstance(n, P.TopK) or any(has_topk(c)
                                            for c in n.children())
    assert has_topk(plan)
    out = eng.sql(sql)
    assert out.num_rows == 8
    rep = eng.last_report
    # proxy scored everything, the oracle only the escalated candidates
    assert rep.ai_calls == 200 + 24
    assert any("topk-prefilter" in ev for ev in rep.reoptimizations)
    # with near-zero difficulty the top rows should be true positives
    truth = dict(zip(cat.table("t")["id"].tolist(),
                     cat.table("t")["_truth"].tolist()))
    hits = sum(truth[i] for i in out.column("t.id").tolist())
    assert hits >= 6


def test_topk_prefilter_off_scores_everything_with_oracle():
    cat = Catalog({"t": _alternating_table(120)})
    eng = _engine(cat, topk_prefilter=False)
    out = eng.sql("SELECT t.id FROM t ORDER BY "
                  "AI_SCORE(PROMPT('relevant? {0}', t.text)) DESC LIMIT 5")
    assert out.num_rows == 5
    assert eng.last_report.ai_calls == 120
    assert not any("topk-prefilter" in ev
                   for ev in eng.last_report.reoptimizations)


def test_unfused_sort_full_scores_then_truncates():
    cat = Catalog({"t": _alternating_table(96)})
    eng = AisqlEngine(cat, make_simulated_client(),
                      optimizer=OptimizerConfig(enable_topk_fusion=False))
    out = eng.sql("SELECT t.id FROM t ORDER BY "
                  "AI_SCORE(PROMPT('relevant? {0}', t.text)) DESC LIMIT 5")
    assert out.num_rows == 5
    assert eng.last_report.ai_calls == 96


def test_ai_score_recorded_in_stats_and_operators():
    cat = Catalog({"t": _alternating_table(150)})
    eng = _engine(cat)
    eng.sql("SELECT t.id FROM t ORDER BY "
            "AI_SCORE(PROMPT('relevant? {0}', t.text)) DESC LIMIT 6")
    rep = eng.last_report
    score_ops = [op for op in rep.operators if "AI_SCORE" in op.operator]
    assert len(score_ops) == 2          # proxy + oracle populations
    assert all(op.actual_rows_in for op in score_ops)
    total = sum(op.actual_credits for op in score_ops)
    assert total == pytest.approx(rep.ai_credits, rel=1e-9)
    # the StatsStore learned both populations under distinct fingerprints
    fps = [k for k in eng.stats.keys() if k.startswith("AI_SCORE|")]
    assert len(fps) == 2


def test_ai_score_in_select_list_and_order_by_alias():
    cat = Catalog({"t": _alternating_table(60)})
    eng = _engine(cat)
    out = eng.sql("SELECT t.id, AI_SCORE(PROMPT('relevant? {0}', t.text)) "
                  "AS s FROM t ORDER BY s DESC LIMIT 5")
    assert out.num_rows == 5 and "s" in out.column_names
    scores = out.column("s").tolist()
    assert scores == sorted(scores, reverse=True)
    assert all(0.0 <= v <= 1.0 for v in scores)


def test_prefetch_size_flush_spend_is_attributed():
    """A size-threshold flush that dispatches prefetched partitions while
    they are being *submitted* must not orphan their credits: per-op
    credits still sum to the meter and learned cost/row stays real."""
    from repro.inference.pipeline import PipelineConfig
    cat = Catalog({"t": _alternating_table(256)})
    client = make_simulated_client(pipeline=PipelineConfig(max_batch=32))
    eng = AisqlEngine(cat, client, executor=ExecConfig(
        partitioned=True, partition_rows=32, partition_lookahead=3))
    eng.sql(AI_SQL)
    rep = eng.last_report
    ai_ops = [op for op in rep.operators if op.actual_credits is not None]
    assert sum(op.actual_credits for op in ai_ops) == pytest.approx(
        rep.ai_credits, rel=1e-9)
    # the learned cost per row must reflect the real spend, not ~0
    fp = [k for k in eng.stats.keys() if k.startswith("AI_FILTER|")][0]
    obs = eng.stats.get(fp)
    assert obs.cost_per_row > 1e-7


def test_topk_estimates_follow_prefilter_config():
    """With the prefilter disabled the planner must price (and report)
    the full oracle scan, not a phantom proxy pass."""
    cat = Catalog({"t": _alternating_table(120)})
    sql = ("SELECT t.id FROM t ORDER BY "
           "AI_SCORE(PROMPT('relevant? {0}', t.text)) DESC LIMIT 5")
    on = _engine(cat)
    on.sql(sql)
    off = _engine(cat, topk_prefilter=False)
    off.sql(sql)
    on_ops = [op for op in on.last_report.operators
              if "AI_SCORE" in op.operator]
    off_ops = [op for op in off.last_report.operators
               if "AI_SCORE" in op.operator]
    assert len(on_ops) == 2 and len(off_ops) == 1
    assert off_ops[0].est_rows_in == 120
    assert off_ops[0].actual_rows_in == 120
    assert "proxy" not in off_ops[0].operator
    # est cost of the disabled path reflects the full oracle scan
    assert off.last_report.est_llm_cost > on.last_report.est_llm_cost


def test_order_by_parse_rejects_malformed():
    for bad in ("SELECT * FROM t ORDER t.id",
                "SELECT * FROM t ORDER BY",
                "SELECT * FROM t ORDER BY t.id,",
                "SELECT * FROM t LIMIT t.id",
                "SELECT * FROM t LIMIT 3.5",
                "SELECT * FROM t LIMIT -1",
                "SELECT * FROM t LIMIT 5 ORDER BY t.id"):
        with pytest.raises(SyntaxError):
            sqlparse.parse(bad)


def test_order_by_plan_placement():
    q = sqlparse.parse("SELECT t.id FROM t ORDER BY t.v DESC LIMIT 4")
    node = P.build_plan(q)
    assert isinstance(node, P.Limit)
    assert isinstance(node.child, P.Project)
    assert isinstance(node.child.child, P.Sort)
    key = node.child.child.keys[0]
    assert key.desc and isinstance(key.expr, E.Column)
