"""Adaptive re-optimization: StatsStore, pilot sampling, learned CostModel."""
import dataclasses

import numpy as np
import pytest

from repro.core import (AisqlEngine, Catalog, CostDefaults, CostModel,
                        ExecConfig, Optimizer, OptimizerConfig, StatsStore,
                        predicate_fingerprint)
from repro.core import expr as E
from repro.core import plan as P
from repro.core import sqlparse
from repro.core.stats import PredObservation, wilson_interval
from repro.data import datasets as D
from repro.inference.api import make_simulated_client


def _ai(template="p {0}", col="t.text", model=None):
    return E.AIFilter(E.Prompt(template, (E.Column(col),)), model=model)


def _catalog(n=400, seed=0):
    return Catalog({"articles": D.skewed_articles(n, seed=seed)})


# ---------------------------------------------------------------------------
# StatsStore: fingerprints, intervals, persistence
# ---------------------------------------------------------------------------


def test_fingerprint_stable_across_aliases():
    """Equivalent predicates written against different aliases share one
    fingerprint; different templates/models do not."""
    a = _ai("is {0} happy?", "a.body")
    b = _ai("is {0} happy?", "reviews.body")
    assert predicate_fingerprint(a) == predicate_fingerprint(b)
    assert predicate_fingerprint(a) != predicate_fingerprint(
        _ai("is {0} sad?", "a.body"))
    assert predicate_fingerprint(a) != predicate_fingerprint(
        _ai("is {0} happy?", "a.body", model="proxy-8b"))
    assert predicate_fingerprint(a) != predicate_fingerprint(
        _ai("is {0} happy?", "a.title"))


def test_statsstore_roundtrip(tmp_path):
    path = str(tmp_path / "stats.json")
    store = StatsStore(path)
    key = predicate_fingerprint(_ai())
    store.observe_predicate(key, evaluated=80, passed=20, credits=0.4,
                            seconds=1.5, new_query=True)
    store.observe_cascade(key, rows=80, oracle_calls=60)
    store.observe_pipeline(submitted=100, dedup_hits=25)
    store.save()

    loaded = StatsStore(path)
    obs = loaded.get(key)
    assert obs is not None
    assert obs.evaluated == 80 and obs.passed == 20
    assert obs.selectivity == pytest.approx(0.25)
    assert obs.cost_per_row == pytest.approx(0.4 / 80)
    assert obs.delegation_rate == pytest.approx(0.75)
    assert loaded.get("__pipeline__").dedup_hit_rate == pytest.approx(0.25)
    # loading into a non-empty store merges counts instead of overwriting
    loaded.load(path)
    assert loaded.get(key).evaluated == 160


def test_wilson_interval_brackets_rate():
    lo, hi = wilson_interval(20, 80)
    assert 0.0 < lo < 0.25 < hi < 1.0
    assert wilson_interval(0, 0) == (0.0, 1.0)
    lo_small, hi_small = wilson_interval(2, 8)
    assert hi_small - lo_small > hi - lo      # less data, wider interval


# ---------------------------------------------------------------------------
# CostModel: observed stats before defaults; named fallbacks
# ---------------------------------------------------------------------------


def test_costmodel_consults_observed_stats():
    cat = _catalog()
    store = StatsStore()
    pred = _ai("x {0}", "a.headline")
    cost = CostModel(cat, stats=store)
    static_sel = cost.predicate_selectivity(pred)
    assert static_sel == cost.defaults.ai_selectivity
    # enough evidence: observed values are used verbatim
    store.observe_predicate(predicate_fingerprint(pred),
                            evaluated=200, passed=10, credits=0.002)
    assert cost.predicate_selectivity(pred) == pytest.approx(0.05)
    assert cost.predicate_cost_per_row(pred) == pytest.approx(0.002 / 200)
    assert cost.estimate_source(pred) == "observed"


def test_costmodel_blends_small_samples_toward_prior():
    cat = _catalog()
    store = StatsStore()
    pred = _ai("x {0}", "a.headline")
    cost = CostModel(cat, stats=store)
    store.observe_predicate(predicate_fingerprint(pred),
                            evaluated=4, passed=0, credits=0.0)
    sel = cost.predicate_selectivity(pred)
    assert 0.0 < sel < cost.defaults.ai_selectivity   # shrunk, not 0
    assert cost.estimate_source(pred) == "blended"


def test_cost_defaults_are_configurable():
    cat = _catalog()
    d = CostDefaults(ai_selectivity=0.9, between_selectivity=0.5)
    cost = CostModel(cat, defaults=d)
    assert cost.predicate_selectivity(_ai()) == pytest.approx(0.9)
    bet = E.Between(E.Column("a.id"), E.Literal(1), E.Literal(5))
    assert cost.predicate_selectivity(bet) == pytest.approx(0.5)
    # OptimizerConfig carries the defaults into a fresh cost model
    opt = Optimizer(cat, cfg=OptimizerConfig(cost_defaults=d))
    assert opt.cost.defaults.ai_selectivity == pytest.approx(0.9)


def test_cold_start_plan_is_static_plan():
    """With an empty store the optimizer must emit exactly the plan it
    emitted before learned statistics existed."""
    cat = _catalog()
    sql = ("SELECT * FROM articles AS a WHERE "
           "AI_FILTER(PROMPT('n? {0}', a.headline)) AND a.id < 100")
    node = P.build_plan(sqlparse.parse(sql))
    bare = Optimizer(cat, cost=CostModel(cat)).optimize(node)
    cold = Optimizer(cat, cost=CostModel(cat, stats=StatsStore())
                     ).optimize(node)
    assert bare.pretty() == cold.pretty()
    assert [type(p).__name__ for p in _first_filter(bare).predicates] == \
        [type(p).__name__ for p in _first_filter(cold).predicates]


def _first_filter(node):
    if isinstance(node, P.Filter):
        return node
    for c in node.children():
        f = _first_filter(c)
        if f is not None:
            return f
    return None


# ---------------------------------------------------------------------------
# pilot sampling + mid-query re-ordering
# ---------------------------------------------------------------------------

# statically the short 'broad?' template ranks first; its true
# selectivity (~0.95) makes that the worst order
SKEWED_SQL = ("SELECT * FROM articles AS a WHERE "
              "AI_FILTER(PROMPT('broad? {0}', a.headline)) AND "
              "AI_FILTER(PROMPT('does this text concern database "
              "research? {0}', a.summary))")


def _run(store, *, pilot, n=400, pipelined=True):
    cat = _catalog(n=n)
    client = make_simulated_client(pipelined=pipelined)
    eng = AisqlEngine(cat, client,
                      executor=ExecConfig(adaptive_reorder=pilot,
                                          pilot_rows=48 if pilot else 0,
                                          min_rows_for_pilot=64),
                      stats=store)
    out = eng.sql(SKEWED_SQL)
    return eng, out


def test_pilot_reorders_when_stats_contradict_static():
    static_eng, static_out = _run(StatsStore(), pilot=False)
    adaptive_eng, adaptive_out = _run(StatsStore(), pilot=True)
    rep = adaptive_eng.last_report
    # the pilot fired, observed the skew, and flipped the order mid-query
    assert rep.pilot is not None and rep.pilot["sampled_rows"] > 0
    assert rep.pilot["reordered"]
    assert any("pilot reorder" in ev for ev in rep.reoptimizations)
    # same answer, fewer LLM calls than the static order
    assert sorted(adaptive_out.column("a.id").tolist()) == \
        sorted(static_out.column("a.id").tolist())
    assert rep.ai_calls < static_eng.last_report.ai_calls


def test_warm_store_skips_pilot_and_preorders():
    store = StatsStore()
    _run(store, pilot=True)                      # query 1 learns
    eng, _ = _run(store, pilot=True)             # query 2 is warm
    rep = eng.last_report
    assert rep.pilot["cold_predicates"] == 0
    assert rep.pilot["sampled_rows"] == 0
    # compile-time order already correct: no mid-query flip needed
    assert not rep.pilot["reordered"]
    # and the narrow predicate is planned first in the optimized plan
    filt = _first_filter(eng.plan(SKEWED_SQL))
    assert "database" in filt.predicates[0].prompt.template


def test_estimated_vs_actual_in_report():
    store = StatsStore()
    eng, _ = _run(store, pilot=True)
    ops = eng.last_report.operators
    assert ops and all(op.actual_selectivity is not None for op in ops)
    # cold estimates use the default source; the warm run's are observed
    assert {op.est_source for op in ops} == {"default"}
    eng2, _ = _run(store, pilot=True)
    ops2 = eng2.last_report.operators
    assert {op.est_source for op in ops2} == {"observed"}
    for op in ops2:
        assert abs(op.est_selectivity - op.actual_selectivity) < 0.15
    text = eng2.last_report.explain_analyze()
    assert "estimated vs actual" in text and "observed" in text


def test_pilot_disabled_matches_seed_behaviour():
    """pilot_rows=0 must leave results and call counts untouched."""
    outs = {}
    for pilot in (False, True):
        cat = _catalog(n=300)
        client = make_simulated_client()
        eng = AisqlEngine(cat, client,
                          executor=ExecConfig(pilot_rows=48 if pilot else 0,
                                              min_rows_for_pilot=64))
        out = eng.sql(SKEWED_SQL)
        outs[pilot] = (sorted(out.column("a.id").tolist()),
                       eng.last_report.ai_calls)
    assert outs[False][0] == outs[True][0]
    assert outs[True][1] != outs[False][1]   # pilot changed the schedule


def test_cascade_bypass_after_high_delegation():
    cat = _catalog(n=300)
    store = StatsStore()
    pred = E.AIFilter(E.Prompt("broad? {0}", (E.Column("a.headline"),)))
    # fake history: the proxy escalated 95% of 200 cascaded rows
    store.observe_cascade(predicate_fingerprint(pred),
                          rows=200, oracle_calls=190)
    client = make_simulated_client()
    eng = AisqlEngine(cat, client,
                      executor=ExecConfig(use_cascade=True, pilot_rows=0),
                      stats=store)
    eng.sql("SELECT * FROM articles AS a WHERE "
            "AI_FILTER(PROMPT('broad? {0}', a.headline)) AND a.id < 250")
    assert any("cascade-bypass" in ev
               for ev in eng.last_report.reoptimizations)
    # bypass means no proxy model calls for this predicate
    assert client.calls_by_model.get(client.proxy_model, 0) == 0


def test_engine_stats_path_persists(tmp_path):
    path = str(tmp_path / "learned.json")
    cat = _catalog(n=300)
    eng = AisqlEngine(cat, make_simulated_client(pipelined=True),
                      executor=ExecConfig(min_rows_for_pilot=64),
                      stats_path=path)
    eng.sql(SKEWED_SQL)
    # a fresh engine over the persisted file starts warm
    eng2 = AisqlEngine(cat, make_simulated_client(pipelined=True),
                       executor=ExecConfig(min_rows_for_pilot=64),
                       stats_path=path)
    eng2.sql(SKEWED_SQL)
    assert eng2.last_report.pilot["cold_predicates"] == 0


def test_semantic_join_records_observed_cost():
    left, right, _ = D.join_tables("AGNEWS_100")
    cat = Catalog({"l": left, "r": right})
    store = StatsStore()
    eng = AisqlEngine(cat, make_simulated_client(), stats=store)
    eng.sql("SELECT * FROM l JOIN r ON "
            "AI_FILTER(PROMPT('{0} is about {1}', l.content, r.label))")
    classify_keys = [k for k in store.keys() if k.startswith("AI_CLASSIFY")]
    assert classify_keys, f"no classify observation in {list(store.keys())}"
    obs = store.get(classify_keys[0])
    assert obs.evaluated > 0 and obs.credits > 0


def test_pilot_rows_not_double_counted():
    """Pilot results are carried into the full pass: the first predicate
    evaluates exactly num_rows rows in total (never rows + pilot), on
    eager and pipelined clients alike."""
    n = 400
    for pipelined in (False, True):
        store = StatsStore()
        eng, _ = _run(store, pilot=True, n=n, pipelined=pipelined)
        evaluated = [op.actual_rows_in for op in eng.last_report.operators]
        # the predicate evaluated first at runtime sees every row exactly
        # once; no predicate ever sees more than the table has
        assert max(evaluated) == n, (pipelined, evaluated)
        assert all(e <= n for e in evaluated), (pipelined, evaluated)
        obs = [store.get(k) for k in store.keys()
               if k.startswith("AI_FILTER")]
        assert max(o.evaluated for o in obs) == n
        assert all(o.evaluated <= n for o in obs)


def test_store_counts_contributing_queries():
    store = StatsStore()
    _run(store, pilot=True)
    _run(store, pilot=True)
    key = next(k for k in store.keys() if k.startswith("AI_FILTER"))
    assert store.get(key).queries == 2


def test_scoped_truth_for_multi_column_predicate_is_conjunction():
    from repro.core.executor import row_metadata
    t = D.skewed_articles(50)
    rows = np.arange(50)
    md = row_metadata(t, rows, arg_cols=["headline", "summary"])
    want = (t.column("_truth__headline").astype(bool)
            & t.column("_truth__summary").astype(bool))
    got = np.asarray([m["truth"] for m in md])
    assert (got == want).all()


def test_operator_report_carries_confidence_interval():
    store = StatsStore()
    eng, _ = _run(store, pilot=True)
    cold_ops = eng.last_report.operators
    assert all(op.est_selectivity_ci == (0.0, 1.0) for op in cold_ops)
    eng2, _ = _run(store, pilot=True)
    for op in eng2.last_report.operators:
        lo, hi = op.est_selectivity_ci
        assert 0.0 <= lo <= op.est_selectivity <= hi <= 1.0
        assert (lo, hi) != (0.0, 1.0)


def test_operator_report_fields_match_dataclass():
    """Guard for the docs: the estimated-vs-actual section promises these
    exact fields."""
    from repro.core import OperatorReport
    names = {f.name for f in dataclasses.fields(OperatorReport)}
    assert names == {"operator", "est_rows_in", "est_selectivity",
                     "est_selectivity_ci", "est_cost_per_row", "est_source",
                     "actual_rows_in", "actual_selectivity",
                     "actual_cost_per_row", "actual_credits"}
