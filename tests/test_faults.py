"""Fault-injection tests: transient engine failures/timeouts under the
serving runtime and the pipeline's bounded retry-with-backoff.

Invariants:
  * with ``fault_rate > 0`` every corpus query still completes with rows
    identical to the fault-free run (retries are deterministic re-rolls;
    result draws are keyed by request fingerprint, not by attempt);
  * retries are metered and reported (`ServingReport.retries` /
    ``scheduler_retries`` / ``scheduler_timeouts``), and spend stays
    conserved — a faulted batch is billed zero, a retried batch once;
  * a request exceeding max retries raises a clean `RequestFailed`
    (never a hang, never a silent drop);
  * speculative prefetches abandoned by a failing query are cancelled,
    never dispatched, never billed.
"""
import pytest

from _serving_corpus import SEED, canon_rows, make_catalog
from repro.core import (AisqlEngine, Catalog, ExecConfig, ServingConfig,
                        ServingEngine)
from repro.data import datasets as D
from repro.inference.api import CortexClient
from repro.inference.backend import SCORE, EngineFailure, Request
from repro.inference.pipeline import (PipelineConfig, RequestFailed,
                                      RequestPipeline)
from repro.inference.scheduler import Scheduler, SchedulerError
from repro.inference.simulator import SimulatedBackend

CORPUS = [
    ("acme", "SELECT * FROM articles AS a WHERE "
             "AI_FILTER(PROMPT('broad topic? {0}', a.headline))"),
    ("beta", "SELECT a.id FROM articles AS a WHERE "
             "AI_FILTER(PROMPT('narrow topic? {0}', a.summary))"),
    ("beta", "SELECT * FROM articles AS a WHERE "
             "AI_FILTER(PROMPT('broad topic? {0}', a.headline)) LIMIT 12"),
    ("gamma", "SELECT r.id FROM reviews AS r WHERE "
              "AI_FILTER(PROMPT('positive? {0}', r.text))"),
]


def run_corpus(fault_rate=0.0, timeout_rate=0.0, repeats=2):
    # small batches => many dispatches => many fault rolls
    cfg = ServingConfig(workers=6, pipeline=PipelineConfig(
        max_batch=24, retry_backoff_s=0.0005))
    with ServingEngine.simulated(make_catalog(), seed=SEED,
                                 fault_rate=fault_rate,
                                 timeout_rate=timeout_rate, cfg=cfg) as srv:
        tickets = srv.run_all(CORPUS * repeats)
        rows = [canon_rows(t.result()) for t in tickets]
        backend = srv.scheduler._replicas["proxy-8b"][0]
        return rows, srv.report(), backend


# ---------------------------------------------------------------------------
# the differential: faulty == fault-free, with retries metered
# ---------------------------------------------------------------------------


def test_fault_rate_differential_identical_rows_and_metered_retries():
    clean_rows, clean_rep, _ = run_corpus(fault_rate=0.0)
    rows, rep, backend = run_corpus(fault_rate=0.2, timeout_rate=0.05)
    assert rows == clean_rows, "faulty run diverged from fault-free rows"
    # the injected faults really happened and were retried, visibly
    assert backend.faults_injected + backend.timeouts_injected > 0
    assert rep.retries + rep.scheduler_retries > 0
    assert "retries" in rep.render()
    # retry spend is conserved: faulted batches billed zero, success once
    assert rep.total_credits == pytest.approx(rep.backend_credits, abs=1e-9)
    assert rep.total_credits == pytest.approx(clean_rep.total_credits,
                                              abs=1e-9)
    # nobody failed, nothing rejected
    for t in rep.tenants.values():
        assert t.failed == 0 and t.rejected == 0
        assert t.completed == t.queries


def test_timeouts_are_counted_separately():
    _, rep, backend = run_corpus(fault_rate=0.0, timeout_rate=0.5,
                                 repeats=1)
    assert backend.timeouts_injected > 0
    assert rep.scheduler_timeouts > 0
    assert rep.scheduler_timeouts == backend.timeouts_injected


# ---------------------------------------------------------------------------
# exhausted retries: clean error, no hang, no spend
# ---------------------------------------------------------------------------


def flaky_pipeline(fault_rate, *, sched_retries=1, pipe_retries=1):
    sched = Scheduler(max_retries=sched_retries)
    sim = SimulatedBackend(seed=SEED, fault_rate=fault_rate)
    sched.register(sim)
    pipe = RequestPipeline(sched, PipelineConfig(
        max_retries=pipe_retries, retry_backoff_s=0.0005))
    return sched, sim, pipe


def test_exceeding_max_retries_raises_clean_error():
    _, sim, pipe = flaky_pipeline(1.0)
    futs = pipe.submit_many([Request(f"p{i}", "proxy-8b", SCORE)
                             for i in range(5)])
    with pytest.raises(RequestFailed) as exc:
        futs[0].result()
    assert isinstance(exc.value.__cause__, (EngineFailure, SchedulerError))
    # every sibling future resolved with the same clean error — no hang,
    # no silent drop
    for f in futs:
        assert f.done()
        with pytest.raises(RequestFailed):
            f.result()
    assert pipe.stats.failures == 5
    assert pipe.stats.dispatched == 0
    assert sim.total_credits == 0.0                # faults are never billed


def test_permanent_failure_surfaces_on_serving_ticket():
    cfg = ServingConfig(workers=2, pipeline=PipelineConfig(
        max_retries=1, retry_backoff_s=0.0005))
    with ServingEngine.simulated(make_catalog(), seed=SEED, fault_rate=1.0,
                                 cfg=cfg) as srv:
        ticket = srv.submit("acme", CORPUS[0][1])
        srv.drain()
        rep = srv.report()
    with pytest.raises(RequestFailed):
        ticket.result(timeout=30.0)
    assert rep.tenants["acme"].failed == 1
    assert rep.failed_requests > 0
    assert rep.total_credits == 0.0
    assert rep.backend_credits == 0.0


def test_partial_fault_recovery_between_retries():
    # scheduler retries exhausted (1 replica, max_retries=0) but the
    # pipeline's own retry layer re-dispatches and eventually succeeds
    sched = Scheduler(max_retries=0)
    # seed 12's first fault draw is 0.05 (< 0.5 -> injected fault), its
    # second 0.81 (-> success): attempt 1 fails, the pipeline retries
    sim = SimulatedBackend(seed=12, fault_rate=0.5)
    sched.register(sim)
    pipe = RequestPipeline(sched, PipelineConfig(
        max_retries=8, retry_backoff_s=0.0005))
    futs = pipe.submit_many([Request(f"q{i}", "proxy-8b", SCORE)
                             for i in range(4)])
    scores = [f.result().score for f in futs]
    assert all(0.0 <= s <= 1.0 for s in scores)
    assert pipe.stats.retries > 0                  # the path was exercised
    assert pipe.stats.failures == 0
    # billed exactly once despite the re-dispatches
    assert sim.total_credits == pytest.approx(
        sum(r.credits for r in (f.result() for f in futs)))


# ---------------------------------------------------------------------------
# failure cleanup: abandoned prefetches are withdrawn, never billed
# ---------------------------------------------------------------------------


def test_failed_query_cancels_queued_prefetches_unbilled():
    sched = Scheduler(max_retries=1)
    sim = SimulatedBackend(seed=SEED, fault_rate=1.0)
    sched.register(sim)
    client = CortexClient(sched, pipeline=PipelineConfig(
        max_batch=64, max_retries=1, retry_backoff_s=0.0005))
    eng = AisqlEngine(
        Catalog({"articles": D.skewed_articles(600, seed=3)}), client,
        executor=ExecConfig(partitioned=True, partition_rows=64,
                            partition_lookahead=4,
                            min_rows_for_pilot=10 ** 9))
    with pytest.raises(RequestFailed):
        eng.sql("SELECT * FROM articles AS a WHERE "
                "AI_FILTER(PROMPT('x? {0}', a.headline)) LIMIT 5")
    # nothing queued, nothing billed — the failed query left no debris
    # for a later barrier to dispatch on its behalf
    assert client.pipeline.pending == 0
    assert client.ai_credits == 0.0
    assert sim.total_credits == 0.0


def test_cancelled_requests_under_faults_never_billed():
    # a healthy partitioned LIMIT query cancels its speculative tail;
    # with faults in the mix the cancelled requests still cost nothing
    sched = Scheduler(max_retries=2)
    sim = SimulatedBackend(seed=1, fault_rate=0.15)
    sched.register(sim)
    client = CortexClient(sched, pipeline=PipelineConfig(
        max_batch=512, retry_backoff_s=0.0005))
    eng = AisqlEngine(
        Catalog({"articles": D.skewed_articles(2000, seed=3)}), client,
        executor=ExecConfig(partitioned=True, partition_rows=128,
                            partition_lookahead=3,
                            min_rows_for_pilot=10 ** 9))
    # ~5% selectivity: the LIMIT spans several partitions, so later
    # iterations keep prefetching speculative partitions that are still
    # queued when the limit satisfies — those get withdrawn
    out = eng.sql("SELECT * FROM articles AS a WHERE "
                  "AI_FILTER(PROMPT('narrow topic? {0}', a.summary)) "
                  "LIMIT 10")
    assert out.num_rows == 10
    rep = eng.last_report
    assert rep.partitions["early_terminated"]
    assert client.pipeline.stats.cancelled > 0
    # conservation: the client's meter equals the backend's spend, i.e.
    # cancelled (never-dispatched) requests were billed to no one
    assert client.ai_credits == pytest.approx(sim.total_credits, abs=1e-12)
    assert client.pipeline.pending == 0
