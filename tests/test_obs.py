"""Observability subsystem: span tracing, metrics registry, wiring.

The load-bearing property is **determinism**: with a seeded simulator
and an injected `TickClock`, a query's span tree serializes to
byte-identical JSON across runs — in eager and partitioned execution,
and under fault-injected scheduler retries (the retry spans themselves
are part of the stable tree).  `tools/replay.py --trace-out` and the
resume/debug workflows depend on this.
"""
import json

import numpy as np
import pytest

from repro.core import (AisqlEngine, Catalog, ExecConfig, ServingConfig,
                        ServingEngine)
from repro.core.serving import TenantPolicy
from repro.inference.api import CortexClient
from repro.inference.pipeline import PipelineConfig
from repro.inference.scheduler import Scheduler
from repro.inference.simulator import SimulatedBackend
from repro.obs import (EVENT_KINDS, METRIC_FAMILIES, QUANTILE_REL_ERROR,
                       SPAN_KINDS, MetricsRegistry, Observability, TickClock,
                       TraceRing, Tracer, activate, active_tracer,
                       critical_path, locked_snapshot, parse_prometheus_text,
                       to_chrome, to_json, walk_spans)
from repro.obs.metrics import BUCKET_BOUNDS, BUCKET_FACTOR
from repro.obs.trace import NOOP
from repro.tables.table import Table


def small_catalog(n=64, seed=0):
    rng = np.random.default_rng(seed)
    return Catalog({"t": Table({
        "id": np.arange(n),
        "score": rng.random(n),
        "text": [f"row {i} text" for i in range(n)],
        "_truth": rng.random(n) < 0.4,
        "_difficulty": np.full(n, 0.05),
    }, name="t")})


def make_engine(*, obs, fault_rate=0.0, partitioned=False, seed=0,
                pipelined=True):
    sched = Scheduler()
    sched.register(SimulatedBackend(seed=seed, fault_rate=fault_rate,
                                    fault_seed=seed + 7))
    client = CortexClient(
        sched, pipeline=PipelineConfig(retry_backoff_s=0.001,
                                       retry_backoff_cap_s=0.01,
                                       max_retries=6)
        if pipelined else None)
    exec_cfg = ExecConfig(partitioned=partitioned, partition_rows=16,
                          adaptive_reorder=False, pilot_rows=0)
    return AisqlEngine(small_catalog(seed=seed), client,
                       executor=exec_cfg, obs=obs)


AI_SQL = ("SELECT t.id FROM t WHERE t.score < 0.8 AND "
          "AI_FILTER(PROMPT('is this interesting? {0}', t.text))")


# ---------------------------------------------------------------------------
# tracer units
# ---------------------------------------------------------------------------


def test_tick_clock_is_deterministic():
    c1, c2 = TickClock(), TickClock()
    assert [c1() for _ in range(3)] == [c2() for _ in range(3)]
    assert c1() > 0


def test_span_ids_and_nesting():
    tr = Tracer(clock=TickClock())
    with tr.span("query", kind="query") as q:
        with tr.span("parse", kind="parse"):
            pass
        with tr.span("execute", kind="execute") as e:
            e.set(rows_out=3)
            tr.event("optimize.memo_hit", reuses=1)
    tree = tr.to_dict()
    assert tree["id"] == 1 and tree["parent"] == 0
    kids = tree["children"]
    assert [c["kind"] for c in kids] == ["parse", "execute"]
    assert all(c["parent"] == 1 for c in kids)
    assert kids[1]["attrs"]["rows_out"] == 3
    assert kids[1]["events"][0]["name"] == "optimize.memo_hit"
    assert q.t1 is not None and q.t1 > q.t0
    # every recorded kind is in the taxonomy
    for span in walk_spans(tree):
        assert span["kind"] in SPAN_KINDS


def test_noop_tracer_records_nothing():
    with NOOP.span("query", kind="query") as sp:
        sp.set(rows=1)
        NOOP.event("whatever")
    assert not NOOP.enabled and NOOP.to_dict() is None
    # active_tracer defaults to the no-op outside any activate()
    assert active_tracer() is NOOP


def test_activate_scopes_the_tracer():
    tr = Tracer(clock=TickClock())
    with activate(tr):
        assert active_tracer() is tr
    assert active_tracer() is NOOP


def test_chrome_export_and_critical_path():
    tr = Tracer(clock=TickClock())
    with tr.span("query", kind="query"):
        with tr.span("execute", kind="execute"):
            tr.event("cascade.proxy", rows=4)
    tree = tr.to_dict()
    events = to_chrome(tree)["traceEvents"]
    phases = {e["ph"] for e in events}
    assert "X" in phases and "i" in phases
    assert all("ts" in e and "pid" in e for e in events)
    line = critical_path(tree)
    assert "critical path" in line and "execute" in line


def test_trace_ring_eviction():
    ring = TraceRing(capacity=2)
    for i in range(4):
        ring.put(f"q{i}", {"span_id": i})
    assert len(ring) == 2
    assert ring.ids() == ["q2", "q3"]
    assert ring.get("q0") is None
    assert ring.get("q3") == {"span_id": 3}


# ---------------------------------------------------------------------------
# determinism: byte-identical span-tree JSON
# ---------------------------------------------------------------------------


def _trace_json(**kw):
    obs = Observability(clock=TickClock)
    eng = make_engine(obs=obs, **kw)
    eng.sql(AI_SQL)
    assert eng.last_report.trace is not None
    return to_json(eng.last_report.trace)


@pytest.mark.parametrize("partitioned", [False, True],
                         ids=["eager", "partitioned"])
def test_trace_bytes_stable(partitioned):
    a = _trace_json(partitioned=partitioned)
    b = _trace_json(partitioned=partitioned)
    assert a == b
    tree = json.loads(a)
    kinds = {s["kind"] for s in walk_spans(tree)}
    assert {"query", "parse", "optimize", "execute",
            "pipeline.dispatch", "dispatch.replica"} <= kinds
    if partitioned:
        assert "partition" in kinds


def test_trace_bytes_stable_under_faults():
    a = _trace_json(partitioned=True, fault_rate=0.25)
    b = _trace_json(partitioned=True, fault_rate=0.25)
    assert a == b
    tree = json.loads(a)
    # the retries themselves are recorded — and stably so
    outcomes = [s["attrs"].get("outcome")
                for s in walk_spans(tree)
                if s["kind"] == "dispatch.replica"]
    assert "ok" in outcomes
    assert any(o in ("fault", "timeout") for o in outcomes)


def test_trace_attrs_reconcile_with_query_report():
    obs = Observability(clock=TickClock)
    eng = make_engine(obs=obs)
    eng.sql(AI_SQL)
    rep = eng.last_report
    root = rep.trace
    assert root["attrs"]["credits"] == pytest.approx(rep.ai_credits)
    span_credits = sum(
        s["attrs"].get("credits", 0.0) for s in walk_spans(root)
        if s["kind"] == "dispatch.replica"
        and s["attrs"].get("outcome") == "ok")
    assert span_credits == pytest.approx(rep.ai_credits)
    # the explain output gains the critical-path line
    assert "critical path" in rep.explain_analyze()


def test_disabled_obs_records_no_trace():
    eng = make_engine(obs=Observability(enabled=False))
    eng.sql(AI_SQL)
    assert eng.last_report.trace is None
    assert "critical path" not in eng.last_report.explain_analyze()


# ---------------------------------------------------------------------------
# metrics registry units
# ---------------------------------------------------------------------------


def test_registry_rejects_unknown_family():
    reg = MetricsRegistry()
    with pytest.raises(ValueError, match="unknown metric family"):
        reg.counter("aisql_bogus_total")
    with pytest.raises(ValueError, match="is a counter"):
        reg.histogram("aisql_queries_total")


def test_counter_and_gauge_children():
    reg = MetricsRegistry()
    c = reg.counter("aisql_queries_total")
    c.inc(tenant="a", status="completed")
    c.inc(2, tenant="a", status="completed")
    assert c.labels(tenant="a", status="completed").value == 3
    g = reg.gauge("aisql_storage_bytes")
    g.set(123, state="resident")
    assert g.labels(state="resident").value == 123


def test_histogram_quantile_error_bound():
    reg = MetricsRegistry()
    h = reg.histogram("aisql_query_latency_seconds")
    child = h.labels(tenant="x")
    rng = np.random.default_rng(0)
    xs = rng.uniform(1e-3, 5.0, 400)
    for x in xs:
        child.observe(float(x))
    for q in (0.5, 0.9, 0.95):
        exact = float(np.quantile(xs, q))
        est = child.quantile(q)
        assert est == pytest.approx(exact, rel=2 * QUANTILE_REL_ERROR)
    # monotone in q
    assert child.quantile(0.95) >= child.quantile(0.5) >= child.quantile(0.05)
    assert child.quantile(0.5) > 0


def test_histogram_bucket_geometry():
    assert BUCKET_BOUNDS[0] == pytest.approx(1e-4)
    ratios = [BUCKET_BOUNDS[i + 1] / BUCKET_BOUNDS[i]
              for i in range(len(BUCKET_BOUNDS) - 1)]
    assert all(r == pytest.approx(BUCKET_FACTOR) for r in ratios)


def test_render_parse_roundtrip():
    reg = MetricsRegistry()
    reg.counter("aisql_credits_total").inc(1.25, tenant="a")
    reg.histogram("aisql_queue_wait_seconds").observe(0.01, tenant="a")
    reg.gauge("aisql_storage_bytes").set(4096, state="peak")
    text = reg.render_prometheus()
    parsed = parse_prometheus_text(text)
    assert parsed["aisql_credits_total"] == [({"tenant": "a"}, 1.25)]
    assert ({"state": "peak"}, 4096.0) in parsed["aisql_storage_bytes"]
    # histogram exposition: cumulative buckets, _sum, _count
    assert parsed["aisql_queue_wait_seconds_count"][0][1] == 1.0
    les = [lb["le"] for lb, _ in
           parsed["aisql_queue_wait_seconds_bucket"]]
    assert les[-1] == "+Inf"
    counts = [v for _, v in parsed["aisql_queue_wait_seconds_bucket"]]
    assert counts == sorted(counts)          # cumulative


def test_parse_rejects_malformed_text():
    with pytest.raises(ValueError):
        parse_prometheus_text("this is { not a metric\n")


def test_locked_snapshot_smoke():
    import threading
    lock = threading.Lock()
    state = {"n": 41}
    out = locked_snapshot(lock, lambda: dict(state))
    assert out == {"n": 41} and not lock.locked()


# ---------------------------------------------------------------------------
# serving integration: reports are views over the registry
# ---------------------------------------------------------------------------


def test_serving_report_matches_registry():
    obs = Observability(clock=TickClock)
    eng = ServingEngine.simulated(
        small_catalog(), tenants={"a": TenantPolicy(), "b": TenantPolicy()},
        cfg=ServingConfig(workers=2, obs=obs))
    with eng:
        for t in ("a", "b", "a"):
            eng.submit(t, AI_SQL)
        eng.drain()
        rep = eng.report()
    reg = obs.registry
    q = reg.counter("aisql_queries_total")
    for name, tr in rep.tenants.items():
        assert q.labels(tenant=name, status="completed").value \
            == tr.completed
        assert reg.counter("aisql_credits_total").labels(
            tenant=name).value == pytest.approx(tr.credits_spent)
    # conservation: tenant credit children sum to the backends' meters
    assert rep.total_credits == pytest.approx(rep.backend_credits)
    # collectors expose the same scheduler counters the report reads
    snap = reg.snapshot()
    sched = {s["labels"]["event"]: s["value"]
             for s in snap["aisql_scheduler_events_total"]["series"]}
    assert sched["retries"] == rep.scheduler_retries
    # per-tenant percentiles come from the histogram children
    hist = reg.histogram("aisql_query_latency_seconds").labels(tenant="a")
    assert rep.tenants["a"].latency_p95_s == hist.quantile(0.95)
    # the trace ring holds each query's span tree under its query id
    assert len(obs.ring) == 3
    for qid in obs.ring.ids():
        assert obs.ring.get(qid)["kind"] == "query"


def test_serving_percentiles_survive_many_queries():
    """The old bounded sample window truncated history; histograms keep
    every observation with bounded relative error instead."""
    obs = Observability(enabled=False)
    eng = ServingEngine.simulated(
        small_catalog(), cfg=ServingConfig(workers=4, obs=obs))
    with eng:
        for _ in range(40):
            eng.submit("a", "SELECT t.id FROM t WHERE t.id < 3")
        eng.drain()
        rep = eng.report()
    t = rep.tenants["a"]
    assert t.completed == 40
    child = obs.registry.histogram(
        "aisql_query_latency_seconds").labels(tenant="a")
    assert child.count == 40
    assert t.latency_p95_s >= t.latency_p50_s > 0


def test_event_kinds_catalog_covers_emitted_events():
    obs = Observability(clock=TickClock)
    eng = make_engine(obs=obs, partitioned=True, fault_rate=0.2)
    eng.sql(AI_SQL)
    for span in walk_spans(eng.last_report.trace):
        for ev in span["events"]:
            assert ev["name"] in EVENT_KINDS, ev["name"]


def test_metric_families_catalog_is_wellformed():
    for name, (mtype, help_text, labels) in METRIC_FAMILIES.items():
        assert name.startswith("aisql_")
        assert mtype in ("counter", "gauge", "histogram")
        assert help_text
        assert isinstance(labels, tuple)
