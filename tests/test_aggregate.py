"""Hierarchical AI aggregation (Algorithm 1) + §5.4 short-circuit."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.aggregate import AggConfig, HierarchicalAggregator


class RecordingClient:
    """Fake CortexClient capturing every prompt (deterministic echo)."""

    def __init__(self):
        self.prompts = []

    def complete(self, prompts, *, model=None, max_tokens=64, metadata=None):
        self.prompts.extend(prompts)
        # echo a digest so combine/summarize carry provenance markers
        return [f"<state:{abs(hash(p)) % 997}>" for p in prompts]


def agg(texts, *, batch_tokens=128, ctx_tokens=192, short_circuit=True,
        instruction=None):
    client = RecordingClient()
    a = HierarchicalAggregator(client, AggConfig(
        batch_size_tokens=batch_tokens, context_window_tokens=ctx_tokens,
        short_circuit=short_circuit))
    out = a.aggregate(texts, instruction)
    return out, a.telemetry, client


def test_short_circuit_small_input():
    out, tel, client = agg(["tiny", "rows"])
    assert tel.short_circuited and tel.llm_calls == 1
    assert tel.extract_calls == 0 and tel.combine_calls == 0


def test_hierarchy_on_large_input():
    texts = [f"row {i} " + "x" * 300 for i in range(40)]
    out, tel, client = agg(texts)
    assert not tel.short_circuited
    assert tel.extract_calls > 1          # multiple row batches
    assert tel.summarize_calls == 1
    assert out.startswith("<state:")


def test_every_row_reaches_an_extract_call():
    texts = [f"UNIQ{i:04d} " + "y" * 200 for i in range(25)]
    _, tel, client = agg(texts)
    joined = "\n".join(p for p in client.prompts)
    for i in range(25):
        assert f"UNIQ{i:04d}" in joined


def test_instruction_threaded_through_all_phases():
    texts = [f"row {i} " + "z" * 300 for i in range(30)]
    _, _, client = agg(texts, instruction="find the top complaints")
    assert all("find the top complaints" in p for p in client.prompts)


def test_short_circuit_disabled_still_works():
    out, tel, _ = agg(["tiny", "rows"], short_circuit=False)
    assert not tel.short_circuited
    assert tel.extract_calls >= 1 and tel.summarize_calls == 1


@given(st.integers(1, 60), st.integers(20, 400))
@settings(max_examples=20, deadline=None)
def test_property_always_single_result_and_bounded_calls(n_rows, row_len):
    texts = [f"r{i} " + "a" * row_len for i in range(n_rows)]
    out, tel, _ = agg(texts, batch_tokens=96, ctx_tokens=128)
    assert isinstance(out, str) and out
    # calls are linear-ish in input size: extract ≤ rows, combine bounded
    assert tel.extract_calls <= n_rows + 1
    assert tel.llm_calls <= 3 * n_rows + 4
