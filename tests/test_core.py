"""AISQL core: parser, plan, optimizer, executor correctness."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (AisqlEngine, Catalog, CostModel, ExecConfig,
                        Optimizer, OptimizerConfig)
from repro.core import expr as E
from repro.core import plan as P
from repro.core import sqlparse
from repro.data import datasets as D
from repro.inference.api import make_simulated_client
from repro.tables.table import FileRef, Table


def small_catalog(n=40, seed=0):
    rng = np.random.default_rng(seed)
    t = Table({
        "id": np.arange(n),
        "score": rng.random(n),
        "category": rng.choice(["a", "b", "c"], n),
        "text": [f"row {i} text" for i in range(n)],
        "_truth": rng.random(n) < 0.4,
        "_difficulty": np.full(n, 0.05),
    }, name="t")
    return Catalog({"t": t})


# ---------------------------------------------------------------------------
# parser
# ---------------------------------------------------------------------------


def test_parse_ai_filter_prompt():
    q = sqlparse.parse(
        "SELECT * FROM reviews AS r WHERE r.id < 5 AND "
        "AI_FILTER(PROMPT('is {0} happy?', r.text), model => 'proxy-8b')")
    conjuncts = E.split_conjuncts(q.where)
    assert len(conjuncts) == 2
    ai = [c for c in conjuncts if isinstance(c, E.AIFilter)]
    assert len(ai) == 1 and ai[0].model == "proxy-8b"
    assert ai[0].prompt.template == "is {0} happy?"


def test_parse_join_group_limit():
    q = sqlparse.parse(
        "SELECT p.id, COUNT(*), AI_SUMMARIZE_AGG(p.abstract) FROM papers p "
        "JOIN imgs i ON p.id = i.id AND AI_FILTER(PROMPT('x {0}', i.f)) "
        "WHERE p.date BETWEEN 2010 AND 2015 GROUP BY p.id LIMIT 7")
    assert q.joins and q.group_by == ["p.id"] and q.limit == 7
    agg = [it.expr for it in q.select if isinstance(it.expr, E.AggCall)]
    assert {a.name for a in agg} == {"COUNT", "AI_SUMMARIZE_AGG"}


def test_parse_classify_labels():
    q = sqlparse.parse("SELECT AI_CLASSIFY(r.text, ['pos','neg']) FROM t r")
    c = q.select[0].expr
    assert isinstance(c, E.AIClassify) and c.labels == ("pos", "neg")


def test_parse_errors():
    with pytest.raises(SyntaxError):
        sqlparse.parse("SELECT FROM t")
    with pytest.raises(SyntaxError):
        sqlparse.parse("SELECT * FROM t WHERE ???")


BAD_SQL = [
    "SELECT FROM t",
    "SELECT * FROM t WHERE ???",
    "SELECT a FROM",
    "SELECT a FROM t LIMIT x",
    "SELECT a FROM t ORDER BY",
    "SELECT AI_EMBED(a, b) FROM t",
    "SELECT AI_SIMILARITY(a) FROM t",
    "SELECT PROMPT(a) FROM t",
    "SELECT AI_AGG(a, b) FROM t",
    "SELECT a FROM t WHERE model => 3",
]


@pytest.mark.parametrize("sql", BAD_SQL)
def test_parse_errors_are_structured(sql):
    """Every malformed query raises ParseError (a SyntaxError subclass
    carrying source position), never a builtin-only SyntaxError."""
    with pytest.raises(sqlparse.ParseError) as exc:
        sqlparse.parse(sql)
    err = exc.value
    assert isinstance(err, SyntaxError)
    assert err.pos is None or 0 <= err.pos <= len(sql)
    assert err.message


@pytest.mark.parametrize("sql", [
    "SELECT a FROM\n",
    "SELECT\n",
    "SELECT a FROM t WHERE\n",
    "SELECT a FROM t LIMIT\n\n",
])
def test_parse_error_at_trailing_newline(sql):
    """A truncated query ending in a newline puts the failure offset one
    line past ``splitlines()``; this used to crash ParseError.__init__
    with IndexError instead of raising the ParseError."""
    with pytest.raises(sqlparse.ParseError) as exc:
        sqlparse.parse(sql)
    err = exc.value
    # caret/str must render (clamped to the last line), not crash
    caret = err.caret()
    if caret:
        line, marker = caret.splitlines()
        assert marker.index("^") <= len(line)
    assert err.message in str(err)


def test_parse_error_caret_marks_position():
    with pytest.raises(sqlparse.ParseError) as exc:
        sqlparse.parse("SELECT a FROM t LIMIT x")
    err = exc.value
    caret = err.caret()
    line, marker = caret.splitlines()
    assert line == "SELECT a FROM t LIMIT x"
    assert marker.index("^") == err.pos
    assert line[err.pos] == "x"
    assert "position" in str(err)


def test_prompt_validation_survives_optimized_mode():
    """The PROMPT-template check was a bare assert that vanished under
    ``python -O``; it is now a ParseError (mirrors the Table fix)."""
    import os
    import subprocess
    import sys
    code = ("from repro.core.sqlparse import parse, ParseError\n"
            "try:\n"
            "    parse('SELECT PROMPT(a) FROM t')\n"
            "except ParseError:\n"
            "    print('OK')\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-O", "-c", code],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.stdout.strip() == "OK", out.stderr


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def _ai(template="p {0}", col="t.text"):
    return E.AIFilter(E.Prompt(template, (E.Column(col),)))


def test_reorder_puts_ai_last():
    cat = small_catalog()
    opt = Optimizer(cat)
    node = P.Filter(P.Scan("t", "t"),
                    (_ai(), E.BinOp("<", E.Column("t.id"), E.Literal(5))))
    out = opt.optimize(node)
    assert isinstance(out, P.Filter)
    assert not out.predicates[0].is_ai() and out.predicates[-1].is_ai()


def test_optimize_never_increases_est_cost():
    cat = small_catalog()
    for mode in ("ai_aware",):
        opt = Optimizer(cat, cfg=OptimizerConfig(mode=mode))
        cost = CostModel(cat)
        node = P.Filter(P.Scan("t", "t"),
                        (_ai(), E.InList(E.Column("t.category"), ("a",))))
        before = cost.est_llm_cost(node)
        after = cost.est_llm_cost(opt.optimize(node))
        assert after <= before + 1e-12


def test_join_placement_modes():
    left, right = D.nyt_join_pair(100, out_in_ratio=2.0)
    cat = Catalog({"ny_articles_v1": left, "ny_meta": right})
    sql = ("SELECT * FROM ny_articles_v1 AS a JOIN ny_meta AS m "
           "ON a.key = m.key AND AI_FILTER(PROMPT('x? {0}', a.body))")
    q = P.build_plan(sqlparse.parse(sql))
    cost = CostModel(cat)
    costs = {}
    for mode in ("always_pushdown", "always_pullup", "ai_aware"):
        opt = Optimizer(cat, cfg=OptimizerConfig(mode=mode))
        costs[mode] = cost.est_llm_cost(opt.optimize(q))
    assert costs["ai_aware"] <= min(costs["always_pushdown"],
                                    costs["always_pullup"]) + 1e-12


def test_semantic_join_rewrite_triggers():
    left, right, _ = D.join_tables("AGNEWS_100")
    cat = Catalog({"l": left, "r": right})
    sql = ("SELECT * FROM l JOIN r ON "
           "AI_FILTER(PROMPT('{0} is about {1}', l.content, r.label))")
    opt = Optimizer(cat)
    out = opt.optimize(P.build_plan(sqlparse.parse(sql)))

    def find(node):
        if isinstance(node, P.SemanticJoinClassify):
            return node
        for c in node.children():
            f = find(c)
            if f is not None:
                return f
        return None
    sj = find(out)
    assert sj is not None and sj.label_col == "r.label"


def test_semantic_join_rewrite_not_for_equi():
    cat = small_catalog()
    node = P.Join(P.Scan("t", "a"), P.Scan("t", "b"),
                  (("a.id", "b.id"),), ( _ai(col="a.text"),))
    out = Optimizer(cat).optimize(node)

    def has_sjc(n):
        return isinstance(n, P.SemanticJoinClassify) or any(
            has_sjc(c) for c in n.children())
    assert not has_sjc(out)


@given(st.lists(st.tuples(st.sampled_from(["cheap", "ai"]),
                          st.floats(0.05, 0.95)), min_size=1, max_size=5))
@settings(max_examples=30, deadline=None)
def test_reorder_rank_is_sorted(preds):
    """Property: optimizer output is sorted by rank = cost/(1-sel)."""
    cat = small_catalog()
    opt = Optimizer(cat)
    exprs = []
    for kind, sel in preds:
        if kind == "cheap":
            exprs.append(E.BinOp("<", E.Column("t.score"), E.Literal(sel)))
        else:
            exprs.append(_ai(f"pred {sel} {{0}}"))
    out = opt.optimize(P.Filter(P.Scan("t", "t"), tuple(exprs)))
    ranks = [opt.rank(p) for p in out.predicates]
    assert ranks == sorted(ranks)


# ---------------------------------------------------------------------------
# executor correctness (AI + relational paths)
# ---------------------------------------------------------------------------


def _engine(cat, **exec_kw):
    return AisqlEngine(cat, make_simulated_client(),
                       executor=ExecConfig(**exec_kw))


def test_relational_filter_matches_numpy():
    cat = small_catalog()
    eng = _engine(cat)
    out = eng.sql("SELECT * FROM t WHERE t.score < 0.5 AND t.category = 'a'")
    t = cat.table("t")
    expect = (t["score"] < 0.5) & (t["category"] == "a")
    assert out.num_rows == int(expect.sum())
    assert "_truth" not in " ".join(out.column_names)   # hidden cols excluded


def test_group_by_aggregates():
    cat = small_catalog()
    eng = _engine(cat)
    out = eng.sql("SELECT t.category, COUNT(*), AVG(t.score) "
                  "FROM t GROUP BY t.category")
    t = cat.table("t")
    for i in range(out.num_rows):
        c = out.column("t.category")[i]
        sel = t["category"] == c
        assert out.column("count")[i] == int(sel.sum())
        np.testing.assert_allclose(out.column("avg")[i],
                                   float(t["score"][sel].mean()))


def test_equi_join_matches_numpy():
    rng = np.random.default_rng(1)
    a = Table({"k": rng.integers(0, 10, 30), "x": np.arange(30)})
    b = Table({"k": rng.integers(0, 10, 20), "y": np.arange(20)})
    cat = Catalog({"a": a, "b": b})
    eng = _engine(cat)
    out = eng.sql("SELECT * FROM a JOIN b ON a.k = b.k")
    expect = sum(int((b["k"] == k).sum()) for k in a["k"])
    assert out.num_rows == expect
    assert (out.column("a.k") == out.column("b.k")).all()


def test_ai_filter_simulated_accuracy():
    cat = small_catalog(n=200)
    eng = _engine(cat)
    out = eng.sql("SELECT * FROM t WHERE "
                  "AI_FILTER(PROMPT('truthy? {0}', t.text))")
    t = cat.table("t")
    ids = set(out.column("t.id").tolist())
    pred = np.array([i in ids for i in t["id"]])
    acc = (pred == t["_truth"]).mean()
    assert acc > 0.9      # difficulty 0.05 oracle should be near-perfect


def test_adaptive_reorder_fixes_bad_static_order():
    """With the optimizer off and the AI predicate written first, runtime
    cost/selectivity stats must flip the order after the first chunk —
    and that flip must reduce LLM calls vs. a non-adaptive run."""
    n = 600
    cat = small_catalog(n=n, seed=3)
    sql = ("SELECT * FROM t WHERE "
           "AI_FILTER(PROMPT('truthy? {0}', t.text)) AND t.score < 0.3")
    calls = {}
    for adaptive in (False, True):
        client = make_simulated_client()
        eng = AisqlEngine(cat, client,
                          optimizer=OptimizerConfig(mode="none"),
                          executor=ExecConfig(adaptive_reorder=adaptive,
                                              chunk_rows=100))
        eng.sql(sql)
        calls[adaptive] = eng.last_report.ai_calls
        if adaptive:
            assert eng.exec.reorder_events, "expected a runtime reorder"
    assert calls[True] < calls[False]


def test_limit_and_projection():
    cat = small_catalog()
    eng = _engine(cat)
    out = eng.sql("SELECT t.id AS ident FROM t LIMIT 3")
    assert out.num_rows == 3 and out.column_names == ["ident"]


# ---------------------------------------------------------------------------
# table substrate properties
# ---------------------------------------------------------------------------


@given(st.lists(st.integers(0, 5), min_size=1, max_size=40),
       st.lists(st.integers(0, 5), min_size=1, max_size=40))
@settings(max_examples=40, deadline=None)
def test_hash_join_matches_nested_loop(lk, rk):
    a = Table({"k": np.asarray(lk), "x": np.arange(len(lk))})
    b = Table({"k": np.asarray(rk), "y": np.arange(len(rk))})
    joined = a.hash_join(b, "k", "k")
    expect = [(i, j) for i, x in enumerate(lk) for j, y in enumerate(rk)
              if x == y]
    assert joined.num_rows == len(expect)


def test_file_type_predicates():
    t = Table({"f": [FileRef("s3://a.png", "image/png"),
                     FileRef("s3://b.wav", "audio/wav"),
                     FileRef("s3://c.pdf", "application/pdf")]})
    cat = Catalog({"files": t})
    eng = _engine(cat)
    out = eng.sql("SELECT * FROM files AS f WHERE FL_IS_IMAGE(f.f)")
    assert out.num_rows == 1
    out = eng.sql("SELECT * FROM files AS f WHERE FL_IS_AUDIO(f.f)")
    assert out.num_rows == 1


@given(st.integers(0, 10 ** 6), st.integers(2, 5))
@settings(max_examples=25, deadline=None)
def test_optimizer_modes_preserve_relational_semantics(seed, nfilters):
    """Property: every optimizer mode returns the same row set for
    relational queries (plan rewrites must be semantics-preserving)."""
    rng = np.random.default_rng(seed)
    n = 60
    a = Table({"k": rng.integers(0, 8, n), "v": rng.random(n),
               "id": np.arange(n)})
    b = Table({"k": rng.integers(0, 8, 30), "w": rng.random(30)})
    cat = Catalog({"a": a, "b": b})
    conds = ["a.v < 0.7", "b.w >= 0.2", "a.k IN (1,2,3,4)",
             "a.v BETWEEN 0.1 AND 0.9", "b.k < 6"]
    where = " AND ".join(conds[:nfilters])
    sql = f"SELECT a.id, b.w FROM a JOIN b ON a.k = b.k WHERE {where}"
    results = {}
    for mode in ("none", "always_pushdown", "always_pullup", "ai_aware"):
        client = make_simulated_client()
        eng = AisqlEngine(cat, client, optimizer=OptimizerConfig(mode=mode))
        out = eng.sql(sql)
        results[mode] = sorted(zip(out.column("a.id").tolist(),
                                   out.column("b.w").tolist()))
    base = results["none"]
    for mode, rows in results.items():
        assert rows == base, f"mode {mode} changed the result set"
