"""End-to-end behaviour of the paper's system (parse -> optimize -> execute
over the Cortex-platform analogue), including the three §5 techniques."""
import numpy as np
import pytest

from repro.core import (AisqlEngine, Catalog, CascadeConfig, ExecConfig,
                        OptimizerConfig)
from repro.data import datasets as D
from repro.inference.api import make_engine_client, make_simulated_client


def test_paper_example_query_runs():
    """The §5.1 arXiv example: join + date filter + 2 AI filters + AI agg."""
    papers, images = D.papers_tables(n_papers=60, images_per_paper=3)
    cat = Catalog({"papers": papers, "paper_images": images})
    eng = AisqlEngine(cat, make_simulated_client())
    out = eng.sql("""
        SELECT AI_SUMMARIZE_AGG(p.abstract)
        FROM papers p JOIN paper_images i ON p.id = i.id
        WHERE p.date BETWEEN 2010 AND 2015 AND
        AI_FILTER(PROMPT('Abstract {0} discusses energy efficiency', p.abstract))
        AND AI_FILTER(PROMPT('Image {0} shows TPC-H results', i.image_file))
    """)
    assert out.num_rows == 1
    assert isinstance(out.row(0)[out.column_names[0]], str)
    # the optimizer must have pulled the (more expensive) image filter up
    assert any("pull-up" in t or "reorder" in t for t in eng.opt.trace)


def test_plan_b_beats_plan_a_llm_calls():
    """Fig 7: AI-aware placement must use fewer LLM calls than pushdown."""
    papers, images = D.papers_tables(n_papers=80, images_per_paper=4)
    cat = Catalog({"papers": papers, "paper_images": images})
    sql = """
        SELECT COUNT(*)
        FROM papers p JOIN paper_images i ON p.id = i.id
        WHERE p.date BETWEEN 2005 AND 2015 AND
        AI_FILTER(PROMPT('Abstract {0} discusses energy', p.abstract)) AND
        AI_FILTER(PROMPT('Image {0} shows TPC-H', i.image_file))
    """
    calls = {}
    for mode in ("always_pushdown", "ai_aware"):
        client = make_simulated_client()
        eng = AisqlEngine(cat, client, optimizer=OptimizerConfig(mode=mode))
        eng.sql(sql)
        calls[mode] = eng.last_report.ai_calls
    assert calls["ai_aware"] < calls["always_pushdown"]


def test_cascade_end_to_end_quality_and_delegation():
    t = D.cascade_table("NQ", rows=1500)
    cat = Catalog({"ds": t})
    eng = AisqlEngine(cat, make_simulated_client(),
                      executor=ExecConfig(use_cascade=True,
                                          cascade=CascadeConfig(seed=0)))
    out = eng.sql("SELECT * FROM ds AS d WHERE "
                  "AI_FILTER(PROMPT('answers? {0}', d.text))")
    ids = set(out.column("d.id").tolist())
    pred = np.array([i in ids for i in t.column("id")])
    m = D.binary_metrics(pred, t.column("_truth"))
    cascade = list(eng.cascades.values())[0]
    assert m["f1"] > 0.85
    assert cascade.stats.delegation_rate < 0.6
    # user-facing delegation report exists (paper: reported after each query)
    assert cascade.stats.rows == 1500


def test_join_rewrite_end_to_end_speed_and_quality():
    left, right, _ = D.join_tables("NASDAQ")
    cat = Catalog({"l": left, "r": right})
    sql = ("SELECT * FROM l JOIN r ON "
           f"AI_FILTER(PROMPT('{D.JOIN_PROMPTS['NASDAQ']}', l.content, r.label))")
    truth = D.true_pairs_of(left, right)
    res = {}
    for mode in ("none", "ai_aware"):
        client = make_simulated_client()
        eng = AisqlEngine(cat, client, optimizer=OptimizerConfig(mode=mode))
        out = eng.sql(sql)
        pairs = set(zip((int(x) for x in out.column("l.id")),
                        (str(x) for x in out.column("r.label"))))
        res[mode] = (eng.last_report.ai_calls, D.pair_metrics(pairs, truth))
    base_calls, base_m = res["none"]
    rw_calls, rw_m = res["ai_aware"]
    assert base_calls == 100 * 100          # O(L*R)
    assert rw_calls == 100                  # O(L)
    assert rw_m["f1"] > base_m["f1"]        # comparative reasoning wins


def test_classify_groupby_pipeline():
    t = D.cascade_table("SST2", rows=60)
    cat = Catalog({"reviews": t})
    eng = AisqlEngine(cat, make_simulated_client())
    out = eng.sql("""
        SELECT AI_CLASSIFY(PROMPT('sentiment of {0}', r.text),
                           ['positive','negative']) AS sentiment
        FROM reviews AS r
    """)
    assert set(np.unique(out.column("sentiment"))) <= {"positive", "negative"}


def test_real_jax_engine_end_to_end():
    """The whole stack over REAL model forward passes (smoke sizes)."""
    t = D.cascade_table("IMDB", rows=12)
    cat = Catalog({"reviews": t})
    client = make_engine_client(("proxy-8b",), replicas=1)
    eng = AisqlEngine(cat, client)
    eng.client.default_model = "proxy-8b"
    out = eng.sql("SELECT * FROM reviews AS r WHERE "
                  "AI_FILTER(PROMPT('good? {0}', r.text))")
    assert 0 <= out.num_rows <= 12
    assert eng.last_report.ai_calls == 12
    assert eng.last_report.ai_credits > 0


def test_multimodal_routing_costs_more():
    """FILE-typed predicates route to the multimodal tier (paper §5.1)."""
    papers, images = D.papers_tables(n_papers=30, images_per_paper=1)
    cat = Catalog({"imgs": images})
    client = make_simulated_client()
    eng = AisqlEngine(cat, client)
    eng.sql("SELECT * FROM imgs AS i WHERE "
            "AI_FILTER(PROMPT('chart? {0}', FL_IS_IMAGE(i.image_file)))")
    assert client.calls_by_model.get("qwen2-vl-7b", 0) > 0


def test_hybrid_join_multipass_improves_recall():
    """Beyond-paper (§8 future work): k-pass classify union recovers the
    recall the conservative rewrite sacrifices, at O(k*L) cost."""
    left, right, _ = D.join_tables("EURLEX")
    cat = Catalog({"l": left, "r": right})
    sql = ("SELECT * FROM l JOIN r ON "
           f"AI_FILTER(PROMPT('{D.JOIN_PROMPTS['EURLEX']}', "
           "l.content, r.label))")
    truth = D.true_pairs_of(left, right)
    recalls = {}
    for passes in (1, 3):
        client = make_simulated_client()
        eng = AisqlEngine(cat, client,
                          executor=ExecConfig(classify_passes=passes))
        out = eng.sql(sql)
        pairs = set(zip((int(x) for x in out.column("l.id")),
                        (str(x) for x in out.column("r.label"))))
        recalls[passes] = D.pair_metrics(pairs, truth)["recall"]
        assert eng.last_report.ai_calls == passes * 50   # O(k*L)
    assert recalls[3] > recalls[1] * 1.5
