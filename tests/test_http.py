"""HTTP front-end end-to-end tests — over a real socket.

Covers the wire error contract (401/400-with-position/429/503), NDJSON
streaming row-identical to the library call, billing conservation under
concurrent tenants, the NL→AISQL validation loop, and the
shutdown-under-load guarantees of `ServingEngine.close`.
"""
import json
import threading

import numpy as np
import pytest

from _serving_corpus import canon_rows, make_catalog

from repro.core import AisqlEngine, Catalog, ExecConfig
from repro.core.serving import (ServingConfig, ServingEngine,
                                TenantPolicy)
from repro.inference.api import make_simulated_client
from repro.serve import (AisqlHttpClient, AisqlHttpServer, HttpConfig,
                         NL2SQLOperator, SemanticModel,
                         SemanticValidationError, VerifiedQuery,
                         question_corpus)
from repro.serve.http import ERROR_CONTRACT, HttpStatusError, table_rows
from repro.tables.table import Table

SEED = 7
PART_CFG = ExecConfig(partitioned=True, partition_rows=32)


def small_catalog(n=160):
    rng = np.random.default_rng(SEED)
    return Catalog({"t": Table({
        "id": np.arange(n),
        "score": rng.random(n),
        "text": [f"row {i} text" for i in range(n)],
        "_truth": rng.random(n) < 0.5,
        "_difficulty": np.full(n, 0.05),
    }, name="t")})


def serving_engine(catalog, *, tenants=None, workers=4):
    return ServingEngine.simulated(
        catalog, seed=SEED, tenants=tenants,
        cfg=ServingConfig(workers=workers, executor=PART_CFG))


def default_model(catalog):
    model = SemanticModel.from_catalog(catalog)
    model.verified = [
        VerifiedQuery("high", "list the ids with score above one half",
                      "SELECT id, score FROM t WHERE score > 0.5"),
        VerifiedQuery("count", "count all rows",
                      "SELECT COUNT(*) FROM t"),
        VerifiedQuery("low", "list the ids with tiny scores",
                      "SELECT id FROM t WHERE score < 0.1"),
    ]
    return model


# ---------------------------------------------------------------------------
# wire error contract
# ---------------------------------------------------------------------------


def test_auth_failure_is_401():
    cat = small_catalog()
    with serving_engine(cat) as eng, AisqlHttpServer(
            eng, cfg=HttpConfig(tokens={"good": "acme"})) as srv:
        for token in (None, "bad"):
            client = AisqlHttpClient(srv.host, srv.port, token=token)
            with pytest.raises(HttpStatusError) as exc:
                client.query("SELECT id FROM t")
            assert exc.value.status == 401
            assert exc.value.code == "unauthorized"
        # the right token works
        ok = AisqlHttpClient(srv.host, srv.port, token="good")
        assert ok.query("SELECT COUNT(*) FROM t")["row_count"] == 1


def test_malformed_sql_is_400_with_position():
    cat = small_catalog()
    with serving_engine(cat) as eng, AisqlHttpServer(eng) as srv:
        client = AisqlHttpClient(srv.host, srv.port)
        with pytest.raises(HttpStatusError) as exc:
            client.query("SELECT id FROM t LIMIT x")
        err = exc.value.body["error"]
        assert exc.value.status == 400 and exc.value.code == "invalid_sql"
        assert err["pos"] == 23 and err["token"] == "x"
        line, caret = err["caret"].splitlines()
        assert caret.index("^") == err["pos"]
        assert line[err["pos"]] == "x"


def test_unknown_table_is_400():
    cat = small_catalog()
    with serving_engine(cat) as eng, AisqlHttpServer(eng) as srv:
        client = AisqlHttpClient(srv.host, srv.port)
        with pytest.raises(HttpStatusError) as exc:
            client.query("SELECT id FROM nope")
        assert exc.value.status == 400
        assert exc.value.code == "unknown_table"


def test_truncated_sql_with_trailing_newline_is_400():
    """A parse failure at the very end of a newline-terminated query
    used to crash ParseError.__init__ (IndexError) and surface as a
    500 internal instead of 400 invalid_sql."""
    cat = small_catalog()
    with serving_engine(cat) as eng, AisqlHttpServer(eng) as srv:
        client = AisqlHttpClient(srv.host, srv.port)
        for sql in ("SELECT id FROM\n", "SELECT id FROM t WHERE\n"):
            with pytest.raises(HttpStatusError) as exc:
                client.query(sql)
            assert exc.value.status == 400
            assert exc.value.code == "invalid_sql"


def test_internal_keyerror_is_500_not_unknown_table():
    """Only the catalog's own `UnknownTableError` is a client error;
    a bare KeyError from engine internals is a server bug (500) and
    must not leak as unknown_table."""
    from repro.core.cost import UnknownTableError
    from repro.serve.http import error_for

    assert error_for(KeyError("internal_state_key")).code == "internal"
    err = error_for(UnknownTableError("nope", {"t": None}))
    assert err.code == "unknown_table"
    assert "nope" in err.message and "t" in err.message


def test_budget_exhaustion_is_429():
    cat = small_catalog()
    tenants = {"tiny": TenantPolicy(credit_budget=0.0)}
    with serving_engine(cat, tenants=tenants) as eng, \
            AisqlHttpServer(eng, cfg=HttpConfig(
                tokens={"tok": "tiny"})) as srv:
        client = AisqlHttpClient(srv.host, srv.port, token="tok",
                                 max_retries=1)
        with pytest.raises(HttpStatusError) as exc:
            client.query("SELECT id FROM t")
        assert exc.value.status == 429
        assert exc.value.code == "budget_exhausted"


def test_rate_limit_is_429_and_client_honors_retry_after():
    cat = small_catalog()
    # burst of 1 at 5 qps: back-to-back queries must see a 429, and the
    # retrying client must absorb it by honouring Retry-After
    tenants = {"slow": TenantPolicy(queries_per_s=5.0, burst=1)}
    with serving_engine(cat, tenants=tenants) as eng, \
            AisqlHttpServer(eng, cfg=HttpConfig(
                tokens={"tok": "slow"})) as srv:
        impatient = AisqlHttpClient(srv.host, srv.port, token="tok",
                                    max_retries=0)
        patient = AisqlHttpClient(srv.host, srv.port, token="tok",
                                  max_retries=8)
        saw_429 = False
        for _ in range(6):
            try:
                impatient.query("SELECT COUNT(*) FROM t")
            except HttpStatusError as e:
                assert e.status == 429 and e.code == "throttled"
                saw_429 = True
                break
        assert saw_429, "rapid-fire queries never hit the rate limit"
        # the patient client makes progress through the same limit by
        # waiting out at least one Retry-After
        out = patient.query("SELECT COUNT(*) FROM t")
        assert out["row_count"] == 1
        assert patient.throttled_retries >= 1, \
            "client never needed a retry (limit not exercised)"


def test_post_close_query_is_503():
    cat = small_catalog()
    eng = serving_engine(cat)
    srv = AisqlHttpServer(eng).start()
    client = AisqlHttpClient(srv.host, srv.port)
    assert client.healthz() == {"status": "ok"}
    eng.close()
    with pytest.raises(HttpStatusError) as exc:
        client.query("SELECT id FROM t")
    assert exc.value.status == 503
    assert exc.value.code == "shutting_down"
    srv.stop()


def test_unknown_endpoint_is_404():
    cat = small_catalog()
    with serving_engine(cat) as eng, AisqlHttpServer(eng) as srv:
        client = AisqlHttpClient(srv.host, srv.port)
        with pytest.raises(HttpStatusError) as exc:
            client._request("GET", "/v1/nope").read()
        assert exc.value.status == 404


# ---------------------------------------------------------------------------
# streaming
# ---------------------------------------------------------------------------


STREAM_QUERIES = [
    "SELECT id, score FROM t WHERE score > 0.5",
    "SELECT id FROM t WHERE score > 0.25 LIMIT 17",
    "SELECT COUNT(*) FROM t",
    "SELECT id FROM t WHERE score > 2.0",          # empty result
]


@pytest.mark.parametrize("sql", STREAM_QUERIES)
def test_streamed_rows_identical_to_library_call(sql):
    cat = small_catalog()
    # library reference: a private engine over the same seeded simulator
    ref_engine = AisqlEngine(small_catalog(),
                             make_simulated_client(seed=SEED),
                             executor=PART_CFG)
    ref_table = ref_engine.sql(sql)
    _, ref_rows = table_rows(ref_table)
    ref_bytes = json.dumps(ref_rows).encode()
    with serving_engine(cat) as eng, AisqlHttpServer(eng) as srv:
        client = AisqlHttpClient(srv.host, srv.port)
        events = list(client.query_stream(sql))
    assert events[0]["kind"] == "schema"
    assert events[0]["columns"] == list(ref_table.column_names)
    assert events[-1]["kind"] == "summary"
    rows = [e["values"] for e in events if e["kind"] == "row"]
    assert events[-1]["row_count"] == len(rows)
    # byte-identical once both sides render through the same JSON rule
    assert json.dumps(rows).encode() == ref_bytes


def test_streamed_equals_buffered_over_http():
    cat = small_catalog()
    sql = "SELECT id, score FROM t WHERE score > 0.5"
    with serving_engine(cat) as eng, AisqlHttpServer(eng) as srv:
        client = AisqlHttpClient(srv.host, srv.port)
        buffered = client.query(sql)
        streamed = [e["values"] for e in client.query_stream(sql)
                    if e["kind"] == "row"]
    assert buffered["rows"] == streamed


def test_stream_delivers_multiple_batches():
    cat = small_catalog()
    with serving_engine(cat) as eng:
        ticket = eng.submit("default",
                            "SELECT id FROM t WHERE score > 0.5",
                            stream=True)
        batches = list(ticket.batches(timeout=30.0))
        assert len(batches) > 1          # partition_rows=32 over 160 rows
        total = sum(b.num_rows for b in batches)
        assert total == ticket.result().num_rows


def test_stream_error_surfaces_as_status():
    cat = small_catalog()
    with serving_engine(cat) as eng, AisqlHttpServer(eng) as srv:
        client = AisqlHttpClient(srv.host, srv.port)
        with pytest.raises(HttpStatusError) as exc:
            list(client.query_stream("SELECT id FROM t LIMIT x"))
        assert exc.value.status == 400


def test_mid_stream_failure_emits_terminal_error_chunk(monkeypatch):
    """A failure after the chunked response has started (here: while
    emitting the summary) must finish the body with a terminal
    ``{"kind": "error"}`` event — not call send_response again, which
    would put a second status line inside the chunked body and corrupt
    the keep-alive framing."""
    from repro.serve.http import _Handler

    def boom(self, ticket, count):
        raise RuntimeError("summary exploded")

    monkeypatch.setattr(_Handler, "_emit_summary", boom)
    cat = small_catalog()
    with serving_engine(cat) as eng, AisqlHttpServer(eng) as srv:
        client = AisqlHttpClient(srv.host, srv.port)
        events = []
        with pytest.raises(HttpStatusError) as exc:
            for ev in client.query_stream("SELECT id FROM t"):
                events.append(ev)
        assert exc.value.code == "internal"
        # the rows before the failure were delivered intact
        assert events and events[0]["kind"] == "schema"
        assert sum(e["kind"] == "row" for e in events) == 160
        # and the framing survived: the same keep-alive connection
        # still serves a well-formed follow-up response
        monkeypatch.undo()
        assert client.query("SELECT COUNT(*) FROM t")["row_count"] == 1


def test_client_does_not_retry_posts_on_connection_error():
    """A POST whose connection dies mid-exchange may already have been
    executed (and billed) server-side; resubmitting it would double-run
    the query.  Connection errors are only retried for GETs."""
    attempts = []

    class _DeadConn:
        def request(self, *a, **k):
            attempts.append(1)
            raise ConnectionError("wire died")

        def close(self):
            pass

    client = AisqlHttpClient("127.0.0.1", 1, max_retries=3)
    client._connection = lambda: _DeadConn()
    with pytest.raises(ConnectionError):
        client.query("SELECT id FROM t")
    assert len(attempts) == 1            # surfaced, not resubmitted
    attempts.clear()
    with pytest.raises(ConnectionError):
        client.healthz()
    assert len(attempts) == 4            # GETs retry max_retries times


# ---------------------------------------------------------------------------
# concurrent tenants: billing conservation over the wire
# ---------------------------------------------------------------------------


def test_concurrent_tenant_billing_conserved():
    cat = make_catalog()
    sqls = [
        "SELECT a.id FROM articles a WHERE "
        "AI_FILTER(PROMPT('broad topic? {0}', a.headline))",
        "SELECT r.id FROM reviews r WHERE "
        "AI_FILTER(PROMPT('positive? {0}', r.text))",
        "SELECT a.id, a.headline FROM articles a WHERE a.id < 40",
    ]
    tenants = ["alpha", "beta", "gamma"]
    tokens = {f"tok-{t}": t for t in tenants}
    with ServingEngine.simulated(
            cat, seed=SEED,
            cfg=ServingConfig(workers=6, executor=PART_CFG)) as eng, \
            AisqlHttpServer(eng, cfg=HttpConfig(tokens=tokens)) as srv:
        errors = []

        def drive(tenant):
            client = AisqlHttpClient(srv.host, srv.port,
                                     token=f"tok-{tenant}")
            try:
                for sql in sqls:
                    out = client.query(sql)
                    assert out["tenant"] == tenant
            except Exception as e:       # surfaced after the join
                errors.append((tenant, e))

        threads = [threading.Thread(target=drive, args=(t,))
                   for t in tenants]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert not errors, errors
        eng.drain()
        report = eng.report()
        client = AisqlHttpClient(srv.host, srv.port,
                                 token="tok-alpha")
        wire = client.report()
    # every tenant completed everything it submitted
    for t in tenants:
        tr = wire["tenants"][t]
        assert tr["queries"] == len(sqls)
        assert tr["completed"] == len(sqls)
        assert tr["failed"] == 0
    # conservation: tenant meters sum to the dispatch spend, and the
    # wire report agrees with the library report
    total = sum(wire["tenants"][t]["credits_spent"] for t in tenants)
    assert total == pytest.approx(wire["total_credits"])
    assert wire["total_credits"] == pytest.approx(report.total_credits)
    if report.backend_credits is not None:
        assert wire["total_credits"] == \
            pytest.approx(wire["backend_credits"])


# ---------------------------------------------------------------------------
# shutdown under load
# ---------------------------------------------------------------------------


def test_close_is_idempotent_and_drains_in_flight_work():
    cat = make_catalog()
    eng = ServingEngine.simulated(
        cat, seed=SEED, cfg=ServingConfig(workers=4, executor=PART_CFG))
    tickets = [eng.submit("acme",
                          "SELECT a.id FROM articles a WHERE "
                          "AI_FILTER(PROMPT('broad topic? {0}', "
                          "a.headline))")
               for _ in range(12)]
    # concurrent closes: every caller returns only once shutdown is done
    closers = [threading.Thread(target=eng.close) for _ in range(4)]
    for c in closers:
        c.start()
    eng.close()
    for c in closers:
        c.join(timeout=30.0)
        assert not c.is_alive()
    # every pre-close ticket completed (drain-then-stop)
    for tk in tickets:
        assert tk.done()
        assert tk.result().num_rows >= 0
    # post-close submit fails fast with a clean error, never hangs
    with pytest.raises(RuntimeError, match="closed"):
        eng.submit("acme", "SELECT a.id FROM articles a")
    # and close stays a no-op
    eng.close()


def test_close_races_submit_without_stranding_tickets():
    """A submit racing close() must either be admitted (and then
    complete) or fail fast — a stranded ticket would hang result()."""
    cat = small_catalog()
    for _ in range(5):
        eng = serving_engine(cat, workers=2)
        out = {}

        def submitter():
            try:
                out["ticket"] = eng.submit("acme", "SELECT COUNT(*) FROM t")
            except RuntimeError as e:
                out["error"] = e

        th = threading.Thread(target=submitter)
        th.start()
        eng.close()
        th.join(timeout=10.0)
        assert not th.is_alive()
        if "ticket" in out:
            # admitted -> must resolve, never hang
            assert out["ticket"].result(timeout=10.0).num_rows == 1
        else:
            assert "closed" in str(out["error"])


def test_streaming_ticket_terminates_on_error():
    cat = small_catalog()
    with serving_engine(cat) as eng:
        ticket = eng.submit("acme", "SELECT id FROM t LIMIT x",
                            stream=True)
        with pytest.raises(SyntaxError):
            list(ticket.batches(timeout=10.0))
        with pytest.raises(SyntaxError):
            ticket.result(timeout=1.0)


# ---------------------------------------------------------------------------
# semantic model + NL2SQL
# ---------------------------------------------------------------------------


def test_semantic_model_validates_against_live_catalog():
    cat = small_catalog()
    model = default_model(cat)
    model.validate(cat)                 # round-trips clean
    # unknown table
    bad = default_model(cat)
    bad.tables[0].name = "ghost"
    with pytest.raises(SemanticValidationError, match="ghost"):
        bad.validate(cat)
    # unknown column
    bad2 = default_model(cat)
    bad2.tables[0].columns[0].name = "nope"
    with pytest.raises(SemanticValidationError, match="nope"):
        bad2.validate(cat)
    # verified query referencing a missing column
    bad3 = default_model(cat)
    bad3.verified.append(VerifiedQuery(
        "broken", "q", "SELECT missing_col FROM t"))
    with pytest.raises(SemanticValidationError, match="missing_col"):
        bad3.validate(cat)


def test_semantic_model_round_trips_through_json():
    cat = small_catalog()
    model = default_model(cat)
    model.tables[0].description = "the table"
    model.tables[0].columns[0].synonyms = ("identifier",)
    back = SemanticModel.from_json(model.to_json())
    assert back.to_dict() == model.to_dict()
    back.validate(cat)


def test_nl2sql_compiles_corpus_and_matches_grounded_rows():
    cat = small_catalog()
    model = default_model(cat)
    client = make_simulated_client(seed=SEED)
    op = NL2SQLOperator(model, cat, client, max_attempts=3)
    ref_engine = AisqlEngine(cat, make_simulated_client(seed=SEED),
                             executor=PART_CFG)
    corpus = question_corpus(model, 20, seed=1)
    compiled = 0
    for question, truth in corpus:
        sql = op.compile(question)       # NL2SQLError would fail the test
        compiled += 1
        got = canon_rows(ref_engine.sql(sql))
        want = canon_rows(ref_engine.sql(truth.sql))
        assert got == want, (question, sql)
    assert compiled == len(corpus)


def test_nl2sql_rejects_invalid_sql_with_validation_error():
    cat = small_catalog()
    model = default_model(cat)
    op = NL2SQLOperator(model, cat, make_simulated_client(seed=SEED))
    with pytest.raises(SemanticValidationError):
        op.validate_sql("SELECT ghost_col FROM t")
    with pytest.raises(SyntaxError):
        op.validate_sql("SELECT id FROM t LIMIT x")


def test_nl2sql_over_http_executes_grounded_query():
    cat = small_catalog()
    model = default_model(cat)
    op = NL2SQLOperator(model, cat, make_simulated_client(seed=SEED),
                        max_attempts=3)
    with serving_engine(cat) as eng, \
            AisqlHttpServer(eng, nl2sql=op) as srv:
        client = AisqlHttpClient(srv.host, srv.port)
        out = client.nl2sql("count all rows", execute=True)
        assert out["rows"] == [[len(cat.tables["t"].column("id"))]]
        # the semantic model is served too
        served = client.semantic_model()
        assert [t["name"] for t in served["tables"]] == ["t"]


def test_nl2sql_unanswerable_question_is_422():
    cat = small_catalog()
    # an operator whose model's only example is a broken query cannot
    # compile anything: the simulator answers with it verbatim and the
    # validation loop rejects every attempt
    broken = SemanticModel.from_catalog(cat)
    broken.verified = [VerifiedQuery(
        "bad", "show the ghost data", "SELECT ghost FROM t")]
    op = NL2SQLOperator(broken, cat, make_simulated_client(seed=SEED),
                        max_attempts=2, validate_model=False)
    with serving_engine(cat) as eng, \
            AisqlHttpServer(eng, nl2sql=op) as srv:
        client = AisqlHttpClient(srv.host, srv.port)
        with pytest.raises(HttpStatusError) as exc:
            client.nl2sql("show the ghost data")
        assert exc.value.status == 422
        assert exc.value.code == "nl2sql_rejected"


# ---------------------------------------------------------------------------
# report plumbing
# ---------------------------------------------------------------------------


def test_wire_report_matches_library_report():
    cat = small_catalog()
    with serving_engine(cat) as eng, AisqlHttpServer(eng) as srv:
        client = AisqlHttpClient(srv.host, srv.port)
        client.query("SELECT COUNT(*) FROM t")
        eng.drain()
        wire = client.report()
        lib = eng.report()
    assert wire["queries"] == lib.queries
    assert wire["total_credits"] == pytest.approx(lib.total_credits)
    assert set(wire["tenants"]) == set(lib.tenants)


def test_error_contract_statuses_are_wellformed():
    for code, (status, desc) in ERROR_CONTRACT.items():
        assert 400 <= status <= 599, code
        assert desc


def test_replay_over_http_matches_direct_replay():
    """`tools/replay.py --http` is observationally the direct replay:
    identical per-tenant row digests (same canonicalization) and
    conserved total credits on a fault-free trace."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))
    from replay import (TraceConfig, build_catalog, generate_trace,
                        replay, replay_http)
    cfg = TraceConfig(seed=11, sessions=30, tenants=2, rows=256)
    trace = generate_trace(cfg)
    direct = replay(trace, build_catalog(cfg), workers=4, seed=11)
    wire = replay_http(trace, build_catalog(cfg), workers=4, seed=11)
    assert direct.failed_queries == wire.failed_queries == 0
    for t in direct.per_tenant:
        assert direct.per_tenant[t].rows_sha256 == \
            wire.per_tenant[t].rows_sha256, t
    assert abs(direct.total_credits - wire.total_credits) < 1e-9
