"""Shared fixtures for the serving/fault harnesses: one catalog builder
and one result-canonicalization rule, so the concurrency differential
(test_serving) and the fault differential (test_faults) compare rows by
identical rules."""
from repro.core import Catalog
from repro.data import datasets as D

SEED = 0
ROWS = 160          # < min_rows_for_pilot: keeps runs fast + deterministic


def make_catalog():
    return Catalog({
        "articles": D.skewed_articles(ROWS, seed=3),
        "reviews": D.cascade_table("IMDB", rows=ROWS, seed=1),
    })


def canon_rows(table):
    """Order-insensitive canonical form of a result table."""
    cols = table.column_names
    return sorted(tuple(str(table.column(c)[i]) for c in cols)
                  for i in range(table.num_rows))
