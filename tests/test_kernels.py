"""Per-kernel correctness: Pallas (interpret=True on CPU) vs jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.decode_attention.ops import flash_decode
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rwkv6_scan.ops import rwkv6_scan
from repro.kernels.similarity_topk.ops import similarity_topk


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
       jnp.bfloat16: dict(rtol=5e-2, atol=5e-2)}


# ---------------------------------------------------------------------------
# flash attention (prefill)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 128, 4, 4, 64),      # MHA
    (2, 256, 8, 2, 64),      # GQA 4:1
    (1, 384, 4, 1, 128),     # MQA, non-pow2 seq
    (2, 128, 4, 4, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(B, S, H, KV, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, S, H, hd), dtype)
    k = _rand(ks[1], (B, S, KV, hd), dtype)
    v = _rand(ks[2], (B, S, KV, hd), dtype)
    out = flash_attention(q, k, v, causal=True, impl="interpret")
    ref = flash_attention(q, k, v, causal=True, impl="reference")
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


@pytest.mark.parametrize("window", [64, 128])
def test_flash_attention_windowed(window):
    B, S, H, hd = 1, 256, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = _rand(ks[0], (B, S, H, hd), jnp.float32)
    k = _rand(ks[1], (B, S, H, hd), jnp.float32)
    v = _rand(ks[2], (B, S, H, hd), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          impl="interpret")
    ref = flash_attention(q, k, v, causal=True, window=window,
                          impl="reference")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,Smax,H,KV,hd", [
    (2, 512, 4, 4, 64),
    (3, 1024, 8, 2, 64),
    (1, 768, 4, 1, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode(B, Smax, H, KV, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    q = _rand(ks[0], (B, 1, H, hd), dtype)
    kc = _rand(ks[1], (B, Smax, KV, hd), dtype)
    vc = _rand(ks[2], (B, Smax, KV, hd), dtype)
    lengths = jnp.asarray(
        np.random.default_rng(0).integers(1, Smax, B), jnp.int32)
    out = flash_decode(q, kc, vc, lengths, impl="interpret")
    ref = flash_decode(q, kc, vc, lengths, impl="reference")
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOL[dtype])


def test_flash_decode_matches_model_decode_attention():
    """Kernel contract == the model's decode attention core."""
    from repro.models.attention import decode_attention
    B, Smax, H, KV, hd = 2, 256, 8, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(ks[0], (B, 1, H, hd), jnp.float32)
    kc = _rand(ks[1], (B, Smax, KV, hd), jnp.float32)
    vc = _rand(ks[2], (B, Smax, KV, hd), jnp.float32)
    lengths = jnp.asarray([100, 256], jnp.int32)
    out = flash_decode(q, kc, vc, lengths, impl="interpret")
    ref = decode_attention(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,W", [(2, 64, 256), (1, 200, 512), (3, 33, 128)])
def test_rglru_scan(B, S, W):
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    a = jax.nn.sigmoid(_rand(ks[0], (B, S, W), jnp.float32))
    b = _rand(ks[1], (B, S, W), jnp.float32)
    h0 = _rand(ks[2], (B, W), jnp.float32)
    hs, hT = rglru_scan(a, b, h0, impl="interpret")
    hs_r, hT_r = rglru_scan(a, b, h0, impl="reference")
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hs_r),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hT_r),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# RWKV6 scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,S,H,hd", [(1, 64, 2, 32), (2, 96, 4, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_scan(B, S, H, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(5), 6)
    r = _rand(ks[0], (B, S, H, hd), dtype)
    k = _rand(ks[1], (B, S, H, hd), dtype)
    v = _rand(ks[2], (B, S, H, hd), dtype)
    w = jax.nn.sigmoid(_rand(ks[3], (B, S, H, hd), jnp.float32)) * 0.5 + 0.45
    u = _rand(ks[4], (H, hd), jnp.float32)
    s0 = _rand(ks[5], (B, H, hd, hd), jnp.float32) * 0.1
    o, sT = rwkv6_scan(r, k, v, w.astype(dtype), u, s0, impl="interpret")
    o_r, sT_r = rwkv6_scan(r, k, v, w.astype(dtype), u, s0, impl="reference")
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_r),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_r),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# similarity top-k (the semantic index's scoring kernel)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("Q,N,D,k,bq,bn", [
    (13, 201, 48, 5, 8, 64),      # nothing aligns: every pad path hit
    (32, 512, 64, 17, 16, 128),
    (1, 1000, 32, 1, 8, 256),     # single query, k=1
    (64, 64, 128, 64, 64, 64),    # k == N, one block each way
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_similarity_topk_parity(Q, N, D, k, bq, bn, dtype):
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    q = _rand(ks[0], (Q, D), dtype)
    c = _rand(ks[1], (N, D), dtype)
    v_i, i_i = similarity_topk(q, c, k, impl="interpret",
                               block_q=bq, block_n=bn)
    v_r, i_r = similarity_topk(q, c, k, impl="reference")
    np.testing.assert_array_equal(np.asarray(i_i), np.asarray(i_r))
    np.testing.assert_allclose(np.asarray(v_i), np.asarray(v_r),
                               **TOL[jnp.float32])


def test_similarity_topk_k_exceeds_corpus():
    """k > N pads the tail with -inf values and index -1."""
    ks = jax.random.split(jax.random.PRNGKey(8), 2)
    q = _rand(ks[0], (3, 16), jnp.float32)
    c = _rand(ks[1], (4, 16), jnp.float32)
    v_i, i_i = similarity_topk(q, c, 7, impl="interpret",
                               block_q=2, block_n=2)
    v_r, i_r = similarity_topk(q, c, 7, impl="reference")
    np.testing.assert_array_equal(np.asarray(i_i), np.asarray(i_r))
    assert (np.asarray(i_i)[:, 4:] == -1).all()
    assert np.isneginf(np.asarray(v_i)[:, 4:]).all()


def test_similarity_topk_values_descending_and_self_match():
    ks = jax.random.split(jax.random.PRNGKey(9), 1)
    c = _rand(ks[0], (50, 24), jnp.float32)
    v, i = similarity_topk(c[:10], c, 5, impl="interpret",
                           block_q=4, block_n=16)
    v = np.asarray(v)
    assert (np.diff(v, axis=1) <= 1e-6).all()          # descending
    np.testing.assert_array_equal(np.asarray(i)[:, 0], np.arange(10))
    np.testing.assert_allclose(v[:, 0], 1.0, atol=1e-5)  # cos(x, x) = 1


def test_rwkv6_state_chaining():
    """Running two halves with carried state == one full run."""
    B, S, H, hd = 1, 64, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    r = _rand(ks[0], (B, S, H, hd), jnp.float32)
    k = _rand(ks[1], (B, S, H, hd), jnp.float32)
    v = _rand(ks[2], (B, S, H, hd), jnp.float32)
    w = jax.nn.sigmoid(_rand(ks[3], (B, S, H, hd), jnp.float32))
    u = _rand(ks[4], (H, hd), jnp.float32)
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    o_full, sT_full = rwkv6_scan(r, k, v, w, u, s0, impl="reference")
    half = S // 2
    o1, s_mid = rwkv6_scan(r[:, :half], k[:, :half], v[:, :half],
                           w[:, :half], u, s0, impl="reference")
    o2, sT = rwkv6_scan(r[:, half:], k[:, half:], v[:, half:],
                        w[:, half:], u, s_mid, impl="reference")
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                               np.asarray(o_full), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_full),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# paged flash decode (block-table gather + flash_decode)
# ---------------------------------------------------------------------------


def _paged_from_dense(kc, vc, bs, rng):
    """Split dense [B,Smax] caches into a scrambled block pool + tables."""
    B, Smax, KV, hd = kc.shape
    nb = Smax // bs
    NB = B * nb + 1                     # block 0 left as scratch
    perm = rng.permutation(np.arange(1, NB))
    tables = perm.reshape(B, nb).astype(np.int32)
    kp = np.zeros((NB, bs, KV, hd), kc.dtype)
    vp = np.zeros((NB, bs, KV, hd), vc.dtype)
    for b in range(B):
        for j in range(nb):
            kp[tables[b, j]] = kc[b, j * bs:(j + 1) * bs]
            vp[tables[b, j]] = vc[b, j * bs:(j + 1) * bs]
    return jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(tables)


@pytest.mark.parametrize("B,Smax,bs,H,KV,hd", [
    (2, 256, 32, 4, 4, 64),
    (3, 512, 64, 8, 2, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_matches_dense(B, Smax, bs, H, KV, hd, dtype):
    """flash_decode over a scrambled block pool == dense cache, bitwise."""
    from repro.kernels.decode_attention.ops import flash_decode_paged
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = _rand(ks[0], (B, 1, H, hd), dtype)
    kc = _rand(ks[1], (B, Smax, KV, hd), dtype)
    vc = _rand(ks[2], (B, Smax, KV, hd), dtype)
    rng = np.random.default_rng(7)
    lengths = jnp.asarray(rng.integers(1, Smax, B), jnp.int32)
    kp, vp, tables = _paged_from_dense(np.asarray(kc), np.asarray(vc),
                                       bs, rng)
    out = flash_decode_paged(q, kp, vp, tables, lengths, impl="reference")
    ref = flash_decode(q, kc, vc, lengths, impl="reference")
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(ref, np.float32))


def test_paged_decode_gather_reconstructs_dense():
    from repro.kernels.decode_attention.ops import gather_kv_blocks
    B, Smax, bs, KV, hd = 2, 128, 16, 2, 32
    kc = _rand(jax.random.PRNGKey(8), (B, Smax, KV, hd), jnp.float32)
    rng = np.random.default_rng(8)
    kp, _, tables = _paged_from_dense(np.asarray(kc), np.asarray(kc),
                                      bs, rng)
    np.testing.assert_array_equal(
        np.asarray(gather_kv_blocks(kp, tables)), np.asarray(kc))


def test_paged_decode_ragged_short_lengths():
    """Rows shorter than one block attend only to their valid prefix."""
    from repro.kernels.decode_attention.ops import flash_decode_paged
    B, Smax, bs, H, KV, hd = 4, 128, 32, 4, 1, 32
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = _rand(ks[0], (B, 1, H, hd), jnp.float32)
    kc = _rand(ks[1], (B, Smax, KV, hd), jnp.float32)
    vc = _rand(ks[2], (B, Smax, KV, hd), jnp.float32)
    lengths = jnp.asarray([1, 5, 32, 128], jnp.int32)
    kp, vp, tables = _paged_from_dense(np.asarray(kc), np.asarray(vc),
                                       bs, np.random.default_rng(9))
    out = flash_decode_paged(q, kp, vp, tables, lengths, impl="reference")
    ref = flash_decode(q, kc, vc, lengths, impl="reference")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
