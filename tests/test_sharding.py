"""Sharding rules + the trip-count-aware HLO cost analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import hlo_cost as H
from repro.launch import sharding as shd
from repro.launch.mesh import make_host_mesh


def host_mesh():
    return make_host_mesh()     # (data=1, model=1) on CPU


def test_param_spec_rules():
    mesh = host_mesh()
    # on a 1x1 mesh every axis divides; check the axis CHOICE
    cases = {
        "embed/w": ((1024, 64), P("model", "data")),
        "lm_head/w": ((64, 1024), P("data", "model")),
        "periods/b0/attn/wq": ((4, 64, 128), P(None, "data", "model")),
        "periods/b0/attn/wk": ((4, 64, 32), P(None, "data", None)),
        "periods/b0/attn/wo": ((4, 128, 64), P(None, "model", "data")),
        "periods/b0/mlp/wi": ((4, 64, 256), P(None, "data", "model")),
        "periods/b0/mlp/wo": ((4, 256, 64), P(None, "model", "data")),
        "periods/b0/moe/experts/wi": ((4, 8, 64, 128),
                                      P(None, "model", "data", None)),
        "periods/b0/moe/router/w": ((4, 64, 8), P(None, "data", None)),
        "periods/b0/rec/in_x": ((4, 64, 128), P(None, "data", "model")),
        "periods/b0/rwkv/tmix/wr": ((4, 64, 64), P(None, "data", "model")),
        "periods/b0/rwkv/tmix/wo": ((4, 64, 64), P(None, "model", "data")),
        "final_norm/scale": ((64,), P()),
    }
    for key, (shape, want) in cases.items():
        got = shd.param_spec(mesh, key, shape, fsdp="data", tp="model")
        assert got == want, (key, got, want)


def test_param_spec_divisibility_fallback():
    mesh = jax.make_mesh((1,), ("model",))
    # vocab 51865 doesn't divide 16; on this mesh size 1 divides everything,
    # so emulate by checking the helper directly
    spec = shd._fit(mesh, (51865, 512), ["model", None])
    assert spec == P("model", None)   # size-1 axis always divides
    # emulate a 16-way axis via raw check
    assert 51865 % 16 != 0


def test_moment_specs_match_param_specs():
    mesh = host_mesh()
    p = shd.param_spec(mesh, "periods/b0/mlp/wi", (4, 64, 256),
                       fsdp="data", tp="model")
    m = shd.param_spec(mesh, "mu/periods/b0/mlp/wi", (4, 64, 256),
                       fsdp="data", tp="model")
    assert p == m


def test_batch_and_cache_specs():
    mesh = host_mesh()
    batch = {"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)}
    bs = shd.batch_specs(mesh, batch)
    assert bs["tokens"] == P("data", None)
    cache = {"periods": {"b0": {"k": jax.ShapeDtypeStruct(
        (4, 8, 128, 2, 16), jnp.bfloat16)}},
        "len": jax.ShapeDtypeStruct((8,), jnp.int32)}
    cs = shd.cache_specs_tree(mesh, cache)
    assert cs["periods"]["b0"]["k"] == P(None, "data", "model", None, None)
    assert cs["len"] == P("data")


def test_state_specs_cover_all_leaves():
    from repro.launch.steps import _abstract_state
    from repro.models import model_zoo
    mesh = host_mesh()
    for arch in ("minitron-8b", "qwen2-moe-a2.7b", "recurrentgemma-9b",
                 "rwkv6-1.6b", "whisper-base"):
        model = model_zoo.build(arch, smoke=True)
        state = _abstract_state(model)
        specs = shd.state_specs(mesh, state)
        n_leaves = len(jax.tree.leaves(state))
        n_specs = len(jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P)))
        assert n_specs == n_leaves


# ---------------------------------------------------------------------------
# HLO cost analyzer
# ---------------------------------------------------------------------------


def test_hlo_cost_counts_scan_trip_counts():
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=8)
        return h
    x = jnp.zeros((64, 128), jnp.float32)
    w = jnp.zeros((128, 128), jnp.float32)
    scan_cost = H.analyze_hlo(jax.jit(f).lower(x, w).compile().as_text())

    def g(x, w):
        for _ in range(8):
            x = jnp.tanh(x @ w)
        return x
    unrolled = H.analyze_hlo(jax.jit(g).lower(x, w).compile().as_text())
    dot_flops = 8 * 2 * 64 * 128 * 128
    assert scan_cost.flops >= dot_flops
    assert abs(scan_cost.flops - unrolled.flops) / unrolled.flops < 0.1
    assert abs(scan_cost.bytes - unrolled.bytes) / unrolled.bytes < 0.5


def test_hlo_cost_nested_scans():
    def f(x, w):
        def outer(h, _):
            def inner(g, _):
                return jnp.tanh(g @ w), None
            g, _ = jax.lax.scan(inner, h, None, length=4)
            return g, None
        h, _ = jax.lax.scan(outer, x, None, length=3)
        return h
    x = jnp.zeros((64, 128), jnp.float32)
    w = jnp.zeros((128, 128), jnp.float32)
    cost = H.analyze_hlo(jax.jit(f).lower(x, w).compile().as_text())
    assert cost.flops >= 12 * 2 * 64 * 128 * 128


def test_hlo_cost_shape_parse():
    assert H.shape_elems_bytes("bf16[2,3]{1,0}") == (6, 12)
    assert H.shape_elems_bytes("(f32[4], s32[2])") == (6, 24)
    assert H.shape_elems_bytes("pred[]")[0] == 1


def test_wire_bytes_model():
    # ring all-reduce moves 2(n-1)/n of the buffer
    assert H._wire_bytes("all-reduce", 1000, 1000, 4) == pytest.approx(1500)
    assert H._wire_bytes("all-gather", 1600, 100, 16) == pytest.approx(1500)
    assert H._wire_bytes("all-reduce", 1000, 1000, 1) == 0.0


def test_sharded_decode_path_matches_dense():
    """With the shard context armed (1-device host mesh), the shard-local
    KV write + logsumexp-combined decode must equal the dense path."""
    import numpy as np
    from repro.kernels.decode_attention import ops as dec
    from repro.models import attention as attn
    from repro.models import shardctx

    mesh = make_host_mesh()
    B, Smax, H, KV, hd = 2, 64, 8, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    ck = jax.random.normal(ks[1], (B, Smax, KV, hd), jnp.float32)
    cv = jax.random.normal(ks[2], (B, Smax, KV, hd), jnp.float32)
    kn = jax.random.normal(ks[3], (B, 1, KV, hd), jnp.float32)
    vn = jax.random.normal(ks[4], (B, 1, KV, hd), jnp.float32)
    lengths = jnp.asarray([10, 33], jnp.int32)

    # dense reference
    ck_d, cv_d = attn.write_kv(ck, cv, kn, vn, lengths - 1)
    out_d = attn.decode_attention(q, ck_d, cv_d, lengths)

    shardctx.enable(mesh)
    try:
        assert attn.seq_sharded_decode_ready(ck)
        with mesh:
            out_s, ck_s, cv_s = attn.sharded_cache_decode(
                q, ck, cv, kn, vn, lengths)
    finally:
        shardctx.disable()
    np.testing.assert_allclose(np.asarray(ck_s), np.asarray(ck_d),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out_s), np.asarray(out_d),
                               rtol=1e-4, atol=1e-4)


def test_head_padding_is_exact(monkeypatch):
    """Zero-padded head sharding (28 -> 32 style) must not change outputs."""
    import dataclasses
    import numpy as np
    from repro.configs import base as cfgs
    from repro.models import blocks

    # head count (7) that a multi-way model axis wouldn't divide
    cfg = dataclasses.replace(
        cfgs.get_smoke_config("qwen2-vl-7b"), num_heads=7, num_kv_heads=1,
        d_model=7 * 16, head_dim=16, mrope_sections=())
    key = jax.random.PRNGKey(0)
    params = blocks.block_init("attn", key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32)[None], (2, 8))
    ctx = blocks.Ctx(cfg=cfg, mode="train", positions=pos)
    y_plain, _, _ = blocks.block_apply("attn", params, x, ctx)
    # force the padded path (as a 2-way model axis would)
    monkeypatch.setattr(blocks, "_padded_heads", lambda c: 8)
    y_padded, _, _ = blocks.block_apply("attn", params, x, ctx)
    np.testing.assert_allclose(np.asarray(y_padded), np.asarray(y_plain),
                               rtol=1e-5, atol=1e-5)
