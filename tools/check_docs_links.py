#!/usr/bin/env python
"""Docs link checker: relative markdown links must resolve.

Scans README.md and docs/*.md for ``[text](target)`` links and verifies
that every relative target (optionally with a ``#anchor``) exists on
disk.  External (``http(s)://``) and pure-anchor links are skipped.
Also verifies that file paths mentioned in backticks under docs/ exist
when they look like repo paths (``src/…``, ``benchmarks/…``, …).

Exit code 0 when everything resolves; 1 otherwise (one line per broken
link).  Run from anywhere:

    python tools/check_docs_links.py
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
PATH_RE = re.compile(r"`((?:src|benchmarks|tests|docs|tools|examples|"
                     r"results)/[A-Za-z0-9_./-]+)`")


def doc_files():
    out = [os.path.join(REPO, "README.md")]
    docs = os.path.join(REPO, "docs")
    if os.path.isdir(docs):
        out += [os.path.join(docs, f) for f in sorted(os.listdir(docs))
                if f.endswith(".md")]
    return out


def check_file(path: str) -> list:
    errors = []
    base = os.path.dirname(path)
    rel = os.path.relpath(path, REPO)
    with open(path) as f:
        text = f.read()
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "#", "mailto:")):
            continue
        target_path = target.split("#", 1)[0]
        if not target_path:
            continue
        resolved = os.path.normpath(os.path.join(base, target_path))
        if not os.path.exists(resolved):
            errors.append(f"{rel}: broken link -> {target}")
    for target in PATH_RE.findall(text):
        resolved = os.path.join(REPO, target.rstrip("/"))
        if not os.path.exists(resolved):
            errors.append(f"{rel}: missing path reference -> `{target}`")
    return errors


def main() -> int:
    errors = []
    for path in doc_files():
        errors += check_file(path)
    for e in errors:
        print(e)
    if not errors:
        print(f"docs links OK ({len(doc_files())} files checked)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
