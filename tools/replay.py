"""Deterministic workload-replay harness for the serving engine.

Production-shaped load, reproducible to the byte: a seeded trace
generator emits thousands of tenant *sessions* (each a short run of
queries) with the skew the paper's deployment data describes —

  * **Zipf-hot queries**: each tenant draws from a small hot template
    pool with a Zipf(``zipf_a``) rank distribution, so a handful of
    dashboards dominate;
  * **shared subexpressions**: a global template pool is sampled by
    every tenant (identical SQL text across tenants), feeding the
    pipeline's cross-query/cross-tenant cache;
  * **LIMIT-heavy dashboards**: a configurable fraction of sessions are
    dashboard-shaped (ORDER BY … LIMIT k), exercising partitioned
    early termination;
  * **fault bursts**: replayed against a backend whose transient-fault
    process clusters in attempt-time windows
    (``SimulatedBackend.fault_burst_every/len``).

`replay` drives a `ServingEngine` over the trace and distils sustained
QPS, p50/p95 latency, dedup / cross-query cache-hit rates, per-tenant
row digests + billing, retry counters and storage (spill) telemetry.

Determinism contract (what `tests/test_replay.py` pins):

  * result rows and per-tenant row digests are bit-identical for a
    given trace seed — across repeat runs, worker counts, chunk sizes
    and spill budgets (simulator results are content-keyed; retries
    re-serve identical answers);
  * *total* credits are identical across worker counts whenever the
    cache never evicts (every unique request is dispatched — and billed
    — exactly once, whatever the schedule);
  * *per-tenant* billing is additionally identical across worker
    counts when ``tenant_salt=True`` **and** ``billing_pure=True``:
    salted prompts make dedup groups tenant-pure, and dropping the
    AI_SIMILARITY shape removes column-text embed requests — shared
    infrastructure whose cost lands on whichever tenant's query happens
    to dispatch first (totals conserve; attribution is
    schedule-dependent, as in any real shared-cache deployment).  The
    replay executor disables pilot sampling, adaptive reordering and
    partition lookahead, and the pipeline cache has no TTL, so no other
    billing path is schedule-dependent;
  * retry *counters* are only deterministic at ``workers=1`` — batch
    composition under concurrency is schedule-dependent, so the fault
    die meets different batches (results still agree).

Trace-format details are documented in docs/storage.md.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import Catalog, ExecConfig
from repro.core.serving import ServingConfig, ServingEngine
from repro.inference.pipeline import PipelineConfig
from repro.tables.chunked import ChunkedTable
from repro.tables.spill import SpillManager
from repro.tables.table import Table


# ---------------------------------------------------------------------------
# trace generation
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TraceConfig:
    """Knobs of the seeded trace generator (all derived state is a pure
    function of ``seed``)."""
    seed: int = 0
    tenants: int = 8
    sessions: int = 1000                 # tenant sessions in the trace
    queries_per_session: Tuple[int, int] = (1, 3)   # inclusive range
    zipf_a: float = 1.4                  # hot-template skew exponent
    hot_pool: int = 10                   # per-tenant hot templates
    shared_pool: int = 8                 # cross-tenant shared templates
    shared_frac: float = 0.45            # P(draw from the shared pool)
    dashboard_frac: float = 0.4          # LIMIT-heavy dashboard sessions
    tenant_salt: bool = False            # salt prompts with the tenant
    billing_pure: bool = False           # drop shared-embed (similarity) shapes
    # catalog shape (build_catalog reads these)
    rows: int = 2048                     # events table rows
    chunk_rows: int = 256
    users: int = 32                      # dimension-table rows


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    session: int
    tenant: str
    kind: str                            # "dashboard" | "adhoc"
    sql: str


_TOPICS = ("databases", "weather", "finance", "sports", "security",
           "travel", "cooking", "music", "science", "politics",
           "health", "gaming")


def build_catalog(cfg: TraceConfig, *,
                  budget_bytes: Optional[int] = None,
                  chunked: bool = True) -> Catalog:
    """The replay catalog: a chunk-backed ``events`` table (optionally
    under a spill byte budget) plus a small ``users`` dimension."""
    rng = np.random.default_rng(cfg.seed + 7)
    n = cfg.rows
    cols = {
        "id": np.arange(n),
        "gid": np.arange(n) % cfg.users,
        "val": rng.random(n),
        "cat": rng.choice(["a", "b", "c", "d"], n),
        "text": [f"[e:{i}] event log about "
                 f"{_TOPICS[i % len(_TOPICS)]} item {i}" for i in range(n)],
        "_truth": rng.random(n) < 0.3,
        "_difficulty": np.full(n, 0.05),
    }
    if chunked:
        spill = SpillManager(budget_bytes=budget_bytes)
        events: Table = ChunkedTable(cols, name="events",
                                     chunk_rows=cfg.chunk_rows, spill=spill)
    else:
        events = Table(cols, name="events")
    users = Table({"k": np.arange(cfg.users),
                   "w": rng.random(cfg.users)}, name="users")
    return Catalog({"events": events, "users": users})


def _dashboard_templates(rng: np.random.Generator, topics: List[str]
                         ) -> List[str]:
    """LIMIT-heavy dashboard shapes; ``{salt}`` is filled per tenant.
    Every template carries exactly one AI construct, so billing never
    depends on a stats-informed predicate ordering (see the determinism
    contract above)."""
    out = []
    for t in topics:
        k = int(rng.choice([5, 10, 20]))
        out.append(
            "SELECT e.id, e.val FROM events AS e WHERE "
            f"AI_FILTER(PROMPT('is this about {t}{{salt}}? {{{{0}}}}', "
            f"e.text)) ORDER BY e.val DESC LIMIT {k}")
    return out


def _adhoc_templates(rng: np.random.Generator, topics: List[str],
                     billing_pure: bool = False) -> List[str]:
    out = []
    shapes = (["filter", "agg", "join"] if billing_pure
              else ["filter", "similarity", "agg", "join"])
    for i, t in enumerate(topics):
        shape = shapes[i % len(shapes)]
        x = round(float(rng.uniform(0.3, 0.8)), 2)
        thr = round(float(rng.uniform(0.3, 0.5)), 2)
        if shape == "filter":
            out.append(
                "SELECT e.id, e.cat FROM events AS e WHERE "
                f"e.val < {x} AND AI_FILTER(PROMPT('does this mention "
                f"{t}{{salt}}? {{{{0}}}}', e.text))")
        elif shape == "similarity":
            out.append(
                "SELECT e.id FROM events AS e WHERE "
                f"AI_SIMILARITY(e.text, '{t} report{{salt}}') > {thr}")
        elif shape == "agg":
            out.append(
                "SELECT e.cat, COUNT(*) FROM events AS e WHERE "
                f"AI_FILTER(PROMPT('related to {t}{{salt}}? {{{{0}}}}', "
                f"e.text)) GROUP BY e.cat")
        else:
            out.append(
                "SELECT e.id, u.w FROM events AS e JOIN users AS u ON "
                f"e.gid = u.k WHERE e.val < {x} AND "
                f"AI_FILTER(PROMPT('about {t}{{salt}}? {{{{0}}}}', "
                f"e.text))")
    return out


def _pools(cfg: TraceConfig) -> Tuple[Dict[str, Dict[str, List[str]]],
                                      Dict[str, List[str]]]:
    """(per-tenant pools, shared pool), each keyed dashboard/adhoc."""
    shared_rng = np.random.default_rng([cfg.seed, 1])
    n_topics = len(_TOPICS)
    shared_topics = [f"{_TOPICS[i % n_topics]} (shared {i})"
                     for i in range(cfg.shared_pool)]
    shared = {
        "dashboard": _dashboard_templates(shared_rng, shared_topics),
        "adhoc": _adhoc_templates(shared_rng, shared_topics,
                                  cfg.billing_pure),
    }
    tenants: Dict[str, Dict[str, List[str]]] = {}
    for ti in range(cfg.tenants):
        name = f"t{ti:02d}"
        rng = np.random.default_rng([cfg.seed, 2, ti])
        topics = [f"{_TOPICS[int(rng.integers(n_topics))]} (team {ti}.{i})"
                  for i in range(cfg.hot_pool)]
        tenants[name] = {
            "dashboard": _dashboard_templates(rng, topics),
            "adhoc": _adhoc_templates(rng, topics, cfg.billing_pure),
        }
    return tenants, shared


def _zipf_rank(rng: np.random.Generator, a: float, n: int) -> int:
    return min(int(rng.zipf(a)), n) - 1


def generate_trace(cfg: TraceConfig) -> List[TraceEvent]:
    """The replay trace: a pure function of ``cfg`` (seed included)."""
    tenants, shared = _pools(cfg)
    rng = np.random.default_rng([cfg.seed, 3])
    lo, hi = cfg.queries_per_session
    events: List[TraceEvent] = []
    for s in range(cfg.sessions):
        tenant = f"t{int(rng.integers(cfg.tenants)):02d}"
        salt = f" [{tenant}]" if cfg.tenant_salt else ""
        kind = ("dashboard" if rng.random() < cfg.dashboard_frac
                else "adhoc")
        for _ in range(int(rng.integers(lo, hi + 1))):
            pool = (shared if rng.random() < cfg.shared_frac
                    else tenants[tenant])[kind]
            sql = pool[_zipf_rank(rng, cfg.zipf_a, len(pool))]
            events.append(TraceEvent(
                session=s, tenant=tenant, kind=kind,
                sql=sql.format(salt=salt)))
    return events


# ---------------------------------------------------------------------------
# replay
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TenantOutcome:
    """One tenant's deterministic digest of a replay run."""
    queries: int
    failed: int
    rows_sha256: str                 # digest over per-query canonical rows
    credits: float
    dispatched_calls: int


@dataclasses.dataclass
class ReplayReport:
    queries: int
    sessions: int
    tenants: int
    wall_s: float
    qps: float                       # completed queries / wall second
    latency_p50_s: float
    latency_p95_s: float
    queue_p95_s: float
    total_credits: float
    backend_credits: Optional[float]
    submitted_requests: int
    dispatched_requests: int
    dedup_hit_rate: float            # dedup+cache hits / submitted
    cross_query_hit_rate: float      # cross-session hits / submitted
    retries: int                     # pipeline batch re-dispatches
    scheduler_retries: int
    faults_injected: int
    timeouts_injected: int
    failed_queries: int
    per_tenant: Dict[str, TenantOutcome]
    storage: Optional[Dict[str, int]]   # aggregated spill counters

    def render(self) -> str:
        lines = [
            f"-- replay: {self.queries} queries / {self.sessions} sessions"
            f" / {self.tenants} tenants in {self.wall_s:.2f}s"
            f" -> {self.qps:.1f} qps",
            f"-- latency: p50 {self.latency_p50_s * 1e3:.1f}ms, "
            f"p95 {self.latency_p95_s * 1e3:.1f}ms "
            f"(queue p95 {self.queue_p95_s * 1e3:.1f}ms)",
            f"-- cache: {self.dedup_hit_rate:.1%} dedup hits, "
            f"{self.cross_query_hit_rate:.1%} cross-query "
            f"({self.dispatched_requests}/{self.submitted_requests} "
            f"dispatched)",
            f"-- faults: {self.faults_injected} injected, "
            f"{self.timeouts_injected} timeouts, {self.retries} pipeline "
            f"retries, {self.scheduler_retries} scheduler retries, "
            f"{self.failed_queries} failed queries",
        ]
        if self.storage is not None:
            s = self.storage
            lines.append(
                f"-- storage: peak {s['peak_bytes']} tracked bytes, "
                f"{s['spill_events']} spills, {s['reload_events']} reloads")
        return "\n".join(lines)


def _digest_rows(h: "hashlib._Hash", table: Table) -> None:
    cols = sorted(table.column_names)
    rows = sorted(tuple(str(table.column(c)[i]) for c in cols)
                  for i in range(table.num_rows))
    h.update(repr(rows).encode())
    h.update(b"\x1e")


def _digest_wire_rows(h: "hashlib._Hash", cols: List[str],
                      rows: List[list]) -> None:
    """`_digest_rows` over the HTTP JSON row encoding.  JSON round-trips
    every scalar the serving layer emits (int/float/bool/str) to a value
    whose ``str()`` matches the numpy original, so a query digested here
    equals the same query digested from the library-call `Table`."""
    order = sorted(range(len(cols)), key=lambda i: cols[i])
    canon = sorted(tuple(str(r[i]) for i in order) for r in rows)
    h.update(repr(canon).encode())
    h.update(b"\x1e")


def replay(trace: List[TraceEvent], catalog: Catalog, *,
           workers: int = 4, seed: int = 0,
           fault_rate: float = 0.0, timeout_rate: float = 0.0,
           fault_burst_every: int = 0, fault_burst_len: int = 0,
           replicas: int = 1, partition_rows: int = 256,
           max_retries: int = 6, cache_size: int = 1 << 17,
           semindex=None, obs=None) -> ReplayReport:
    """Drive ``trace`` through a simulated `ServingEngine` and distil a
    `ReplayReport`.  Executor and pipeline knobs are pinned to the
    schedule-independent configuration (see the module docstring).
    Pass an `Observability` (ideally with ``clock=TickClock``) to keep
    the per-query span trees and the metrics registry around after the
    run — ``--trace-out`` / ``--metrics-out`` dump them."""
    cfg = ServingConfig(
        workers=workers,
        pipeline=PipelineConfig(cache_size=cache_size, cache_ttl_s=None,
                                max_retries=max_retries,
                                retry_backoff_s=0.001,
                                retry_backoff_cap_s=0.05),
        executor=ExecConfig(partitioned=True,
                            partition_rows=partition_rows,
                            partition_lookahead=1,
                            adaptive_reorder=False, pilot_rows=0),
        obs=obs)
    eng = ServingEngine.simulated(
        catalog, seed=seed, fault_rate=fault_rate,
        timeout_rate=timeout_rate, fault_burst_every=fault_burst_every,
        fault_burst_len=fault_burst_len, replicas=replicas, cfg=cfg,
        semindex=semindex)
    try:
        t0 = time.perf_counter()
        tickets = [(ev, eng.submit(ev.tenant, ev.sql)) for ev in trace]
        eng.drain()
        wall = time.perf_counter() - t0
        digests = {}
        failed_by_tenant: Dict[str, int] = {}
        for ev, ticket in tickets:
            h = digests.get(ev.tenant)
            if h is None:
                h = digests[ev.tenant] = hashlib.sha256()
            err = ticket.exception()
            if err is not None:
                failed_by_tenant[ev.tenant] = \
                    failed_by_tenant.get(ev.tenant, 0) + 1
                h.update(f"ERR:{type(err).__name__}".encode())
                h.update(b"\x1e")
            else:
                _digest_rows(h, ticket.result())
        return _assemble_report(trace, digests, failed_by_tenant, eng, wall)
    finally:
        eng.close()


def _assemble_report(trace: List[TraceEvent],
                     digests: Dict[str, "hashlib._Hash"],
                     failed_by_tenant: Dict[str, int],
                     eng: ServingEngine, wall: float) -> ReplayReport:
    rep = eng.report()
    faults = timeouts = 0
    seen = set()
    for reps in eng.scheduler._replicas.values():
        for b in reps:
            if id(b) not in seen and hasattr(b, "faults_injected"):
                faults += b.faults_injected
                timeouts += b.timeouts_injected
                seen.add(id(b))
    per_tenant = {}
    for name in sorted(digests):
        tr = rep.tenants[name]
        per_tenant[name] = TenantOutcome(
            queries=tr.queries,
            failed=failed_by_tenant.get(name, 0),
            rows_sha256=digests[name].hexdigest(),
            credits=tr.credits_spent,
            dispatched_calls=tr.dispatched_calls)
    submitted = max(rep.submitted_requests, 1)
    return ReplayReport(
        queries=len(trace),
        sessions=len({ev.session for ev in trace}),
        tenants=len(digests),
        wall_s=wall,
        qps=len(trace) / wall if wall > 0 else 0.0,
        latency_p50_s=rep.latency_p50_s,
        latency_p95_s=rep.latency_p95_s,
        queue_p95_s=rep.queue_wait_p95_s,
        total_credits=rep.total_credits,
        backend_credits=rep.backend_credits,
        submitted_requests=rep.submitted_requests,
        dispatched_requests=rep.dispatched_requests,
        dedup_hit_rate=rep.dedup_hits / submitted,
        cross_query_hit_rate=rep.cross_query_hits / submitted,
        retries=rep.retries,
        scheduler_retries=rep.scheduler_retries,
        faults_injected=faults,
        timeouts_injected=timeouts,
        failed_queries=sum(failed_by_tenant.values()),
        per_tenant=per_tenant,
        storage=rep.storage)


def replay_http(trace: List[TraceEvent], catalog: Catalog, *,
                workers: int = 4, seed: int = 0,
                fault_rate: float = 0.0, timeout_rate: float = 0.0,
                fault_burst_every: int = 0, fault_burst_len: int = 0,
                replicas: int = 1, partition_rows: int = 256,
                max_retries: int = 6, cache_size: int = 1 << 17,
                semindex=None, obs=None) -> ReplayReport:
    """`replay`, but over the wire: boots `AisqlHttpServer` on the same
    pinned engine configuration and drives each tenant's slice of the
    trace in order through a persistent authenticated HTTP client.  Row
    digests use the same canonicalization as the direct path, so on a
    fault-free trace `replay` and `replay_http` report identical
    per-tenant ``rows_sha256`` and conserved credits."""
    import threading

    from repro.serve import AisqlHttpClient, AisqlHttpServer, HttpConfig

    cfg = ServingConfig(
        workers=workers,
        pipeline=PipelineConfig(cache_size=cache_size, cache_ttl_s=None,
                                max_retries=max_retries,
                                retry_backoff_s=0.001,
                                retry_backoff_cap_s=0.05),
        executor=ExecConfig(partitioned=True,
                            partition_rows=partition_rows,
                            partition_lookahead=1,
                            adaptive_reorder=False, pilot_rows=0),
        obs=obs)
    eng = ServingEngine.simulated(
        catalog, seed=seed, fault_rate=fault_rate,
        timeout_rate=timeout_rate, fault_burst_every=fault_burst_every,
        fault_burst_len=fault_burst_len, replicas=replicas, cfg=cfg,
        semindex=semindex)
    tenant_names = sorted({ev.tenant for ev in trace})
    by_tenant: Dict[str, List[TraceEvent]] = {t: [] for t in tenant_names}
    for ev in trace:
        by_tenant[ev.tenant].append(ev)
    http_cfg = HttpConfig(tokens={f"tok-{t}": t for t in tenant_names},
                          throttle=False)
    digests = {t: hashlib.sha256() for t in tenant_names}
    failed_by_tenant: Dict[str, int] = {}
    lock = threading.Lock()
    try:
        with AisqlHttpServer(eng, cfg=http_cfg) as srv:
            def drive(tenant: str) -> None:
                client = AisqlHttpClient(srv.host, srv.port,
                                         token=f"tok-{tenant}",
                                         timeout=300.0)
                h = digests[tenant]
                for ev in by_tenant[tenant]:
                    try:
                        out = client.query(ev.sql)
                    except Exception as err:
                        with lock:
                            failed_by_tenant[tenant] = \
                                failed_by_tenant.get(tenant, 0) + 1
                        code = getattr(err, "code", type(err).__name__)
                        h.update(f"ERR:{code}".encode())
                        h.update(b"\x1e")
                    else:
                        _digest_wire_rows(h, out["columns"], out["rows"])
                client.close()

            t0 = time.perf_counter()
            threads = [threading.Thread(target=drive, args=(t,))
                       for t in tenant_names]
            for th in threads:
                th.start()
            for th in threads:
                th.join()
            eng.drain()
            wall = time.perf_counter() - t0
            return _assemble_report(trace, digests, failed_by_tenant,
                                    eng, wall)
    finally:
        eng.close()


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sessions", type=int, default=1000)
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--rows", type=int, default=2048)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--fault-rate", type=float, default=0.0)
    ap.add_argument("--burst-every", type=int, default=0)
    ap.add_argument("--burst-len", type=int, default=0)
    ap.add_argument("--budget-bytes", type=int, default=None)
    ap.add_argument("--http", action="store_true",
                    help="drive the trace over the HTTP front-end "
                         "instead of direct ServingEngine submission")
    ap.add_argument("--trace-out", metavar="DIR", default=None,
                    help="dump each recent query's span tree as a "
                         "chrome://tracing JSON file into DIR")
    ap.add_argument("--metrics-out", metavar="FILE", default=None,
                    help="dump the final metrics-registry snapshot "
                         "(every family, JSON) to FILE")
    args = ap.parse_args(argv)
    cfg = TraceConfig(seed=args.seed, sessions=args.sessions,
                      tenants=args.tenants, rows=args.rows)
    trace = generate_trace(cfg)
    catalog = build_catalog(cfg, budget_bytes=args.budget_bytes)
    obs = None
    if args.trace_out or args.metrics_out:
        from repro.obs import Observability, TickClock

        # deterministic tick clock: the dumped span trees are a pure
        # function of the trace seed, like every other replay artifact
        obs = Observability(clock=TickClock,
                            ring_size=max(len(trace), 1))
    fn = replay_http if args.http else replay
    rep = fn(trace, catalog, workers=args.workers, seed=args.seed,
             fault_rate=args.fault_rate,
             fault_burst_every=args.burst_every,
             fault_burst_len=args.burst_len, obs=obs)
    print(rep.render())
    if obs is not None and args.trace_out:
        import json
        import os

        from repro.obs import to_chrome

        os.makedirs(args.trace_out, exist_ok=True)
        for qid in obs.ring.ids():
            path = os.path.join(args.trace_out, f"{qid}.trace.json")
            with open(path, "w") as f:
                json.dump(to_chrome(obs.ring.get(qid)), f)
        print(f"-- traces: {len(obs.ring)} chrome://tracing files "
              f"in {args.trace_out}")
    if obs is not None and args.metrics_out:
        import json

        with open(args.metrics_out, "w") as f:
            json.dump(obs.registry.snapshot(), f, indent=2,
                      sort_keys=True)
        print(f"-- metrics: registry snapshot at {args.metrics_out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
