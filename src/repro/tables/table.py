"""Columnar relational substrate for the AISQL engine.

A deliberately small but real column-store: typed columns (including the
paper's FILE type for multimodal references, §3.6), vectorised filters,
hash joins, group-by, and statistics (NDV, avg token length) used by the
AI-aware optimizer.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class FileRef:
    """The FILE data type (§3.6): URI + metadata for an object in storage."""
    uri: str
    mime_type: str = "application/octet-stream"
    size: int = 0
    created_at: str = ""

    def is_image(self) -> bool:
        return self.mime_type.startswith("image/")

    def is_audio(self) -> bool:
        return self.mime_type.startswith("audio/")

    def __str__(self) -> str:  # used when spliced into prompts
        return self.uri


def fl_is_image(f: Any) -> bool:
    return isinstance(f, FileRef) and f.is_image()


def fl_is_audio(f: Any) -> bool:
    return isinstance(f, FileRef) and f.is_audio()


_COLUMN_TYPES = ("int", "float", "str", "bool", "file")


def _typed_column(values, t: str) -> np.ndarray:
    """Convert ``values`` to the canonical numpy representation of type
    ``t`` (int64 / float64 / bool, object array for str/file)."""
    if t == "int":
        return np.asarray(values, dtype=np.int64)
    if t == "float":
        return np.asarray(values, dtype=np.float64)
    if t == "bool":
        return np.asarray(values, dtype=bool)
    vals = list(values)
    arr = np.empty(len(vals), dtype=object)
    for i, x in enumerate(vals):   # keeps tuple cells 1-D
        arr[i] = x
    return arr


def _infer_type(values) -> str:
    for v in values:
        if v is None:
            continue
        if isinstance(v, FileRef):
            return "file"
        if isinstance(v, bool):
            return "bool"
        if isinstance(v, (int, np.integer)):
            return "int"
        if isinstance(v, (float, np.floating)):
            return "float"
        return "str"
    return "str"


class Table:
    """Immutable columnar table."""

    def __init__(self, columns: Dict[str, Sequence[Any]],
                 types: Optional[Dict[str, str]] = None,
                 name: str = ""):
        if not columns:
            raise ValueError("empty table")
        lens = {len(v) for v in columns.values()}
        if len(lens) != 1:
            raise ValueError(f"ragged columns: { {k: len(v) for k, v in columns.items()} }")
        self.name = name
        self._cols: Dict[str, np.ndarray] = {}
        self.types: Dict[str, str] = {}
        for k, v in columns.items():
            t = (types or {}).get(k) or _infer_type(v)
            if t not in _COLUMN_TYPES:
                raise ValueError(
                    f"column {k!r}: unknown type {t!r}"
                    f" (expected one of {_COLUMN_TYPES})")
            self.types[k] = t
            self._cols[k] = _typed_column(v, t)

    @classmethod
    def _from_arrays(cls, cols: Dict[str, np.ndarray],
                     types: Dict[str, str], name: str = "") -> "Table":
        """Adopt already-typed arrays without copying.

        Trusted internal constructor: callers guarantee the arrays are in
        the canonical representation (`_typed_column` output) and equal
        length.  `ChunkedTable.morsel` relies on this to hand the
        executor zero-copy views of a chunk's columns.
        """
        t = cls.__new__(Table)
        t.name = name
        t._cols = dict(cols)
        t.types = dict(types)
        return t

    # ---- basics ----
    @property
    def num_rows(self) -> int:
        return len(next(iter(self._cols.values())))

    @property
    def column_names(self) -> List[str]:
        return list(self._cols)

    def column(self, name: str) -> np.ndarray:
        return self._cols[name]

    def __getitem__(self, name: str) -> np.ndarray:
        return self._cols[name]

    def __contains__(self, name: str) -> bool:
        return name in self._cols

    def gather(self, name: str, rows) -> np.ndarray:
        """Values of column ``name`` at row indices ``rows``.

        On a monolithic table this is fancy indexing; `ChunkedTable`
        overrides it to gather segment-wise, so expression evaluation
        over a row subset never materializes the full column.
        """
        return self.column(name)[np.asarray(rows, dtype=np.int64)]

    def row(self, i: int) -> Dict[str, Any]:
        return {k: v[i] for k, v in self._cols.items()}

    def rows(self) -> Iterable[Dict[str, Any]]:
        for i in range(self.num_rows):
            yield self.row(i)

    def with_column(self, name: str, values, type_: Optional[str] = None
                    ) -> "Table":
        cols = dict(self._cols)
        cols[name] = values
        types = dict(self.types)
        if type_:
            types[name] = type_
        else:
            types.pop(name, None)
        return Table(cols, types, name=self.name)

    def select(self, names: Sequence[str]) -> "Table":
        return Table({n: self._cols[n] for n in names},
                     {n: self.types[n] for n in names}, name=self.name)

    def rename(self, mapping: Dict[str, str]) -> "Table":
        return Table({mapping.get(k, k): v for k, v in self._cols.items()},
                     {mapping.get(k, k): t for k, t in self.types.items()},
                     name=self.name)

    def prefixed(self, prefix: str) -> "Table":
        return self.rename({c: f"{prefix}.{c}" for c in self.column_names})

    # ---- relational ops ----
    def take(self, idx: np.ndarray) -> "Table":
        return Table({k: v[idx] for k, v in self._cols.items()}, self.types,
                     name=self.name)

    def filter_mask(self, mask: np.ndarray) -> "Table":
        return self.take(np.nonzero(np.asarray(mask, bool))[0])

    def head(self, n: int) -> "Table":
        return self.take(np.arange(min(n, self.num_rows)))

    def concat_rows(self, other: "Table") -> "Table":
        return Table({k: np.concatenate([self._cols[k], other._cols[k]])
                      for k in self._cols}, self.types, name=self.name)

    def hash_join(self, other: "Table", left_on: str, right_on: str
                  ) -> "Table":
        """Equi inner join (build on the smaller side)."""
        lidx, ridx = _hash_join_indices(self._cols[left_on],
                                        other._cols[right_on])
        out = {k: v[lidx] for k, v in self._cols.items()}
        for k, v in other._cols.items():
            key = k if k not in out else f"{other.name or 'r'}.{k}"
            out[key] = v[ridx]
        return Table(out, name=self.name)

    def cross_join_indices(self, other: "Table"):
        li = np.repeat(np.arange(self.num_rows), other.num_rows)
        ri = np.tile(np.arange(other.num_rows), self.num_rows)
        return li, ri

    def group_indices(self, key: str) -> Dict[Any, np.ndarray]:
        groups: Dict[Any, List[int]] = {}
        for i, k in enumerate(self._cols[key]):
            groups.setdefault(k, []).append(i)
        return {k: np.asarray(v) for k, v in groups.items()}

    # ---- statistics for the optimizer ----
    def ndv(self, name: str) -> int:
        col = self._cols[name]
        try:
            return len(set(col.tolist()))
        except TypeError:
            return len({str(x) for x in col})

    def avg_len(self, name: str) -> float:
        col = self._cols[name]
        if self.types[name] != "str":
            return 8.0
        if self.num_rows == 0:
            return 0.0
        sample = col[:256]
        return float(np.mean([len(str(x)) for x in sample]))

    def sample_values(self, name: str, n: int = 5) -> List[Any]:
        return list(self._cols[name][:n])

    def __repr__(self) -> str:
        return (f"Table({self.name or '?'}, rows={self.num_rows}, "
                f"cols={self.column_names})")


def _hash_join_indices(left: np.ndarray, right: np.ndarray):
    table: Dict[Any, List[int]] = {}
    for j, key in enumerate(right):
        table.setdefault(key, []).append(j)
    li, ri = [], []
    for i, key in enumerate(left):
        for j in table.get(key, ()):
            li.append(i)
            ri.append(j)
    return np.asarray(li, dtype=np.int64), np.asarray(ri, dtype=np.int64)
