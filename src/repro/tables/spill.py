"""Byte-budgeted spill manager for chunked storage.

A `SpillManager` tracks the resident bytes of every `SpillSegment`
registered with it and, when a byte budget is configured, evicts the
least-recently-used segments to disk (one ``.npz`` file per segment)
until the resident set fits.  Reload is transparent: touching a spilled
segment's `arrays()` reads the file back and re-admits the segment,
possibly evicting others.

Segments are immutable once *sealed* (the normal state for table
chunks).  A segment may be created unsealed — the embedding store's
append-open vector pages use this — in which case it is pinned in
memory and skipped by eviction until `seal()` is called.  Because
sealed segments never change, a segment that has been spilled once
never rewrites its file: a later eviction just drops the in-memory
arrays again.

Byte accounting: fixed-width arrays count `arr.nbytes`; object arrays
(str/file columns) additionally count the string payload of each cell,
`sum(len(str(x)))` — an estimate, but a stable one, so budgets and the
reported `peak_bytes` are deterministic across runs.

Thread safety: one re-entrant lock per manager guards all segment state
transitions (admit / touch / evict / reload), giving a single lock
order and making concurrent executor workers safe.
"""
from __future__ import annotations

import os
import tempfile
import threading
from collections import OrderedDict
from typing import Dict, Optional

import numpy as np

from repro.obs.trace import active_tracer


def array_bytes(arr: np.ndarray) -> int:
    """Estimated resident bytes of ``arr`` including object payloads."""
    n = int(arr.nbytes)
    if arr.dtype == object:
        n += int(sum(len(str(x)) for x in arr))
    return n


class SpillSegment:
    """A named bundle of equal-length arrays that can round-trip to disk.

    State is one of: resident (arrays in memory), spilled (arrays on
    disk at `path`).  All transitions go through the owning manager's
    lock.  `arrays()` is the only accessor — it loads on demand and
    counts as an LRU touch.
    """

    def __init__(self, manager: "SpillManager", arrays: Dict[str, np.ndarray],
                 *, sealed: bool = True):
        self._mgr = manager
        self._arrays: Optional[Dict[str, np.ndarray]] = dict(arrays)
        self._names = list(arrays)
        self.sealed = sealed
        self.nbytes = sum(array_bytes(a) for a in arrays.values())
        self.path: Optional[str] = None
        self.sid = manager._next_sid()
        manager._admit(self)

    @property
    def resident(self) -> bool:
        return self._arrays is not None

    def arrays(self) -> Dict[str, np.ndarray]:
        return self._mgr._access(self)

    def seal(self) -> None:
        """Mark immutable; the segment becomes eligible for eviction."""
        self._mgr._seal(self)

    # -- manager-internal (called under the manager lock) --------------
    def _recount(self) -> None:
        assert self._arrays is not None
        self.nbytes = sum(array_bytes(a) for a in self._arrays.values())

    def _write(self) -> None:
        if self.path is None:
            self.path = os.path.join(self._mgr.directory(),
                                     f"seg{self.sid}.npz")
            assert self._arrays is not None
            # positional member names: column names may not be valid
            # npz keywords; order is recovered from self._names
            np.savez(self.path, *[self._arrays[n] for n in self._names])

    def _drop(self) -> None:
        self._arrays = None

    def _load(self) -> None:
        assert self.path is not None
        with np.load(self.path, allow_pickle=True) as z:
            self._arrays = {n: z[f"arr_{i}"]
                            for i, n in enumerate(self._names)}


class SpillManager:
    """LRU byte-budget accountant for a set of `SpillSegment`s.

    Args:
        budget_bytes: resident-byte ceiling; ``None`` tracks bytes but
            never evicts.  The segment currently being admitted or read
            is exempt, so the instantaneous peak can exceed the budget
            by roughly one segment.
        spill_dir: where segment files go; defaults to a lazily created
            temporary directory.

    Counters (all monotonic): ``tracked_bytes`` resident now,
    ``peak_bytes`` high-water mark, ``spill_events`` / ``reload_events``
    segment evictions and reloads, ``spilled_bytes`` total bytes written.
    """

    def __init__(self, budget_bytes: Optional[int] = None,
                 spill_dir: Optional[str] = None):
        self.budget_bytes = budget_bytes
        self._dir = spill_dir
        self._lock = threading.RLock()
        self._resident: "OrderedDict[int, SpillSegment]" = OrderedDict()
        self._sid = 0
        self.tracked_bytes = 0
        self.peak_bytes = 0
        self.spill_events = 0
        self.reload_events = 0
        self.spilled_bytes = 0

    def directory(self) -> str:
        with self._lock:
            if self._dir is None:
                self._dir = tempfile.mkdtemp(prefix="repro-spill-")
            else:
                os.makedirs(self._dir, exist_ok=True)
            return self._dir

    def _next_sid(self) -> int:
        with self._lock:
            self._sid += 1
            return self._sid

    # -- segment protocol ----------------------------------------------
    def _admit(self, seg: SpillSegment) -> None:
        with self._lock:
            self._resident[seg.sid] = seg
            self.tracked_bytes += seg.nbytes
            self.peak_bytes = max(self.peak_bytes, self.tracked_bytes)
            self._evict_over_budget(exempt=seg)

    def _access(self, seg: SpillSegment) -> Dict[str, np.ndarray]:
        with self._lock:
            if seg._arrays is None:
                seg._load()
                self.reload_events += 1
                active_tracer().event("storage.reload", sid=seg.sid,
                                      bytes=seg.nbytes)
                self._resident[seg.sid] = seg
                self.tracked_bytes += seg.nbytes
                self.peak_bytes = max(self.peak_bytes, self.tracked_bytes)
            else:
                self._resident.move_to_end(seg.sid)
            self._evict_over_budget(exempt=seg)
            return seg._arrays

    def _seal(self, seg: SpillSegment) -> None:
        with self._lock:
            if not seg.sealed:
                seg.sealed = True
                if seg._arrays is not None:
                    delta = -seg.nbytes
                    seg._recount()
                    self.tracked_bytes += seg.nbytes + delta
                    self.peak_bytes = max(self.peak_bytes,
                                          self.tracked_bytes)
                self._evict_over_budget(exempt=None)

    def _evict_over_budget(self, exempt: Optional[SpillSegment]) -> None:
        if self.budget_bytes is None:
            return
        while self.tracked_bytes > self.budget_bytes:
            victim = next(
                (s for s in self._resident.values()
                 if s.sealed and s is not exempt), None)
            if victim is None:
                return
            victim._write()
            victim._drop()
            del self._resident[victim.sid]
            self.tracked_bytes -= victim.nbytes
            self.spill_events += 1
            self.spilled_bytes += victim.nbytes
            active_tracer().event("storage.spill", sid=victim.sid,
                                  bytes=victim.nbytes)

    # -- reporting ------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "tracked_bytes": self.tracked_bytes,
                "peak_bytes": self.peak_bytes,
                "spill_events": self.spill_events,
                "reload_events": self.reload_events,
                "spilled_bytes": self.spilled_bytes,
                "resident_segments": len(self._resident),
                "budget_bytes": self.budget_bytes or 0,
            }
