"""Chunk-backed columnar table.

`ChunkedTable` stores each column as a sequence of fixed-size row
segments (`SpillSegment`s owned by a `SpillManager`), instead of one
monolithic numpy allocation per column.  The executor's partitioned
pull loop consumes it through two primitives:

* `segment_bounds()` — the chunk row ranges, so partitions can be
  aligned to never straddle a chunk;
* `morsel(i)` — a plain `Table` whose columns *are* the segment's
  arrays (adopted via `Table._from_arrays`, zero copy).

Everything else a `Table` can do still works: point lookups and row
subsets go through `gather` (segment-wise, touching only the chunks
that hold the requested rows), and any operation that genuinely needs
a whole column assembles it on demand — counted in
``materializations`` so benchmarks can assert the big table was never
materialized.

`take` on more rows than one chunk returns another `ChunkedTable`
registered with the same spill manager, which is how wide intermediate
results participate in the byte budget.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .spill import SpillManager, SpillSegment
from .table import (Table, _COLUMN_TYPES, _infer_type, _typed_column)

DEFAULT_CHUNK_ROWS = 65536

_EMPTY_DTYPE = {"int": np.int64, "float": np.float64, "bool": bool}


def _empty_typed(t: str) -> np.ndarray:
    return np.empty(0, dtype=_EMPTY_DTYPE.get(t, object))


class ChunkedTable(Table):
    """Columnar table backed by fixed-size row chunks with disk spill."""

    def __init__(self, columns: Dict[str, Sequence[Any]],
                 types: Optional[Dict[str, str]] = None,
                 name: str = "", *,
                 chunk_rows: int = DEFAULT_CHUNK_ROWS,
                 spill: Optional[SpillManager] = None):
        if not columns:
            raise ValueError("empty table")
        lens = {len(v) for v in columns.values()}
        if len(lens) != 1:
            raise ValueError(
                f"ragged columns: { {k: len(v) for k, v in columns.items()} }")
        self._init_store(name, chunk_rows, spill)
        typed: Dict[str, np.ndarray] = {}
        for k, v in columns.items():
            t = (types or {}).get(k) or _infer_type(v)
            if t not in _COLUMN_TYPES:
                raise ValueError(
                    f"column {k!r}: unknown type {t!r}"
                    f" (expected one of {_COLUMN_TYPES})")
            self.types[k] = t
            typed[k] = _typed_column(v, t)
        self._colmap = {k: k for k in typed}
        n = lens.pop()
        for lo in range(0, n, self._chunk_rows):
            hi = min(lo + self._chunk_rows, n)
            self._append_segment(
                {k: (a[lo:hi].copy() if hi - lo < len(a) else a)
                 for k, a in typed.items()}, hi - lo)
        self._finalize()

    # -- construction helpers ------------------------------------------
    def _init_store(self, name: str, chunk_rows: int,
                    spill: Optional[SpillManager]) -> None:
        self.name = name
        self.types: Dict[str, str] = {}
        self._chunk_rows = max(int(chunk_rows), 1)
        self._spill = spill if spill is not None else SpillManager()
        self._segments: List[SpillSegment] = []
        self._bounds: List[Tuple[int, int]] = []
        self._nrows = 0
        self._colmap: Dict[str, str] = {}
        self._colcache: Dict[str, np.ndarray] = {}
        self.materializations = 0

    def _append_segment(self, arrays: Dict[str, np.ndarray],
                        nrows: int) -> None:
        self._segments.append(SpillSegment(self._spill, arrays))
        self._bounds.append((self._nrows, self._nrows + nrows))
        self._nrows += nrows

    def _finalize(self) -> None:
        self._starts = np.asarray([lo for lo, _ in self._bounds],
                                  dtype=np.int64)

    @classmethod
    def from_batches(cls, batches: Iterable[Dict[str, Sequence[Any]]], *,
                     types: Optional[Dict[str, str]] = None,
                     name: str = "",
                     chunk_rows: int = DEFAULT_CHUNK_ROWS,
                     spill: Optional[SpillManager] = None) -> "ChunkedTable":
        """Build incrementally from an iterable of column-dict batches.

        The generator is the only holder of unchunked data, so peak
        resident bytes stay near the spill budget even for tables far
        larger than memory.  Types are inferred from the first batch
        unless given.
        """
        self = cls.__new__(cls)
        self._init_store(name, chunk_rows, spill)
        for batch in batches:
            if not self._colmap:
                for k, v in batch.items():
                    t = (types or {}).get(k) or _infer_type(v)
                    if t not in _COLUMN_TYPES:
                        raise ValueError(
                            f"column {k!r}: unknown type {t!r}"
                            f" (expected one of {_COLUMN_TYPES})")
                    self.types[k] = t
                self._colmap = {k: k for k in batch}
            elif set(batch) != set(self._colmap):
                raise ValueError(f"batch columns {sorted(batch)} != "
                                 f"{sorted(self._colmap)}")
            typed = {k: _typed_column(v, self.types[k])
                     for k, v in batch.items()}
            bn = len(next(iter(typed.values())))
            for lo in range(0, bn, self._chunk_rows):
                hi = min(lo + self._chunk_rows, bn)
                self._append_segment(
                    {k: (a[lo:hi].copy() if hi - lo < bn else a)
                     for k, a in typed.items()}, hi - lo)
        if not self._colmap:
            raise ValueError("empty table")
        self._finalize()
        return self

    def _shallow(self, colmap: Dict[str, str], types: Dict[str, str],
                 name: str) -> "ChunkedTable":
        """Column-level view sharing this table's segments (rename /
        select are O(1) on a chunked table)."""
        t = ChunkedTable.__new__(ChunkedTable)
        t.name = name
        t.types = dict(types)
        t._chunk_rows = self._chunk_rows
        t._spill = self._spill
        t._segments = self._segments
        t._bounds = self._bounds
        t._starts = self._starts
        t._nrows = self._nrows
        t._colmap = dict(colmap)
        t._colcache = {}
        t.materializations = 0
        return t

    # -- chunk protocol (consumed by the executor) ---------------------
    @property
    def spill(self) -> SpillManager:
        return self._spill

    @property
    def chunk_rows(self) -> int:
        return self._chunk_rows

    def segment_bounds(self) -> List[Tuple[int, int]]:
        """Global row range ``[lo, hi)`` of each chunk."""
        return list(self._bounds)

    def morsel(self, i: int) -> Table:
        """Zero-copy `Table` view of chunk ``i`` (rows are local to the
        chunk; add ``segment_bounds()[i][0]`` to go global)."""
        arrs = self._segments[i].arrays()
        return Table._from_arrays(
            {pub: arrs[itl] for pub, itl in self._colmap.items()},
            self.types, self.name)

    # -- basics ---------------------------------------------------------
    @property
    def num_rows(self) -> int:
        return self._nrows

    @property
    def column_names(self) -> List[str]:
        return list(self._colmap)

    def __contains__(self, name: str) -> bool:
        return name in self._colmap

    def column(self, name: str) -> np.ndarray:
        """Assemble (and cache) one full column.  This is the
        materialization escape hatch — counted so scale benchmarks can
        assert it never fires on the big table."""
        arr = self._colcache.get(name)
        if arr is None:
            arr = self.gather(name, np.arange(self._nrows, dtype=np.int64))
            self._colcache[name] = arr
            self.materializations += 1
        return arr

    def __getitem__(self, name: str) -> np.ndarray:
        return self.column(name)

    @property
    def _cols(self) -> Dict[str, np.ndarray]:
        # base-class ops (hash_join, concat_rows, with_column, ...) fall
        # back to full materialization through this property
        return {n: self.column(n) for n in self._colmap}

    def gather(self, name: str, rows) -> np.ndarray:
        internal = self._colmap[name]
        t = self.types[name]
        rows = np.asarray(rows, dtype=np.int64)
        if rows.size == 0:
            return _empty_typed(t)
        seg_ids = np.searchsorted(self._starts, rows, side="right") - 1
        out = np.empty(rows.size, dtype=_EMPTY_DTYPE.get(t, object))
        for sid in np.unique(seg_ids):
            m = seg_ids == sid
            col = self._segments[sid].arrays()[internal]
            out[m] = col[rows[m] - self._bounds[sid][0]]
        return out

    def row(self, i: int) -> Dict[str, Any]:
        sid = int(np.searchsorted(self._starts, i, side="right")) - 1
        local = i - self._bounds[sid][0]
        arrs = self._segments[sid].arrays()
        return {pub: arrs[itl][local] for pub, itl in self._colmap.items()}

    def rows(self) -> Iterable[Dict[str, Any]]:
        for si in range(len(self._segments)):
            m = self.morsel(si)
            for i in range(m.num_rows):
                yield m.row(i)

    # -- relational ops -------------------------------------------------
    def select(self, names: Sequence[str]) -> "ChunkedTable":
        return self._shallow({n: self._colmap[n] for n in names},
                             {n: self.types[n] for n in names}, self.name)

    def rename(self, mapping: Dict[str, str]) -> "ChunkedTable":
        return self._shallow(
            {mapping.get(k, k): i for k, i in self._colmap.items()},
            {mapping.get(k, k): t for k, t in self.types.items()},
            self.name)

    def take(self, idx: np.ndarray) -> Table:
        idx = np.asarray(idx, dtype=np.int64)
        if idx.size > self._chunk_rows:
            # wide intermediate: keep it chunked under the same spill
            # manager so it participates in the byte budget
            step = self._chunk_rows
            return ChunkedTable.from_batches(
                ({n: self.gather(n, idx[lo:lo + step])
                  for n in self._colmap}
                 for lo in range(0, idx.size, step)),
                types=self.types, name=self.name,
                chunk_rows=step, spill=self._spill)
        return Table._from_arrays(
            {n: self.gather(n, idx) for n in self._colmap},
            self.types, self.name)

    def group_indices(self, key: str) -> Dict[Any, np.ndarray]:
        groups: Dict[Any, List[int]] = {}
        for i, k in enumerate(self.column(key)):
            groups.setdefault(k, []).append(i)
        return {k: np.asarray(v) for k, v in groups.items()}

    # -- statistics for the optimizer -----------------------------------
    NDV_EXACT_ROWS = 1 << 17

    def ndv(self, name: str) -> int:
        """Exact distinct count up to `NDV_EXACT_ROWS` rows (identical
        to the monolithic store); a linear-extrapolated sample-based
        estimate beyond that, so catalog statistics never require a
        full materialization of a million-row column."""
        internal = self._colmap[name]
        vals: set = set()
        sampled = 0
        for sid, (lo, hi) in enumerate(self._bounds):
            col = self._segments[sid].arrays()[internal]
            try:
                vals.update(col.tolist())
            except TypeError:
                vals.update(str(x) for x in col)
            sampled = hi
            if self._nrows > self.NDV_EXACT_ROWS and \
                    sampled >= self.NDV_EXACT_ROWS:
                break
        if sampled >= self._nrows:
            return len(vals)
        return int(min(self._nrows,
                       round(len(vals) * self._nrows / max(sampled, 1))))

    def avg_len(self, name: str) -> float:
        if self.types[name] != "str":
            return 8.0
        if self._nrows == 0:
            return 0.0
        sample = self.gather(
            name, np.arange(min(256, self._nrows), dtype=np.int64))
        return float(np.mean([len(str(x)) for x in sample]))

    def sample_values(self, name: str, n: int = 5) -> List[Any]:
        return list(self.gather(
            name, np.arange(min(n, self._nrows), dtype=np.int64)))

    def __repr__(self) -> str:
        return (f"ChunkedTable({self.name or '?'}, rows={self._nrows}, "
                f"cols={self.column_names}, chunks={len(self._segments)})")
