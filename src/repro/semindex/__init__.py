"""Semantic index subsystem: embedding store + IVF-flat ANN index.

Connects the SQL layer to the Pallas kernel library: `EmbeddingStore`
caches content-addressed vectors, `IvfFlatIndex` retrieves top-k
neighbours through the ``similarity_topk`` kernel, and
`SemanticIndexManager` ties both to catalog columns, the inference
client (EMBED requests) and the optimizer's cost race.  See
``docs/semantic-index.md``.
"""
from repro.semindex.store import EmbeddingStore, content_key  # noqa: F401
from repro.semindex.index import IvfConfig, IvfFlatIndex      # noqa: F401
from repro.semindex.manager import (SemanticIndexManager,     # noqa: F401
                                    SemIndexConfig)
