"""IVF-flat approximate-nearest-neighbour index over unit vectors.

The classical two-level design: a seeded k-means partitions the corpus
into ``nlist`` coarse cells; a query probes the ``nprobe`` nearest cells
and scores only their members.  Every scoring path — centroid ranking,
cell scans, and the exact flat fallback — runs through the
`similarity_topk` Pallas kernel (tiled batched cosine + top-k), so the
index is the SQL layer's on-ramp to the hardware-speed path.

With ``nprobe >= nlist`` the search degenerates to an exact flat scan
(same results as `search_flat`), which is how callers that need
bit-identical answers to the index-off path configure it.  Recall below
that is the classical IVF trade-off; `measure_recall` quantifies it
against the flat scan so the knob is tunable from evidence.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.kernels.similarity_topk.ops import similarity_topk


@dataclasses.dataclass
class IvfConfig:
    """Index-build and search policy.

    Args:
        nlist: number of coarse k-means cells; 0/1 disables the coarse
            level (pure flat index).  Sized ~sqrt(N) classically.
        nprobe: cells scanned per query; recall knob (nprobe == nlist is
            an exact search).
        kmeans_iters: Lloyd iterations at build time (seeded, few).
        seed: determinism for centroid init.
        impl: kernel implementation — "auto" (pallas on TPU, reference
            elsewhere), "interpret", "reference".
    """
    nlist: int = 16
    nprobe: int = 4
    kmeans_iters: int = 5
    seed: int = 0
    impl: str = "auto"


def _normalize(x: np.ndarray) -> np.ndarray:
    x = np.asarray(x, np.float32)
    n = np.linalg.norm(x, axis=-1, keepdims=True)
    return x / np.maximum(n, 1e-12)


class IvfFlatIndex:
    """Build once over a column's vectors, search many times."""

    def __init__(self, vectors: np.ndarray,
                 cfg: Optional[IvfConfig] = None):
        self.cfg = cfg or IvfConfig()
        self.vectors = _normalize(vectors)
        n = self.vectors.shape[0]
        self.nlist = max(1, min(self.cfg.nlist, n))
        self.centroids, self.assign = self._kmeans()
        # cell id -> member row ids (ascending, so ties keep flat order)
        self.cells = [np.nonzero(self.assign == c)[0]
                      for c in range(self.nlist)]

    @property
    def num_vectors(self) -> int:
        return int(self.vectors.shape[0])

    # -- build ---------------------------------------------------------
    def _kmeans(self) -> Tuple[np.ndarray, np.ndarray]:
        """Seeded spherical k-means (cosine Lloyd iterations)."""
        v = self.vectors
        n = v.shape[0]
        rng = np.random.default_rng(self.cfg.seed)
        cent = _normalize(v[rng.permutation(n)[:self.nlist]].copy())
        assign = np.zeros(n, np.int64)
        for _ in range(max(self.cfg.kmeans_iters, 1)):
            sims = v @ cent.T                       # [n, nlist]
            assign = np.argmax(sims, axis=1)
            for c in range(self.nlist):
                members = v[assign == c]
                if len(members):
                    cent[c] = members.mean(axis=0)
            cent = _normalize(cent)
        return cent, assign

    # -- search --------------------------------------------------------
    def search_flat(self, queries: np.ndarray, k: int
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact top-k over the whole corpus (kernel-scored)."""
        q = _normalize(np.atleast_2d(queries))
        vals, idx = similarity_topk(q, self.vectors, k, impl=self.cfg.impl)
        return np.asarray(vals), np.asarray(idx)

    def search(self, queries: np.ndarray, k: int,
               nprobe: Optional[int] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
        """IVF search: probe the ``nprobe`` best cells per query, scan
        their members through the kernel, merge per query.  Returns
        ``(vals [Q, k] descending, ids [Q, k] int64; -1 padding when a
        probe set holds fewer than k vectors)``."""
        nprobe = min(nprobe or self.cfg.nprobe, self.nlist)
        q = _normalize(np.atleast_2d(queries))
        if nprobe >= self.nlist:
            return self.search_flat(q, k)
        _, probe = similarity_topk(q, self.centroids, nprobe,
                                   impl=self.cfg.impl)
        probe = np.asarray(probe)                   # [Q, nprobe]
        Q = q.shape[0]
        cand_v = [[] for _ in range(Q)]
        cand_i = [[] for _ in range(Q)]
        # scan cell by cell so each kernel call is one dense batch of
        # every query probing that cell
        for c in range(self.nlist):
            rows = np.nonzero((probe == c).any(axis=1))[0]
            members = self.cells[c]
            if not len(rows) or not len(members):
                continue
            kk = min(k, len(members))
            vals, idx = similarity_topk(q[rows], self.vectors[members], kk,
                                        impl=self.cfg.impl)
            vals, idx = np.asarray(vals), np.asarray(idx)
            gids = members[idx]
            for j, qi in enumerate(rows):
                cand_v[qi].append(vals[j])
                cand_i[qi].append(gids[j])
        out_v = np.full((Q, k), -np.inf, np.float32)
        out_i = np.full((Q, k), -1, np.int64)
        for qi in range(Q):
            if not cand_v[qi]:
                continue
            v = np.concatenate(cand_v[qi])
            i = np.concatenate(cand_i[qi])
            # descending value, ascending id on ties — flat-scan order
            order = np.lexsort((i, -v))[:k]
            out_v[qi, :len(order)] = v[order]
            out_i[qi, :len(order)] = i[order]
        return out_v, out_i

    def measure_recall(self, queries: np.ndarray, k: int,
                       nprobe: Optional[int] = None) -> float:
        """Observed recall@k of the IVF search vs the exact flat scan —
        the evidence behind the ``nprobe`` knob."""
        q = np.atleast_2d(queries)
        _, exact = self.search_flat(q, k)
        _, approx = self.search(q, k, nprobe=nprobe)
        hits = total = 0
        for e, a in zip(np.asarray(exact), np.asarray(approx)):
            want = set(int(x) for x in e if x >= 0)
            got = set(int(x) for x in a if x >= 0)
            hits += len(want & got)
            total += len(want)
        return hits / total if total else 1.0
