"""SemanticIndexManager — the glue between SQL and the vector layer.

One manager instance is shared by the cost model (coverage estimates),
the executor (candidate generation, top-k pruning) and — under the
serving runtime — every tenant session (one lock, one store, one set of
indexes; an index built for tenant A's query serves tenant B's for
free).  It owns:

  * an `EmbeddingStore` (content-hash cache, JSON+npz persisted),
  * per-column `IvfFlatIndex` instances, rebuilt automatically when the
    column snapshot's content signature changes (refresh-on-drift),
  * the EMBED traffic itself: cache misses are batched through the
    shared `CortexClient` — coalesced, deduplicated and billed by the
    `RequestPipeline` like every other request kind.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.semindex.index import IvfConfig, IvfFlatIndex
from repro.semindex.store import EmbeddingStore


@dataclasses.dataclass
class SemIndexConfig:
    """Semantic-index policy knobs.

    Args:
        model: embedding model; None uses the client's ``embed_model``.
        dim: embedding dimensionality requested from the backend
            (forwarded as ``embed_dim`` metadata).
        nlist / nprobe / kmeans_iters / impl: `IvfConfig` passthrough —
            coarse-cell count, cells probed per query (the recall knob),
            Lloyd iterations, kernel implementation.
        min_index_rows: columns smaller than this are scanned flat (an
            IVF level cannot pay for itself).
        join_k: kNN candidates generated per probe row for
            index-assisted semantic-join blocking.
        join_min_sim: optional cosine floor on join candidates (prunes
            the candidate list below ``join_k`` when the tail is noise).
        exact_topk: when True (default) index searches — ORDER BY
            pruning and join blocking alike — use the exact flat scan,
            guaranteeing index-on == index-off rows; False trades that
            for IVF probing at ``nprobe`` cells per query.
        embed_budget_bytes: when set, the `EmbeddingStore` pages its
            vectors through a byte-budgeted `SpillManager` (LRU page
            eviction to disk) instead of holding every vector resident.
        embed_page_rows: vectors per spillable page (the store's
            eviction granularity).
    """
    model: Optional[str] = None
    dim: int = 64
    nlist: int = 16
    nprobe: int = 4
    kmeans_iters: int = 5
    impl: str = "auto"
    min_index_rows: int = 64
    join_k: int = 8
    join_min_sim: Optional[float] = None
    exact_topk: bool = True
    embed_budget_bytes: Optional[int] = None
    embed_page_rows: int = 1024


class SemanticIndexManager:
    """Thread-safe store + index registry + embed-traffic front end."""

    def __init__(self, cfg: Optional[SemIndexConfig] = None, *,
                 store: Optional[EmbeddingStore] = None,
                 path: Optional[str] = None):
        self.cfg = cfg or SemIndexConfig()
        if store is not None:
            self.store = store
        elif self.cfg.embed_budget_bytes is not None:
            from repro.tables.spill import SpillManager
            self.store = EmbeddingStore(
                path, spill=SpillManager(
                    budget_bytes=self.cfg.embed_budget_bytes),
                page_rows=self.cfg.embed_page_rows)
        else:
            self.store = EmbeddingStore(path)
        self._lock = threading.RLock()
        # column key -> (signature, IvfFlatIndex)
        self._indexes: Dict[str, Tuple[str, IvfFlatIndex]] = {}
        # telemetry (reset never; engines snapshot-delta it per query)
        self.embed_requests = 0
        self.embed_cache_hits = 0
        self.embed_llm_calls = 0
        self.index_builds = 0
        self.index_searches = 0

    # ------------------------------------------------------------------
    def model_for(self, client) -> str:
        return self.cfg.model or client.embed_model

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "embed_requests": self.embed_requests,
                "embed_cache_hits": self.embed_cache_hits,
                "embed_llm_calls": self.embed_llm_calls,
                "index_builds": self.index_builds,
                "index_searches": self.index_searches,
                "stored_vectors": len(self.store),
                "indexed_columns": len(self._indexes),
            }

    # ------------------------------------------------------------------
    # embedding traffic (store-first, misses through the client)
    # ------------------------------------------------------------------

    def embed_texts(self, client, texts: Sequence[str], *,
                    metadata: Optional[Sequence[Dict[str, Any]]] = None,
                    model: Optional[str] = None) -> np.ndarray:
        """Vectors for ``texts`` in order: store hits are free, misses
        are embedded through ``client`` (one coalesced batch — the
        pipeline dedups identical texts) and written back to the store."""
        model = model or self.model_for(client)
        texts = [str(t) for t in texts]
        if not texts:
            return np.zeros((0, 1), np.float32)
        with self._lock:
            cached = self.store.get(model, texts, dim=self.cfg.dim)
            self.embed_requests += len(texts)
            self.embed_cache_hits += sum(v is not None for v in cached)
            miss = [i for i, v in enumerate(cached) if v is None]
        if miss:
            # dispatch OUTSIDE the manager lock: under the serving
            # runtime every tenant session shares this manager, and an
            # EMBED dispatch is the slow part of the path — holding the
            # lock across it would serialize all embedding traffic.
            # Two sessions racing on the same text at worst both
            # dispatch (the shared pipeline dedups them to one engine
            # execution) and the content-keyed put is idempotent.
            md = [dict(metadata[i]) if metadata else {} for i in miss]
            for m in md:
                m.setdefault("embed_dim", self.cfg.dim)
            vecs = client.embed([texts[i] for i in miss], model=model,
                                metadata=md)
            with self._lock:
                self.embed_llm_calls += len(miss)
                self.store.put(model, [texts[i] for i in miss], vecs,
                               dim=self.cfg.dim)
            for i, v in zip(miss, vecs):
                cached[i] = np.asarray(v, np.float32)
        return np.stack(cached).astype(np.float32)

    def coverage(self, client, texts: Sequence[str],
                 model: Optional[str] = None) -> float:
        """Fraction of ``texts`` already embedded — the cost model's
        expected miss rate for pricing an index-assisted plan."""
        return self.store.coverage(model or self.model_for(client),
                                   [str(t) for t in texts],
                                   dim=self.cfg.dim)

    # ------------------------------------------------------------------
    # index lifecycle
    # ------------------------------------------------------------------

    def ensure_index(self, client, column: str, texts: Sequence[str], *,
                     metadata: Optional[Sequence[Dict[str, Any]]] = None,
                     model: Optional[str] = None) -> IvfFlatIndex:
        """The column's index, building (or refreshing) it when the
        snapshot signature changed since the last build.  Embeddings go
        through the store, so a refresh re-embeds only new rows."""
        model = model or self.model_for(client)
        texts = [str(t) for t in texts]
        sig = EmbeddingStore.column_signature(model, texts, self.cfg.dim)
        with self._lock:
            entry = self._indexes.get(column)
            if entry is not None and entry[0] == sig:
                return entry[1]
        # embed outside the lock (see embed_texts); racing builders at
        # worst both construct the same index and the second registration
        # wins — deterministic inputs make the two identical
        vecs = self.embed_texts(client, texts, metadata=metadata,
                                model=model)
        with self._lock:
            entry = self._indexes.get(column)
            if entry is not None and entry[0] == sig:
                return entry[1]
            self.store.register_column(column, model, texts,
                                       dim=self.cfg.dim)
            nlist = (1 if len(texts) < self.cfg.min_index_rows
                     else self.cfg.nlist)
            index = IvfFlatIndex(vecs, IvfConfig(
                nlist=nlist, nprobe=self.cfg.nprobe,
                kmeans_iters=self.cfg.kmeans_iters, impl=self.cfg.impl))
            self._indexes[column] = (sig, index)
            self.index_builds += 1
            return index

    def index_for(self, column: str) -> Optional[IvfFlatIndex]:
        with self._lock:
            entry = self._indexes.get(column)
            return entry[1] if entry else None

    def has_index(self, column: str) -> bool:
        return self.index_for(column) is not None

    # ------------------------------------------------------------------
    # search fronts
    # ------------------------------------------------------------------

    def search(self, column: str, queries: np.ndarray, k: int, *,
               exact: Optional[bool] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Top-k over an indexed column; ``exact`` forces the flat scan
        (defaults to ``cfg.exact_topk``)."""
        index = self.index_for(column)
        if index is None:
            raise KeyError(f"no index for column {column!r}")
        with self._lock:
            self.index_searches += 1
        exact = self.cfg.exact_topk if exact is None else exact
        if exact:
            return index.search_flat(queries, k)
        return index.search(queries, k)

    def topk_candidates(self, queries: np.ndarray, corpus: np.ndarray,
                        k: int) -> Tuple[np.ndarray, np.ndarray]:
        """One-shot kernel top-k for ad-hoc (unindexed) vector sets —
        the flat path the filtered-scan pruning uses."""
        from repro.kernels.similarity_topk.ops import similarity_topk
        with self._lock:
            self.index_searches += 1
        vals, idx = similarity_topk(np.atleast_2d(queries),
                                    np.atleast_2d(corpus), k,
                                    impl=self.cfg.impl)
        return np.asarray(vals), np.asarray(idx)

    # ------------------------------------------------------------------
    def save(self, path: Optional[str] = None) -> str:
        return self.store.save(path)
