"""EmbeddingStore — content-hash-keyed persistent embedding cache.

The semantic index's storage layer, designed like the `StatsStore`: one
instance shared by every query (and, under the serving runtime, every
tenant session), persisted alongside it.  Two ideas:

  * **content addressing** — a vector is keyed by
    ``sha256(model ‖ text)``, so re-embedding the same text is a cache
    hit regardless of which table, column, row or query produced it; an
    UPDATE that rewrites 1% of a column re-embeds exactly that 1%.
  * **per-column registries** — an index build needs *the column's
    vectors in row order*; `register_column` records the ordered content
    keys of a column snapshot so `column_matrix` can materialize the
    [N, D] matrix (and detect staleness via the snapshot signature).

Persistence is a JSON sidecar (keys, column registries, model/dim
metadata) plus an ``.npz`` holding one vector matrix — human-inspectable
like the stats JSON, binary where it matters.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
import warnings
import zipfile
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class _VectorPage:
    """A fixed-capacity [page_rows, D] float32 slab of vectors, held as
    one spillable segment.  Open (appendable) pages are pinned in
    memory; once full they seal and become LRU-evictable like any table
    chunk."""

    def __init__(self, spill, dim: int, capacity: int):
        from repro.tables.spill import SpillSegment
        self.capacity = capacity
        self.count = 0
        self.seg = SpillSegment(
            spill, {"v": np.zeros((capacity, dim), np.float32)},
            sealed=False)

    def append(self, vec: np.ndarray) -> int:
        slot = self.count
        self.seg.arrays()["v"][slot] = vec
        self.count += 1
        if self.count == self.capacity:
            self.seg.seal()
        return slot

    def vector(self, slot: int) -> np.ndarray:
        return self.seg.arrays()["v"][slot]


class _PagedVectorMap:
    """dict-of-vectors facade over spillable `_VectorPage`s.

    Vectors are content-addressed and therefore write-once: a repeated
    ``[key] = vec`` always carries the same value, so sealed pages never
    need rewriting on disk.  One open page per dimensionality."""

    def __init__(self, spill, page_rows: int = 1024):
        self._spill = spill
        self._page_rows = max(int(page_rows), 1)
        self._loc: Dict[str, Tuple[_VectorPage, int]] = {}
        self._open: Dict[int, _VectorPage] = {}

    def get(self, key: str, default=None):
        loc = self._loc.get(key)
        if loc is None:
            return default
        return loc[0].vector(loc[1])

    def __getitem__(self, key: str) -> np.ndarray:
        page, slot = self._loc[key]
        return page.vector(slot)

    def __setitem__(self, key: str, vec) -> None:
        vec = np.asarray(vec, np.float32)
        loc = self._loc.get(key)
        if loc is not None:          # content-addressed: same value
            loc[0].seg.arrays()["v"][loc[1]] = vec
            return
        d = int(vec.shape[-1])
        page = self._open.get(d)
        if page is None or page.count >= page.capacity:
            page = _VectorPage(self._spill, d, self._page_rows)
            self._open[d] = page
        self._loc[key] = (page, page.append(vec))

    def setdefault(self, key: str, vec) -> np.ndarray:
        got = self.get(key)
        if got is not None:
            return got
        self[key] = vec
        return self[key]

    def __contains__(self, key: str) -> bool:
        return key in self._loc

    def __len__(self) -> int:
        return len(self._loc)

    def __iter__(self):
        return iter(self._loc)

    def clear(self) -> None:
        self._loc.clear()
        self._open.clear()


def content_key(model: str, text: str, dim: Optional[int] = None) -> str:
    """Content-hash identity of one (model, text, dim) embedding.  The
    dimensionality is part of the key: the same text embedded at two
    configured dims yields two distinct (and differently-shaped)
    vectors, which must never collide in the store."""
    h = hashlib.sha256()
    h.update(model.encode())
    if dim is not None:
        h.update(f"@{int(dim)}".encode())
    h.update(b"\x00")
    h.update(str(text).encode())
    return h.hexdigest()[:32]


class EmbeddingStore:
    """Thread-safe map ``content key -> unit vector`` with per-column
    row-order registries and JSON+npz persistence.

    ``path`` is a *prefix*: ``save`` writes ``<path>.json`` and
    ``<path>.npz``; construction loads them when present (merge-on-load,
    like `StatsStore`).

    With ``spill`` set (a `repro.tables.spill.SpillManager`), vectors
    live in fixed-size spillable pages under that manager's byte budget
    instead of one resident dict — same observable behaviour, bounded
    memory.
    """

    def __init__(self, path: Optional[str] = None, *,
                 spill=None, page_rows: int = 1024):
        self.path = path
        self.spill = spill
        self._lock = threading.RLock()
        self._vecs = (_PagedVectorMap(spill, page_rows)
                      if spill is not None
                      else {})  # type: Dict[str, np.ndarray]
        # column name -> {"model", "keys" (row order), "signature"}
        self._columns: Dict[str, Dict] = {}
        if path is not None and os.path.exists(path + ".json"):
            self.load(path)

    def spill_stats(self) -> Optional[Dict[str, int]]:
        return self.spill.stats() if self.spill is not None else None

    # -- access --------------------------------------------------------
    def __len__(self) -> int:
        return len(self._vecs)

    def __contains__(self, key: str) -> bool:
        return key in self._vecs

    def get(self, model: str, texts: Sequence[str],
            dim: Optional[int] = None) -> List[Optional[np.ndarray]]:
        """Per-text cached vectors (None for misses), in input order."""
        with self._lock:
            return [self._vecs.get(content_key(model, t, dim))
                    for t in texts]

    def put(self, model: str, texts: Sequence[str],
            vectors: Sequence[np.ndarray],
            dim: Optional[int] = None) -> None:
        with self._lock:
            for t, v in zip(texts, vectors):
                self._vecs[content_key(model, t, dim)] = \
                    np.asarray(v, np.float32)

    def coverage(self, model: str, texts: Sequence[str],
                 dim: Optional[int] = None) -> float:
        """Fraction of ``texts`` already embedded (cost-model input:
        the expected per-row embed spend is ``1 - coverage`` misses)."""
        if not len(texts):
            return 1.0
        with self._lock:
            hits = sum(content_key(model, t, dim) in self._vecs
                       for t in texts)
        return hits / len(texts)

    # -- per-column registries -----------------------------------------
    @staticmethod
    def column_signature(model: str, texts: Sequence[str],
                         dim: Optional[int] = None) -> str:
        h = hashlib.sha256()
        h.update(model.encode())
        if dim is not None:
            h.update(f"@{int(dim)}".encode())
        for t in texts:
            h.update(b"\x00")
            h.update(str(t).encode())
        return h.hexdigest()[:32]

    def register_column(self, column: str, model: str,
                        texts: Sequence[str],
                        dim: Optional[int] = None) -> str:
        """Record a column snapshot's ordered content keys; returns the
        snapshot signature (index staleness check)."""
        sig = self.column_signature(model, texts, dim)
        with self._lock:
            self._columns[column] = {
                "model": model,
                "keys": [content_key(model, t, dim) for t in texts],
                "signature": sig,
            }
        return sig

    def column_entry(self, column: str) -> Optional[Dict]:
        return self._columns.get(column)

    def column_matrix(self, column: str) -> Tuple[np.ndarray, List[str]]:
        """The registered column's [N, D] matrix in row order (raises
        ``KeyError`` when unregistered or vectors are missing)."""
        with self._lock:
            entry = self._columns[column]
            vecs = [self._vecs[k] for k in entry["keys"]]
        return np.stack(vecs).astype(np.float32), list(entry["keys"])

    # -- persistence ---------------------------------------------------
    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path
        if path is None:
            raise ValueError("EmbeddingStore.save: no path configured")
        with self._lock:
            keys = sorted(self._vecs)
            mat = (np.stack([self._vecs[k] for k in keys])
                   if keys else np.zeros((0, 0), np.float32))
            meta = {"keys": keys, "columns": self._columns}
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        # temp-file + os.replace, like StatsStore.save: a crash mid-write
        # leaves the previous complete sidecar/matrix pair, never a
        # truncated file that poisons the next load.  The npz temp name
        # must already end in ".npz" or numpy appends the suffix itself.
        tmp_json = f"{path}.json.tmp.{os.getpid()}"
        tmp_npz = f"{path}.tmp.{os.getpid()}.npz"
        try:
            with open(tmp_json, "w") as f:
                json.dump(meta, f, indent=1, sort_keys=True)
            np.savez_compressed(tmp_npz, vectors=mat)
            os.replace(tmp_npz, path + ".npz")
            os.replace(tmp_json, path + ".json")
        finally:
            for tmp in (tmp_json, tmp_npz):
                if os.path.exists(tmp):
                    os.remove(tmp)
        return path

    def load(self, path: Optional[str] = None) -> None:
        """Merge a persisted store into this one.  Corrupt or partial
        files (the pre-atomic-save failure mode) warn and contribute
        nothing instead of raising — cached embeddings are recomputable,
        never a reason the store fails to construct."""
        path = path or self.path
        try:
            with open(path + ".json") as f:
                meta = json.load(f)
            mat = np.load(path + ".npz")["vectors"]
            keys = meta["keys"]
            if len(keys) != len(mat):
                raise ValueError(
                    f"sidecar lists {len(keys)} keys but matrix has "
                    f"{len(mat)} rows")
        except (json.JSONDecodeError, ValueError, KeyError, OSError,
                zipfile.BadZipFile) as exc:
            warnings.warn(
                f"EmbeddingStore: ignoring unreadable store at {path!r} "
                f"({exc}); starting from an empty cache", stacklevel=2)
            return
        with self._lock:
            for i, k in enumerate(keys):
                self._vecs.setdefault(k, mat[i].astype(np.float32))
            for col, entry in meta.get("columns", {}).items():
                self._columns.setdefault(col, entry)

    def clear(self) -> None:
        with self._lock:
            self._vecs.clear()
            self._columns.clear()
