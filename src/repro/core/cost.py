"""Cost model for AI-aware query optimization (paper §5.1).

The key departure from classical optimizers: the objective is the number /
price of LLM invocations, not join cardinality.  AI-operator selectivity is
unknown at compile time (default 0.5); cost per row is estimable from the
average token length of the referenced columns and the per-model price —
multimodal predicates (FILE args) are priced on the multimodal model tier.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core import expr as E
from repro.core import plan as P
from repro.inference.backend import CREDITS_PER_MTOK
from repro.tables.table import Table

# relative per-row evaluation cost of non-AI predicates (arbitrary tiny unit:
# one numpy comparison vs an LLM call is ~6-9 orders of magnitude)
REL_PRED_COST = 1e-7


@dataclasses.dataclass
class TableStats:
    rows: int
    ndv: Dict[str, int]
    avg_len: Dict[str, float]

    @classmethod
    def of(cls, t: Table) -> "TableStats":
        return cls(rows=t.num_rows,
                   ndv={c: t.ndv(c) for c in t.column_names},
                   avg_len={c: t.avg_len(c) for c in t.column_names})


@dataclasses.dataclass
class Catalog:
    tables: Dict[str, Table]

    def __post_init__(self):
        self.stats = {k: TableStats.of(v) for k, v in self.tables.items()}

    def table(self, name: str) -> Table:
        return self.tables[name]


class CostModel:
    def __init__(self, catalog: Catalog, *, default_model: str = "oracle-70b",
                 multimodal_model: str = "qwen2-vl-7b",
                 ai_selectivity_default: float = 0.5):
        self.catalog = catalog
        self.default_model = default_model
        self.multimodal_model = multimodal_model
        self.ai_sel = ai_selectivity_default
        # alias -> table stats resolved at plan time
        self._alias_stats: Dict[str, TableStats] = {}

    # ------------------------------------------------------------------
    def bind_alias(self, alias: str, table_name: str) -> None:
        self._alias_stats[alias] = self.catalog.stats[table_name]

    def _col_stats(self, qualified: str):
        alias, _, col = qualified.partition(".")
        st = self._alias_stats.get(alias)
        if st is None or col not in st.ndv:
            # unqualified or unknown: search all
            for st2 in self._alias_stats.values():
                if qualified in st2.ndv:
                    return st2, qualified
            return None, col
        return st, col

    def ndv(self, qualified: str) -> int:
        st, col = self._col_stats(qualified)
        return st.ndv.get(col, 100) if st else 100

    def avg_tokens(self, qualified: str) -> float:
        st, col = self._col_stats(qualified)
        chars = st.avg_len.get(col, 64.0) if st else 64.0
        return max(chars / 4.0, 2.0)

    # ------------------------------------------------------------------
    # per-predicate estimates
    # ------------------------------------------------------------------

    def predicate_cost_per_row(self, pred: E.Expr) -> float:
        """Credits per evaluated row."""
        if isinstance(pred, E.AIFilter):
            model = pred.model or (
                self.multimodal_model if pred.multimodal else self.default_model)
            toks = len(pred.prompt.template) / 4.0 + sum(
                self.avg_tokens(r) for r in pred.refs())
            return CREDITS_PER_MTOK.get(model, 0.5) * toks / 1e6
        if isinstance(pred, E.AIClassify):
            model = pred.model or self.default_model
            toks = sum(self.avg_tokens(r) for r in pred.refs()) + \
                4.0 * max(len(pred.labels), 4)
            return CREDITS_PER_MTOK.get(model, 0.5) * toks / 1e6
        return REL_PRED_COST

    def predicate_selectivity(self, pred: E.Expr) -> float:
        if isinstance(pred, (E.AIFilter, E.AIClassify)):
            return self.ai_sel                     # unknown at compile time
        if isinstance(pred, E.InList):
            if isinstance(pred.expr, E.Column):
                nd = self.ndv(pred.expr.name)
                return min(1.0, len(pred.values) / max(nd, 1))
            return 0.5
        if isinstance(pred, E.Between):
            return 0.25
        if isinstance(pred, E.BinOp):
            if pred.op == "=":
                lc = pred.left if isinstance(pred.left, E.Column) else None
                if lc is not None:
                    return 1.0 / max(self.ndv(lc.name), 1)
                return 0.1
            return 1.0 / 3.0
        if isinstance(pred, E.Not):
            return 1.0 - self.predicate_selectivity(pred.arg)
        if isinstance(pred, E.BoolOp):
            sels = [self.predicate_selectivity(a) for a in pred.args]
            if pred.op == "and":
                out = 1.0
                for s in sels:
                    out *= s
            else:
                inv = 1.0
                for s in sels:
                    inv *= (1.0 - s)
                out = 1.0 - inv
            return out
        if isinstance(pred, E.FuncCall):
            return 0.5
        return 0.5

    # ------------------------------------------------------------------
    # plan-level cardinality & LLM-cost estimation
    # ------------------------------------------------------------------

    def est_rows(self, node: P.PlanNode) -> float:
        if isinstance(node, P.Scan):
            self.bind_alias(node.alias, node.table)
            return float(self.catalog.stats[node.table].rows)
        if isinstance(node, P.Filter):
            r = self.est_rows(node.child)
            for p in node.predicates:
                r *= self.predicate_selectivity(p)
            return r
        if isinstance(node, P.Join):
            l = self.est_rows(node.left)
            r = self.est_rows(node.right)
            if node.equi:
                lk, rk = node.equi[0]
                denom = max(self.ndv(lk), self.ndv(rk), 1)
                out = l * r / denom
            else:
                out = l * r
            for p in node.residual:
                out *= self.predicate_selectivity(p)
            return out
        if isinstance(node, P.SemanticJoinClassify):
            l = self.est_rows(node.left)
            return l * 1.5                        # avg labels per row
        if isinstance(node, (P.Project, P.Aggregate, P.Limit)):
            r = self.est_rows(node.children()[0])
            if isinstance(node, P.Aggregate) and node.group_by:
                return min(r, self.ndv(node.group_by[0]))
            if isinstance(node, P.Limit):
                return min(r, node.n)
            return r
        raise TypeError(node)

    def est_llm_cost(self, node: P.PlanNode) -> float:
        """Total expected LLM credits of the plan (the §5.1 objective)."""
        total = 0.0
        if isinstance(node, P.Filter):
            rows = self.est_rows(node.child)
            for p in node.predicates:
                total += rows * self.predicate_cost_per_row(p)
                rows *= self.predicate_selectivity(p)
        if isinstance(node, P.Join):
            l = self.est_rows(node.left)
            r = self.est_rows(node.right)
            pairs = l * r if not node.equi else self.est_rows(
                P.Join(node.left, node.right, node.equi, ()))
            for p in node.residual:
                total += pairs * self.predicate_cost_per_row(p)
                pairs *= self.predicate_selectivity(p)
        if isinstance(node, P.SemanticJoinClassify):
            l = self.est_rows(node.left)
            r = self.est_rows(node.right)
            import math
            calls_per_row = max(1.0, math.ceil(r / node.max_labels_per_call))
            fake = E.AIClassify(node.prompt, labels=())
            total += l * calls_per_row * self.predicate_cost_per_row(fake)
        for c in node.children():
            total += self.est_llm_cost(c)
        return total
