"""Cost model for AI-aware query optimization (paper §5.1) + learned stats.

The key departure from classical optimizers: the objective is the number /
price of LLM invocations, not join cardinality.  AI-operator selectivity
is unknown at compile time; cost per row is estimable from the average
token length of the referenced columns and the per-model price —
multimodal predicates (FILE args) are priced on the multimodal model tier.

Two estimate sources, consulted in order:

  1. **observed statistics** — when a `StatsStore` is attached and holds
     enough evidence for a predicate's fingerprint (pilot samples or past
     queries), selectivity and cost-per-row come from real executions,
     Bayes-blended with the static prior while the sample is small;
  2. **static defaults** — the classical fallbacks, all named and
     configurable on `CostDefaults` (reachable via
     ``OptimizerConfig.cost_defaults``) instead of inline literals.

Units used throughout this module:

  * **credits** — the paper's §4 billing unit; ``CREDITS_PER_MTOK[model]
    × tokens / 1e6``.  All ``*_cost_per_row`` / ``est_llm_cost`` values.
  * **tokens** — model-input tokens, estimated as ``chars / 4``.
  * **rows** — table cardinalities; ``est_rows`` returns fractional
    expected rows, not integers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from typing import List, Tuple

import numpy as np

from repro.core import expr as E
from repro.core import plan as P
from repro.core.stats import (StatsStore, index_join_fingerprint,
                              predicate_fingerprint, predicate_prompt_text,
                              wilson_interval)
from repro.inference.backend import CREDITS_PER_MTOK, EMBED, credits_for
from repro.tables.table import Table

def _expr_name(e: E.Expr) -> str:
    """Column name of an expression side (fingerprint input)."""
    return e.name if isinstance(e, E.Column) else type(e).__name__


@dataclasses.dataclass
class CostDefaults:
    """Named fallback constants for every estimate the model cannot derive
    from catalog statistics or the `StatsStore`.

    Exposed on ``OptimizerConfig.cost_defaults`` so a workload can tune
    the planner's priors without touching code.  Units: selectivities are
    fractions in [0, 1]; ``rel_pred_cost`` is credits per row (kept many
    orders of magnitude below any LLM call); lengths are characters.
    """
    ai_selectivity: float = 0.5        # AI predicate pass rate, unknown a priori
    rel_pred_cost: float = 1e-7        # credits/row of a numpy comparison
    unknown_ndv: int = 100             # NDV of an unknown column
    unknown_avg_chars: float = 64.0    # avg value length of an unknown column
    min_tokens_per_value: float = 2.0  # floor on per-value token estimates
    eq_selectivity: float = 0.1        # "=" with a non-column left side
    inequality_selectivity: float = 1.0 / 3.0   # < <= > >= !=
    between_selectivity: float = 0.25
    in_list_selectivity: float = 0.5   # IN over a non-column expression
    func_selectivity: float = 0.5      # scalar builtins (FL_IS_IMAGE, ...)
    default_selectivity: float = 0.5   # anything else
    labels_per_left_row: float = 1.5   # SemanticJoinClassify fan-out
    # top-k prefilter: candidates escalated to the ordering model are
    # ``ceil(topk_candidate_factor * k)`` of the proxy's best rows
    topk_candidate_factor: float = 3.0
    # semantic index: fraction of a column's rows assumed *already*
    # embedded when the store cannot be consulted (0.0 = price the full
    # cold build; observed store coverage replaces this when available)
    index_coverage_default: float = 0.0
    # -- learned-stats trust policy -----------------------------------
    stats_min_rows: int = 24           # below this, observations are ignored
    stats_prior_strength: float = 16.0  # pseudo-rows backing the static prior
    # -- kNN prior transfer across predicates (cost model v2) ----------
    # a cold fingerprint borrows selectivity / cost-per-row / delegation
    # priors from the nearest *observed* predicates by prompt-embedding
    # similarity; needs a semindex + embed-capable client attached
    enable_stat_transfer: bool = True
    transfer_k: int = 3                # donor neighbours consulted
    transfer_min_sim: float = 0.35     # cosine floor for a donor to count
    # pseudo-row mass a perfect-similarity neighbour contributes; always
    # capped strictly below stats_min_rows, so a transferred prior can
    # never outrank a direct observation of the same size
    transfer_strength: float = 12.0


@dataclasses.dataclass
class TransferredPrior:
    """A cold predicate's estimates borrowed from its nearest observed
    neighbours by prompt-embedding similarity (cost model v2).

    ``n_eff`` is the pseudo-row mass backing the prior — similarity-
    scaled and hard-capped strictly below ``stats_min_rows``, so the
    `CostModel` always blends it toward the static prior and a direct
    observation of equal size always wins.  ``ci`` is a Wilson interval
    at a further similarity-discounted sample size: visibly wider than
    a same-``n`` direct observation's interval.
    """
    selectivity: float
    cost_per_row: float
    delegation_rate: float
    cascade_rows: int                  # donors' total cascaded rows
    n_eff: float                       # pseudo-rows (< stats_min_rows)
    donors: List[Tuple[str, float]]    # (fingerprint, cosine similarity)
    ci: Tuple[float, float]


@dataclasses.dataclass
class TableStats:
    """Per-table catalog statistics: row count, per-column NDV (distinct
    values) and average value length in characters."""
    rows: int
    ndv: Dict[str, int]
    avg_len: Dict[str, float]

    @classmethod
    def of(cls, t: Table) -> "TableStats":
        return cls(rows=t.num_rows,
                   ndv={c: t.ndv(c) for c in t.column_names},
                   avg_len={c: t.avg_len(c) for c in t.column_names})


class UnknownTableError(KeyError):
    """A query referenced a table the `Catalog` does not have.

    A ``KeyError`` subclass so every pre-existing ``except KeyError``
    around catalog lookups still works, but distinct enough that the
    serving layer maps *only* this — not every internal ``KeyError``
    bug — onto its client-side ``unknown_table`` error."""

    def __init__(self, name: str, known: "Dict[str, Table]"):
        super().__init__(name)
        self.table = name
        self.known = sorted(known)

    def __str__(self) -> str:
        return f"unknown table {self.table!r} (catalog has: {self.known})"


class _StatsDict(Dict[str, "TableStats"]):
    """Table-name -> `TableStats` that reports a miss as
    `UnknownTableError` (the first catalog lookup a query plan makes is
    usually ``catalog.stats[...]``, so the miss must carry the same
    client-mappable type as `Catalog.table`)."""

    def __missing__(self, name: str) -> "TableStats":
        raise UnknownTableError(name, self)


@dataclasses.dataclass
class Catalog:
    """The engine's table registry.

    Maps table name -> `Table` and eagerly computes `TableStats` for each
    (``self.stats``); both the optimizer's NDV/length estimates and the
    rewrite oracle's sample-value probes read through here.  Tables added
    after construction are not re-scanned — build a new Catalog instead.
    """
    tables: Dict[str, Table]

    def __post_init__(self):
        self.stats = _StatsDict(
            (k, TableStats.of(v)) for k, v in self.tables.items())

    def table(self, name: str) -> Table:
        """Return the registered `Table`; raises `UnknownTableError`
        (a ``KeyError``) if absent."""
        try:
            return self.tables[name]
        except KeyError:
            raise UnknownTableError(name, self.tables) from None


class CostModel:
    """Estimates rows, per-predicate selectivity/cost, and total LLM spend.

    Args:
        catalog: table registry supplying row counts / NDV / lengths.
        default_model: model priced for AI predicates that name none.
        multimodal_model: model priced for FILE-typed (multimodal) args.
        ai_selectivity_default: legacy override of
            ``defaults.ai_selectivity`` (kept for callers that predate
            `CostDefaults`).
        defaults: the static fallback constants (`CostDefaults`).
        stats: optional `StatsStore`; when set, observed selectivity and
            cost-per-row take precedence over the static defaults as soon
            as a fingerprint accumulates ``defaults.stats_min_rows``
            evaluated rows (blended with the prior below that — see
            `predicate_selectivity`).

    All costs are in **credits**, cardinalities in **rows**, token
    figures in **model-input tokens** (chars / 4).
    """

    def __init__(self, catalog: Catalog, *, default_model: str = "oracle-70b",
                 multimodal_model: str = "qwen2-vl-7b",
                 proxy_model: str = "proxy-8b",
                 embed_model: str = "arctic-embed-m",
                 ai_selectivity_default: Optional[float] = None,
                 defaults: Optional[CostDefaults] = None,
                 stats: Optional[StatsStore] = None):
        self.catalog = catalog
        self.default_model = default_model
        self.multimodal_model = multimodal_model
        self.proxy_model = proxy_model
        self.embed_model = embed_model
        # mirrors ExecConfig.topk_prefilter (the engine syncs it) so
        # TopK estimates price the path the executor will actually take
        self.topk_prefilter = True
        # the engine's SemanticIndexManager when a semantic index is
        # configured (None otherwise): unlocks the index-assisted join
        # race and lets TopK estimates read real store coverage
        self.semindex = None
        # the engine's client (set by `AisqlEngine`): lets the model
        # embed predicate prompts for kNN prior transfer; without it —
        # or without a semindex — transfer is disabled cleanly
        self.embed_client = None
        self.defaults = defaults or CostDefaults()
        if ai_selectivity_default is not None:
            self.defaults = dataclasses.replace(
                self.defaults, ai_selectivity=ai_selectivity_default)
        self.stats = stats
        # alias -> table stats resolved at plan time
        self._alias_stats: Dict[str, TableStats] = {}
        self._alias_tables: Dict[str, str] = {}
        # (model, qualified column) -> content keys, for store-coverage
        # estimates (catalog tables are immutable, so keys never change)
        self._coverage_keys: Dict[tuple, list] = {}
        # fingerprint -> (stats version, TransferredPrior|None): one
        # kNN computation per cold predicate per store state
        self._transfer_cache: Dict[str, tuple] = {}

    # ------------------------------------------------------------------
    def bind_alias(self, alias: str, table_name: str) -> None:
        """Associate a query alias with a catalog table's statistics (done
        automatically while walking Scans in `est_rows`)."""
        self._alias_stats[alias] = self.catalog.stats[table_name]
        self._alias_tables[alias] = table_name

    def _col_stats(self, qualified: str):
        alias, _, col = qualified.partition(".")
        st = self._alias_stats.get(alias)
        if st is None or col not in st.ndv:
            # unqualified or unknown: search all
            for st2 in self._alias_stats.values():
                if qualified in st2.ndv:
                    return st2, qualified
            return None, col
        return st, col

    def ndv(self, qualified: str) -> int:
        """Number of distinct values of an (alias-qualified) column;
        ``defaults.unknown_ndv`` when the column cannot be resolved."""
        st, col = self._col_stats(qualified)
        return st.ndv.get(col, self.defaults.unknown_ndv) if st \
            else self.defaults.unknown_ndv

    def avg_tokens(self, qualified: str) -> float:
        """Average per-value token count of a column (chars / 4, floored
        at ``defaults.min_tokens_per_value``)."""
        st, col = self._col_stats(qualified)
        chars = st.avg_len.get(col, self.defaults.unknown_avg_chars) if st \
            else self.defaults.unknown_avg_chars
        return max(chars / 4.0, self.defaults.min_tokens_per_value)

    # ------------------------------------------------------------------
    # observed-stats plumbing
    # ------------------------------------------------------------------

    def observed(self, pred: E.Expr):
        """The predicate's `PredObservation`, or None without a store."""
        if self.stats is None:
            return None
        return self.stats.get(predicate_fingerprint(pred))

    def _blend(self, observed: float, n_obs: float, prior: float) -> float:
        """Bayes-style shrinkage: observed mean backed by ``n_obs`` rows
        against a prior worth ``stats_prior_strength`` pseudo-rows."""
        n0 = self.defaults.stats_prior_strength
        return (observed * n_obs + prior * n0) / (n_obs + n0)

    # ------------------------------------------------------------------
    # kNN prior transfer (cost model v2)
    # ------------------------------------------------------------------

    def transferred_prior(self, pred: E.Expr
                          ) -> Optional[TransferredPrior]:
        """Borrowed estimates for a *cold* predicate from the k nearest
        observed predicates by prompt-embedding similarity.

        Requires the full transfer stack — a `StatsStore` with observed
        donors that registered prompt texts, a `SemanticIndexManager`
        (embedding store + top-k kernel) and an embed-capable client;
        with any piece missing (or ``enable_stat_transfer`` off) returns
        None and every estimate falls back to the static defaults, so
        transfer is an overlay, never a dependency.  Results are cached
        per (fingerprint, store version): re-planning is a dict lookup
        until new evidence lands.
        """
        d = self.defaults
        if not (d.enable_stat_transfer and self.stats is not None
                and self.semindex is not None
                and self.embed_client is not None):
            return None
        if not isinstance(pred, (E.AIFilter, E.AIScore, E.AIClassify)):
            return None
        text = predicate_prompt_text(pred)
        if not text:
            return None
        fp = predicate_fingerprint(pred)
        version = getattr(self.stats, "version", 0)
        cached = self._transfer_cache.get(fp)
        if cached is not None and cached[0] == version:
            return cached[1]
        prior = self._compute_transfer(fp, text)
        self._transfer_cache[fp] = (version, prior)
        return prior

    def _compute_transfer(self, fp: str, text: str
                          ) -> Optional[TransferredPrior]:
        d = self.defaults
        donors = []
        for key, obs in self.stats.items():
            if key == fp or obs.evaluated < d.stats_min_rows:
                continue
            donor_text = self.stats.prompt_text(key)
            if donor_text:
                donors.append((key, donor_text, obs))
        if not donors:
            return None
        vecs = self.semindex.embed_texts(
            self.embed_client, [text] + [t for _, t, _ in donors])
        k = min(d.transfer_k, len(donors))
        sims, idx = self.semindex.topk_candidates(vecs[:1], vecs[1:], k)
        pairs = [(float(s), int(i))
                 for s, i in zip(np.ravel(sims)[:k], np.ravel(idx)[:k])
                 if int(i) >= 0 and float(s) >= d.transfer_min_sim]
        if not pairs:
            return None
        wsum = sum(s for s, _ in pairs)
        sel = sum(s * donors[i][2].selectivity for s, i in pairs) / wsum
        cpr = sum(s * donors[i][2].cost_per_row for s, i in pairs) / wsum
        dele = sum(s * donors[i][2].delegation_rate
                   for s, i in pairs) / wsum
        top_sim = max(s for s, _ in pairs)
        # pseudo-rows: similarity-scaled, hard-capped strictly below the
        # direct-observation trust threshold
        n_eff = max(1.0, min(d.stats_min_rows - 1.0,
                             d.transfer_strength * top_sim))
        # the CI discounts the sample a second time by similarity —
        # transferred evidence at n rows must read wider than a direct
        # observation at n rows
        n_ci = max(1, int(n_eff * top_sim))
        ci = wilson_interval(int(round(sel * n_ci)), n_ci)
        return TransferredPrior(
            selectivity=sel, cost_per_row=cpr, delegation_rate=dele,
            cascade_rows=sum(donors[i][2].cascade_rows for _, i in pairs),
            n_eff=n_eff, donors=[(donors[i][0], s) for s, i in pairs],
            ci=(round(ci[0], 4), round(ci[1], 4)))

    def estimate_source(self, pred: E.Expr) -> str:
        """Provenance of this predicate's estimates: ``"observed"``
        (store is confident), ``"blended"`` (some evidence, shrunk toward
        the prior), ``"transferred"`` (no direct evidence — priors
        borrowed from the nearest observed predicates, or a cross-tenant
        shared-pool view) or ``"default"`` (static fallback only)."""
        if not isinstance(pred, (E.AIFilter, E.AIScore, E.AIClassify,
                                 E.AISimilarity, E.AIEmbed)):
            return "default"
        obs = self.observed(pred)
        if obs is None or not obs.evaluated:
            if self.transferred_prior(pred) is not None:
                return "transferred"
            return "default"
        if getattr(obs, "shared_prior", False):
            return "transferred"
        if obs.evaluated >= self.defaults.stats_min_rows:
            return "observed"
        return "blended"

    # ------------------------------------------------------------------
    # per-predicate estimates
    # ------------------------------------------------------------------

    def predicate_cost_per_row(self, pred: E.Expr) -> float:
        """Credits per evaluated row.

        AI predicates: observed credits/row from the `StatsStore` when
        available (prior-blended below ``stats_min_rows``), else the
        static token estimate ``price(model) × (template + arg tokens)``.
        Non-AI predicates: ``defaults.rel_pred_cost``.
        """
        if isinstance(pred, (E.AIFilter, E.AIScore, E.AIClassify,
                             E.AISimilarity, E.AIEmbed)):
            static = self._static_ai_cost_per_row(pred)
            obs = self.observed(pred)
            if obs is not None and obs.evaluated:
                if (obs.evaluated >= self.defaults.stats_min_rows
                        and not getattr(obs, "shared_prior", False)):
                    return obs.cost_per_row
                # shared-pool views and small samples stay prior-blended:
                # borrowed evidence must read less confident than own
                return self._blend(obs.cost_per_row, obs.evaluated, static)
            tp = self.transferred_prior(pred)
            if tp is not None:
                return self._blend(tp.cost_per_row, tp.n_eff, static)
            return static
        # comparisons over AI_SIMILARITY (e.g. ``AI_SIMILARITY(a,b) >
        # 0.8``) cost their embedded sides per row, not a numpy compare
        inner = [c for c in E.ai_calls_in(pred)
                 if isinstance(c, (E.AISimilarity, E.AIEmbed))]
        if inner:
            return sum(self._static_ai_cost_per_row(c) for c in inner)
        return self.defaults.rel_pred_cost

    def _embed_side_cost(self, side: E.Expr, coverage: float = 0.0,
                         model: Optional[str] = None) -> float:
        """Credits to embed one row of ``side`` on the embedding tier.
        Literals embed once per query — per-row that amortizes to ~0."""
        if not side.refs():
            return 0.0
        toks = sum(self.avg_tokens(r) for r in side.refs())
        return credits_for(model or self.embed_model, 1, EMBED) * toks \
            * (1.0 - coverage)

    def _column_values(self, qualified: str):
        """Raw values of an alias-qualified column, or None."""
        alias, _, col = qualified.partition(".")
        tname = self._alias_tables.get(alias)
        if tname is None:
            return None
        t = self.catalog.tables.get(tname)
        if t is None:
            return None
        name = col if col in t else (qualified if qualified in t else None)
        return t.column(name) if name else None

    def embed_coverage(self, side: E.Expr,
                       model: Optional[str] = None) -> float:
        """Fraction of ``side``'s values already in the embedding store
        (real coverage when a `SemanticIndexManager` is attached and the
        side is one resolvable column; the static default otherwise) —
        this is how a warm store makes index-assisted plans cheap at
        plan time, not just at run time.

        The column's content keys are computed once per (model, column)
        and cached for the engine's lifetime (catalog tables are
        immutable), so repeated plan-time coverage checks are dict
        lookups, not re-hashes of the whole column.
        """
        d = self.defaults.index_coverage_default
        if self.semindex is None:
            return d
        refs = side.refs()
        if len(refs) != 1:
            return d
        qualified = next(iter(refs))
        model = model or self.semindex.cfg.model or self.embed_model
        dim = self.semindex.cfg.dim
        # cache under the *resolved table*, not the query alias — the
        # same alias letter binds to different tables across queries on
        # one long-lived cost model
        alias, _, leaf = qualified.partition(".")
        tname = self._alias_tables.get(alias)
        cache_key = ((model, dim, f"{tname}.{leaf or qualified}")
                     if tname else None)
        keys = self._coverage_keys.get(cache_key) if cache_key else None
        if keys is None:
            vals = self._column_values(qualified)
            if vals is None:
                return d
            from repro.semindex.store import content_key
            keys = [content_key(model, str(v), dim) for v in vals]
            if cache_key:
                self._coverage_keys[cache_key] = keys
        if not keys:
            return d
        store = self.semindex.store
        return sum(k in store for k in keys) / len(keys)

    def _embed_model_of(self, pred: E.Expr) -> str:
        """The embedding model an AI_EMBED / AI_SIMILARITY will actually
        use: an explicit ``model =>`` wins over the default tier."""
        return getattr(pred, "model", None) or self.embed_model

    def _static_ai_cost_per_row(self, pred: E.Expr) -> float:
        if isinstance(pred, E.AIEmbed):
            m = self._embed_model_of(pred)
            return self._embed_side_cost(
                pred.arg, self.embed_coverage(pred.arg, m), m)
        if isinstance(pred, E.AISimilarity):
            m = self._embed_model_of(pred)
            return (self._embed_side_cost(
                        pred.left, self.embed_coverage(pred.left, m), m)
                    + self._embed_side_cost(
                        pred.right, self.embed_coverage(pred.right, m), m))
        if isinstance(pred, (E.AIFilter, E.AIScore)):
            model = pred.model or (
                self.multimodal_model
                if isinstance(pred, E.AIFilter) and pred.multimodal
                else self.default_model)
            toks = len(pred.prompt.template) / 4.0 + sum(
                self.avg_tokens(r) for r in pred.refs())
            return CREDITS_PER_MTOK.get(model, 0.5) * toks / 1e6
        model = pred.model or self.default_model
        toks = sum(self.avg_tokens(r) for r in pred.refs()) + \
            4.0 * max(len(pred.labels), 4)
        return CREDITS_PER_MTOK.get(model, 0.5) * toks / 1e6

    def predicate_selectivity(self, pred: E.Expr) -> float:
        """Expected pass fraction of the predicate, in [0, 1].

        AI predicates consult the `StatsStore` first: with at least
        ``defaults.stats_min_rows`` observed rows the observed pass rate
        is returned as-is; with fewer it is shrunk toward the static
        prior (``defaults.ai_selectivity``) by ``stats_prior_strength``
        pseudo-rows; with none the prior is returned — so a cold-start
        plan is exactly the static plan.  Relational predicates use the
        classical NDV-based rules with `CostDefaults` fallbacks.
        """
        d = self.defaults
        if isinstance(pred, (E.AIScore, E.AISimilarity, E.AIEmbed)):
            return 1.0                 # value-producing, never filters rows
        if isinstance(pred, (E.AIFilter, E.AIClassify)):
            obs = self.observed(pred)
            if obs is not None and obs.evaluated:
                if (obs.evaluated >= d.stats_min_rows
                        and not getattr(obs, "shared_prior", False)):
                    return obs.selectivity
                return self._blend(obs.selectivity, obs.evaluated,
                                   d.ai_selectivity)
            tp = self.transferred_prior(pred)
            if tp is not None:
                return self._blend(tp.selectivity, tp.n_eff,
                                   d.ai_selectivity)
            return d.ai_selectivity
        if isinstance(pred, E.InList):
            if isinstance(pred.expr, E.Column):
                nd = self.ndv(pred.expr.name)
                return min(1.0, len(pred.values) / max(nd, 1))
            return d.in_list_selectivity
        if isinstance(pred, E.Between):
            return d.between_selectivity
        if isinstance(pred, E.BinOp):
            if pred.op == "=":
                lc = pred.left if isinstance(pred.left, E.Column) else None
                if lc is not None:
                    return 1.0 / max(self.ndv(lc.name), 1)
                return d.eq_selectivity
            return d.inequality_selectivity
        if isinstance(pred, E.Not):
            return 1.0 - self.predicate_selectivity(pred.arg)
        if isinstance(pred, E.BoolOp):
            sels = [self.predicate_selectivity(a) for a in pred.args]
            if pred.op == "and":
                out = 1.0
                for s in sels:
                    out *= s
            else:
                inv = 1.0
                for s in sels:
                    inv *= (1.0 - s)
                out = 1.0 - inv
            return out
        if isinstance(pred, E.FuncCall):
            return d.func_selectivity
        return d.default_selectivity

    def predicate_rank(self, pred: E.Expr) -> float:
        """Hellerstein expensive-predicate rank: ``cost_per_row / (1 -
        selectivity)`` in credits — evaluation order ascending by rank
        minimises expected filter cost.  Uses observed stats when the
        store has them (same precedence as the underlying estimates)."""
        c = self.predicate_cost_per_row(pred)
        s = self.predicate_selectivity(pred)
        return c / max(1.0 - s, 1e-9)

    def selectivity_interval(self, pred: E.Expr):
        """``(lo, hi)`` Wilson confidence interval on an AI predicate's
        selectivity: from observed evidence when the store has any, from
        the (similarity-widened) transferred prior for a cold predicate
        with usable neighbours, and ``(0.0, 1.0)`` — maximum uncertainty
        — for a true cold start."""
        if not isinstance(pred, (E.AIFilter, E.AIClassify)):
            return 0.0, 1.0
        obs = self.observed(pred)
        if obs is None or not obs.evaluated:
            tp = self.transferred_prior(pred)
            if tp is not None:
                return tp.ci
            return 0.0, 1.0
        return obs.selectivity_ci()

    # ------------------------------------------------------------------
    # plan-level cardinality & LLM-cost estimation
    # ------------------------------------------------------------------

    def est_rows(self, node: P.PlanNode) -> float:
        """Expected output cardinality of a plan subtree, in rows.

        Walking Scans binds aliases to table stats as a side effect, so
        call this on the root before per-predicate estimates.
        """
        if isinstance(node, P.Scan):
            self.bind_alias(node.alias, node.table)
            return float(self.catalog.stats[node.table].rows)
        if isinstance(node, P.Filter):
            r = self.est_rows(node.child)
            for p in node.predicates:
                r *= self.predicate_selectivity(p)
            return r
        if isinstance(node, P.Join):
            l = self.est_rows(node.left)
            r = self.est_rows(node.right)
            if node.equi:
                lk, rk = node.equi[0]
                denom = max(self.ndv(lk), self.ndv(rk), 1)
                out = l * r / denom
            else:
                out = l * r
            for p in node.residual:
                out *= self.predicate_selectivity(p)
            return out
        if isinstance(node, (P.SemanticJoinClassify, P.SemanticJoinIndex)):
            l = self.est_rows(node.left)
            return l * self.defaults.labels_per_left_row
        if isinstance(node, P.TopK):
            return min(self.est_rows(node.child), float(node.n))
        if isinstance(node, (P.Project, P.Aggregate, P.Limit, P.Sort)):
            r = self.est_rows(node.children()[0])
            if isinstance(node, P.Aggregate) and node.group_by:
                return min(r, self.ndv(node.group_by[0]))
            if isinstance(node, P.Limit):
                return min(r, node.n)
            return r
        raise TypeError(node)

    def est_llm_cost(self, node: P.PlanNode) -> float:
        """Total expected LLM **credits** of the plan — the §5.1 objective
        every optimizer rewrite minimises."""
        total = 0.0
        if isinstance(node, P.Filter):
            rows = self.est_rows(node.child)
            for p in node.predicates:
                total += rows * self.predicate_cost_per_row(p)
                rows *= self.predicate_selectivity(p)
        if isinstance(node, P.Join):
            l = self.est_rows(node.left)
            r = self.est_rows(node.right)
            pairs = l * r if not node.equi else self.est_rows(
                P.Join(node.left, node.right, node.equi, ()))
            for p in node.residual:
                total += pairs * self.predicate_cost_per_row(p)
                pairs *= self.predicate_selectivity(p)
        if isinstance(node, P.SemanticJoinClassify):
            l = self.est_rows(node.left)
            r = self.est_rows(node.right)
            calls_per_row = max(1.0, math.ceil(r / node.max_labels_per_call))
            labels_per_call = min(r, float(node.max_labels_per_call))
            # the same surrogate the executor records observations under,
            # so cross-query feedback reaches the rewrite decision; the
            # static fallback prices the real per-call context — left
            # text plus a full label chunk — so the three-way race with
            # the index plan compares like with like
            fake = E.AIClassify(node.prompt, labels=(), model=node.model)
            obs = self.observed(fake)
            static = self._verify_call_cost(node, labels_per_call)
            if obs is not None and obs.evaluated:
                if obs.evaluated >= self.defaults.stats_min_rows:
                    per_call = obs.cost_per_row
                else:
                    per_call = self._blend(obs.cost_per_row, obs.evaluated,
                                           static)
            else:
                per_call = static
            total += l * calls_per_row * per_call
        if isinstance(node, P.SemanticJoinIndex):
            total += self._index_join_cost(node)
        if isinstance(node, P.Sort):
            rows = self.est_rows(node.child)
            for sk in node.keys:
                if isinstance(sk.expr, E.AIScore):
                    total += rows * self.predicate_cost_per_row(
                        self.resolved_score(sk.expr))
                elif isinstance(sk.expr, E.AISimilarity):
                    total += rows * self.predicate_cost_per_row(
                        self.resolved_similarity(sk.expr))
        if isinstance(node, P.TopK):
            rows = self.est_rows(node.child)
            cand = self.topk_candidates(rows, node.n)
            prefilter = self.topk_prefilter_applies(node, rows)
            for i, sk in enumerate(node.keys):
                if isinstance(sk.expr, E.AISimilarity):
                    # embedding-based: every distinct row text embeds
                    # once regardless of pruning (the index saves the
                    # *re*-embeds, which coverage already discounts)
                    total += rows * self.predicate_cost_per_row(
                        self.resolved_similarity(sk.expr))
                    continue
                if not isinstance(sk.expr, E.AIScore):
                    continue
                if prefilter and i == 0:
                    # proxy scores the full input; only the candidates
                    # are escalated to the ordering model
                    total += rows * self.predicate_cost_per_row(
                        self.resolved_score(sk.expr, self.proxy_model))
                    total += cand * self.predicate_cost_per_row(
                        self.resolved_score(sk.expr))
                else:
                    scored = cand if prefilter else rows
                    total += scored * self.predicate_cost_per_row(
                        self.resolved_score(sk.expr))
        for c in node.children():
            total += self.est_llm_cost(c)
        return total

    # ------------------------------------------------------------------
    # semantic ORDER BY helpers
    # ------------------------------------------------------------------

    def resolved_score(self, pred: E.AIScore,
                       model: Optional[str] = None) -> E.AIScore:
        """The surrogate the executor records observations under: an
        `E.AIScore` with its model made explicit (the fingerprint keeps
        proxy-prefilter and oracle scores as distinct populations)."""
        return E.AIScore(pred.prompt,
                         model=model or pred.model or self.default_model)

    def resolved_similarity(self, pred: E.AISimilarity) -> E.AISimilarity:
        """`E.AISimilarity` with the embedding model made explicit —
        the surrogate both pricing and executor telemetry key on."""
        return E.AISimilarity(pred.left, pred.right,
                              model=pred.model or self.embed_model)

    # ------------------------------------------------------------------
    # index-assisted semantic join pricing
    # ------------------------------------------------------------------

    def index_candidates_per_probe(self, node: P.SemanticJoinIndex,
                                   right_rows: float) -> float:
        """Learned mean kNN candidates per probe row for this blocking
        site (`StatsStore.observe_index` feedback); static default is
        the configured ``k``."""
        obs = None
        if self.stats is not None:
            obs = self.stats.get(index_join_fingerprint(
                node.prompt.template, node.model,
                _expr_name(node.left_arg), node.label_col))
        cand = (obs.candidates_per_probe
                if obs is not None and obs.index_probes else float(node.k))
        return min(cand, right_rows) if right_rows else cand

    def index_verify_surrogate(self, node) -> E.AIClassify:
        """The surrogate `E.AIClassify` the executor records the index
        join's verification calls under — labels ``("__index__",)`` keep
        it a distinct fingerprint from the full rewrite's surrogate (the
        two have very different per-call token counts)."""
        return E.AIClassify(node.prompt, labels=("__index__",),
                            model=node.model)

    def _index_join_cost(self, node: P.SemanticJoinIndex) -> float:
        """Expected credits of index-assisted blocking: embed both sides
        (store coverage discounts), then one multi-label verification
        call per left row over ~candidates_per_probe labels."""
        l = self.est_rows(node.left)
        r = self.est_rows(node.right)
        label_side = E.Column(node.label_col)
        emb = (l * self._embed_side_cost(node.left_arg,
                                         self.embed_coverage(node.left_arg))
               + r * self._embed_side_cost(label_side,
                                           self.embed_coverage(label_side)))
        cand = self.index_candidates_per_probe(node, r)
        calls_per_row = max(1.0, math.ceil(
            cand / max(node.max_labels_per_call, 1)))
        fake = self.index_verify_surrogate(node)
        obs = self.observed(fake)
        static = self._verify_call_cost(node, cand)
        if obs is not None and obs.evaluated:
            if obs.evaluated >= self.defaults.stats_min_rows:
                per_call = obs.cost_per_row
            else:
                per_call = self._blend(obs.cost_per_row, obs.evaluated,
                                       static)
        else:
            per_call = static
        return emb + l * calls_per_row * per_call

    def _verify_call_cost(self, node, labels_in_call: float) -> float:
        """Static per-call price of one multi-label verification call
        (classify rewrite or index blocking): the left text plus
        ``labels_in_call`` candidate labels in the context."""
        model = node.model or self.default_model
        label_toks = max(self.avg_tokens(node.label_col), 2.0) + 2.0
        toks = (len(node.prompt.template) / 4.0
                + sum(self.avg_tokens(rf) for rf in node.left_arg.refs())
                + labels_in_call * label_toks)
        return CREDITS_PER_MTOK.get(model, 0.5) * toks / 1e6

    def topk_candidates(self, rows: float, n: int) -> float:
        """Rows escalated to the ordering model by the top-k prefilter."""
        return min(rows, float(max(
            n, math.ceil(self.defaults.topk_candidate_factor * n))))

    def topk_prefilter_applies(self, node: P.TopK, rows: float) -> bool:
        """Whether the executor's proxy prefilter would run for this
        TopK: enabled, AI-scored primary key, a proxy distinct from the
        ordering model, and fewer candidates than input rows."""
        if not (self.topk_prefilter and node.keys
                and isinstance(node.keys[0].expr, E.AIScore)):
            return False
        oracle = node.keys[0].expr.model or self.default_model
        return (oracle != self.proxy_model
                and self.topk_candidates(rows, node.n) < rows)
