"""Cost model for AI-aware query optimization (paper §5.1) + learned stats.

The key departure from classical optimizers: the objective is the number /
price of LLM invocations, not join cardinality.  AI-operator selectivity
is unknown at compile time; cost per row is estimable from the average
token length of the referenced columns and the per-model price —
multimodal predicates (FILE args) are priced on the multimodal model tier.

Two estimate sources, consulted in order:

  1. **observed statistics** — when a `StatsStore` is attached and holds
     enough evidence for a predicate's fingerprint (pilot samples or past
     queries), selectivity and cost-per-row come from real executions,
     Bayes-blended with the static prior while the sample is small;
  2. **static defaults** — the classical fallbacks, all named and
     configurable on `CostDefaults` (reachable via
     ``OptimizerConfig.cost_defaults``) instead of inline literals.

Units used throughout this module:

  * **credits** — the paper's §4 billing unit; ``CREDITS_PER_MTOK[model]
    × tokens / 1e6``.  All ``*_cost_per_row`` / ``est_llm_cost`` values.
  * **tokens** — model-input tokens, estimated as ``chars / 4``.
  * **rows** — table cardinalities; ``est_rows`` returns fractional
    expected rows, not integers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.core import expr as E
from repro.core import plan as P
from repro.core.stats import StatsStore, predicate_fingerprint
from repro.inference.backend import CREDITS_PER_MTOK
from repro.tables.table import Table

@dataclasses.dataclass
class CostDefaults:
    """Named fallback constants for every estimate the model cannot derive
    from catalog statistics or the `StatsStore`.

    Exposed on ``OptimizerConfig.cost_defaults`` so a workload can tune
    the planner's priors without touching code.  Units: selectivities are
    fractions in [0, 1]; ``rel_pred_cost`` is credits per row (kept many
    orders of magnitude below any LLM call); lengths are characters.
    """
    ai_selectivity: float = 0.5        # AI predicate pass rate, unknown a priori
    rel_pred_cost: float = 1e-7        # credits/row of a numpy comparison
    unknown_ndv: int = 100             # NDV of an unknown column
    unknown_avg_chars: float = 64.0    # avg value length of an unknown column
    min_tokens_per_value: float = 2.0  # floor on per-value token estimates
    eq_selectivity: float = 0.1        # "=" with a non-column left side
    inequality_selectivity: float = 1.0 / 3.0   # < <= > >= !=
    between_selectivity: float = 0.25
    in_list_selectivity: float = 0.5   # IN over a non-column expression
    func_selectivity: float = 0.5      # scalar builtins (FL_IS_IMAGE, ...)
    default_selectivity: float = 0.5   # anything else
    labels_per_left_row: float = 1.5   # SemanticJoinClassify fan-out
    # top-k prefilter: candidates escalated to the ordering model are
    # ``ceil(topk_candidate_factor * k)`` of the proxy's best rows
    topk_candidate_factor: float = 3.0
    # -- learned-stats trust policy -----------------------------------
    stats_min_rows: int = 24           # below this, observations are ignored
    stats_prior_strength: float = 16.0  # pseudo-rows backing the static prior


@dataclasses.dataclass
class TableStats:
    """Per-table catalog statistics: row count, per-column NDV (distinct
    values) and average value length in characters."""
    rows: int
    ndv: Dict[str, int]
    avg_len: Dict[str, float]

    @classmethod
    def of(cls, t: Table) -> "TableStats":
        return cls(rows=t.num_rows,
                   ndv={c: t.ndv(c) for c in t.column_names},
                   avg_len={c: t.avg_len(c) for c in t.column_names})


@dataclasses.dataclass
class Catalog:
    """The engine's table registry.

    Maps table name -> `Table` and eagerly computes `TableStats` for each
    (``self.stats``); both the optimizer's NDV/length estimates and the
    rewrite oracle's sample-value probes read through here.  Tables added
    after construction are not re-scanned — build a new Catalog instead.
    """
    tables: Dict[str, Table]

    def __post_init__(self):
        self.stats = {k: TableStats.of(v) for k, v in self.tables.items()}

    def table(self, name: str) -> Table:
        """Return the registered `Table`; raises ``KeyError`` if absent."""
        return self.tables[name]


class CostModel:
    """Estimates rows, per-predicate selectivity/cost, and total LLM spend.

    Args:
        catalog: table registry supplying row counts / NDV / lengths.
        default_model: model priced for AI predicates that name none.
        multimodal_model: model priced for FILE-typed (multimodal) args.
        ai_selectivity_default: legacy override of
            ``defaults.ai_selectivity`` (kept for callers that predate
            `CostDefaults`).
        defaults: the static fallback constants (`CostDefaults`).
        stats: optional `StatsStore`; when set, observed selectivity and
            cost-per-row take precedence over the static defaults as soon
            as a fingerprint accumulates ``defaults.stats_min_rows``
            evaluated rows (blended with the prior below that — see
            `predicate_selectivity`).

    All costs are in **credits**, cardinalities in **rows**, token
    figures in **model-input tokens** (chars / 4).
    """

    def __init__(self, catalog: Catalog, *, default_model: str = "oracle-70b",
                 multimodal_model: str = "qwen2-vl-7b",
                 proxy_model: str = "proxy-8b",
                 ai_selectivity_default: Optional[float] = None,
                 defaults: Optional[CostDefaults] = None,
                 stats: Optional[StatsStore] = None):
        self.catalog = catalog
        self.default_model = default_model
        self.multimodal_model = multimodal_model
        self.proxy_model = proxy_model
        # mirrors ExecConfig.topk_prefilter (the engine syncs it) so
        # TopK estimates price the path the executor will actually take
        self.topk_prefilter = True
        self.defaults = defaults or CostDefaults()
        if ai_selectivity_default is not None:
            self.defaults = dataclasses.replace(
                self.defaults, ai_selectivity=ai_selectivity_default)
        self.stats = stats
        # alias -> table stats resolved at plan time
        self._alias_stats: Dict[str, TableStats] = {}

    # ------------------------------------------------------------------
    def bind_alias(self, alias: str, table_name: str) -> None:
        """Associate a query alias with a catalog table's statistics (done
        automatically while walking Scans in `est_rows`)."""
        self._alias_stats[alias] = self.catalog.stats[table_name]

    def _col_stats(self, qualified: str):
        alias, _, col = qualified.partition(".")
        st = self._alias_stats.get(alias)
        if st is None or col not in st.ndv:
            # unqualified or unknown: search all
            for st2 in self._alias_stats.values():
                if qualified in st2.ndv:
                    return st2, qualified
            return None, col
        return st, col

    def ndv(self, qualified: str) -> int:
        """Number of distinct values of an (alias-qualified) column;
        ``defaults.unknown_ndv`` when the column cannot be resolved."""
        st, col = self._col_stats(qualified)
        return st.ndv.get(col, self.defaults.unknown_ndv) if st \
            else self.defaults.unknown_ndv

    def avg_tokens(self, qualified: str) -> float:
        """Average per-value token count of a column (chars / 4, floored
        at ``defaults.min_tokens_per_value``)."""
        st, col = self._col_stats(qualified)
        chars = st.avg_len.get(col, self.defaults.unknown_avg_chars) if st \
            else self.defaults.unknown_avg_chars
        return max(chars / 4.0, self.defaults.min_tokens_per_value)

    # ------------------------------------------------------------------
    # observed-stats plumbing
    # ------------------------------------------------------------------

    def observed(self, pred: E.Expr):
        """The predicate's `PredObservation`, or None without a store."""
        if self.stats is None:
            return None
        return self.stats.get(predicate_fingerprint(pred))

    def _blend(self, observed: float, n_obs: float, prior: float) -> float:
        """Bayes-style shrinkage: observed mean backed by ``n_obs`` rows
        against a prior worth ``stats_prior_strength`` pseudo-rows."""
        n0 = self.defaults.stats_prior_strength
        return (observed * n_obs + prior * n0) / (n_obs + n0)

    def estimate_source(self, pred: E.Expr) -> str:
        """Provenance of this predicate's estimates: ``"observed"``
        (store is confident), ``"blended"`` (some evidence, shrunk toward
        the prior) or ``"default"`` (static fallback only)."""
        if not isinstance(pred, (E.AIFilter, E.AIScore, E.AIClassify)):
            return "default"
        obs = self.observed(pred)
        if obs is None or not obs.evaluated:
            return "default"
        if obs.evaluated >= self.defaults.stats_min_rows:
            return "observed"
        return "blended"

    # ------------------------------------------------------------------
    # per-predicate estimates
    # ------------------------------------------------------------------

    def predicate_cost_per_row(self, pred: E.Expr) -> float:
        """Credits per evaluated row.

        AI predicates: observed credits/row from the `StatsStore` when
        available (prior-blended below ``stats_min_rows``), else the
        static token estimate ``price(model) × (template + arg tokens)``.
        Non-AI predicates: ``defaults.rel_pred_cost``.
        """
        if isinstance(pred, (E.AIFilter, E.AIScore, E.AIClassify)):
            static = self._static_ai_cost_per_row(pred)
            obs = self.observed(pred)
            if obs is not None and obs.evaluated:
                if obs.evaluated >= self.defaults.stats_min_rows:
                    return obs.cost_per_row
                return self._blend(obs.cost_per_row, obs.evaluated, static)
            return static
        return self.defaults.rel_pred_cost

    def _static_ai_cost_per_row(self, pred: E.Expr) -> float:
        if isinstance(pred, (E.AIFilter, E.AIScore)):
            model = pred.model or (
                self.multimodal_model
                if isinstance(pred, E.AIFilter) and pred.multimodal
                else self.default_model)
            toks = len(pred.prompt.template) / 4.0 + sum(
                self.avg_tokens(r) for r in pred.refs())
            return CREDITS_PER_MTOK.get(model, 0.5) * toks / 1e6
        model = pred.model or self.default_model
        toks = sum(self.avg_tokens(r) for r in pred.refs()) + \
            4.0 * max(len(pred.labels), 4)
        return CREDITS_PER_MTOK.get(model, 0.5) * toks / 1e6

    def predicate_selectivity(self, pred: E.Expr) -> float:
        """Expected pass fraction of the predicate, in [0, 1].

        AI predicates consult the `StatsStore` first: with at least
        ``defaults.stats_min_rows`` observed rows the observed pass rate
        is returned as-is; with fewer it is shrunk toward the static
        prior (``defaults.ai_selectivity``) by ``stats_prior_strength``
        pseudo-rows; with none the prior is returned — so a cold-start
        plan is exactly the static plan.  Relational predicates use the
        classical NDV-based rules with `CostDefaults` fallbacks.
        """
        d = self.defaults
        if isinstance(pred, E.AIScore):
            return 1.0                 # ORDER BY keys never filter rows
        if isinstance(pred, (E.AIFilter, E.AIClassify)):
            obs = self.observed(pred)
            if obs is not None and obs.evaluated:
                if obs.evaluated >= d.stats_min_rows:
                    return obs.selectivity
                return self._blend(obs.selectivity, obs.evaluated,
                                   d.ai_selectivity)
            return d.ai_selectivity
        if isinstance(pred, E.InList):
            if isinstance(pred.expr, E.Column):
                nd = self.ndv(pred.expr.name)
                return min(1.0, len(pred.values) / max(nd, 1))
            return d.in_list_selectivity
        if isinstance(pred, E.Between):
            return d.between_selectivity
        if isinstance(pred, E.BinOp):
            if pred.op == "=":
                lc = pred.left if isinstance(pred.left, E.Column) else None
                if lc is not None:
                    return 1.0 / max(self.ndv(lc.name), 1)
                return d.eq_selectivity
            return d.inequality_selectivity
        if isinstance(pred, E.Not):
            return 1.0 - self.predicate_selectivity(pred.arg)
        if isinstance(pred, E.BoolOp):
            sels = [self.predicate_selectivity(a) for a in pred.args]
            if pred.op == "and":
                out = 1.0
                for s in sels:
                    out *= s
            else:
                inv = 1.0
                for s in sels:
                    inv *= (1.0 - s)
                out = 1.0 - inv
            return out
        if isinstance(pred, E.FuncCall):
            return d.func_selectivity
        return d.default_selectivity

    def predicate_rank(self, pred: E.Expr) -> float:
        """Hellerstein expensive-predicate rank: ``cost_per_row / (1 -
        selectivity)`` in credits — evaluation order ascending by rank
        minimises expected filter cost.  Uses observed stats when the
        store has them (same precedence as the underlying estimates)."""
        c = self.predicate_cost_per_row(pred)
        s = self.predicate_selectivity(pred)
        return c / max(1.0 - s, 1e-9)

    def selectivity_interval(self, pred: E.Expr):
        """``(lo, hi)`` Wilson confidence interval on an AI predicate's
        selectivity from observed evidence; ``(0.0, 1.0)`` when the store
        has nothing (maximum uncertainty — the cold-start case)."""
        obs = self.observed(pred) if isinstance(
            pred, (E.AIFilter, E.AIClassify)) else None
        if obs is None or not obs.evaluated:
            return 0.0, 1.0
        return obs.selectivity_ci()

    # ------------------------------------------------------------------
    # plan-level cardinality & LLM-cost estimation
    # ------------------------------------------------------------------

    def est_rows(self, node: P.PlanNode) -> float:
        """Expected output cardinality of a plan subtree, in rows.

        Walking Scans binds aliases to table stats as a side effect, so
        call this on the root before per-predicate estimates.
        """
        if isinstance(node, P.Scan):
            self.bind_alias(node.alias, node.table)
            return float(self.catalog.stats[node.table].rows)
        if isinstance(node, P.Filter):
            r = self.est_rows(node.child)
            for p in node.predicates:
                r *= self.predicate_selectivity(p)
            return r
        if isinstance(node, P.Join):
            l = self.est_rows(node.left)
            r = self.est_rows(node.right)
            if node.equi:
                lk, rk = node.equi[0]
                denom = max(self.ndv(lk), self.ndv(rk), 1)
                out = l * r / denom
            else:
                out = l * r
            for p in node.residual:
                out *= self.predicate_selectivity(p)
            return out
        if isinstance(node, P.SemanticJoinClassify):
            l = self.est_rows(node.left)
            return l * self.defaults.labels_per_left_row
        if isinstance(node, P.TopK):
            return min(self.est_rows(node.child), float(node.n))
        if isinstance(node, (P.Project, P.Aggregate, P.Limit, P.Sort)):
            r = self.est_rows(node.children()[0])
            if isinstance(node, P.Aggregate) and node.group_by:
                return min(r, self.ndv(node.group_by[0]))
            if isinstance(node, P.Limit):
                return min(r, node.n)
            return r
        raise TypeError(node)

    def est_llm_cost(self, node: P.PlanNode) -> float:
        """Total expected LLM **credits** of the plan — the §5.1 objective
        every optimizer rewrite minimises."""
        total = 0.0
        if isinstance(node, P.Filter):
            rows = self.est_rows(node.child)
            for p in node.predicates:
                total += rows * self.predicate_cost_per_row(p)
                rows *= self.predicate_selectivity(p)
        if isinstance(node, P.Join):
            l = self.est_rows(node.left)
            r = self.est_rows(node.right)
            pairs = l * r if not node.equi else self.est_rows(
                P.Join(node.left, node.right, node.equi, ()))
            for p in node.residual:
                total += pairs * self.predicate_cost_per_row(p)
                pairs *= self.predicate_selectivity(p)
        if isinstance(node, P.SemanticJoinClassify):
            l = self.est_rows(node.left)
            r = self.est_rows(node.right)
            calls_per_row = max(1.0, math.ceil(r / node.max_labels_per_call))
            # the same surrogate the executor records observations under,
            # so cross-query feedback reaches the rewrite decision
            fake = E.AIClassify(node.prompt, labels=(), model=node.model)
            total += l * calls_per_row * self.predicate_cost_per_row(fake)
        if isinstance(node, P.Sort):
            rows = self.est_rows(node.child)
            for sk in node.keys:
                if isinstance(sk.expr, E.AIScore):
                    total += rows * self.predicate_cost_per_row(
                        self.resolved_score(sk.expr))
        if isinstance(node, P.TopK):
            rows = self.est_rows(node.child)
            cand = self.topk_candidates(rows, node.n)
            prefilter = self.topk_prefilter_applies(node, rows)
            for i, sk in enumerate(node.keys):
                if not isinstance(sk.expr, E.AIScore):
                    continue
                if prefilter and i == 0:
                    # proxy scores the full input; only the candidates
                    # are escalated to the ordering model
                    total += rows * self.predicate_cost_per_row(
                        self.resolved_score(sk.expr, self.proxy_model))
                    total += cand * self.predicate_cost_per_row(
                        self.resolved_score(sk.expr))
                else:
                    scored = cand if prefilter else rows
                    total += scored * self.predicate_cost_per_row(
                        self.resolved_score(sk.expr))
        for c in node.children():
            total += self.est_llm_cost(c)
        return total

    # ------------------------------------------------------------------
    # semantic ORDER BY helpers
    # ------------------------------------------------------------------

    def resolved_score(self, pred: E.AIScore,
                       model: Optional[str] = None) -> E.AIScore:
        """The surrogate the executor records observations under: an
        `E.AIScore` with its model made explicit (the fingerprint keeps
        proxy-prefilter and oracle scores as distinct populations)."""
        return E.AIScore(pred.prompt,
                         model=model or pred.model or self.default_model)

    def topk_candidates(self, rows: float, n: int) -> float:
        """Rows escalated to the ordering model by the top-k prefilter."""
        return min(rows, float(max(
            n, math.ceil(self.defaults.topk_candidate_factor * n))))

    def topk_prefilter_applies(self, node: P.TopK, rows: float) -> bool:
        """Whether the executor's proxy prefilter would run for this
        TopK: enabled, AI-scored primary key, a proxy distinct from the
        ordering model, and fewer candidates than input rows."""
        if not (self.topk_prefilter and node.keys
                and isinstance(node.keys[0].expr, E.AIScore)):
            return False
        oracle = node.keys[0].expr.model or self.default_model
        return (oracle != self.proxy_model
                and self.topk_candidates(rows, node.n) < rows)
