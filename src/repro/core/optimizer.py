"""AI-aware query optimization (paper §5.1) + semantic-join rewrite (§5.3).

Three plan rewrites, all driven by the LLM-cost objective (``CostModel.
est_llm_cost``) rather than join cardinality:

1. **Predicate reordering** — within every Filter, order conjuncts by the
   classical expensive-predicate rank cost/(1 - selectivity); with AI
   selectivities unknown (default 0.5) this degenerates to exactly the
   paper's rule "most expensive predicates last".

2. **AI-predicate placement wrt joins** — every AI conjunct sitting below a
   join may be *pulled up* above it (and conversely a post-join AI conjunct
   referencing one side only may be *pushed down*).  We enumerate the
   pull/push assignment per AI predicate and keep the plan with the lowest
   estimated total LLM cost — reproducing Plan A → Plan B of Fig. 7.

3. **Semantic-join rewrite** — a join whose residual is an AI_FILTER over
   one column from each side is a multi-label classification in disguise
   when one side's column behaves like a label set.  A *rewrite oracle*
   inspects the prompt text, schema metadata, NDV statistics and sample
   values (and can optionally consult an LLM) to pick the label side; the
   join is then rewritten to ``SemanticJoinClassify`` — O(|L|·|R|) → O(|L|).
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core import expr as E
from repro.core import plan as P
from repro.core.cost import Catalog, CostDefaults, CostModel
from repro.core.plan import refs_aliases
from repro.core.stats import predicate_fingerprint
from repro.obs.trace import active_tracer

MODES = ("ai_aware", "always_pushdown", "always_pullup", "none")


@dataclasses.dataclass
class OptimizerConfig:
    """Planner policy knobs.

    Args:
        mode: one of `MODES`.  ``"ai_aware"`` (default) enumerates AI
            predicate placement by estimated LLM cost; ``"always_pushdown"``
            / ``"always_pullup"`` force the classical extremes (the paper's
            Fig. 7 baselines); ``"none"`` returns the plan untouched.
        enable_reorder: sort Filter conjuncts by `Optimizer.rank`
            (cheap/selective first; units: credits per surviving row).
        enable_join_placement: allow AI conjuncts to move across joins.
        enable_semantic_join_rewrite: allow the §5.3 join -> multi-label
            AI_CLASSIFY rewrite (still subject to the oracle and, when
            ``cost_gate_semantic_rewrite``, an estimated-cost comparison).
        enable_topk_fusion: fuse ``Limit(Sort(...))`` with an AI-scored
            (or AI-similarity) primary key into a `TopK` node, unlocking
            the executor's prefilter early-exit paths (proxy scores, or
            the semantic index); applied only when the fused plan's
            estimated LLM credits are not higher.
        enable_semantic_index_join: allow the index-assisted blocking
            plan (`SemanticJoinIndex`) to enter the §5.3 race.  Only
            effective when the engine has a `SemanticIndexManager`
            attached; the three-way cost race (naive nested loop vs
            classification rewrite vs index blocking) picks the cheapest
            by estimated credits, using learned candidate-rate and
            per-call-cost statistics when the store has them.
        cost_gate_semantic_rewrite: only apply the §5.3 rewrite when the
            rewritten plan's estimated LLM credits are lower than the
            original's — with a warm `StatsStore` this re-decides the
            rewrite from *observed* per-call costs instead of priors.
        max_labels_per_call: AI_CLASSIFY context-window chunking — label
            sets larger than this are split across calls (count of labels).
        label_ndv_max: rewrite-oracle gate — a side whose join column has
            more distinct values than this cannot be the label set.
        label_avg_len_max: rewrite-oracle gate — average label length cap
            in characters (labels are short phrases, not documents).
        min_pairs_for_rewrite: joins with fewer |L|×|R| candidate pairs
            than this are left alone (rewrite overhead won't pay off).
        enable_plan_memo: memoize ``logical plan fingerprint -> chosen
            physical plan`` so hot dashboard-style repeats skip every
            optimizer cost race; a memo entry is invalidated when the
            backing statistics drift past the thresholds below (the plan
            was chosen with numbers that no longer hold).
        memo_max_entries: LRU capacity of the plan memo.
        memo_drift_sel: absolute selectivity drift that invalidates a
            memo entry (any memoized AI predicate).
        memo_drift_cost_rel: relative cost-per-row drift that
            invalidates a memo entry.
        cost_defaults: every static fallback constant the `CostModel`
            uses when neither catalog statistics nor the learned
            `StatsStore` can answer (see `CostDefaults` for units).
    """
    mode: str = "ai_aware"
    enable_reorder: bool = True
    enable_join_placement: bool = True
    enable_semantic_join_rewrite: bool = True
    cost_gate_semantic_rewrite: bool = True
    enable_topk_fusion: bool = True
    enable_semantic_index_join: bool = True
    max_labels_per_call: int = 250      # AI_CLASSIFY context-window chunking
    # rewrite-oracle gates
    label_ndv_max: int = 512            # label sets are small-cardinality
    label_avg_len_max: float = 120.0    # labels are short strings
    min_pairs_for_rewrite: int = 64     # tiny joins are left alone
    # plan memo: repeated logical plans reuse the chosen physical plan
    enable_plan_memo: bool = True
    memo_max_entries: int = 128
    memo_drift_sel: float = 0.15        # |Δ selectivity| that invalidates
    memo_drift_cost_rel: float = 0.5    # relative Δ cost/row that invalidates
    # static fallback constants for the cost model (named, not inline)
    cost_defaults: CostDefaults = dataclasses.field(
        default_factory=CostDefaults)


@dataclasses.dataclass
class RewriteDecision:
    applicable: bool
    label_side: str = ""                # "left" | "right"
    label_col: str = ""
    reason: str = ""


# ---------------------------------------------------------------------------
# the plan memo: fingerprinted logical plan -> chosen physical plan
# ---------------------------------------------------------------------------


def plan_fingerprint(node: P.PlanNode) -> str:
    """Deterministic identity of a *logical* plan: node kinds plus every
    semantically-relevant attribute, with predicates keyed by their
    `predicate_fingerprint` (alias-free, symmetric) — so the same query
    resubmitted (dashboard repeats) maps to the same memo slot."""
    parts: List[str] = []

    def visit(n: P.PlanNode) -> None:
        if isinstance(n, P.Scan):
            parts.append(f"scan:{n.table}:{n.alias}")
        elif isinstance(n, P.Filter):
            preds = ";".join(sorted(predicate_fingerprint(p)
                                    for p in n.predicates))
            parts.append(f"filter:{preds}")
        elif isinstance(n, P.Join):
            res = ";".join(sorted(predicate_fingerprint(p)
                                  for p in n.residual))
            parts.append(f"join:{sorted(n.equi)}:{res}")
        elif isinstance(n, (P.SemanticJoinClassify, P.SemanticJoinIndex)):
            parts.append(f"{type(n).__name__}:{n.prompt.template}:"
                         f"{n.model or ''}:{n.label_col}")
        elif isinstance(n, (P.Sort, P.TopK)):
            keys = ";".join(
                f"{predicate_fingerprint(k.expr)}:{int(k.desc)}"
                for k in n.keys)
            limit = f":{n.n}" if isinstance(n, P.TopK) else ""
            parts.append(f"{type(n).__name__}:{keys}{limit}")
        elif isinstance(n, P.Limit):
            parts.append(f"limit:{n.n}")
        elif isinstance(n, P.Project):
            items = ";".join(f"{predicate_fingerprint(it.expr)}:"
                             f"{it.alias or ''}" for it in n.items)
            parts.append(f"project:{items}")
        elif isinstance(n, P.Aggregate):
            items = ";".join(f"{predicate_fingerprint(it.expr)}:"
                             f"{it.alias or ''}" for it in n.items)
            parts.append(f"agg:{sorted(n.group_by)}:{items}")
        else:
            parts.append(type(n).__name__)
        for c in n.children():
            visit(c)
        parts.append(")")

    visit(node)
    return "|".join(parts)


@dataclasses.dataclass
class MemoEntry:
    """One memoized optimization: the chosen physical plan, the trace
    that led to it, and a snapshot of the estimates it was chosen with
    (the drift-invalidation baseline)."""
    plan: P.PlanNode
    trace: List[str]
    # (predicate, selectivity, cost_per_row) at memoization time
    snapshot: List[Tuple[E.Expr, float, float]]
    hits: int = 0


class PlanMemo:
    """LRU map ``plan_fingerprint -> MemoEntry``.

    A hit returns the previously-chosen physical plan without re-running
    any optimizer cost race; entries self-invalidate when the backing
    statistics have drifted past the configured thresholds since the
    plan was chosen (the cached decision may no longer be the winner).
    """

    def __init__(self, max_entries: int = 128):
        self.max_entries = max(int(max_entries), 1)
        self._entries: "collections.OrderedDict[str, MemoEntry]" = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.invalidations = 0

    def __len__(self) -> int:
        return len(self._entries)

    def lookup(self, key: str, cost: CostModel, *, drift_sel: float,
               drift_cost_rel: float) -> Optional[MemoEntry]:
        entry = self._entries.get(key)
        if entry is None:
            self.misses += 1
            return None
        if self._drifted(entry, cost, drift_sel, drift_cost_rel):
            del self._entries[key]
            self.invalidations += 1
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        entry.hits += 1
        self.hits += 1
        return entry

    @staticmethod
    def _drifted(entry: MemoEntry, cost: CostModel, drift_sel: float,
                 drift_cost_rel: float) -> bool:
        for pred, sel, cpr in entry.snapshot:
            if abs(cost.predicate_selectivity(pred) - sel) > drift_sel:
                return True
            now = cost.predicate_cost_per_row(pred)
            if abs(now - cpr) > drift_cost_rel * max(cpr, 1e-12):
                return True
        return False

    def store(self, key: str, entry: MemoEntry) -> None:
        self._entries[key] = entry
        self._entries.move_to_end(key)
        while len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)

    def clear(self) -> None:
        self._entries.clear()


def _ai_predicates_of(node: P.PlanNode) -> List[E.Expr]:
    """Every AI predicate whose estimates the optimizer's decisions for
    this plan depend on (the drift-snapshot population)."""
    out: List[E.Expr] = []

    def visit(n: P.PlanNode) -> None:
        if isinstance(n, P.Filter):
            out.extend(p for p in n.predicates if p.is_ai())
        elif isinstance(n, P.Join):
            out.extend(p for p in n.residual if p.is_ai())
        elif isinstance(n, (P.Sort, P.TopK)):
            out.extend(k.expr for k in n.keys
                       if isinstance(k.expr, (E.AIScore, E.AISimilarity)))
        for c in n.children():
            visit(c)

    visit(node)
    return out


# ---------------------------------------------------------------------------
# the rewrite oracle (§5.3)
# ---------------------------------------------------------------------------


class RewriteOracle:
    """Decides if a semantic join is a multi-label classification.

    Inputs mirror the paper: the natural-language prompt, schema metadata
    (table/column names), statistics (NDV), and sample values.  An optional
    LLM hook (`llm_judge`) lets an AI model veto/confirm borderline cases —
    by default a deterministic heuristic decides.
    """

    LABELY_WORDS = ("category", "categories", "label", "class", "topic",
                    "type", "tag", "genre", "sentiment")

    def __init__(self, cost: CostModel, cfg: OptimizerConfig,
                 llm_judge=None):
        self.cost = cost
        self.cfg = cfg
        self.llm_judge = llm_judge

    def decide(self, node: P.Join, pred: E.AIFilter) -> RewriteDecision:
        """Judge whether ``pred`` over ``node`` is a classification join.

        Args:
            node: a non-equi `Join` whose residual is exactly ``pred``.
            pred: the two-side `AIFilter` (its prompt must reference one
                column from each join side).

        Returns:
            A `RewriteDecision`; ``applicable=True`` names the label side
            (``"left"``/``"right"``), the label column (alias-qualified),
            and a human-readable reason including the evidence score.
            Scores accumulate from schema naming (+2), NDV-vs-rows (+1),
            short labels (+1) and clean sample values (+1); below 2.0 the
            rewrite is refused.  An optional ``llm_judge(template,
            label_col, samples) -> bool`` hook can veto borderline wins.
        """
        sides = self._split_prompt_args(node, pred)
        if sides is None:
            return RewriteDecision(False, reason="prompt does not reference "
                                   "exactly one column from each side")
        (l_col, r_col) = sides
        l_rows = self.cost.est_rows(node.left)
        r_rows = self.cost.est_rows(node.right)
        if l_rows * r_rows < self.cfg.min_pairs_for_rewrite:
            return RewriteDecision(False, reason="join too small to benefit")
        cand: List[Tuple[str, str, float]] = []     # (side, col, score)
        for side, col, rows in (("right", r_col, r_rows),
                                ("left", l_col, l_rows)):
            ndv = self.cost.ndv(col)
            avg_len = self.cost.avg_tokens(col) * 4.0
            if ndv > self.cfg.label_ndv_max:
                continue
            if avg_len > self.cfg.label_avg_len_max:
                continue
            score = 0.0
            # schema signal: label-like column/table names
            name_l = col.lower()
            if any(wd in name_l for wd in self.LABELY_WORDS):
                score += 2.0
            # statistics signal: low NDV relative to row count
            score += 1.0 if ndv <= rows * 0.9 else 0.0
            score += 1.0 if avg_len <= 40 else 0.0
            # sample-value signal: short single-phrase values
            samples = self._samples(node, side, col)
            if samples and all(len(str(s)) <= 80 and "\n" not in str(s)
                               for s in samples):
                score += 1.0
            cand.append((side, col, score))
        if not cand:
            return RewriteDecision(False, reason="no side looks like a "
                                   "label set (NDV/length gates failed)")
        cand.sort(key=lambda t: -t[2])
        side, col, score = cand[0]
        if score < 2.0:
            return RewriteDecision(False, reason=f"weak label evidence "
                                   f"(score={score})")
        if self.llm_judge is not None:
            verdict = self.llm_judge(pred.prompt.template, col,
                                     self._samples(node, side, col))
            if not verdict:
                return RewriteDecision(False, reason="LLM judge vetoed")
        return RewriteDecision(True, label_side=side, label_col=col,
                               reason=f"label side={side} col={col} "
                                      f"score={score}")

    # -- helpers --
    def _split_prompt_args(self, node: P.Join, pred: E.AIFilter):
        """-> (left_col, right_col) if the prompt has exactly one column
        from each side; else None."""
        largs = node.left.out_aliases()
        rargs = node.right.out_aliases()
        lcols, rcols = [], []
        for a in pred.prompt.args:
            if not isinstance(a, E.Column):
                return None
            alias = a.name.split(".", 1)[0] if "." in a.name else ""
            if alias in largs:
                lcols.append(a.name)
            elif alias in rargs:
                rcols.append(a.name)
            else:
                return None
        if len(lcols) == 1 and len(rcols) == 1:
            return lcols[0], rcols[0]
        return None

    def _samples(self, node: P.Join, side: str, col: str):
        alias, _, c = col.partition(".")
        sub = node.left if side == "left" else node.right
        for n in _walk(sub):
            if isinstance(n, P.Scan) and n.alias == alias:
                try:
                    return self.cost.catalog.table(n.table).sample_values(c)
                except KeyError:
                    return []
        return []


def _walk(node: P.PlanNode):
    yield node
    for c in node.children():
        yield from _walk(c)


# ---------------------------------------------------------------------------
# the optimizer
# ---------------------------------------------------------------------------


class Optimizer:
    """AI-aware plan rewriter (paper §5.1 / §5.3).

    Args:
        catalog: the engine's `Catalog` (row counts, NDV, sample values).
        cfg: policy knobs; defaults to `OptimizerConfig()` (ai_aware).
        cost: a shared `CostModel`.  Pass the engine's instance so the
            optimizer, executor and EXPLAIN output agree on estimates —
            and so a `StatsStore` attached to it feeds re-optimization.
            When omitted a fresh model is built from
            ``cfg.cost_defaults`` (no learned stats).
        llm_judge: optional rewrite-oracle veto hook, see
            `RewriteOracle.decide`.

    After each `optimize` call, ``self.trace`` holds one human-readable
    line per rewrite decision (surfaced via ``EXPLAIN`` and
    `QueryReport.optimizer_trace`).
    """

    def __init__(self, catalog: Catalog, *,
                 cfg: Optional[OptimizerConfig] = None,
                 cost: Optional[CostModel] = None, llm_judge=None):
        self.cfg = cfg or OptimizerConfig()
        assert self.cfg.mode in MODES, self.cfg.mode
        self.cost = cost or CostModel(catalog,
                                      defaults=self.cfg.cost_defaults)
        self.oracle = RewriteOracle(self.cost, self.cfg, llm_judge)
        self.trace: List[str] = []
        self.memo = PlanMemo(self.cfg.memo_max_entries)
        # per-optimize telemetry: whether the memo answered, and how
        # many cost races (placement enumerations, rewrite races, top-k
        # gates) the call actually ran — zero on a memo hit
        self.memo_hit = False
        self.cost_races = 0

    # ------------------------------------------------------------------
    def optimize(self, root: P.PlanNode) -> P.PlanNode:
        """Rewrite ``root`` to minimise estimated LLM credits.

        Applies, in order: semantic-join rewrite (§5.3), filter pushdown,
        AI-predicate placement across joins (§5.1), and conjunct
        reordering.  Returns a new plan tree (nodes are immutable);
        ``self.trace`` is reset and filled as a side effect.
        """
        self.trace = []
        self.memo_hit = False
        self.cost_races = 0
        self.cost.est_rows(root)        # bind aliases for stats lookups
        if self.cfg.mode == "none":
            return root
        memo_key = None
        if self.cfg.enable_plan_memo:
            memo_key = plan_fingerprint(root)
            entry = self.memo.lookup(
                memo_key, self.cost, drift_sel=self.cfg.memo_drift_sel,
                drift_cost_rel=self.cfg.memo_drift_cost_rel)
            if entry is not None:
                self.memo_hit = True
                active_tracer().event("optimize.memo_hit",
                                      reuses=entry.hits)
                self.trace = list(entry.trace)
                self.trace.append(
                    f"plan-memo: hit ({entry.hits} reuse(s), "
                    "0 cost races)")
                return entry.plan
        node = root
        if self.cfg.enable_semantic_join_rewrite:
            node = self._rewrite_semantic_joins(node)
        # Snowflake's default pushes filters below joins; relational
        # predicates always benefit.  AI predicates are pushed in
        # always_pushdown/ai_aware (ai_aware may pull them back up below,
        # by LLM-cost enumeration) and held above in always_pullup.
        node = self._pushdown_filters(node, push_ai=self.cfg.mode
                                      in ("ai_aware", "always_pushdown"))
        if self.cfg.enable_join_placement:
            # ai_aware: cost-based enumeration; always_pullup: forced pull;
            # always_pushdown: stays below (no-op after the pushdown pass)
            node = self._place_ai_predicates(node)
        if self.cfg.enable_reorder:
            node = self._reorder_filters(node)
        if self.cfg.enable_topk_fusion:
            node = self._fuse_topk(node)
        if memo_key is not None:
            snapshot = [(p, self.cost.predicate_selectivity(p),
                         self.cost.predicate_cost_per_row(p))
                        for p in _ai_predicates_of(root)]
            self.memo.store(memo_key, MemoEntry(
                plan=node, trace=list(self.trace), snapshot=snapshot))
        return node

    # ------------------------------------------------------------------
    # 0. filter pushdown below joins
    # ------------------------------------------------------------------

    def _pushdown_filters(self, node: P.PlanNode, *, push_ai: bool
                          ) -> P.PlanNode:
        node = _map_children(
            node, lambda c: self._pushdown_filters(c, push_ai=push_ai))
        if not (isinstance(node, P.Filter)
                and isinstance(node.child, P.Join)):
            return node
        join = node.child
        la = join.left.out_aliases()
        ra = join.right.out_aliases()
        to_left, to_right, keep = [], [], []
        for pred in node.predicates:
            if pred.is_ai() and not push_ai:
                keep.append(pred)
                continue
            al = refs_aliases(pred)
            if al and al <= la:
                to_left.append(pred)
            elif al and al <= ra:
                to_right.append(pred)
            else:
                keep.append(pred)
        if not (to_left or to_right):
            return node
        left = P.Filter(join.left, tuple(to_left)) if to_left else join.left
        right = (P.Filter(join.right, tuple(to_right)) if to_right
                 else join.right)
        out: P.PlanNode = dataclasses.replace(join, left=left, right=right)
        if keep:
            out = P.Filter(out, tuple(keep))
        self.trace.append(
            f"pushdown: {len(to_left)}L/{len(to_right)}R below join")
        return out

    # ------------------------------------------------------------------
    # 1. predicate reordering
    # ------------------------------------------------------------------

    def rank(self, pred: E.Expr) -> float:
        """Hellerstein-style rank ``cost_per_row / (1 - selectivity)`` in
        credits; filters evaluate ascending by rank.  Delegates to
        `CostModel.predicate_rank`, so observed stats (when a `StatsStore`
        is attached) take precedence over static defaults."""
        return self.cost.predicate_rank(pred)

    def _reorder_filters(self, node: P.PlanNode) -> P.PlanNode:
        node = _map_children(node, self._reorder_filters)
        if isinstance(node, P.Filter):
            if len(node.predicates) > 1:
                self.cost_races += 1        # rank race over the conjuncts
                active_tracer().event("optimize.cost_race", race="reorder")
            ordered = tuple(sorted(node.predicates, key=self.rank))
            if ordered != node.predicates:
                self.trace.append(
                    "reorder: " + " -> ".join(_pname(p) for p in ordered))
            return dataclasses.replace(node, predicates=ordered)
        return node

    # ------------------------------------------------------------------
    # 2. AI-predicate placement with respect to joins
    # ------------------------------------------------------------------

    def _place_ai_predicates(self, node: P.PlanNode) -> P.PlanNode:
        node = _map_children(node, self._place_ai_predicates)
        if not isinstance(node, P.Join):
            return node
        mode = self.cfg.mode
        # collect movable AI conjuncts from single-side pre-join filters
        movable: List[Tuple[str, E.Expr]] = []   # (side, pred)
        left, right = node.left, node.right
        l_keep, left = _strip_ai_filter(left)
        r_keep, right = _strip_ai_filter(right)
        movable += [("left", p) for p in l_keep]
        movable += [("right", p) for p in r_keep]
        if not movable:
            return node
        if mode == "always_pushdown":
            choice = [False] * len(movable)       # stay below the join
        elif mode == "always_pullup":
            choice = [True] * len(movable)
        else:
            choice = self._best_placement(node, left, right, movable)
        below_l = [p for (s, p), up in zip(movable, choice)
                   if not up and s == "left"]
        below_r = [p for (s, p), up in zip(movable, choice)
                   if not up and s == "right"]
        above = [p for (_, p), up in zip(movable, choice) if up]
        new_left = P.Filter(left, tuple(below_l)) if below_l else left
        new_right = P.Filter(right, tuple(below_r)) if below_r else right
        out: P.PlanNode = dataclasses.replace(node, left=new_left,
                                              right=new_right)
        if above:
            out = P.Filter(out, tuple(above))
            self.trace.append(
                f"pull-up: {len(above)} AI predicate(s) above join")
        return out

    def _best_placement(self, join: P.Join, left, right, movable
                        ) -> List[bool]:
        self.cost_races += 1
        active_tracer().event("optimize.cost_race", race="placement")
        best_cost = float("inf")
        best: List[bool] = [False] * len(movable)
        for choice in itertools.product([False, True], repeat=len(movable)):
            below_l = [p for (s, p), up in zip(movable, choice)
                       if not up and s == "left"]
            below_r = [p for (s, p), up in zip(movable, choice)
                       if not up and s == "right"]
            above = [p for (_, p), up in zip(movable, choice) if up]
            nl = P.Filter(left, tuple(below_l)) if below_l else left
            nr = P.Filter(right, tuple(below_r)) if below_r else right
            cand: P.PlanNode = dataclasses.replace(join, left=nl, right=nr)
            if above:
                cand = P.Filter(cand, tuple(above))
            c = self.cost.est_llm_cost(cand)
            if c < best_cost - 1e-15:
                best_cost = c
                best = list(choice)
        self.trace.append(f"placement: best LLM cost {best_cost:.6g}")
        return best

    # ------------------------------------------------------------------
    # 3. top-k fusion: Limit over a semantic Sort -> TopK
    # ------------------------------------------------------------------

    def _fuse_topk(self, node: P.PlanNode) -> P.PlanNode:
        """``Limit(Sort)`` / ``Limit(Project(Sort))`` with an AI-scored
        primary key -> ``TopK`` (under the unchanged projection): only k
        rows survive, so the projection and the prefilter both run on a
        bounded row set.  Cost-gated like every other rewrite."""
        node = _map_children(node, self._fuse_topk)
        if not isinstance(node, P.Limit):
            return node
        project: Optional[P.Project] = None
        sort = node.child
        if isinstance(sort, P.Project):
            project, sort = sort, sort.child
        if not isinstance(sort, P.Sort):
            return node
        if not (sort.keys and isinstance(sort.keys[0].expr,
                                         (E.AIScore, E.AISimilarity))):
            return node          # prefilter needs a semantic primary key
        fused: P.PlanNode = P.TopK(sort.child, sort.keys, node.n)
        if project is not None:
            fused = P.Project(fused, project.items)
        self.cost_races += 1
        active_tracer().event("optimize.cost_race", race="topk-fusion")
        c_orig = self.cost.est_llm_cost(node)
        c_new = self.cost.est_llm_cost(fused)
        self.trace.append(
            f"topk-fusion: TopK {c_new:.6g} vs sort-then-limit "
            f"{c_orig:.6g} credits")
        if c_new <= c_orig:
            return fused
        return node

    # ------------------------------------------------------------------
    # 4. semantic-join -> multi-label classification rewrite
    # ------------------------------------------------------------------

    def _rewrite_semantic_joins(self, node: P.PlanNode) -> P.PlanNode:
        node = _map_children(node, self._rewrite_semantic_joins)
        if not (isinstance(node, P.Join) and not node.equi):
            return node
        ai_res = [p for p in node.residual if isinstance(p, E.AIFilter)]
        if len(ai_res) != 1 or len(node.residual) != 1:
            return node
        pred = ai_res[0]
        dec = self.oracle.decide(node, pred)
        self.trace.append(f"rewrite-oracle: {dec.reason}")
        if not dec.applicable:
            return node
        if dec.label_side == "right":
            left, right = node.left, node.right
            l_col = self.oracle._split_prompt_args(node, pred)[0]
        else:
            left, right = node.right, node.left
            l_col = self.oracle._split_prompt_args(node, pred)[1]
        rewritten: P.PlanNode = P.SemanticJoinClassify(
            left=left, right=right, prompt=pred.prompt,
            left_arg=E.Column(l_col), label_col=dec.label_col,
            model=pred.model,
            max_labels_per_call=self.cfg.max_labels_per_call)
        indexed: Optional[P.PlanNode] = None
        if (self.cfg.enable_semantic_index_join
                and self.cost.semindex is not None):
            indexed = P.SemanticJoinIndex(
                left=left, right=right, prompt=pred.prompt,
                left_arg=E.Column(l_col), label_col=dec.label_col,
                model=pred.model, k=self.cost.semindex.cfg.join_k,
                max_labels_per_call=self.cfg.max_labels_per_call)
        if self.cfg.cost_gate_semantic_rewrite:
            # re-decide with real numbers: with a warm StatsStore every
            # contender in this race is priced from observed per-call
            # costs, candidate rates and selectivities, so a strategy
            # that lost last time is undone.  Three-way when a semantic
            # index is attached: naive nested loop vs classification
            # rewrite vs index-assisted blocking.
            contenders = [("cross-join", node), ("classify", rewritten)]
            if indexed is not None:
                contenders.append(("index", indexed))
            self.cost_races += 1
            active_tracer().event("optimize.cost_race", race="join-rewrite")
            priced = [(self.cost.est_llm_cost(n), name, n)
                      for name, n in contenders]
            self.trace.append(
                "rewrite-cost: " + " vs ".join(
                    f"{name} {c:.6g}" for c, name, _ in priced)
                + " credits")
            best = min(priced, key=lambda t: t[0])
            if best[1] != "cross-join":
                self.trace.append(f"rewrite-winner: {best[1]}")
            return best[2]
        # gate disabled: legacy behaviour — always the classify rewrite
        return rewritten


# ---------------------------------------------------------------------------
# plan-tree utilities
# ---------------------------------------------------------------------------


def _map_children(node: P.PlanNode, fn) -> P.PlanNode:
    kids = node.children()
    if not kids:
        return node
    new = tuple(fn(c) for c in kids)
    if new == kids:
        return node
    if isinstance(node, P.Filter):
        return dataclasses.replace(node, child=new[0])
    if isinstance(node, (P.Join, P.SemanticJoinClassify,
                         P.SemanticJoinIndex)):
        return dataclasses.replace(node, left=new[0], right=new[1])
    if isinstance(node, (P.Project, P.Aggregate, P.Limit, P.Sort, P.TopK)):
        return dataclasses.replace(node, child=new[0])
    raise TypeError(node)


def _strip_ai_filter(node: P.PlanNode) -> Tuple[List[E.Expr], P.PlanNode]:
    """Remove AI conjuncts from a top-of-side Filter; returns (ai, rest)."""
    if not isinstance(node, P.Filter):
        return [], node
    ai = [p for p in node.predicates if p.is_ai()]
    rel = [p for p in node.predicates if not p.is_ai()]
    if not ai:
        return [], node
    rest: P.PlanNode = (P.Filter(node.child, tuple(rel)) if rel
                        else node.child)
    return ai, rest


def _pname(p: E.Expr) -> str:
    if isinstance(p, E.AIFilter):
        return "AI_FILTER" + ("[mm]" if p.multimodal else "")
    if isinstance(p, E.AIScore):
        return "AI_SCORE"
    if isinstance(p, E.AIClassify):
        return "AI_CLASSIFY"
    if isinstance(p, E.AISimilarity):
        return "AI_SIMILARITY"
    if isinstance(p, E.AIEmbed):
        return "AI_EMBED"
    return type(p).__name__
