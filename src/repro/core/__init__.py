"""AISQL core: the paper's contribution (operators + AI-aware engine)."""
from repro.core.engine import (AisqlEngine, OperatorReport,      # noqa: F401
                               QueryReport)
from repro.core.stats import (PredObservation, StatsStore,       # noqa: F401
                              predicate_fingerprint,
                              predicate_prompt_text)
from repro.core.cost import CostDefaults, TransferredPrior       # noqa: F401
from repro.core.cascade import (CascadeConfig, SupgItCascade,    # noqa: F401
                                CalibratedCascade)
from repro.core.optimizer import (Optimizer, OptimizerConfig,    # noqa: F401
                                  PlanMemo, plan_fingerprint)
from repro.core.executor import ExecConfig, Executor             # noqa: F401
from repro.core.sqlparse import ParseError, parse                # noqa: F401
from repro.core.aggregate import AggConfig, HierarchicalAggregator  # noqa: F401
from repro.core.cost import Catalog, CostModel                   # noqa: F401
from repro.core.serving import (AdmissionError, QuerySession,    # noqa: F401
                                QueryTicket, ServingConfig,
                                ServingEngine, ServingReport,
                                TenantPolicy, TenantReport,
                                TenantStatsStore)
from repro.semindex import (EmbeddingStore, IvfFlatIndex,        # noqa: F401
                            SemanticIndexManager, SemIndexConfig)
