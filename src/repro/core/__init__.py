"""AISQL core: the paper's contribution (operators + AI-aware engine)."""
from repro.core.engine import AisqlEngine, QueryReport           # noqa: F401
from repro.core.cascade import (CascadeConfig, SupgItCascade,    # noqa: F401
                                CalibratedCascade)
from repro.core.optimizer import Optimizer, OptimizerConfig      # noqa: F401
from repro.core.executor import ExecConfig, Executor             # noqa: F401
from repro.core.aggregate import AggConfig, HierarchicalAggregator  # noqa: F401
from repro.core.cost import Catalog, CostModel                   # noqa: F401
