"""AI_AGG / AI_SUMMARIZE_AGG — hierarchical aggregation (paper §3.5, Alg. 1)
with the §5.4 short-circuit.

Three LLM phases over a text column that exceeds any context window:

  Extract(R)   — key information from a batch of rows -> intermediate state
  Combine(S)   — recursively merge intermediate states
  Summarize(s) — final user-facing text

``BATCH_SIZE`` is a token budget; rows are accumulated until the buffer
exceeds it.  The short-circuit detects inputs that fit in one context
window and performs a single Summarize call (−86.1 % latency on small
groups in the paper).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.inference.api import CortexClient


def _tokens(text: str) -> int:
    return max(len(text) // 4, 1)


@dataclasses.dataclass
class AggConfig:
    batch_size_tokens: int = 2048      # BATCH_SIZE of Algorithm 1
    context_window_tokens: int = 3072  # short-circuit threshold
    short_circuit: bool = True
    model: Optional[str] = None
    max_tokens_out: int = 96


@dataclasses.dataclass
class AggTelemetry:
    extract_calls: int = 0
    combine_calls: int = 0
    summarize_calls: int = 0
    short_circuited: bool = False

    @property
    def llm_calls(self) -> int:
        return self.extract_calls + self.combine_calls + self.summarize_calls


_EXTRACT_TMPL = ("Extract the key information relevant to the task from the "
                 "following rows.{task}\nRows:\n{rows}")
_COMBINE_TMPL = ("Combine these intermediate notes, discarding redundant "
                 "information.{task}\nNotes:\n{states}")
_SUMMARIZE_TMPL = ("Produce the final aggregate answer.{task}\nNotes:\n{state}")


class HierarchicalAggregator:
    """Implements Algorithm 1 (incremental fold with bounded buffers)."""

    def __init__(self, client: CortexClient, cfg: Optional[AggConfig] = None):
        self.client = client
        self.cfg = cfg or AggConfig()
        self.telemetry = AggTelemetry()

    # ------------------------------------------------------------------
    def _task_clause(self, instruction: Optional[str]) -> str:
        return f"\nTask: {instruction}" if instruction else ""

    def _extract(self, rows: List[str], instruction) -> str:
        self.telemetry.extract_calls += 1
        prompt = _EXTRACT_TMPL.format(task=self._task_clause(instruction),
                                      rows="\n".join(rows))
        return self.client.complete([prompt], model=self.cfg.model,
                                    max_tokens=self.cfg.max_tokens_out)[0]

    def _combine(self, states: List[str], instruction) -> List[str]:
        """Merge as many states as fit one context window per call."""
        out: List[str] = []
        group: List[str] = []
        budget = self.cfg.context_window_tokens
        used = 0
        prompts: List[str] = []
        for s in states:
            t = _tokens(s)
            if group and used + t > budget:
                prompts.append(_COMBINE_TMPL.format(
                    task=self._task_clause(instruction),
                    states="\n".join(group)))
                group, used = [], 0
            group.append(s)
            used += t
        if group:
            prompts.append(_COMBINE_TMPL.format(
                task=self._task_clause(instruction), states="\n".join(group)))
        self.telemetry.combine_calls += len(prompts)
        return self.client.complete(prompts, model=self.cfg.model,
                                    max_tokens=self.cfg.max_tokens_out)

    def _summarize(self, state: str, instruction) -> str:
        self.telemetry.summarize_calls += 1
        prompt = _SUMMARIZE_TMPL.format(task=self._task_clause(instruction),
                                        state=state)
        return self.client.complete([prompt], model=self.cfg.model,
                                    max_tokens=self.cfg.max_tokens_out)[0]

    # ------------------------------------------------------------------
    def aggregate(self, texts: Sequence[str],
                  instruction: Optional[str] = None) -> str:
        texts = [str(t) for t in texts]
        self.telemetry = AggTelemetry()
        total = sum(_tokens(t) for t in texts)
        # §5.4 short-circuit: the whole input fits one context window
        if self.cfg.short_circuit and total <= self.cfg.context_window_tokens:
            self.telemetry.short_circuited = True
            return self._summarize("\n".join(texts), instruction)

        R: List[str] = []      # row buffer
        S: List[str] = []      # intermediate-state buffer
        r_tokens = 0
        for t in texts:
            if R and r_tokens + _tokens(t) > self.cfg.batch_size_tokens:
                S.append(self._extract(R, instruction))
                R, r_tokens = [], 0
            R.append(t)
            r_tokens += _tokens(t)
            while sum(_tokens(s) for s in S) > self.cfg.batch_size_tokens:
                S = self._combine(S, instruction)
                if len(S) == 1:
                    break
        if R:
            S.append(self._extract(R, instruction))
        # the naive three-phase path always invokes Combine (the per-phase
        # API overhead the §5.4 short-circuit eliminates)
        S = self._combine(S, instruction)
        while len(S) > 1:
            S = self._combine(S, instruction)
        return self._summarize(S[0], instruction)
