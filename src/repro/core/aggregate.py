"""AI_AGG / AI_SUMMARIZE_AGG — hierarchical aggregation (paper §3.5, Alg. 1)
with the §5.4 short-circuit.

Three LLM phases over a text column that exceeds any context window:

  Extract(R)   — key information from a batch of rows -> intermediate state
  Combine(S)   — recursively merge intermediate states
  Summarize(s) — final user-facing text

``BATCH_SIZE`` is a token budget; rows are accumulated until the buffer
exceeds it.  The short-circuit detects inputs that fit in one context
window and performs a single Summarize call (−86.1 % latency on small
groups in the paper).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

from repro.inference.api import CortexClient


def _tokens(text: str) -> int:
    return max(len(text) // 4, 1)


@dataclasses.dataclass
class AggConfig:
    batch_size_tokens: int = 2048      # BATCH_SIZE of Algorithm 1
    context_window_tokens: int = 3072  # short-circuit threshold
    short_circuit: bool = True
    model: Optional[str] = None
    max_tokens_out: int = 96


@dataclasses.dataclass
class AggTelemetry:
    extract_calls: int = 0
    combine_calls: int = 0
    summarize_calls: int = 0
    short_circuited: bool = False

    @property
    def llm_calls(self) -> int:
        return self.extract_calls + self.combine_calls + self.summarize_calls


_EXTRACT_TMPL = ("Extract the key information relevant to the task from the "
                 "following rows.{task}\nRows:\n{rows}")
_COMBINE_TMPL = ("Combine these intermediate notes, discarding redundant "
                 "information.{task}\nNotes:\n{states}")
_SUMMARIZE_TMPL = ("Produce the final aggregate answer.{task}\nNotes:\n{state}")


class HierarchicalAggregator:
    """Implements Algorithm 1 (incremental fold with bounded buffers)."""

    def __init__(self, client: CortexClient, cfg: Optional[AggConfig] = None):
        self.client = client
        self.cfg = cfg or AggConfig()
        self.telemetry = AggTelemetry()

    # ------------------------------------------------------------------
    def _task_clause(self, instruction: Optional[str]) -> str:
        return f"\nTask: {instruction}" if instruction else ""

    def _extract_all(self, buffers: List[List[str]], instruction
                     ) -> List[str]:
        """One batched Extract pass over every row buffer (buffer
        boundaries are pure token arithmetic, so all Extract calls are
        independent and ride a single engine batch)."""
        prompts = [_EXTRACT_TMPL.format(task=self._task_clause(instruction),
                                        rows="\n".join(rows))
                   for rows in buffers]
        self.telemetry.extract_calls += len(prompts)
        return self.client.complete(prompts, model=self.cfg.model,
                                    max_tokens=self.cfg.max_tokens_out)

    def _combine(self, states: List[str], instruction) -> List[str]:
        """Merge as many states as fit one context window per call."""
        out: List[str] = []
        group: List[str] = []
        budget = self.cfg.context_window_tokens
        used = 0
        prompts: List[str] = []
        for s in states:
            t = _tokens(s)
            if group and used + t > budget:
                prompts.append(_COMBINE_TMPL.format(
                    task=self._task_clause(instruction),
                    states="\n".join(group)))
                group, used = [], 0
            group.append(s)
            used += t
        if group:
            prompts.append(_COMBINE_TMPL.format(
                task=self._task_clause(instruction), states="\n".join(group)))
        self.telemetry.combine_calls += len(prompts)
        return self.client.complete(prompts, model=self.cfg.model,
                                    max_tokens=self.cfg.max_tokens_out)

    def _summarize(self, state: str, instruction) -> str:
        self.telemetry.summarize_calls += 1
        prompt = _SUMMARIZE_TMPL.format(task=self._task_clause(instruction),
                                        state=state)
        return self.client.complete([prompt], model=self.cfg.model,
                                    max_tokens=self.cfg.max_tokens_out)[0]

    # ------------------------------------------------------------------
    def aggregate(self, texts: Sequence[str],
                  instruction: Optional[str] = None) -> str:
        """Algorithm 1 as a batched three-phase fold.

        Rows are partitioned into BATCH_SIZE-bounded buffers up front (a
        pure token computation), so the Extract phase is ONE batched LLM
        call over all buffers instead of a sequential per-buffer fold;
        Combine then reduces the intermediate states level by level (each
        level one batched call).  Call counts match the incremental fold;
        the batching is what lets the request pipeline coalesce an entire
        aggregation into a handful of engine batches.
        """
        texts = [str(t) for t in texts]
        self.telemetry = AggTelemetry()
        total = sum(_tokens(t) for t in texts)
        # §5.4 short-circuit: the whole input fits one context window
        if self.cfg.short_circuit and total <= self.cfg.context_window_tokens:
            self.telemetry.short_circuited = True
            return self._summarize("\n".join(texts), instruction)

        # phase 1: partition rows into token-budget buffers, batch-extract
        buffers: List[List[str]] = []
        cur: List[str] = []
        used = 0
        for t in texts:
            if cur and used + _tokens(t) > self.cfg.batch_size_tokens:
                buffers.append(cur)
                cur, used = [], 0
            cur.append(t)
            used += _tokens(t)
        if cur:
            buffers.append(cur)
        S = self._extract_all(buffers, instruction)
        # phase 2: combine tree.  The naive three-phase path always invokes
        # Combine at least once (the per-phase API overhead the §5.4
        # short-circuit eliminates).
        S = self._combine(S, instruction)
        while len(S) > 1:
            nxt = self._combine(S, instruction)
            if len(nxt) >= len(S):      # states no longer shrink: force-merge
                nxt = ["\n".join(nxt)]
            S = nxt
        return self._summarize(S[0], instruction)
