"""Vectorised plan executor with runtime adaptation (paper §5.1–5.3).

Executes optimized plans against the columnar substrate + the Cortex client.

Runtime behaviour mirrored from the paper:

  * **adaptive predicate reordering** — Filters evaluate in row chunks;
    per-predicate cost and selectivity statistics are collected and the
    evaluation order is re-ranked between chunks (cheap/selective first);
  * **model cascades** — AI_FILTER predicates route through a streaming
    SUPG-IT cascade (proxy scores + learned thresholds + oracle escalation)
    when enabled;
  * **semantic-join rewrite execution** — SemanticJoinClassify runs one
    multi-label AI_CLASSIFY per left row (chunked over the label set)
    instead of |L|·|R| AI_FILTER calls.

  * **partitioned streaming execution** — with ``ExecConfig.partitioned``
    the driver loop switches from tree-recursive materialization to a
    partition-pull model: the source splits into morsels of
    ``partition_rows``, each morsel flows through the whole filter chain
    (eager, pipelined and cascade paths alike) as an independently
    submitted batch that the scheduler spreads across engine replicas,
    and a `StreamingLimit` consumer drains partitions until a LIMIT's
    ``n`` surviving rows are collected — then cancels unsubmitted
    partitions, so LIMIT-bounded queries stop buying inference they do
    not need.  ``partition_lookahead`` optionally prefetches the next
    partitions' first AI predicate into the pipeline for cross-partition
    coalescing (bounded speculation; still-queued prefetches are
    cancelled on early termination and never billed);

  * **semantic ORDER BY / top-k** — `Sort` keys may be AI_SCORE
    expressions (scored via the SCORE request kind, recorded in the
    `StatsStore` like every predicate); a fused `TopK` prefilters with
    cheap proxy scores and escalates only the top candidates to the
    ordering model;

  * **pilot sampling + mid-query re-optimization** — before a Filter with
    cold AI predicates runs in full, each such predicate is evaluated on a
    small evenly-spaced row sample; observed selectivity / cost-per-row
    land in the shared `StatsStore` and the remaining evaluation order is
    re-ranked with real numbers (the paper's "cost and selectivity are
    unknown during query compilation" closed as a feedback loop).  The
    pilot's per-row results are carried into the full pass — pilot rows
    are never re-submitted or re-billed — and predicates the store is
    already confident about skip the pilot entirely (warm start).
    Learned cascade delegation rates can also *bypass* a cascade whose
    proxy has proven useless (delegation ≈ 1).

Semantic-operator runtime: every AI call site assembles its requests
through one typed builder, `SemanticOp`, and awaits `SemanticHandle`
futures instead of blocking per-site client calls.  With a pipelined
client (``client.pipeline`` set) independent micro-batches — label chunks
of a semantic join, hybrid-join passes, multiple projection items — are
submitted *before* any is awaited, so the RequestPipeline coalesces them
into right-sized engine batches; filters switch from chunk-major to
predicate-major evaluation (all surviving rows of one predicate in one
coalesced pass) trading mid-stream reordering for batching.  With an
eager client the exact seed behaviour (and telemetry) is preserved.

Ground-truth plumbing: hidden columns (leaf name starting with ``_``) are
never returned by ``SELECT *`` but travel with rows and are forwarded as
request metadata (``_truth`` → truth, ``_difficulty`` → difficulty,
``_labels`` → truth_labels, ``_recall_penalty`` → recall_penalty) so the
calibrated simulator can ground quality metrics.  The real JAX engine
ignores metadata entirely.
"""
from __future__ import annotations

import dataclasses
import time
from typing import (Any, Callable, Dict, List, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.core import expr as E
from repro.core import plan as P
from repro.core.aggregate import AggConfig, HierarchicalAggregator
from repro.core.cascade import CascadeConfig, SupgItCascade
from repro.core.cost import Catalog, CostModel
from repro.core.stats import (StatsStore, index_join_fingerprint,
                              predicate_fingerprint, predicate_prompt_text)
from repro.inference.api import CortexClient
from repro.inference.backend import CLASSIFY, COMPLETE, SCORE, Request
from repro.inference.pipeline import ResultFuture
from repro.obs.trace import active_tracer
from repro.tables.chunked import ChunkedTable
from repro.tables.table import Table, _hash_join_indices


def _is_hidden(col: str) -> bool:
    return col.rsplit(".", 1)[-1].startswith("_")


def _strip_format_slots(template: str) -> str:
    """The prompt template as free text: ``{0}``-style slots removed."""
    import re
    return re.sub(r"\{\d+\}", " ", template).strip()


def _side_desc(e: E.Expr) -> str:
    """Compact description of an AI_SIMILARITY / AI_EMBED argument."""
    if isinstance(e, E.Column):
        return e.name
    if isinstance(e, E.Literal):
        return repr(str(e.value)[:24])
    return type(e).__name__


def _unit_rows(x: np.ndarray) -> np.ndarray:
    x = np.asarray(x, np.float64)
    n = np.linalg.norm(x, axis=-1, keepdims=True)
    return x / np.maximum(n, 1e-12)


_MD_MAP = {"_truth": "truth", "_difficulty": "difficulty",
           "_labels": "truth_labels", "_recall_penalty": "recall_penalty",
           "_fp_bias": "fp_bias", "_fn_bias": "fn_bias",
           "_drop_prob": "drop_prob", "_add_frac": "add_frac"}


def row_metadata(table: Table, rows: np.ndarray,
                 label_args: Sequence[np.ndarray] = (),
                 arg_cols: Sequence[str] = ()) -> List[Dict[str, Any]]:
    """Simulator grounding: hidden columns -> per-row request metadata.

    ``label_args``: rendered per-row values of prompt args; when the row
    carries a ``_labels`` truth set, pairwise truth is derived as "any arg
    value is one of the true labels" (used by cross-join AI_FILTER so that
    baseline and rewrite share identical ground truth).

    ``arg_cols``: unqualified column names the predicate references.
    A hidden column ``_truth__<col>`` carries *column-scoped* ground
    truth: it becomes the request's ``truth`` only for predicates that
    reference ``<col>``, so two AI predicates over different columns of
    one table can have independent (skewed) selectivities.  Scoped truth
    wins over a table-wide ``_truth``.
    """
    hidden: Dict[str, np.ndarray] = {}
    arg_set = {c.rsplit(".", 1)[-1] for c in arg_cols}
    scoped_truth: List[np.ndarray] = []
    for c in table.column_names:
        leaf = c.rsplit(".", 1)[-1]
        if leaf.startswith("_truth__"):
            if leaf[len("_truth__"):] in arg_set:
                scoped_truth.append(table.gather(c, rows))
        elif leaf in _MD_MAP:
            # last matching column wins (pre-existing contract for joined
            # tables that carry several hidden columns of the same leaf)
            hidden[_MD_MAP[leaf]] = table.gather(c, rows)
    if scoped_truth:
        # scoped truth wins over table-wide _truth; a predicate that
        # references several scoped-truth columns is true iff all are
        # (deterministic regardless of column order)
        agg = scoped_truth[0].astype(bool)
        for t in scoped_truth[1:]:
            agg = agg & t.astype(bool)
        hidden["truth"] = agg
    n = len(rows)
    out: List[Dict[str, Any]] = []
    for i in range(n):
        md = {k: v[i] for k, v in hidden.items()}
        if "truth_labels" in md and "truth" not in md and label_args:
            lbls = md["truth_labels"]
            lbls = set(lbls) if isinstance(lbls, (tuple, list, set)) else {lbls}
            md["truth"] = any(str(a[i]) in lbls for a in label_args)
        out.append(md)
    return out


# ---------------------------------------------------------------------------
# SemanticOp: the one request-builder behind every AI call site
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SemanticOp:
    """Typed request assembly for one semantic-operator micro-batch.

    Replaces the five per-site copies of prompt/metadata/model plumbing
    (plain AI_FILTER, cascade AI_FILTER, SemanticJoinClassify, projection
    AI_CLASSIFY / AI_COMPLETE, AI_AGG text phases).  ``submit`` hands the
    typed batch to the client and returns an awaitable `SemanticHandle`.
    """
    kind: str                              # SCORE | CLASSIFY | COMPLETE
    prompts: List[str]
    metadata: List[Dict[str, Any]]
    model: str
    labels: Tuple[str, ...] = ()
    multi_label: bool = False
    max_tokens: int = 32

    # -- factories ------------------------------------------------------
    @classmethod
    def scores(cls, prompts: Sequence[str],
               metadata: Sequence[Dict[str, Any]], model: str) -> "SemanticOp":
        return cls(SCORE, list(prompts), list(metadata), model)

    @classmethod
    def from_filter(cls, pred: E.AIFilter, table: Table, rows: np.ndarray,
                    model: str) -> "SemanticOp":
        prompts = pred.prompt.render(table, rows)
        args = [E.eval_expr(a, table, rows) for a in pred.prompt.args]
        md = row_metadata(table, rows, args, arg_cols=sorted(pred.refs()))
        return cls(SCORE, list(prompts), md, model)

    @classmethod
    def classify(cls, prompts: Sequence[str],
                 metadata: Sequence[Dict[str, Any]],
                 labels: Sequence[str], model: str,
                 multi_label: bool) -> "SemanticOp":
        labels = tuple(labels)
        md = [{**m, "candidate_labels": labels} for m in metadata]
        return cls(CLASSIFY, list(prompts), md, model, labels=labels,
                   multi_label=multi_label)

    @classmethod
    def complete(cls, prompts: Sequence[str],
                 metadata: Sequence[Dict[str, Any]], model: str,
                 max_tokens: int) -> "SemanticOp":
        return cls(COMPLETE, list(prompts), list(metadata), model,
                   max_tokens=max_tokens)

    # -- submission -----------------------------------------------------
    def requests(self) -> List[Request]:
        return [Request(p, self.model, self.kind, max_tokens=self.max_tokens,
                        labels=self.labels or None,
                        multi_label=self.multi_label, metadata=m)
                for p, m in zip(self.prompts, self.metadata)]

    def submit(self, client: CortexClient) -> "SemanticHandle":
        return SemanticHandle(self.kind, client.submit_async(self.requests()))


class SemanticHandle:
    """Typed view over a batch of result futures (awaits on first access)."""

    def __init__(self, kind: str, futures: List[ResultFuture]):
        self.kind = kind
        self.futures = futures

    def results(self):
        return [f.result() for f in self.futures]

    def scores(self) -> np.ndarray:
        return np.asarray([r.score for r in self.results()], np.float64)

    def chosen_labels(self) -> List[Tuple[str, ...]]:
        return [tuple(r.labels or ((r.label,) if r.label else ()))
                for r in self.results()]

    def texts(self) -> List[str]:
        return [r.text for r in self.results()]


@dataclasses.dataclass
class ExecConfig:
    use_cascade: bool = False
    cascade: CascadeConfig = dataclasses.field(default_factory=CascadeConfig)
    adaptive_reorder: bool = True
    chunk_rows: int = 256            # runtime-adaptation granularity
    # -- pilot sampling (adaptive re-optimization) ----------------------
    # rows per cold AI predicate scored up-front to learn selectivity /
    # cost before committing to an evaluation order; 0 disables the pilot
    pilot_rows: int = 48
    # tables smaller than this skip the pilot (it cannot pay for itself)
    min_rows_for_pilot: int = 192
    # -- learned cascade bypass -----------------------------------------
    # skip the SUPG-IT cascade (straight to the oracle) once the store has
    # seen >= cascade_bypass_min_rows cascaded rows for a predicate with a
    # delegation rate at or above this threshold: a proxy that escalates
    # nearly everything only adds its own calls on top of the oracle's
    cascade_bypass_delegation: float = 0.9
    cascade_bypass_min_rows: int = 64
    # treat a cold predicate with a kNN-transferred prior (cost model v2)
    # as warm for pilot purposes: skip its pilot sample and rank it with
    # the transferred selectivity / cost instead of paying sample calls
    pilot_trust_transfer: bool = True
    agg: AggConfig = dataclasses.field(default_factory=AggConfig)
    proxy_model: Optional[str] = None    # default: client.proxy_model
    classify_multi_label: bool = True    # semantic-join rewrite labels
    # Hybrid join strategy (paper §8 future work): run the multi-label
    # classification k times and union the selections.  Conservative
    # selection drops true labels independently per pass, so recall
    # improves ~1-(1-R1)^k at k× the (still O(L)) call cost.
    classify_passes: int = 1
    # None: predicate-major batched filter evaluation iff the client has a
    # RequestPipeline; True/False force it on/off.
    pipeline_filters: Optional[bool] = None
    # -- partitioned streaming execution (the third execution mode) -----
    # opt-in: split every filter scan into morsels of partition_rows and
    # pull them through the predicate chain one partition at a time; a
    # LIMIT above the chain terminates the pull as soon as n surviving
    # rows exist (unsubmitted partitions are cancelled, not billed)
    partitioned: bool = False
    partition_rows: int = 256
    # partitions whose *first* AI predicate is submitted into the
    # pipeline ahead of need (1 = just-in-time, no speculation).  Higher
    # values coalesce across partitions at the cost of up to
    # lookahead - 1 speculative partitions on early termination;
    # still-queued prefetches are cancelled and never billed.
    partition_lookahead: int = 1
    # -- semantic ORDER BY / top-k --------------------------------------
    # fused TopK: score everything with the proxy model, escalate only
    # ceil(topk_candidate_factor * k) candidates to the ordering model
    topk_prefilter: bool = True
    # -- semantic index (requires an attached SemanticIndexManager) -----
    # ORDER BY AI_SCORE top-k: replace proxy-score-all with index-
    # candidates-then-oracle (the index ranks rows by similarity to the
    # score prompt; only the escalated candidates reach the oracle).
    # Opt-in: unlike the AI_SIMILARITY pruning (exact by construction),
    # result rows are only guaranteed up to the index's recall bound —
    # see docs/semantic-index.md.
    topk_index_score: bool = False


class StreamingLimit:
    """LIMIT-aware consumer of the partition-pull loop.

    Collects surviving (global) row indices partition by partition;
    ``satisfied`` flips once ``n`` rows exist, signalling the driver to
    stop submitting partitions.  With ``n=None`` it degrades to a plain
    accumulator (the no-LIMIT partitioned filter path).
    """

    def __init__(self, n: Optional[int] = None):
        self.n = n
        self._parts: List[np.ndarray] = []
        self._count = 0

    def add(self, rows: np.ndarray) -> np.ndarray:
        """Accept ``rows`` up to the remaining limit; returns the
        accepted slice (what a streaming sink may forward downstream —
        rows past the limit are dropped here, so ``take()`` and the sum
        of accepted slices always agree)."""
        rows = np.asarray(rows, dtype=np.int64)
        if self.n is not None:
            rows = rows[:max(self.n - self._count, 0)]
        if len(rows):
            self._parts.append(rows)
            self._count += len(rows)
        return rows

    @property
    def count(self) -> int:
        return self._count

    @property
    def satisfied(self) -> bool:
        return self.n is not None and self._count >= self.n

    def take(self) -> np.ndarray:
        out = (np.concatenate(self._parts) if self._parts
               else np.empty(0, dtype=np.int64))
        return out[:self.n] if self.n is not None else out


@dataclasses.dataclass
class PredicateStats:
    evaluated: int = 0
    passed: int = 0
    seconds: float = 0.0
    credits: float = 0.0

    @property
    def selectivity(self) -> float:
        return self.passed / self.evaluated if self.evaluated else 0.5

    @property
    def cost_per_row(self) -> float:
        # runtime rank uses observed credits (primary) + wall time tiebreak
        if not self.evaluated:
            return 0.0
        return (self.credits + 1e-6 * self.seconds) / self.evaluated

    @property
    def rank(self) -> float:
        return self.cost_per_row / max(1.0 - self.selectivity, 1e-9)


class Executor:
    def __init__(self, catalog: Catalog, client: CortexClient, *,
                 cfg: Optional[ExecConfig] = None,
                 cost: Optional[CostModel] = None,
                 stats: Optional[StatsStore] = None,
                 semindex=None):
        self.catalog = catalog
        self.client = client
        self.cfg = cfg or ExecConfig()
        self.cost = cost or CostModel(catalog)
        # optional SemanticIndexManager: embedding store + ANN indexes
        # (shared across engines/sessions when serving)
        self.semindex = semindex
        # the learned-statistics feedback loop: every evaluation writes
        # observations here; the (shared) cost model reads them back
        self.stats = stats if stats is not None else StatsStore()
        if self.cost.stats is None:
            self.cost.stats = self.stats
        # telemetry of the last execute()
        self.pred_stats: Dict[str, PredicateStats] = {}
        self.cascades: Dict[str, SupgItCascade] = {}
        self.agg_telemetry = None
        self.reorder_events: List[str] = []
        self.reoptimizations: List[str] = []
        self.pilot_telemetry: Optional[Dict[str, Any]] = None
        self.partition_telemetry: Optional[Dict[str, Any]] = None
        self.index_telemetry: Optional[Dict[str, Any]] = None
        self._fp_by_key: Dict[str, str] = {}
        self._prefetch_spend: Dict[str, float] = {}
        # per-query embedding memo (model, text) -> vector: a literal
        # query side embeds once per query on *every* client and
        # execution mode (chunked/partitioned evaluation would otherwise
        # re-embed it per batch on an eager client)
        self._embed_memo: Dict[Tuple[str, str], np.ndarray] = {}
        # incremental-result hook: when set, `_partition_pull` forwards
        # each partition's accepted row indices here as they survive
        self._stream_sink: Optional[Callable[[np.ndarray], None]] = None
        # an `Observability` for the metrics registry (set by the owning
        # engine); span tracing rides the thread-local active tracer
        self.obs = None

    @property
    def pipelined(self) -> bool:
        if self.cfg.pipeline_filters is not None:
            return self.cfg.pipeline_filters
        return self.client.pipeline is not None

    @property
    def mode(self) -> str:
        """The execution mode this config+client combination selects."""
        if self.cfg.partitioned:
            return "partitioned"
        return "pipelined" if self.pipelined else "eager"

    # ------------------------------------------------------------------
    def _reset_query_state(self) -> None:
        self.pred_stats = {}
        self.cascades = {}
        self.reorder_events = []
        self.reoptimizations = []
        self.pilot_telemetry = None
        self.partition_telemetry = None
        self.index_telemetry = None
        self._fp_by_key: Dict[str, str] = {}
        self._embed_memo = {}

    def execute(self, node: P.PlanNode) -> Table:
        self._reset_query_state()
        out = self._exec(node)
        self._fold_cascade_stats()
        self.stats.note_query(set(self._fp_by_key.values()))
        return out

    def execute_stream(self, node: P.PlanNode,
                       emit: Callable[[Table], None]) -> Table:
        """Execute ``node``, invoking ``emit(batch)`` with incremental
        result `Table` batches as partitions complete, and return the
        full result (row-identical to ``execute``).  Streaming engages
        on the same shapes the partitioned LIMIT path handles —
        ``[Limit] [Project] Filter* -> source`` in partitioned mode;
        any other plan falls back to one terminal ``emit`` of the
        materialized result."""
        self._reset_query_state()
        out = self._exec_stream(node, emit)
        self._fold_cascade_stats()
        self.stats.note_query(set(self._fp_by_key.values()))
        return out

    def _exec_stream(self, node: P.PlanNode,
                     emit: Callable[[Table], None]) -> Table:
        limit: Optional[int] = None
        child = node
        if isinstance(node, P.Limit):
            limit, child = node.n, node.child
        spine = (self._streamable_spine(child)
                 if self.cfg.partitioned else None)
        if spine is None:
            out = self._exec(node)
            if out.num_rows:
                emit(out)
            return out
        project, preds, inner = spine
        source = self._exec(inner)
        if preds:
            preds, known = self._maybe_pilot(source, list(preds))
        else:
            known = {}
        batches: List[Table] = []

        def sink(accepted: np.ndarray) -> None:
            batch = source.take(accepted)
            if project is not None:
                batch = self._exec_project(
                    P.Project(_Materialized(batch), project.items))
            batches.append(batch)
            emit(batch)

        self._stream_sink = sink
        try:
            self._partition_pull(source, preds, known, limit=limit)
        finally:
            self._stream_sink = None
        if batches:
            out = batches[0]
            for b in batches[1:]:
                out = out.concat_rows(b)
        else:
            # zero surviving rows: an empty projection of the source
            # keeps the output schema identical to the buffered path
            out = source.take(np.empty(0, dtype=np.int64))
            if project is not None:
                out = self._exec_project(
                    P.Project(_Materialized(out), project.items))
        return out.head(limit) if limit is not None else out

    def _fold_cascade_stats(self) -> None:
        """Record per-predicate cascade routing volume into the store so
        future queries can re-decide the proxy-vs-direct choice."""
        for key, cascade in self.cascades.items():
            fp = self._fp_by_key.get(key)
            if fp is not None and cascade.stats.rows:
                self.stats.observe_cascade(
                    fp, rows=cascade.stats.rows,
                    oracle_calls=cascade.stats.oracle_calls)

    def _exec(self, node: P.PlanNode) -> Table:
        if isinstance(node, _Materialized):
            return node.table
        if isinstance(node, P.Scan):
            return self.catalog.table(node.table).prefixed(node.alias)
        if isinstance(node, P.Filter):
            return self._exec_filter(node)
        if isinstance(node, P.Join):
            return self._exec_join(node)
        if isinstance(node, P.SemanticJoinClassify):
            return self._exec_semantic_join(node)
        if isinstance(node, P.SemanticJoinIndex):
            return self._exec_semantic_join_index(node)
        if isinstance(node, P.Aggregate):
            return self._exec_aggregate(node)
        if isinstance(node, P.Project):
            return self._exec_project(node)
        if isinstance(node, P.Sort):
            return self._exec_sort(node)
        if isinstance(node, P.TopK):
            return self._exec_topk(node)
        if isinstance(node, P.Limit):
            return self._exec_limit(node)
        raise TypeError(node)

    # ------------------------------------------------------------------
    # Filter: chunked evaluation + adaptive reordering + cascades
    # ------------------------------------------------------------------

    def _pred_key(self, pred: E.Expr) -> str:
        if isinstance(pred, E.AIFilter):
            return f"AI_FILTER({pred.prompt.template[:40]!r})"
        if isinstance(pred, E.AIScore):
            # the model is part of the key: proxy-prefilter and oracle
            # scores of one prompt are separate telemetry rows
            model = pred.model or self.client.default_model
            return f"AI_SCORE({pred.prompt.template[:40]!r}, {model})"
        if isinstance(pred, E.AIClassify):
            return f"AI_CLASSIFY({pred.text.template[:40]!r})"
        if isinstance(pred, E.AISimilarity):
            model = pred.model or self.client.embed_model
            return (f"AI_SIMILARITY({_side_desc(pred.left)}, "
                    f"{_side_desc(pred.right)}, {model})")
        if isinstance(pred, E.AIEmbed):
            model = pred.model or self.client.embed_model
            return f"AI_EMBED({_side_desc(pred.arg)}, {model})"
        return f"{type(pred).__name__}:{abs(hash(pred)) % 10 ** 8}"

    def _stats_for(self, pred: E.Expr) -> PredicateStats:
        key = self._pred_key(pred)
        if key not in self._fp_by_key:
            fp = predicate_fingerprint(pred)
            self._fp_by_key[key] = fp
            text = predicate_prompt_text(pred)
            if text:
                # prompt registry feeds the kNN prior transfer: future
                # cold predicates find this one as an embedding neighbour
                self.stats.register_prompt(fp, text)
        return self.pred_stats.setdefault(key, PredicateStats())

    def _filter_model(self, pred: E.AIFilter) -> str:
        return pred.model or (
            self.cost.multimodal_model if pred.multimodal
            else self.client.default_model)

    def _exec_filter(self, node: P.Filter) -> Table:
        table = self._exec(node.child)
        mask = self.eval_predicates(table, list(node.predicates))
        return table.filter_mask(mask)

    def eval_predicates(self, table: Table, preds: List[E.Expr]
                        ) -> np.ndarray:
        n = table.num_rows
        if not preds:
            return np.ones(n, dtype=bool)
        preds, known = self._maybe_pilot(table, list(preds))
        if self.cfg.partitioned:
            sel = self._partition_pull(table, preds, known, limit=None)
            mask = np.zeros(n, dtype=bool)
            mask[sel] = True
            return mask
        if self.pipelined:
            return self._eval_predicates_batched(table, preds, known)
        return self._eval_predicates_chunked(table, preds, known)

    # ------------------------------------------------------------------
    # pilot sampling: learn cost/selectivity, then re-optimize mid-query
    # ------------------------------------------------------------------

    def _maybe_pilot(self, table: Table, preds: List[E.Expr]
                     ) -> Tuple[List[E.Expr], Dict[str, Dict[int, bool]]]:
        """Score cold AI predicates on a small row sample, fold the
        observations into the `StatsStore`, and re-rank the conjunct
        order with real numbers before the full evaluation commits.

        Returns the (possibly re-ordered) predicate list plus the
        pilot's per-row results (pred key -> {row: passed}), which the
        full pass consumes via `_timed_pred` instead of re-evaluating —
        so each pilot row is paid for exactly once, on eager and
        pipelined clients alike.  Skipped when the table is small, the
        pilot is disabled, there is nothing to re-order, or every AI
        predicate is already confidently known (warm start: the store
        answers from past queries).
        """
        cfg = self.cfg
        n = table.num_rows
        ai_preds = [p for p in preds if isinstance(p, E.AIFilter)]
        if (not cfg.adaptive_reorder or cfg.pilot_rows <= 0
                or n < cfg.min_rows_for_pilot or len(preds) < 2
                or not ai_preds):
            return preds, {}
        min_rows = self.cost.defaults.stats_min_rows
        cold = [p for p in ai_preds
                if not self.stats.confident(
                    predicate_fingerprint(p), min_rows=min_rows)]
        transferred: List[E.Expr] = []
        if cfg.pilot_trust_transfer and cold:
            # cost model v2: a cold predicate whose kNN-transferred prior
            # is live already has a usable selectivity/cost estimate —
            # rank with that instead of buying pilot sample calls
            transferred = [p for p in cold
                           if self.cost.estimate_source(p) == "transferred"]
            if transferred:
                skip = {id(p) for p in transferred}
                cold = [p for p in cold if id(p) not in skip]
        t0 = time.perf_counter()
        sampled: Dict[str, Dict[str, float]] = {}
        known: Dict[str, Dict[int, bool]] = {}
        n_sampled = 0
        if cold:
            with active_tracer().span("pilot", kind="pilot",
                                      predicates=len(cold)) as psp:
                k = min(cfg.pilot_rows, n)
                idx = np.unique(np.linspace(0, n - 1, k).astype(np.int64))
                n_sampled = int(len(idx))
                # submit every pilot batch before awaiting any, so the
                # pipeline coalesces across predicates
                c0 = self.client.ai_credits
                handles = [(p, SemanticOp.from_filter(
                    p, table, idx,
                    self._filter_model(p)).submit(self.client))
                    for p in cold]
                per_pred = []
                for pred, handle in handles:
                    results = handle.results()
                    passes = [r.score >= 0.5 for r in results]
                    # raw result credits apportion the dispatch-metered
                    # spend across predicates; dedup-served results cost
                    # nothing at dispatch, so the apportioned total
                    # matches real spend
                    per_pred.append((pred, passes,
                                     float(sum(r.credits
                                               for r in results)),
                                     float(sum(r.latency_s
                                               for r in results))))
                spent = self.client.ai_credits - c0
                raw_total = sum(raw for _, _, raw, _ in per_pred)
                scale = spent / raw_total if raw_total > 0 else 0.0
                psp.set(rows_in=n_sampled, credits=spent)
                for pred, passes, raw, seconds in per_pred:
                    passed = int(sum(passes))
                    credits = raw * scale
                    key = self._pred_key(pred)
                    known[key] = dict(zip(idx.tolist(), passes))
                    st = self._stats_for(pred)
                    st.evaluated += len(idx)
                    st.passed += passed
                    st.credits += credits
                    st.seconds += seconds
                    obs = self.stats.observe_predicate(
                        self._fp_by_key[key],
                        evaluated=len(idx), passed=passed,
                        credits=credits, seconds=seconds)
                    lo, hi = obs.selectivity_ci()
                    sampled[key] = {
                        "rows": int(len(idx)),
                        "selectivity": obs.selectivity,
                        "selectivity_ci": (round(lo, 4), round(hi, 4)),
                        "cost_per_row": obs.cost_per_row}
        # re-rank with the stats-informed cost model: observed numbers
        # for piloted/warm AI predicates, static estimates elsewhere
        ranked = sorted(preds, key=self.cost.predicate_rank)
        reordered = ranked != preds
        if reordered:
            event = ("pilot reorder: "
                     + " -> ".join(self._pred_key(p) for p in ranked))
            self.reorder_events.append(event)
            self.reoptimizations.append(event)
        entry = {
            "sampled_rows": n_sampled,
            "cold_predicates": len(cold),
            "warm_predicates": len(ai_preds) - len(cold) - len(transferred),
            "transferred_predicates": len(transferred),
            "reordered": reordered,
            "seconds": time.perf_counter() - t0,
            "predicates": sampled,
        }
        if self.pilot_telemetry is None:
            self.pilot_telemetry = entry
        else:                      # several Filter nodes piloted: merge
            agg = self.pilot_telemetry
            for k in ("sampled_rows", "cold_predicates", "warm_predicates",
                      "transferred_predicates", "seconds"):
                agg[k] += entry[k]
            agg["reordered"] = agg["reordered"] or reordered
            agg["predicates"].update(sampled)
        return ranked, known

    def _timed_pred(self, pred: E.Expr, table: Table, rows: np.ndarray,
                    known: Optional[Dict[str, Dict[int, bool]]] = None
                    ) -> np.ndarray:
        """Evaluate one predicate over rows, folding cost into its stats
        (per-query telemetry) and into the persistent `StatsStore` (the
        cross-query learned-statistics feedback loop).

        ``known`` carries per-row results the pilot phase already paid
        for (pred key -> {row index: passed}); those rows are answered
        from it — never re-submitted, never re-counted — so pilot rows
        are billed and recorded exactly once even on an eager client.
        """
        st = self._stats_for(pred)
        rows = np.asarray(rows)
        km = (known or {}).get(self._pred_key(pred))
        if km:
            in_known = np.isin(rows, np.fromiter(km, dtype=np.int64))
        else:
            in_known = np.zeros(len(rows), dtype=bool)
        out = np.zeros(len(rows), dtype=bool)
        if km:
            out[in_known] = [km[int(r)] for r in rows[in_known]]
        unk = rows[~in_known]
        if len(unk):
            with active_tracer().span(self._pred_key(pred),
                                      kind="predicate",
                                      rows_in=int(len(unk))) as sp:
                t0 = time.perf_counter()
                c0 = self.client.ai_credits
                res = np.asarray(self._eval_pred(pred, table, unk),
                                 dtype=bool)
                seconds = time.perf_counter() - t0
                credits = self.client.ai_credits - c0
                sp.set(rows_out=int(res.sum()), credits=credits)
            st.seconds += seconds
            st.credits += credits
            st.evaluated += len(unk)
            st.passed += int(res.sum())
            if pred.is_ai():
                if self.obs is not None:
                    self.obs.registry.histogram(
                        "aisql_operator_seconds").observe(
                            seconds, operator=type(pred).__name__)
                self.stats.observe_predicate(
                    self._fp_by_key[self._pred_key(pred)],
                    evaluated=len(unk), passed=int(res.sum()),
                    credits=credits, seconds=seconds)
            out[~in_known] = res
        return out

    def _eval_predicates_chunked(self, table: Table, preds: List[E.Expr],
                                 known: Optional[Dict[str, Dict[int, bool]]]
                                 = None) -> np.ndarray:
        """Chunk-major evaluation with adaptive mid-stream reordering."""
        n = table.num_rows
        mask = np.ones(n, dtype=bool)
        order = list(preds)            # compile-time order from the optimizer
        chunk = self.cfg.chunk_rows if self.cfg.adaptive_reorder else n
        chunk = max(chunk, 1)
        for lo in range(0, n, chunk):
            hi = min(lo + chunk, n)
            alive = np.arange(lo, hi)
            for pred in order:
                if len(alive) == 0:
                    break
                res = self._timed_pred(pred, table, alive, known)
                alive = alive[res]
            sel = np.zeros(hi - lo, dtype=bool)
            sel[alive - lo] = True
            mask[lo:hi] = sel
            # --- adaptive reordering between chunks (§5.1 runtime) ---
            if self.cfg.adaptive_reorder and hi < n:
                ranked = sorted(order, key=lambda p: self._stats_for(p).rank)
                if ranked != order:
                    self.reorder_events.append(
                        f"rows[{hi}]: reorder -> "
                        + ", ".join(self._pred_key(p) for p in ranked))
                    order = ranked
        return mask

    def _eval_predicates_batched(self, table: Table, preds: List[E.Expr],
                                 known: Optional[Dict[str, Dict[int, bool]]]
                                 = None) -> np.ndarray:
        """Predicate-major evaluation for the pipelined runtime: each
        predicate scans all surviving rows in one coalesced pass (the
        pipeline right-sizes the engine batches), trading mid-stream
        reordering for batching.  Row results are per-row deterministic,
        so the output mask matches chunk-major evaluation exactly for
        exact (non-cascade) predicates."""
        n = table.num_rows
        order = list(preds)
        alive = np.arange(n)
        for pred in order:
            if len(alive) == 0:
                break
            res = self._timed_pred(pred, table, alive, known)
            alive = alive[res]
        mask = np.zeros(n, dtype=bool)
        mask[alive] = True
        if self.cfg.adaptive_reorder:
            ranked = sorted(order, key=lambda p: self._stats_for(p).rank)
            if ranked != order:           # observational: next query's hint
                self.reorder_events.append(
                    "batched: observed rank -> "
                    + ", ".join(self._pred_key(p) for p in ranked))
        return mask

    # ------------------------------------------------------------------
    # partition-pull streaming execution (the partitioned mode driver)
    # ------------------------------------------------------------------

    def _partition_spans(self, table: Table
                         ) -> List[Tuple[int, int, Optional[int]]]:
        """Partition boundaries ``(lo, hi, segment_id)`` for the pull
        loop.  On a chunk-backed table, partitions are aligned to never
        straddle a chunk — each span maps to exactly one segment whose
        morsel view feeds the predicate chain zero-copy; on a monolithic
        table ``segment_id`` is None and spans are plain
        ``partition_rows`` strides."""
        n = table.num_rows
        psize = max(self.cfg.partition_rows, 1)
        if isinstance(table, ChunkedTable):
            spans: List[Tuple[int, int, Optional[int]]] = []
            for sid, (slo, shi) in enumerate(table.segment_bounds()):
                for lo in range(slo, shi, psize):
                    spans.append((lo, min(lo + psize, shi), sid))
            return spans or [(0, 0, None)]
        return [(lo, min(lo + psize, n), None)
                for lo in range(0, n, psize)] or [(0, 0, None)]

    def _span_morsel(self, table: Table, sid: Optional[int]
                     ) -> Tuple[Table, int]:
        """The (morsel table, global row offset) a span evaluates on."""
        if sid is None:
            return table, 0
        return table.morsel(sid), table.segment_bounds()[sid][0]

    @staticmethod
    def _localize_known(known: Optional[Dict[str, Dict[int, bool]]],
                        moff: int, mend: int
                        ) -> Optional[Dict[str, Dict[int, bool]]]:
        """Rebase pilot-known row results (global indices) onto a
        morsel's local indices for rows inside ``[moff, mend)``."""
        if not known or moff == 0:
            return known
        return {key: {g - moff: v for g, v in km.items()
                      if moff <= g < mend}
                for key, km in known.items()}

    def _partition_pull(self, table: Table, preds: List[E.Expr],
                        known: Optional[Dict[str, Dict[int, bool]]],
                        limit: Optional[int]) -> np.ndarray:
        """The partition-pull loop: morsels of ``partition_rows`` flow
        through the whole predicate chain one partition at a time (each
        an independently submitted batch the scheduler spreads across
        replicas), feeding a `StreamingLimit` consumer.  With a limit the
        loop stops — and cancels still-queued prefetches — as soon as
        ``n`` surviving rows exist.  On a `ChunkedTable` each partition
        evaluates against its chunk's morsel view, so the table is never
        materialized; surviving-row bookkeeping stays in global indices
        throughout.  Returns the selected global row indices in table
        order."""
        psize = max(self.cfg.partition_rows, 1)
        spans = self._partition_spans(table)
        consumer = StreamingLimit(limit)
        order = list(preds)
        prefetched: Dict[int, Tuple[str, np.ndarray, SemanticHandle]] = {}
        # credits metered while *submitting* prefetches (a size-threshold
        # flush can dispatch mid-submit); folded into the predicate's
        # accounting at consume time so no spend is ever orphaned
        self._prefetch_spend: Dict[str, float] = {}
        tel = {"partitions_total": len(spans), "partitions_executed": 0,
               "partitions_cancelled": 0, "partition_rows": psize,
               "rows_scanned": 0, "rows_emitted": 0,
               "early_terminated": False, "cancelled_requests": 0}
        tr = active_tracer()
        try:
            for i, (lo, hi, sid) in enumerate(spans):
                part = np.arange(lo, hi, dtype=np.int64)
                tel["rows_scanned"] += int(len(part))
                with tr.span(f"partition[{i}]", kind="partition",
                             index=i, rows_in=int(len(part))) as msp:
                    self._prefetch_first_pred(table, order, known, spans,
                                              i, prefetched)
                    mtable, moff = self._span_morsel(table, sid)
                    kloc = known if sid is None else self._localize_known(
                        known, moff, table.segment_bounds()[sid][1])
                    alive = part
                    for pred in order:
                        if not len(alive):
                            break
                        pf = prefetched.get(lo)
                        if pf is not None and pf[0] == self._pred_key(pred):
                            _, rows, handle = prefetched.pop(lo)
                            res = self._consume_prefetched(pred, rows,
                                                           handle, alive)
                        else:
                            res = self._timed_pred(pred, mtable,
                                                   alive - moff, kloc)
                        alive = alive[res]
                    msp.set(rows_out=int(len(alive)))
                # a prefetch this partition never reached (rows died
                # first, or a reorder changed the chain): withdraw it
                leftover = prefetched.pop(lo, None)
                if leftover is not None:
                    tel["cancelled_requests"] += \
                        self._cancel_handles([leftover])
                tel["partitions_executed"] += 1
                accepted = consumer.add(alive)
                if self._stream_sink is not None and len(accepted):
                    self._stream_sink(accepted)
                # adaptive reordering between partitions (§5.1 runtime)
                if self.cfg.adaptive_reorder and order \
                        and i + 1 < len(spans):
                    ranked = sorted(order,
                                    key=lambda p: self._stats_for(p).rank)
                    if ranked != order:
                        self.reorder_events.append(
                            f"partition[{i}]: reorder -> "
                            + ", ".join(self._pred_key(p) for p in ranked))
                        tr.event("partition.reorder", index=i)
                        order = ranked
                if consumer.satisfied:
                    remaining = len(spans) - (i + 1)
                    if remaining or prefetched:
                        tel["early_terminated"] = True
                        tr.event("partition.early_stop",
                                 cancelled=remaining)
                    tel["partitions_cancelled"] = remaining
                    break
        except Exception:
            # a mid-query failure (e.g. a predicate batch that exhausted
            # its retries) must withdraw still-queued speculative
            # prefetches: abandoned in the pipeline they would be
            # dispatched — and billed — at some later barrier
            self._cancel_handles(prefetched.values())
            prefetched.clear()
            raise
        tel["cancelled_requests"] += self._cancel_handles(
            prefetched.values())
        prefetched.clear()
        # spend of dispatched-but-never-consumed prefetches still belongs
        # to the predicate (real credits, zero extra evaluated rows)
        for key, spend in self._prefetch_spend.items():
            if spend > 0.0:
                st = self.pred_stats.setdefault(key, PredicateStats())
                st.credits += spend
                fp = self._fp_by_key.get(key)
                if fp is not None:
                    self.stats.observe_predicate(fp, evaluated=0, passed=0,
                                                 credits=spend)
        self._prefetch_spend = {}
        out = consumer.take()
        tel["rows_emitted"] = int(len(out))
        self._note_partitions(tel)
        return out

    def _prefetch_first_pred(self, table: Table, order: List[E.Expr],
                             known, spans: List[Tuple], i: int,
                             prefetched: Dict[int, Tuple]) -> None:
        """Speculatively queue the first AI predicate of the next
        ``partition_lookahead`` partitions into the pipeline so their
        rows coalesce into one engine batch (split across replicas by
        the scheduler).  On a chunked table each lookahead span renders
        from its own morsel view (prompts are identical to a full-table
        render, so cache/dedup keys agree across stores); bookkeeping
        stays global.  Bounded speculation: on early termination the
        still-queued requests are cancelled, never dispatched or
        billed."""
        lookahead = self.cfg.partition_lookahead
        if (lookahead <= 1 or self.client.pipeline is None or not order
                or self.cfg.use_cascade):
            return
        pred = order[0]
        if not isinstance(pred, E.AIFilter):
            return
        key = self._pred_key(pred)
        if (known or {}).get(key):
            return      # pilot already paid for rows; avoid recounting
        c0 = self.client.ai_credits
        for j in range(i, min(i + lookahead, len(spans))):
            lo, hi, sid = spans[j]
            if lo in prefetched:
                continue
            rows = np.arange(lo, hi, dtype=np.int64)
            mtable, moff = self._span_morsel(table, sid)
            op = SemanticOp.from_filter(pred, mtable, rows - moff,
                                        self._filter_model(pred))
            prefetched[lo] = (key, rows, op.submit(self.client))
        spent = self.client.ai_credits - c0
        if spent > 0.0:       # a size-threshold flush dispatched mid-submit
            self._prefetch_spend[key] = \
                self._prefetch_spend.get(key, 0.0) + spent

    def _consume_prefetched(self, pred: E.Expr, rows: np.ndarray,
                            handle: SemanticHandle, alive: np.ndarray
                            ) -> np.ndarray:
        """Await a prefetched partition batch and fold its spend into the
        same per-query telemetry and `StatsStore` rows as `_timed_pred`
        (every prefetched row is billed and recorded exactly once)."""
        st = self._stats_for(pred)
        with active_tracer().span(self._pred_key(pred), kind="predicate",
                                  rows_in=int(len(rows)),
                                  prefetched=True) as sp:
            t0 = time.perf_counter()
            c0 = self.client.ai_credits
            passes = handle.scores() >= 0.5
            seconds = time.perf_counter() - t0
            credits = self.client.ai_credits - c0
            sp.set(rows_out=int(passes.sum()), credits=credits)
        # credits already metered while this (or a sibling) prefetch was
        # being submitted belong to the same predicate: claim them here
        # so learned cost-per-row reflects the real spend
        credits += self._prefetch_spend.pop(self._pred_key(pred), 0.0)
        st.evaluated += len(rows)
        st.passed += int(passes.sum())
        st.credits += credits
        st.seconds += seconds
        self.stats.observe_predicate(
            self._fp_by_key[self._pred_key(pred)], evaluated=len(rows),
            passed=int(passes.sum()), credits=credits, seconds=seconds)
        by_row = dict(zip(rows.tolist(), passes.tolist()))
        return np.asarray([by_row[int(r)] for r in alive], dtype=bool)

    def _cancel_handles(self, entries) -> int:
        """Cancel the still-queued futures of prefetched partition
        batches; dispatched (already billed/resolved) work is left
        alone.  Returns the number of requests withdrawn."""
        pipe = self.client.pipeline
        if pipe is None:
            return 0
        total = 0
        for _, _, handle in entries:
            pending = [f for f in handle.futures
                       if not f.done() and not f.cancelled()]
            if pending:
                # owner= moves the billing tag off this session when a
                # dedup-shared item survives for another session's sake
                total += pipe.cancel(pending, owner=self.client.owner)
        return total

    def _note_partitions(self, tel: Dict[str, Any]) -> None:
        if self.partition_telemetry is None:
            self.partition_telemetry = tel
            return
        agg = self.partition_telemetry
        for k in ("partitions_total", "partitions_executed",
                  "partitions_cancelled", "rows_scanned", "rows_emitted",
                  "cancelled_requests"):
            agg[k] += tel[k]
        agg["early_terminated"] = (agg["early_terminated"]
                                   or tel["early_terminated"])

    def _exec_limit(self, node: P.Limit) -> Table:
        """LIMIT.  In partitioned mode a streamable spine underneath —
        ``[Project] -> Filter* -> source`` — is pulled partition by
        partition with early termination: the filter chain (and any AI
        projection) runs only until ``n`` surviving rows exist instead
        of materializing everything and truncating."""
        if self.cfg.partitioned:
            spine = self._streamable_spine(node.child)
            if spine is not None:
                project, preds, inner = spine
                source = self._exec(inner)
                if preds:
                    preds, known = self._maybe_pilot(source, list(preds))
                else:
                    known = {}
                sel = self._partition_pull(source, preds, known,
                                           limit=node.n)
                out = source.take(sel)
                if project is not None:
                    out = self._exec_project(
                        P.Project(_Materialized(out), project.items))
                return out.head(node.n)
        return self._exec(node.child).head(node.n)

    def _streamable_spine(self, child: P.PlanNode):
        """Peel ``[Project] -> Filter* -> source`` under a Limit.
        Returns ``(project|None, predicates, source)`` when streaming
        can save work (a filter chain to early-terminate or a projection
        to bound), else None.  Predicates are in evaluation order
        (innermost filter first)."""
        project: Optional[P.Project] = None
        inner = child
        if isinstance(inner, P.Project):
            project, inner = inner, inner.child
        preds: List[E.Expr] = []
        while isinstance(inner, P.Filter):
            preds = list(inner.predicates) + preds
            inner = inner.child
        if project is None and not preds:
            return None
        return project, preds, inner

    # ------------------------------------------------------------------
    # ORDER BY: Sort and fused TopK (semantic ordering)
    # ------------------------------------------------------------------

    def _exec_sort(self, node: P.Sort) -> Table:
        table = self._exec(node.child)
        rows = np.arange(table.num_rows, dtype=np.int64)
        return table.take(self._order_rows(table, rows, node.keys))

    def _exec_topk(self, node: P.TopK) -> Table:
        """Fused ORDER BY + LIMIT — three early-exit paths by key kind:

        * AI_SIMILARITY primary: embeddings from the semantic index's
          store (only cold texts cost EMBED requests) ranked by the
          similarity kernel — exact, so index-on == index-off rows;
        * AI_SCORE primary with ``topk_index_score``: the index ranks
          rows against the score prompt's embedding and only the
          escalated candidates reach the ordering model (opt-in:
          bounded by the index's recall, see docs/semantic-index.md);
        * AI_SCORE primary otherwise: the proxy model scores every row
          and the best ``topk_candidate_factor * k`` escalate.
        """
        table = self._exec(node.child)
        n = node.n
        rows = np.arange(table.num_rows, dtype=np.int64)
        primary = node.keys[0] if node.keys else None
        if primary is not None and table.num_rows > n:
            if isinstance(primary.expr, E.AISimilarity):
                out = self._topk_similarity(node, table, rows)
                if out is not None:
                    return out
            if isinstance(primary.expr, E.AIScore):
                if self.semindex is not None and self.cfg.topk_index_score:
                    out = self._topk_index_score(node, table, rows)
                    if out is not None:
                        return out
                if self.cfg.topk_prefilter:
                    out = self._topk_proxy_prefilter(node, table, rows)
                    if out is not None:
                        return out
        return table.take(self._order_rows(table, rows, node.keys)[:n])

    def _topk_proxy_prefilter(self, node: P.TopK, table: Table,
                              rows: np.ndarray) -> Optional[Table]:
        """Proxy-score-all, escalate the best candidates to the oracle."""
        primary = node.keys[0]
        n = node.n
        proxy = self.cfg.proxy_model or self.client.proxy_model
        oracle = primary.expr.model or self.client.default_model
        k_cand = int(self.cost.topk_candidates(float(table.num_rows), n))
        if proxy == oracle or k_cand >= table.num_rows:
            return None
        pscores = self._ai_scores(primary.expr, table, rows, proxy)
        perm = sorted(range(len(rows)), key=lambda i: pscores[i],
                      reverse=primary.desc)
        cand = np.sort(rows[np.asarray(perm[:k_cand], dtype=np.int64)])
        self.reoptimizations.append(
            f"topk-prefilter: {proxy} scored {len(rows)} rows, "
            f"escalated {len(cand)} candidates to {oracle} (k={n})")
        return table.take(self._order_rows(table, cand, node.keys)[:n])

    def _topk_similarity(self, node: P.TopK, table: Table,
                         rows: np.ndarray) -> Optional[Table]:
        """Semantic top-k over an AI_SIMILARITY primary key.

        The similarity values come from embeddings (store-cached when a
        manager is attached) and the full ordering is computed from them
        locally — numerically identical to the unpruned Sort+Limit, so
        this path never changes result rows; it only removes repeat
        EMBED spend and, for the single-key case, ranks through the
        similarity kernel instead of a host sort."""
        primary = node.keys[0]
        e = primary.expr
        n = node.n
        sims, lv, rv = self._similarity_with_vectors(e, table, rows)
        lit_left = not e.left.refs()
        lit_right = not e.right.refs()
        col_side = e.right if lit_left else e.left
        col_refs = col_side.refs()
        if (self.semindex is not None and len(node.keys) == 1
                and lit_left != lit_right and len(col_refs) == 1
                and self._is_base_snapshot(node.child, table,
                                           next(iter(col_refs)))):
            # managed index ranking: the column side's snapshot gets (or
            # reuses) an `IvfFlatIndex`; the literal side is the query
            # vector.  Search honors SemIndexConfig.exact_topk / nprobe:
            # the default flat scan is exact (ties toward the lower row
            # index, matching the stable host sort), IVF probing trades
            # that for the configured recall.  ASC negates the query:
            # the top-k of -q·c is the bottom-k of q·c.
            mgr = self.semindex
            model = e.model or self.client.embed_model
            col_key = self._index_column_key(node.child,
                                             next(iter(col_refs)))
            corpus_texts = self._render_side(col_side, table, rows)
            mgr.ensure_index(self.client, col_key, corpus_texts,
                             metadata=row_metadata(table, rows),
                             model=model)
            qv = (lv if lit_left else rv)[:1].astype(np.float32)
            if not primary.desc:
                qv = -qv
            _, idx = mgr.search(col_key, qv, min(n, len(rows)))
            order = np.asarray(idx[0])
            order = order[order >= 0]
            self._index_note(index_topk=1)
            self.reoptimizations.append(
                f"topk-similarity: index ranked {len(rows)} rows "
                f"through the similarity kernel, top {len(order)} kept")
            return table.take(rows[order[:n]])
        precomputed = {id(e): sims}
        return table.take(self._order_rows(table, rows, node.keys,
                                           precomputed)[:n])

    def _topk_index_score(self, node: P.TopK, table: Table,
                          rows: np.ndarray) -> Optional[Table]:
        """Index-candidates-then-oracle for ``ORDER BY AI_SCORE`` top-k:
        rank rows by embedding similarity to the score prompt, escalate
        only ``topk_candidate_factor * k`` candidates to the ordering
        model — no proxy scan at all.  Opt-in (``topk_index_score``):
        the candidate set is only as good as the embedding space, so
        result rows are guaranteed up to that recall, not exactly."""
        primary = node.keys[0]
        pred: E.AIScore = primary.expr
        n = node.n
        k_cand = int(self.cost.topk_candidates(float(table.num_rows), n))
        if k_cand >= table.num_rows or not pred.prompt.args:
            return None
        model = self.semindex.model_for(self.client)
        # corpus: the rendered prompt arguments (the row text the score
        # judges); query: the prompt template itself, format slots
        # stripped
        arg_vals = [E.eval_expr(a, table, rows) for a in pred.prompt.args]
        texts = [" ".join(str(a[i]) for a in arg_vals)
                 for i in range(len(rows))]
        md = row_metadata(table, rows)
        c0 = self.client.ai_calls
        cv = self.semindex.embed_texts(self.client, texts, metadata=md,
                                       model=model)
        query = _strip_format_slots(pred.prompt.template)
        qv = self.semindex.embed_texts(self.client, [query], model=model)
        self._index_note(embed_texts=len(texts) + 1,
                         embed_llm_calls=self.client.ai_calls - c0)
        if not primary.desc:
            qv = -qv
        _, idx = self.semindex.topk_candidates(qv.astype(np.float32),
                                               cv.astype(np.float32),
                                               k_cand)
        order = np.asarray(idx[0])
        cand = np.sort(rows[order[order >= 0]])
        self._index_note(index_topk=1, probes=1, candidates=len(cand))
        oracle = pred.model or self.client.default_model
        self.reoptimizations.append(
            f"topk-index: semantic index ranked {len(rows)} rows, "
            f"escalated {len(cand)} candidates to {oracle} (k={n}, "
            "no proxy scan)")
        return table.take(self._order_rows(table, cand, node.keys)[:n])

    def _order_rows(self, table: Table, rows: np.ndarray,
                    keys, precomputed=None) -> np.ndarray:
        """Stable multi-key ordering of ``rows``: repeated stable sorts
        from the least-significant key up (Python's sort keeps ties in
        input order even with ``reverse=True``).  ``precomputed`` maps
        ``id(key expr) -> values over rows`` for keys a caller already
        evaluated (the TopK paths never pay for a key twice)."""
        idx = np.arange(len(rows))
        for sk in reversed(list(keys)):
            vals = (precomputed or {}).get(id(sk.expr))
            if vals is None:
                vals = self._sort_key_values(sk.expr, table, rows)
            sub = vals[idx]
            perm = sorted(range(len(sub)), key=lambda i: sub[i],
                          reverse=sk.desc)
            idx = idx[np.asarray(perm, dtype=np.int64)]
        return rows[idx]

    def _sort_key_values(self, expr: E.Expr, table: Table,
                         rows: np.ndarray) -> np.ndarray:
        if isinstance(expr, E.AIScore):
            return self._ai_scores(expr, table, rows,
                                   expr.model or self.client.default_model)
        if isinstance(expr, E.AISimilarity):
            return self._similarity_values(expr, table, rows)
        return np.asarray(E.eval_expr(expr, table, rows))

    def _ai_scores(self, pred: E.AIScore, table: Table, rows: np.ndarray,
                   model: str) -> np.ndarray:
        """Score ``rows`` with the SCORE request kind, metering into the
        per-query telemetry and the `StatsStore` under a model-resolved
        surrogate (proxy and oracle scores are distinct populations)."""
        surrogate = E.AIScore(pred.prompt, model=model)
        st = self._stats_for(surrogate)
        prompts = pred.prompt.render(table, rows)
        args = [E.eval_expr(a, table, rows) for a in pred.prompt.args]
        md = row_metadata(table, rows, args, arg_cols=sorted(pred.refs()))
        t0 = time.perf_counter()
        c0 = self.client.ai_credits
        scores = SemanticOp.scores(prompts, md,
                                   model).submit(self.client).scores()
        seconds = time.perf_counter() - t0
        credits = self.client.ai_credits - c0
        st.evaluated += len(rows)
        st.passed += int((scores >= 0.5).sum())
        st.credits += credits
        st.seconds += seconds
        self.stats.observe_predicate(
            self._fp_by_key[self._pred_key(surrogate)],
            evaluated=len(rows), passed=int((scores >= 0.5).sum()),
            credits=credits, seconds=seconds)
        return scores

    # ------------------------------------------------------------------
    # embeddings: AI_EMBED / AI_SIMILARITY evaluation
    # ------------------------------------------------------------------

    def _index_note(self, **deltas) -> None:
        """Accumulate per-query semantic-index telemetry
        (`QueryReport.semindex`)."""
        if self.index_telemetry is None:
            self.index_telemetry = {
                "index_joins": 0, "index_topk": 0, "probes": 0,
                "candidates": 0, "verify_calls": 0,
                "embed_texts": 0, "embed_llm_calls": 0}
        for k, v in deltas.items():
            self.index_telemetry[k] = self.index_telemetry.get(k, 0) + v

    def _render_side(self, e: E.Expr, table: Table,
                     rows: np.ndarray) -> List[str]:
        if isinstance(e, E.Literal):
            return [str(e.value)] * len(rows)
        return [str(v) for v in E.eval_expr(e, table, rows)]

    def _embed_side(self, e: E.Expr, table: Table, rows: np.ndarray,
                    model: str) -> np.ndarray:
        """Embed one AI_SIMILARITY / AI_EMBED side over ``rows``.

        Distinct texts embed once (crucial on an eager client, where
        there is no pipeline dedup to absorb a repeated literal); the
        `SemanticIndexManager`'s store answers warm texts without any
        EMBED request at all.  Row metadata travels with each request
        so the simulator's grounding hooks see the same evidence the
        AI_FILTER path forwards.
        """
        texts = self._render_side(e, table, rows)
        if not texts:                 # a filter eliminated every row
            return np.zeros((0, 1), np.float32)
        if e.refs():
            md = row_metadata(table, rows)
        else:
            md = [{} for _ in texts]
        first: Dict[str, int] = {}
        for i, t in enumerate(texts):
            first.setdefault(t, i)
        cold = [t for t in first if (model, t) not in self._embed_memo]
        calls0 = self.client.ai_calls
        if cold:
            cold_md = [md[first[t]] for t in cold]
            if self.semindex is not None:
                vecs = self.semindex.embed_texts(self.client, cold,
                                                 metadata=cold_md,
                                                 model=model)
            else:
                vecs = self.client.embed(cold, model=model,
                                         metadata=cold_md)
            for t, v in zip(cold, vecs):
                self._embed_memo[(model, t)] = np.asarray(v, np.float32)
        self._index_note(embed_texts=len(texts),
                         embed_llm_calls=self.client.ai_calls - calls0)
        return np.stack([self._embed_memo[(model, t)]
                         for t in texts]).astype(np.float32)

    def _similarity_with_vectors(self, pred: E.AISimilarity, table: Table,
                                 rows: np.ndarray):
        """``(sims, left_vecs, right_vecs)`` for ``rows``, metered into
        per-query telemetry and the `StatsStore` under the
        model-resolved surrogate (EMBED spend only — AI_SIMILARITY never
        touches a generative model)."""
        model = pred.model or self.client.embed_model
        surrogate = E.AISimilarity(pred.left, pred.right, model=model)
        st = self._stats_for(surrogate)
        t0 = time.perf_counter()
        c0 = self.client.ai_credits
        lv = self._embed_side(pred.left, table, rows, model)
        rv = self._embed_side(pred.right, table, rows, model)
        sims = np.sum(_unit_rows(lv) * _unit_rows(rv), axis=1)
        seconds = time.perf_counter() - t0
        credits = self.client.ai_credits - c0
        st.evaluated += len(rows)
        st.passed += int((sims >= 0.5).sum())
        st.credits += credits
        st.seconds += seconds
        self.stats.observe_predicate(
            self._fp_by_key[self._pred_key(surrogate)],
            evaluated=len(rows), passed=int((sims >= 0.5).sum()),
            credits=credits, seconds=seconds)
        return sims.astype(np.float64), lv, rv

    def _similarity_values(self, pred: E.AISimilarity, table: Table,
                           rows: np.ndarray) -> np.ndarray:
        return self._similarity_with_vectors(pred, table, rows)[0]

    def _embed_values(self, pred: E.AIEmbed, table: Table,
                      rows: np.ndarray) -> np.ndarray:
        """AI_EMBED projection: one unit vector (tuple cell) per row."""
        model = pred.model or self.client.embed_model
        surrogate = E.AIEmbed(pred.arg, model=model)
        st = self._stats_for(surrogate)
        t0 = time.perf_counter()
        c0 = self.client.ai_credits
        vecs = self._embed_side(pred.arg, table, rows, model)
        seconds = time.perf_counter() - t0
        credits = self.client.ai_credits - c0
        st.evaluated += len(rows)
        st.passed += len(rows)
        st.credits += credits
        st.seconds += seconds
        self.stats.observe_predicate(
            self._fp_by_key[self._pred_key(surrogate)],
            evaluated=len(rows), passed=len(rows),
            credits=credits, seconds=seconds)
        out = np.empty(len(rows), dtype=object)
        for i in range(len(rows)):
            out[i] = tuple(float(x) for x in vecs[i])
        return out

    def _eval_mixed(self, e: E.Expr, table: Table,
                    rows: np.ndarray) -> np.ndarray:
        """Evaluate an expression tree containing AI_SIMILARITY leaves
        (e.g. ``AI_SIMILARITY(a, b) > 0.8`` as a WHERE conjunct)."""
        if isinstance(e, E.AISimilarity):
            return self._similarity_values(e, table, rows)
        if isinstance(e, E.BinOp):
            l = self._eval_mixed(e.left, table, rows)
            r = self._eval_mixed(e.right, table, rows)
            ops = {"=": lambda a, b: a == b, "!=": lambda a, b: a != b,
                   "<": lambda a, b: a < b, "<=": lambda a, b: a <= b,
                   ">": lambda a, b: a > b, ">=": lambda a, b: a >= b,
                   "+": lambda a, b: a + b, "-": lambda a, b: a - b,
                   "*": lambda a, b: a * b, "/": lambda a, b: a / b}
            return ops[e.op](l, r)
        if isinstance(e, E.Between):
            v = self._eval_mixed(e.expr, table, rows)
            lo = self._eval_mixed(e.lo, table, rows)
            hi = self._eval_mixed(e.hi, table, rows)
            return (v >= lo) & (v <= hi)
        if isinstance(e, E.Not):
            return ~np.asarray(self._eval_mixed(e.arg, table, rows), bool)
        if isinstance(e, E.BoolOp):
            parts = [np.asarray(self._eval_mixed(a, table, rows), bool)
                     for a in e.args]
            out = parts[0]
            for p in parts[1:]:
                out = (out & p) if e.op == "and" else (out | p)
            return out
        if isinstance(e, E.InList):
            v = self._eval_mixed(e.expr, table, rows)
            allowed = set(e.values)
            return np.asarray([x in allowed for x in v])
        return np.asarray(E.eval_expr(e, table, rows))

    def _eval_pred(self, pred: E.Expr, table: Table, rows: np.ndarray
                   ) -> np.ndarray:
        if isinstance(pred, E.AIFilter):
            return self._eval_ai_filter(pred, table, rows)
        if isinstance(pred, E.AIScore):
            raise NotImplementedError(
                "AI_SCORE is an ORDER BY key, not a predicate; compare "
                "with AI_FILTER instead")
        if isinstance(pred, E.AIClassify):
            raise NotImplementedError("AI_CLASSIFY as a predicate")
        if any(isinstance(c, E.AISimilarity) for c in E.ai_calls_in(pred)):
            return np.asarray(self._eval_mixed(pred, table, rows),
                              dtype=bool)
        return np.asarray(E.eval_expr(pred, table, rows), dtype=bool)

    # -- AI_FILTER with optional cascade --
    def _cascade_bypass(self, pred: E.AIFilter) -> Optional[str]:
        """Learned re-decision: skip the cascade for a predicate whose
        observed delegation rate shows the proxy escalates (nearly)
        everything — running it would only add proxy calls on top of the
        oracle calls.  Requires enough evidence in the store; when the
        store is cold for this fingerprint, a kNN-transferred delegation
        prior (cost model v2) can make the same call from the evidence
        of similar predicates.  Returns the reoptimization event string
        when the bypass applies, else None."""
        cfg = self.cfg
        obs = self.stats.get(predicate_fingerprint(pred))
        if (obs is not None
                and obs.cascade_rows >= cfg.cascade_bypass_min_rows):
            if obs.delegation_rate >= cfg.cascade_bypass_delegation:
                return (f"cascade-bypass: {self._pred_key(pred)} observed "
                        f"delegation {obs.delegation_rate:.2f} >= "
                        f"{cfg.cascade_bypass_delegation:.2f}, "
                        "routing straight to the oracle")
            return None
        tp = self.cost.transferred_prior(pred)
        if (tp is not None
                and tp.cascade_rows >= cfg.cascade_bypass_min_rows
                and tp.delegation_rate >= cfg.cascade_bypass_delegation):
            return (f"cascade-bypass: {self._pred_key(pred)} transferred "
                    f"delegation {tp.delegation_rate:.2f} >= "
                    f"{cfg.cascade_bypass_delegation:.2f} (kNN prior), "
                    "routing straight to the oracle")
        return None

    def _eval_ai_filter(self, pred: E.AIFilter, table: Table,
                        rows: np.ndarray) -> np.ndarray:
        model = self._filter_model(pred)
        op = SemanticOp.from_filter(pred, table, rows, model)
        if not self.cfg.use_cascade:
            return op.submit(self.client).scores() >= 0.5
        bypass = self._cascade_bypass(pred)
        if bypass is not None:
            if bypass not in self.reoptimizations:
                self.reoptimizations.append(bypass)
            return op.submit(self.client).scores() >= 0.5
        proxy = self.cfg.proxy_model or self.client.proxy_model
        cascade = self.cascades.setdefault(
            self._pred_key(pred), SupgItCascade(self.cfg.cascade))
        items = list(zip(op.prompts, op.metadata))

        tr = active_tracer()

        def proxy_scores(batch):
            tr.event("cascade.proxy", rows=len(batch), model=proxy)
            return SemanticOp.scores(
                [p for p, _ in batch], [m for _, m in batch],
                proxy).submit(self.client).scores()

        def oracle_labels(batch):
            tr.event("cascade.escalate", rows=len(batch), model=model)
            s = SemanticOp.scores(
                [p for p, _ in batch], [m for _, m in batch],
                model).submit(self.client).scores()
            return s >= 0.5

        with tr.span(self._pred_key(pred), kind="cascade",
                     rows_in=int(len(rows)), proxy=proxy,
                     oracle=model) as csp:
            out = cascade.run(items, proxy_scores, oracle_labels)
            csp.set(rows_out=int(np.asarray(out).sum()))
        return out

    # ------------------------------------------------------------------
    # Joins
    # ------------------------------------------------------------------

    def _combine(self, left: Table, right: Table, lidx: np.ndarray,
                 ridx: np.ndarray) -> Table:
        cols: Dict[str, Any] = {}
        types: Dict[str, str] = {}
        for k in left.column_names:
            cols[k] = left.column(k)[lidx]
            types[k] = left.types[k]
        for k in right.column_names:
            cols[k] = right.column(k)[ridx]
            types[k] = right.types[k]
        return Table(cols, types)

    def _exec_join(self, node: P.Join) -> Table:
        left = self._exec(node.left)
        right = self._exec(node.right)
        if node.equi:
            lk, rk = node.equi[0]
            lidx, ridx = _hash_join_indices(
                left.column(E.resolve_column(left, lk)),
                right.column(E.resolve_column(right, rk)))
            # extra equi conjuncts as post-filters
            joined = self._combine(left, right, lidx, ridx)
            for lk2, rk2 in node.equi[1:]:
                m = (joined.column(E.resolve_column(joined, lk2))
                     == joined.column(E.resolve_column(joined, rk2)))
                joined = joined.filter_mask(m)
        else:
            lidx, ridx = left.cross_join_indices(right)
            joined = self._combine(left, right, lidx, ridx)
        if node.residual:
            mask = self.eval_predicates(joined, list(node.residual))
            joined = joined.filter_mask(mask)
        return joined

    # ------------------------------------------------------------------
    # SemanticJoinClassify (§5.3 rewritten join)
    # ------------------------------------------------------------------

    def _exec_semantic_join(self, node: P.SemanticJoinClassify) -> Table:
        left = self._exec(node.left)
        right = self._exec(node.right)
        label_col = E.resolve_column(right, node.label_col)
        label_vals = right.column(label_col)
        # label value -> right row indices (labels may repeat)
        label_rows: Dict[str, List[int]] = {}
        uniq: List[str] = []
        for j, v in enumerate(label_vals):
            s = str(v)
            if s not in label_rows:
                uniq.append(s)
                label_rows[s] = []
            label_rows[s].append(j)
        left_rows = np.arange(left.num_rows)
        left_text = np.asarray(E.eval_expr(node.left_arg, left, left_rows),
                               dtype=object)
        chunk = max(node.max_labels_per_call, 1)
        chunks = [uniq[i:i + chunk] for i in range(0, len(uniq), chunk)]
        instruction = node.prompt.template
        md_rows = row_metadata(left, left_rows)
        model = node.model or self.client.default_model
        # submit every (pass × label-chunk) micro-batch before awaiting any:
        # the pipeline coalesces them into right-sized engine batches
        c0 = self.client.ai_credits
        s0 = self.client.ai_seconds
        handles: List[SemanticHandle] = []
        for pass_no in range(max(self.cfg.classify_passes, 1)):
            tag = "" if pass_no == 0 else (
                f" (pass {pass_no + 1}: select any additional matches)")
            for labels in chunks:
                prompts = [
                    ("Select every label that satisfies: "
                     f"{instruction}{tag}\ninput: {t}") for t in left_text]
                op = SemanticOp.classify(
                    prompts, md_rows, labels, model,
                    self.cfg.classify_multi_label)
                handles.append(op.submit(self.client))
        selected: List[set] = [set() for _ in range(left.num_rows)]
        calls = passed = 0
        for handle in handles:
            for i, labs in enumerate(handle.chosen_labels()):
                selected[i].update(labs)
                calls += 1
                passed += bool(labs)
        # dispatch-metered deltas: dedup-served repeats cost (and record)
        # nothing, matching the _timed_pred / StatsStore contract
        credits = self.client.ai_credits - c0
        seconds = self.client.ai_seconds - s0
        if calls:
            # recorded under the same surrogate AIClassify the cost model
            # prices the rewrite with, so the next query's rewrite-vs-
            # cross-join decision runs on observed per-call numbers
            fake = E.AIClassify(node.prompt, labels=(), model=node.model)
            self._stats_for(fake)          # registers key -> fingerprint
            st = self.pred_stats[self._pred_key(fake)]
            st.evaluated += calls
            st.passed += passed
            st.credits += credits
            st.seconds += seconds
            self.stats.observe_predicate(
                self._fp_by_key[self._pred_key(fake)], evaluated=calls,
                passed=passed, credits=credits, seconds=seconds)
        pairs_l: List[int] = []
        pairs_r: List[int] = []
        for i, labs in enumerate(selected):
            for lb in labs:
                for j in label_rows.get(lb, ()):
                    pairs_l.append(i)
                    pairs_r.append(j)
        return self._combine(left, right, np.asarray(pairs_l, np.int64),
                             np.asarray(pairs_r, np.int64))

    # ------------------------------------------------------------------
    # SemanticJoinIndex (index-assisted blocking + LLM verification)
    # ------------------------------------------------------------------

    def _is_base_snapshot(self, plan: P.PlanNode, table: Table,
                          qualified: str) -> bool:
        """Whether the executed snapshot is the referenced base column
        in full.  The managed per-column index is only worth (re)building
        for the full column — a filtered subset would churn the content
        signature on every distinct WHERE clause; those rank from the
        already-computed similarity values instead."""
        key = self._index_column_key(plan, qualified)
        tname = key.split(".", 1)[0]
        try:
            return self.catalog.table(tname).num_rows == table.num_rows
        except KeyError:
            return False

    def _index_column_key(self, plan: P.PlanNode, qualified: str) -> str:
        """Stable registry key for an indexed column: the base table's
        name when the alias resolves to a Scan (so two queries aliasing
        one table share the index), the qualified name otherwise."""
        alias, _, leaf = qualified.partition(".")

        def walk(n: P.PlanNode):
            if isinstance(n, P.Scan) and n.alias == alias:
                return f"{n.table}.{leaf or alias}"
            for c in n.children():
                found = walk(c)
                if found:
                    return found
            return None

        return walk(plan) or qualified

    def _exec_semantic_join_index(self, node: P.SemanticJoinIndex) -> Table:
        """Index-assisted semantic join: kNN candidate labels per left
        row (embedding kernel, near-zero credits), then one multi-label
        AI_CLASSIFY per left row over *only its candidates*.

        The verification prompt is byte-identical to the §5.3 rewrite's,
        so on backends whose per-label decisions are independent of the
        candidate-set composition (the simulator keys them that way) the
        verified pairs are exactly the full rewrite's selections
        restricted to the candidate set — result parity holds whenever
        candidate recall covers the selected labels.
        """
        if self.semindex is None:       # planned elsewhere; degrade safely
            return self._exec_semantic_join(P.SemanticJoinClassify(
                left=node.left, right=node.right, prompt=node.prompt,
                left_arg=node.left_arg, label_col=node.label_col,
                model=node.model,
                max_labels_per_call=node.max_labels_per_call))
        left = self._exec(node.left)
        right = self._exec(node.right)
        mgr = self.semindex
        label_col = E.resolve_column(right, node.label_col)
        label_vals = right.column(label_col)
        label_rows: Dict[str, List[int]] = {}
        uniq: List[str] = []
        for j, v in enumerate(label_vals):
            s = str(v)
            if s not in label_rows:
                uniq.append(s)
                label_rows[s] = []
            label_rows[s].append(j)
        if left.num_rows == 0 or not uniq:
            # an empty side joins to nothing — no blocking, no calls
            return self._combine(left, right, np.empty(0, np.int64),
                                 np.empty(0, np.int64))
        left_rows = np.arange(left.num_rows)
        left_text = [str(v) for v in
                     E.eval_expr(node.left_arg, left, left_rows)]
        md_rows = row_metadata(left, left_rows)
        embed_model = mgr.model_for(self.client)
        # --- blocking: label-side IVF index + kNN through the kernel --
        # the index is built once per column snapshot (refresh-on-drift
        # via content signature) and shared across queries and — under
        # serving — tenants; search honors SemIndexConfig.exact_topk /
        # nprobe (flat exact scan by default)
        calls0 = self.client.ai_calls
        c0 = self.client.ai_credits
        col_key = self._index_column_key(node.right, node.label_col)
        mgr.ensure_index(self.client, col_key, uniq,
                         metadata=[{"embed_anchor": u} for u in uniq],
                         model=embed_model)
        lvec = mgr.embed_texts(self.client, left_text, metadata=md_rows,
                               model=embed_model)
        embed_credits = self.client.ai_credits - c0
        k = min(node.k, len(uniq))
        vals, idx = mgr.search(col_key, lvec, k) if k else \
            (np.zeros((left.num_rows, 0)), np.zeros((left.num_rows, 0),
                                                    np.int64))
        floor = mgr.cfg.join_min_sim
        candidates: List[List[str]] = []
        for i in range(left.num_rows):
            cand = [uniq[int(j)] for v, j in zip(vals[i], idx[i])
                    if j >= 0 and (floor is None or v >= floor)]
            candidates.append(cand)
        total_cand = sum(len(c) for c in candidates)
        fp_index = index_join_fingerprint(
            node.prompt.template, node.model,
            node.left_arg.name if isinstance(node.left_arg, E.Column)
            else type(node.left_arg).__name__, node.label_col)
        self.stats.observe_index(fp_index, probes=left.num_rows,
                                 candidates=total_cand)
        self._index_note(index_joins=1, probes=left.num_rows,
                         candidates=total_cand,
                         embed_texts=left.num_rows + len(uniq),
                         embed_llm_calls=self.client.ai_calls - calls0)
        # --- verification: candidate-set classify per left row --------
        instruction = node.prompt.template
        model = node.model or self.client.default_model
        chunk = max(node.max_labels_per_call, 1)
        c1 = self.client.ai_credits
        s0 = self.client.ai_seconds
        handles: List[Tuple[int, SemanticHandle]] = []
        # same pass structure (and pass-tagged prompts) as the classify
        # rewrite, so the k-pass hybrid-join recall recovery applies to
        # the candidate sets identically
        for pass_no in range(max(self.cfg.classify_passes, 1)):
            tag = "" if pass_no == 0 else (
                f" (pass {pass_no + 1}: select any additional matches)")
            for i, cand in enumerate(candidates):
                if not cand:
                    continue        # index pruned the row to nothing
                prompt = ("Select every label that satisfies: "
                          f"{instruction}{tag}\ninput: {left_text[i]}")
                for lo in range(0, len(cand), chunk):
                    op = SemanticOp.classify(
                        [prompt], [md_rows[i]], cand[lo:lo + chunk], model,
                        self.cfg.classify_multi_label)
                    handles.append((i, op.submit(self.client)))
        selected: List[set] = [set() for _ in range(left.num_rows)]
        calls = passed = 0
        for i, handle in handles:
            for labs in handle.chosen_labels():
                selected[i].update(labs)
                calls += 1
                passed += bool(labs)
        credits = self.client.ai_credits - c1
        seconds = self.client.ai_seconds - s0
        if calls:
            fake = self.cost.index_verify_surrogate(node)
            st = self._stats_for(fake)
            st.evaluated += calls
            st.passed += passed
            st.credits += credits
            st.seconds += seconds
            self.stats.observe_predicate(
                self._fp_by_key[self._pred_key(fake)], evaluated=calls,
                passed=passed, credits=credits, seconds=seconds)
        self._index_note(verify_calls=calls)
        self.reoptimizations.append(
            f"index-join: {left.num_rows} probes -> {total_cand} "
            f"candidate pairs ({len(uniq)} labels), {calls} verification "
            f"calls, embeds {embed_credits:.3g} credits")
        pairs_l: List[int] = []
        pairs_r: List[int] = []
        for i, labs in enumerate(selected):
            for lb in labs:
                for j in label_rows.get(lb, ()):
                    pairs_l.append(i)
                    pairs_r.append(j)
        return self._combine(left, right, np.asarray(pairs_l, np.int64),
                             np.asarray(pairs_r, np.int64))

    # ------------------------------------------------------------------
    # Aggregate / Project
    # ------------------------------------------------------------------

    def _agg_value(self, agg: E.AggCall, table: Table, rows: np.ndarray,
                   aggregator: HierarchicalAggregator):
        name = agg.name
        if name == "COUNT":
            return int(len(rows))
        col = E.eval_expr(agg.args[0], table, rows)
        if name == "SUM":
            return float(np.sum(col.astype(np.float64)))
        if name == "AVG":
            return float(np.mean(col.astype(np.float64))) if len(rows) else 0.0
        if name == "MIN":
            return col.min() if len(rows) else None
        if name == "MAX":
            return col.max() if len(rows) else None
        if name in ("AI_AGG", "AI_SUMMARIZE_AGG"):
            out = aggregator.aggregate([str(v) for v in col],
                                       agg.instruction)
            self.agg_telemetry = aggregator.telemetry
            return out
        raise KeyError(name)

    def _agg_type(self, agg: E.AggCall, table: Table) -> Optional[str]:
        name = agg.name
        if name == "COUNT":
            return "int"
        if name in ("SUM", "AVG"):
            return "float"
        if name in ("AI_AGG", "AI_SUMMARIZE_AGG"):
            return "str"
        if name in ("MIN", "MAX") and agg.args \
                and isinstance(agg.args[0], E.Column):
            try:
                return table.types[
                    E.resolve_column(table, agg.args[0].name)]
            except KeyError:
                return None
        return None

    def _item_name(self, item: E.SelectItem, i: int) -> str:
        if item.alias:
            return item.alias
        e = item.expr
        if isinstance(e, E.Column):
            return e.name
        if isinstance(e, E.AggCall):
            return e.name.lower()
        if isinstance(e, E.AIComplete):
            return "ai_complete"
        if isinstance(e, E.AIClassify):
            return "ai_classify"
        if isinstance(e, E.AIScore):
            return "ai_score"
        if isinstance(e, E.AISimilarity):
            return "ai_similarity"
        if isinstance(e, E.AIEmbed):
            return "ai_embed"
        return f"col{i}"

    def _materialize_item(self, table: Table, item: E.SelectItem) -> Table:
        """Compute one select item as a column (GROUP BY <alias> support)."""
        one = self._exec_project(P.Project(_Materialized(table), (item,)))
        name = self._item_name(item, 0)
        return table.with_column(name, one.column(name))

    def _exec_aggregate(self, node: P.Aggregate) -> Table:
        table = self._exec(node.child)
        aggregator = HierarchicalAggregator(self.client, self.cfg.agg)
        key0 = None
        if node.group_by:
            try:
                key0 = E.resolve_column(table, node.group_by[0])
            except KeyError:
                # GROUP BY a select alias (e.g. an AI_CLASSIFY output):
                # materialize that item first, then group on it
                for item in node.items:
                    if item.alias == node.group_by[0]:
                        table = self._materialize_item(table, item)
                        break
                key0 = E.resolve_column(table, node.group_by[0])
            groups = table.group_indices(key0)
        else:
            groups = {None: np.arange(table.num_rows)}
        cols: Dict[str, List[Any]] = {}
        types: Dict[str, str] = {}
        for gkey, rows in groups.items():
            for i, item in enumerate(node.items):
                name = self._item_name(item, i)
                e = item.expr
                t: Optional[str] = None
                if isinstance(e, E.AggCall):
                    v = self._agg_value(e, table, rows, aggregator)
                    t = self._agg_type(e, table)
                elif isinstance(e, E.Column):
                    c = E.resolve_column(table, e.name)
                    v = table.column(c)[rows[0]]
                    t = table.types[c]
                elif isinstance(e, E.Star):
                    v = gkey
                    t = table.types.get(key0) if key0 is not None else None
                elif name in table:          # materialized alias column
                    v = table.column(name)[rows[0]]
                    t = table.types.get(name)
                else:
                    v = E.eval_expr(e, table, rows[:1])[0]
                cols.setdefault(name, []).append(v)
                if t:
                    types[name] = t
        # never force a dtype onto a column that carries NULLs (e.g. the
        # MIN/MAX of an empty group)
        types = {k: t for k, t in types.items()
                 if all(v is not None for v in cols[k])}
        return Table(cols, types or None)

    def _exec_project(self, node: P.Project) -> Table:
        table = self._exec(node.child)
        rows = np.arange(table.num_rows)
        # phase 1: assemble + submit every semantic item up front so the
        # pipeline can coalesce across projection items (cross-operator)
        handles: Dict[int, SemanticHandle] = {}
        item_labels: Dict[int, Tuple[str, ...]] = {}
        for i, item in enumerate(node.items):
            e = item.expr
            if isinstance(e, E.AIComplete):
                prompts = e.prompt.render(table, rows)
                md = row_metadata(table, rows)
                op = SemanticOp.complete(
                    prompts, md, e.model or self.client.default_model,
                    e.max_tokens)
                handles[i] = op.submit(self.client)
            elif isinstance(e, E.AIClassify):
                prompts = e.text.render(table, rows)
                md = row_metadata(table, rows)
                labels = e.labels
                if e.labels_expr is not None:
                    lv = E.eval_expr(e.labels_expr, table, rows[:1])
                    labels = tuple(lv[0]) if len(lv) else ()
                item_labels[i] = tuple(labels)
                op = SemanticOp.classify(
                    prompts, md, labels, e.model or self.client.default_model,
                    e.multi_label)
                handles[i] = op.submit(self.client)
        # phase 2: await + materialize columns
        cols: Dict[str, Any] = {}
        types: Dict[str, str] = {}
        for i, item in enumerate(node.items):
            e = item.expr
            if isinstance(e, E.Star):
                for c in table.column_names:
                    if not _is_hidden(c):
                        cols[c] = table.column(c)
                        types[c] = table.types[c]
                continue
            name = self._item_name(item, i)
            if isinstance(e, E.AIComplete):
                cols[name] = np.asarray(handles[i].texts(), dtype=object)
                types[name] = "str"
            elif isinstance(e, E.AIClassify):
                chosen = handles[i].chosen_labels()
                if e.multi_label:
                    cols[name] = np.asarray([tuple(c) for c in chosen],
                                            dtype=object)
                else:
                    cols[name] = np.asarray(
                        [c[0] if c else None for c in chosen], dtype=object)
                types[name] = "str"
            elif isinstance(e, E.AIFilter):
                cols[name] = self._eval_ai_filter(e, table, rows)
                types[name] = "bool"
            elif isinstance(e, E.AIScore):
                cols[name] = self._ai_scores(
                    e, table, rows, e.model or self.client.default_model)
                types[name] = "float"
            elif isinstance(e, E.AISimilarity):
                cols[name] = self._similarity_values(e, table, rows)
                types[name] = "float"
            elif isinstance(e, E.AIEmbed):
                cols[name] = self._embed_values(e, table, rows)
                types[name] = "str"
            elif any(isinstance(c, E.AISimilarity)
                     for c in E.ai_calls_in(e)):
                cols[name] = self._eval_mixed(e, table, rows)
            else:
                cols[name] = E.eval_expr(e, table, rows)
        if not cols:                      # SELECT over an empty item list
            cols["rows"] = np.arange(table.num_rows)
        return Table(cols, types or None)


class _Materialized(P.PlanNode):
    """Plan leaf wrapping an already-computed Table (internal)."""

    def __init__(self, table: Table):
        self.table = table
