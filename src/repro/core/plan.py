"""Logical query plan for AISQL.

Plan nodes are immutable; the optimizer rewrites trees.  ``build_plan``
translates a parsed Query into an initial (unoptimized) plan:
scans -> left-deep join tree -> WHERE filter -> aggregate/project -> limit.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Set, Tuple

from repro.core import expr as E
from repro.core.sqlparse import Query


class PlanNode:
    def children(self) -> Tuple["PlanNode", ...]:
        return ()

    def out_aliases(self) -> Set[str]:
        out: Set[str] = set()
        for c in self.children():
            out |= c.out_aliases()
        return out

    def pretty(self, indent: int = 0, annotate=None) -> str:
        """Indented tree rendering.  ``annotate(node) -> str`` (optional)
        appends a per-node suffix — EXPLAIN ANALYZE uses it to attach
        estimated-vs-actual rows/cost to each operator."""
        pad = "  " * indent
        line = pad + self._describe()
        if annotate is not None:
            suffix = annotate(self)
            if suffix:
                line += f"  {suffix}"
        return "\n".join([line] + [c.pretty(indent + 1, annotate)
                                   for c in self.children()])

    def _describe(self) -> str:
        return type(self).__name__


@dataclasses.dataclass(frozen=True)
class Scan(PlanNode):
    table: str
    alias: str

    def out_aliases(self):
        return {self.alias}

    def _describe(self):
        return f"Scan {self.table} AS {self.alias}"


@dataclasses.dataclass(frozen=True)
class Filter(PlanNode):
    child: PlanNode
    predicates: Tuple[E.Expr, ...]     # conjuncts, evaluation order = tuple order

    def children(self):
        return (self.child,)

    def _describe(self):
        kinds = ["AI" if p.is_ai() else "rel" for p in self.predicates]
        return f"Filter [{', '.join(kinds)}] ({len(self.predicates)} conjuncts)"


@dataclasses.dataclass(frozen=True)
class Join(PlanNode):
    left: PlanNode
    right: PlanNode
    equi: Tuple[Tuple[str, str], ...]          # (left_col, right_col)
    residual: Tuple[E.Expr, ...] = ()          # non-equi ON conjuncts (may be AI)

    def children(self):
        return (self.left, self.right)

    def _describe(self):
        r = f" residual={len(self.residual)}" if self.residual else ""
        return f"Join equi={list(self.equi)}{r}"


@dataclasses.dataclass(frozen=True)
class SemanticJoinClassify(PlanNode):
    """§5.3 rewrite: AI_FILTER cross join -> per-left-row multi-label
    AI_CLASSIFY with the right side's label column as candidate set."""
    left: PlanNode
    right: PlanNode
    prompt: E.Prompt                 # original two-side predicate prompt
    left_arg: E.Expr                 # the left-side text expression
    label_col: str                   # right-side column holding labels
    model: Optional[str] = None
    max_labels_per_call: int = 50    # context-window chunking

    def children(self):
        return (self.left, self.right)

    def _describe(self):
        return (f"SemanticJoinClassify labels={self.label_col} "
                f"chunk={self.max_labels_per_call}")


@dataclasses.dataclass(frozen=True)
class SemanticJoinIndex(PlanNode):
    """Index-assisted semantic-join blocking (the tier below §5.3's
    classification rewrite): kNN candidate generation on the vector
    index narrows each left row to ``k`` plausible labels for near-zero
    credits, and the LLM verifies only those candidates — one
    multi-label AI_CLASSIFY per left row over a candidate set that is
    k/|R| of the full label universe.  Cost-raced by the optimizer
    against `SemanticJoinClassify` and the naive nested loop."""
    left: PlanNode
    right: PlanNode
    prompt: E.Prompt                 # original two-side predicate prompt
    left_arg: E.Expr                 # the left-side text expression
    label_col: str                   # right-side column holding labels
    model: Optional[str] = None
    k: int = 8                       # kNN candidates per left row
    max_labels_per_call: int = 50    # context-window chunking

    def children(self):
        return (self.left, self.right)

    def _describe(self):
        return (f"SemanticJoinIndex labels={self.label_col} k={self.k}")


@dataclasses.dataclass(frozen=True)
class Project(PlanNode):
    child: PlanNode
    items: Tuple[E.SelectItem, ...]

    def children(self):
        return (self.child,)

    def _describe(self):
        return f"Project ({len(self.items)} items)"


@dataclasses.dataclass(frozen=True)
class Aggregate(PlanNode):
    child: PlanNode
    group_by: Tuple[str, ...]
    items: Tuple[E.SelectItem, ...]

    def children(self):
        return (self.child,)

    def _describe(self):
        return f"Aggregate by {list(self.group_by)}"


@dataclasses.dataclass(frozen=True)
class SortKey:
    """One ORDER BY key; ``expr`` may be a structured expression or an
    `E.AIScore` (semantic ordering via the SCORE request kind)."""
    expr: E.Expr
    desc: bool = False


@dataclasses.dataclass(frozen=True)
class Sort(PlanNode):
    child: PlanNode
    keys: Tuple[SortKey, ...]

    def children(self):
        return (self.child,)

    def _describe(self):
        kinds = ["AI" if isinstance(k.expr, (E.AIScore, E.AISimilarity))
                 else "rel" for k in self.keys]
        dirs = ["DESC" if k.desc else "ASC" for k in self.keys]
        return ("Sort [" + ", ".join(f"{k} {d}"
                                     for k, d in zip(kinds, dirs)) + "]")


@dataclasses.dataclass(frozen=True)
class TopK(PlanNode):
    """Fused ORDER BY + LIMIT (optimizer rewrite of ``Limit(Sort(...))``
    with an AI-scored primary key): the executor may prefilter with
    cheap proxy scores and escalate only the top candidates to the
    ordering model — the early-exit path for top-k search workloads."""
    child: PlanNode
    keys: Tuple[SortKey, ...]
    n: int

    def children(self):
        return (self.child,)

    def _describe(self):
        return f"TopK {self.n} ({len(self.keys)} keys)"


@dataclasses.dataclass(frozen=True)
class Limit(PlanNode):
    child: PlanNode
    n: int

    def children(self):
        return (self.child,)

    def _describe(self):
        return f"Limit {self.n}"


# ---------------------------------------------------------------------------
# Query -> initial plan
# ---------------------------------------------------------------------------


def _alias_of(name: str) -> str:
    return name.split(".", 1)[0] if "." in name else ""


def refs_aliases(e: E.Expr) -> Set[str]:
    return {_alias_of(r) for r in e.refs() if _alias_of(r)}


def _classify_on_conjunct(c: E.Expr, left_aliases: Set[str],
                          right_alias: str):
    """-> ("equi", (lcol, rcol)) | ("residual", expr) | ("left"/"right", expr)."""
    if (isinstance(c, E.BinOp) and c.op == "="
            and isinstance(c.left, E.Column) and isinstance(c.right, E.Column)):
        la, ra = _alias_of(c.left.name), _alias_of(c.right.name)
        if la in left_aliases and ra == right_alias:
            return "equi", (c.left.name, c.right.name)
        if ra in left_aliases and la == right_alias:
            return "equi", (c.right.name, c.left.name)
    al = refs_aliases(c)
    if al and al <= left_aliases:
        return "left", c
    if al == {right_alias}:
        return "right", c
    return "residual", c


def build_plan(q: Query) -> PlanNode:
    node: PlanNode = Scan(q.table.table, q.table.alias)
    left_aliases = {q.table.alias}
    for jc in q.joins:
        right: PlanNode = Scan(jc.ref.table, jc.ref.alias)
        equi, residual, lpreds, rpreds = [], [], [], []
        for c in E.split_conjuncts(jc.on):
            kind, payload = _classify_on_conjunct(c, left_aliases,
                                                  jc.ref.alias)
            if kind == "equi":
                equi.append(payload)
            elif kind == "left":
                lpreds.append(payload)
            elif kind == "right":
                rpreds.append(payload)
            else:
                residual.append(payload)
        if lpreds:
            node = Filter(node, tuple(lpreds))
        if rpreds:
            right = Filter(right, tuple(rpreds))
        node = Join(node, right, tuple(equi), tuple(residual))
        left_aliases.add(jc.ref.alias)
    if q.where is not None:
        node = Filter(node, tuple(E.split_conjuncts(q.where)))
    has_agg = bool(q.group_by) or any(
        isinstance(it.expr, E.AggCall) for it in q.select)
    if has_agg:
        node = Aggregate(node, tuple(q.group_by), tuple(q.select))
        # ORDER BY above the aggregate: keys reference output columns
        # (aliases, agg names) of the aggregate itself
        if q.order_by:
            node = Sort(node, tuple(SortKey(o.expr, o.desc)
                                    for o in q.order_by))
    else:
        # ORDER BY below the projection so keys can reference base
        # columns the SELECT list drops; keys naming a select alias are
        # substituted with the aliased expression first
        if q.order_by:
            node = Sort(node, tuple(
                SortKey(_substitute_alias(o.expr, q.select), o.desc)
                for o in q.order_by))
        node = Project(node, tuple(q.select))
    if q.limit is not None:
        node = Limit(node, q.limit)
    return node


def _substitute_alias(e: E.Expr, items: Sequence[E.SelectItem]) -> E.Expr:
    """ORDER BY <select-alias> names the aliased expression."""
    if isinstance(e, E.Column):
        for it in items:
            if it.alias is not None and it.alias == e.name:
                return it.expr
    return e
