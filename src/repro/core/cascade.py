"""Adaptive model cascades for AI_FILTER (paper §5.2) — SUPG-IT.

A lightweight *proxy* model scores every row; two learned thresholds
(τ_low, τ_high) partition rows into reject / uncertainty / accept regions;
only the uncertainty region is escalated to the *oracle* model.

The thresholds are learned **online** (streaming, per worker, no
inter-worker communication — the paper's distributed design):

  * within each batch a budget fraction ρ of rows is sampled for oracle
    labeling via importance sampling with weights ∝ sqrt(s_i), mixed with
    a uniform component for coverage;
  * τ_low comes from a weighted ROC curve with a sampling-corrected recall
    target (lower confidence bound on recall ≥ target);
  * τ_high is the minimum threshold whose statistical *lower bound* on
    precision meets the precision target;
  * as oracle labels accumulate across batches the confidence bounds
    tighten and the uncertainty region narrows.

Rows still inside [τ_low, τ_high) are routed to the oracle if the oracle
budget permits; otherwise the proxy prediction (s ≥ 0.5) is the fallback.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class CascadeConfig:
    recall_target: float = 0.90
    precision_target: float = 0.90
    sample_budget_frac: float = 0.10   # ρ: oracle labels per batch (learning)
    oracle_budget_frac: float = 0.50   # cap on total oracle calls / total rows
    uniform_mix: float = 0.25          # α: uniform mass in the sampling dist
    delta: float = 0.05                # 1-δ confidence for the bounds
    batch_size: int = 256
    min_samples: int = 16              # below this: route everything to oracle
    max_learning_samples: int = 384    # stop importance sampling once the
    #                                    bounds are tight (uncertainty region
    #                                    narrows as labels accumulate — §5.2)
    seed: int = 0


@dataclasses.dataclass
class CascadeStats:
    rows: int = 0
    proxy_calls: int = 0
    oracle_calls: int = 0
    sampled_for_learning: int = 0
    accepted_by_proxy: int = 0
    rejected_by_proxy: int = 0
    uncertain_to_oracle: int = 0
    uncertain_fallback: int = 0
    tau_low: float = 0.0
    tau_high: float = 1.0

    @property
    def delegation_rate(self) -> float:
        """Oracle escalations per routed row.  The executor folds this
        into the `StatsStore` after each query; once it is observed near
        1.0 the runtime bypasses the cascade entirely (the proxy is not
        separating this predicate) — see ``ExecConfig.
        cascade_bypass_delegation``."""
        return self.oracle_calls / max(self.rows, 1)


def _norm_lcb(mean: float, var: float, n: float, delta: float) -> float:
    """Normal-approximation lower confidence bound on a weighted mean."""
    if n <= 1:
        return 0.0
    z = _z_of(delta)
    return mean - z * math.sqrt(max(var, 1e-12) / n)


def _z_of(delta: float) -> float:
    # inverse normal CDF via Acklam-lite rational approx (delta in (0, 0.5])
    p = 1.0 - delta
    # Beasley-Springer-Moro
    a = [2.50662823884, -18.61500062529, 41.39119773534, -25.44106049637]
    b = [-8.47351093090, 23.08336743743, -21.06224101826, 3.13082909833]
    c = [0.3374754822726147, 0.9761690190917186, 0.1607979714918209,
         0.0276438810333863, 0.0038405729373609, 0.0003951896511919,
         0.0000321767881768, 0.0000002888167364, 0.0000003960315187]
    y = p - 0.5
    if abs(y) < 0.42:
        r = y * y
        num = y * (((a[3] * r + a[2]) * r + a[1]) * r + a[0])
        den = (((b[3] * r + b[2]) * r + b[1]) * r + b[0]) * r + 1.0
        return num / den
    r = p if y > 0 else 1.0 - p
    r = math.log(-math.log(1.0 - r))
    x = c[0]
    for i in range(1, 9):
        x += c[i] * r ** i
    return x if y > 0 else -x


class SupgItCascade:
    """Streaming two-threshold learner + router (one instance per worker)."""

    def __init__(self, cfg: Optional[CascadeConfig] = None):
        self.cfg = cfg or CascadeConfig()
        self._rng = np.random.default_rng(self.cfg.seed)
        # accumulated oracle-labelled sample: scores, labels, importance wts
        self._s: List[float] = []
        self._y: List[bool] = []
        self._w: List[float] = []
        self.tau_low = 0.0
        self.tau_high = 1.0
        self.stats = CascadeStats(tau_low=0.0, tau_high=1.0)

    # ------------------------------------------------------------------
    # threshold learning
    # ------------------------------------------------------------------

    def _sample_for_labels(self, scores: np.ndarray) -> np.ndarray:
        """Importance sample indices (w ∝ sqrt(s), uniform-mixed)."""
        n = len(scores)
        m = max(1, int(round(self.cfg.sample_budget_frac * n)))
        m = min(m, n)
        imp = np.sqrt(np.clip(scores, 1e-6, 1.0))
        imp = imp / imp.sum()
        p = (1 - self.cfg.uniform_mix) * imp + self.cfg.uniform_mix / n
        p = p / p.sum()
        idx = self._rng.choice(n, size=m, replace=False, p=p)
        # Horvitz-Thompson style weights for the *sampling distribution*
        self._batch_weights = 1.0 / (n * p[idx])
        return idx

    def observe(self, scores: np.ndarray, labels: np.ndarray,
                weights: Optional[np.ndarray] = None) -> None:
        """Fold oracle-labelled (score, label) pairs into the estimator."""
        w = np.ones(len(scores)) if weights is None else weights
        self._s.extend(float(x) for x in scores)
        self._y.extend(bool(x) for x in labels)
        self._w.extend(float(x) for x in w)
        self._refit()

    def _refit(self) -> None:
        if len(self._s) < self.cfg.min_samples:
            return
        s = np.asarray(self._s)
        y = np.asarray(self._y, dtype=bool)
        w = np.asarray(self._w)
        order = np.argsort(s)                       # ascending score
        s, y, w = s[order], y[order], w[order]
        self.tau_low = self._fit_tau_low(s, y, w)
        self.tau_high = self._fit_tau_high(s, y, w)
        if self.tau_high < self.tau_low:            # degenerate: collapse
            mid = 0.5 * (self.tau_high + self.tau_low)
            self.tau_low = self.tau_high = mid
        self.stats.tau_low = self.tau_low
        self.stats.tau_high = self.tau_high

    def _fit_tau_low(self, s, y, w) -> float:
        """Largest τ with (sampling-corrected) recall above τ ≥ target.

        Weighted recall(τ) = Σ{w·y·[s ≥ τ]} / Σ{w·y}.  We take a conservative
        margin: effective sample size based normal correction.
        """
        wy = w * y
        total_pos = wy.sum()
        if total_pos <= 0:
            return 0.0
        # cumulative positive mass ABOVE each candidate threshold
        rev_cum = np.cumsum(wy[::-1])[::-1]          # mass at index >= i
        recall = rev_cum / total_pos
        n_eff = (w.sum() ** 2) / max((w ** 2).sum(), 1e-12)
        z = _z_of(self.cfg.delta)
        margin = z * np.sqrt(np.clip(recall * (1 - recall), 0, None)
                             / max(n_eff, 1.0))
        ok = (recall - margin) >= self.cfg.recall_target
        if not ok.any():
            return 0.0
        # largest threshold index where corrected recall still meets target
        i = int(np.max(np.nonzero(ok)[0]))
        return float(s[i])

    def _fit_tau_high(self, s, y, w) -> float:
        """Minimum τ whose precision lower bound meets the target."""
        wy = w * y
        rev_w = np.cumsum(w[::-1])[::-1]
        rev_wy = np.cumsum(wy[::-1])[::-1]
        prec = rev_wy / np.maximum(rev_w, 1e-12)
        # effective n above each threshold
        rev_w2 = np.cumsum((w ** 2)[::-1])[::-1]
        n_eff = (rev_w ** 2) / np.maximum(rev_w2, 1e-12)
        var = np.clip(prec * (1 - prec), 1e-6, None)
        z = _z_of(self.cfg.delta)
        lcb = prec - z * np.sqrt(var / np.maximum(n_eff, 1.0))
        ok = lcb >= self.cfg.precision_target
        if not ok.any():
            return 1.0 + 1e-9                        # accept nothing
        i = int(np.min(np.nonzero(ok)[0]))
        return float(s[i])

    # ------------------------------------------------------------------
    # routing
    # ------------------------------------------------------------------

    def run(self,
            rows: Sequence,
            proxy_score_fn: Callable[[Sequence], np.ndarray],
            oracle_label_fn: Callable[[Sequence], np.ndarray],
            ) -> np.ndarray:
        """Filter a stream of rows; returns boolean predictions.

        ``proxy_score_fn(batch_rows) -> s_i``; ``oracle_label_fn(batch_rows)
        -> bool labels``.  Batches are processed independently; threshold
        state carries across batches (streaming).
        """
        rows = list(rows)
        n_total = len(rows)
        out = np.zeros(n_total, dtype=bool)
        bs = self.cfg.batch_size
        for lo in range(0, n_total, bs):
            hi = min(lo + bs, n_total)
            batch = rows[lo:hi]
            scores = np.asarray(proxy_score_fn(batch), dtype=np.float64)
            self.stats.rows += len(batch)
            self.stats.proxy_calls += len(batch)
            # streaming budget: the instance may serve many run() calls
            # (the executor feeds row chunks); the cap tracks rows *seen*.
            oracle_budget = int(
                math.ceil(self.cfg.oracle_budget_frac * self.stats.rows))

            # --- importance sample for threshold learning ---
            remaining_budget = oracle_budget - self.stats.oracle_calls
            sampled_idx = np.asarray([], dtype=int)
            sampled_labels = np.asarray([], dtype=bool)
            if (remaining_budget > 0
                    and len(self._s) < self.cfg.max_learning_samples):
                sampled_idx = self._sample_for_labels(scores)
                sampled_idx = sampled_idx[:remaining_budget]
                if len(sampled_idx):
                    sampled_labels = np.asarray(
                        oracle_label_fn([batch[i] for i in sampled_idx]),
                        dtype=bool)
                    self.stats.oracle_calls += len(sampled_idx)
                    self.stats.sampled_for_learning += len(sampled_idx)
                    self.observe(scores[sampled_idx], sampled_labels,
                                 self._batch_weights[:len(sampled_idx)])

            ready = len(self._s) >= self.cfg.min_samples
            if ready:
                accept = scores >= self.tau_high
                reject = scores < self.tau_low
                uncertain = ~(accept | reject)
            else:
                # cold start: no trusted thresholds — everything is uncertain
                # (routed to the oracle while budget permits)
                accept = np.zeros(len(batch), dtype=bool)
                reject = np.zeros(len(batch), dtype=bool)
                uncertain = np.ones(len(batch), dtype=bool)

            pred = np.zeros(len(batch), dtype=bool)
            pred[accept] = True
            self.stats.accepted_by_proxy += int(accept.sum())
            self.stats.rejected_by_proxy += int(reject.sum())

            # reuse labels already bought for learning
            known = dict(zip(sampled_idx.tolist(), sampled_labels.tolist()))
            unc_idx = np.nonzero(uncertain)[0]
            need = [i for i in unc_idx if i not in known]
            for i in unc_idx:
                if i in known:
                    pred[i] = known[i]
            remaining_budget = (oracle_budget - self.stats.oracle_calls
                                if len(self._s) >= self.cfg.min_samples
                                else len(need))   # cold start: always escalate
            to_oracle = need[:max(remaining_budget, 0)]
            fallback = need[len(to_oracle):]
            if to_oracle:
                labels = np.asarray(
                    oracle_label_fn([batch[i] for i in to_oracle]), dtype=bool)
                for i, lb in zip(to_oracle, labels):
                    pred[i] = lb
                self.stats.oracle_calls += len(to_oracle)
                self.stats.uncertain_to_oracle += len(to_oracle)
                # uncertainty-region labels also inform the thresholds
                # (weight 1: they were deterministically selected)
                self.observe(scores[to_oracle], labels)
            for i in fallback:
                pred[i] = scores[i] >= 0.5
            self.stats.uncertain_fallback += len(fallback)
            out[lo:hi] = pred
        return out


# ---------------------------------------------------------------------------
# Calibration-based cascade (the complementary algorithm in [21]):
# fit a reliability curve on accumulated oracle labels, then choose static
# thresholds from the calibrated probabilities.  Used for ablations.
# ---------------------------------------------------------------------------


class CalibratedCascade:
    """Isotonic-calibration cascade: calibrate proxy scores on a warmup
    sample, then set thresholds where the *calibrated* probability crosses
    the precision / (1-recall) targets."""

    def __init__(self, cfg: Optional[CascadeConfig] = None):
        self.cfg = cfg or CascadeConfig()
        self._rng = np.random.default_rng(self.cfg.seed)
        self.stats = CascadeStats()

    @staticmethod
    def _pava(y: np.ndarray, w: np.ndarray) -> np.ndarray:
        """Pool-adjacent-violators: weighted isotonic regression."""
        y = y.astype(np.float64)
        w = w.astype(np.float64)
        n = len(y)
        # classic stack-based PAVA
        vals: List[float] = []
        wts: List[float] = []
        counts: List[int] = []
        for i in range(n):
            vals.append(y[i])
            wts.append(w[i])
            counts.append(1)
            while len(vals) > 1 and vals[-2] > vals[-1]:
                v = (vals[-2] * wts[-2] + vals[-1] * wts[-1]) / (wts[-2] + wts[-1])
                wt = wts[-2] + wts[-1]
                c = counts[-2] + counts[-1]
                vals = vals[:-2] + [v]
                wts = wts[:-2] + [wt]
                counts = counts[:-2] + [c]
        out = np.empty(n)
        pos = 0
        for v, c in zip(vals, counts):
            out[pos:pos + c] = v
            pos += c
        return out

    def run(self, rows, proxy_score_fn, oracle_label_fn) -> np.ndarray:
        rows = list(rows)
        n = len(rows)
        out = np.zeros(n, dtype=bool)
        scores = np.asarray(proxy_score_fn(rows), dtype=np.float64)
        self.stats.rows += n
        self.stats.proxy_calls += n
        m = max(self.cfg.min_samples,
                int(round(self.cfg.sample_budget_frac * n)))
        m = min(m, n)
        warm = self._rng.choice(n, size=m, replace=False)
        labels = np.asarray(oracle_label_fn([rows[i] for i in warm]),
                            dtype=bool)
        self.stats.oracle_calls += m
        order = np.argsort(scores[warm])
        cal = self._pava(labels[order].astype(float), np.ones(m))
        s_sorted = scores[warm][order]
        # calibrated probability for each row by interpolation
        p = np.interp(scores, s_sorted, cal, left=cal[0], right=cal[-1])
        tau_high_p = self.cfg.precision_target
        tau_low_p = 1.0 - self.cfg.recall_target
        accept = p >= tau_high_p
        reject = p < tau_low_p
        uncertain = ~(accept | reject)
        out[accept] = True
        known = dict(zip(warm.tolist(), labels.tolist()))
        need = [i for i in np.nonzero(uncertain)[0] if i not in known]
        for i in np.nonzero(uncertain)[0]:
            if i in known:
                out[i] = known[i]
        budget = int(math.ceil(self.cfg.oracle_budget_frac * n))
        to_oracle = need[:max(budget - self.stats.oracle_calls, 0)]
        if to_oracle:
            lb = np.asarray(oracle_label_fn([rows[i] for i in to_oracle]),
                            dtype=bool)
            for i, v in zip(to_oracle, lb):
                out[i] = v
            self.stats.oracle_calls += len(to_oracle)
            self.stats.uncertain_to_oracle += len(to_oracle)
        for i in need[len(to_oracle):]:
            out[i] = scores[i] >= 0.5
            self.stats.uncertain_fallback += 1
        self.stats.accepted_by_proxy += int(accept.sum())
        self.stats.rejected_by_proxy += int(reject.sum())
        return out
