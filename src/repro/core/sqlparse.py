"""Recursive-descent parser for the AISQL dialect (paper §3).

Supported surface:

    SELECT <items> FROM t [AS] a
      [JOIN t2 [AS] b ON <expr>]*
      [WHERE <expr>] [GROUP BY <cols>]
      [ORDER BY <expr> [ASC|DESC], ...] [LIMIT n]

with the AI operators AI_COMPLETE, AI_FILTER, AI_SCORE, AI_CLASSIFY,
AI_EMBED, AI_SIMILARITY, AI_AGG, AI_SUMMARIZE_AGG, the PROMPT(...)
object, FILE utilities
(FL_IS_IMAGE...), BETWEEN/IN/AND/OR/NOT, array literals ['a','b'] for
label sets, and an optional ``model => 'name'`` keyword argument on AI
calls.  ORDER BY accepts structured expressions and AI_SCORE(...) keys
(semantic ordering); LIMIT requires a non-negative integer literal.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, List, Optional, Tuple

from repro.core import expr as E


def _locate(source: str, pos: int) -> Tuple[int, int, str]:
    """``(lineno, col, line)`` for a 0-based character offset, clamped
    to the last line: an offset at end-of-source in a newline-terminated
    query (e.g. ``'SELECT a FROM\\n'``) lands one line past
    ``splitlines()``, so point just past the last line instead of
    indexing out of range."""
    lines = source.splitlines() or [""]
    prefix = source[:pos]
    lineno = prefix.count("\n") + 1
    col = pos - (prefix.rfind("\n") + 1)
    if lineno > len(lines):
        lineno = len(lines)
        col = len(lines[-1])
    line = lines[lineno - 1]
    return lineno, min(col, len(line)), line


class ParseError(SyntaxError):
    """Structured parse failure: message + source position + offending
    token.

    Kept a `SyntaxError` subclass for back-compat (every pre-existing
    ``except SyntaxError`` still works), but carries machine-readable
    fields the serving layer maps onto its HTTP 400 body:

      * ``pos``    — 0-based character offset into the source SQL
        (None when the failing construct has no single position);
      * ``token``  — the offending token text (or a description);
      * ``source`` — the full SQL text, for caret rendering.

    The standard `SyntaxError` ``(text, lineno, offset)`` triple is
    populated too, so interpreter tracebacks render the caret for free,
    and ``str()`` includes the `caret()` snippet — which is how
    ``explain`` output and error logs show *where* the query broke.
    """

    def __init__(self, message: str, *, pos: Optional[int] = None,
                 token: Optional[str] = None,
                 source: Optional[str] = None):
        self.message = message
        self.pos = pos
        self.token = token
        self.source = source
        if source is not None and pos is not None:
            lineno, col, line = _locate(source, pos)
            super().__init__(message, (None, lineno, col + 1, line))
        else:
            super().__init__(message)

    def caret(self) -> str:
        """Two-line snippet: the offending source line plus a ``^``
        under the failure position; empty when no position is known."""
        if self.source is None or self.pos is None:
            return ""
        _, col, line = _locate(self.source, self.pos)
        return f"{line}\n{' ' * col}^"

    def __str__(self) -> str:
        head = (self.message if self.pos is None
                else f"{self.message} (at position {self.pos})")
        snippet = self.caret()
        if not snippet:
            return head
        body = "\n".join(f"    {ln}" for ln in snippet.splitlines())
        return f"{head}\n{body}"


_TOKEN_RE = re.compile(r"""
    (?P<ws>\s+)
  | (?P<arrow>=>)
  | (?P<op><=|>=|!=|<>|[=<>+\-*/(),\[\].])
  | (?P<num>\d+(\.\d+)?)
  | (?P<str>'(?:[^']|'')*')
  | (?P<ident>[A-Za-z_][A-Za-z_0-9]*)
""", re.VERBOSE)

_KEYWORDS = {
    "SELECT", "FROM", "JOIN", "ON", "WHERE", "GROUP", "BY", "LIMIT", "AS",
    "AND", "OR", "NOT", "BETWEEN", "IN", "INNER", "LEFT", "ORDER", "ASC",
    "DESC", "TRUE", "FALSE",
}


@dataclasses.dataclass
class Tok:
    kind: str      # op | num | str | ident | kw | arrow | eof
    value: str
    pos: int = -1  # 0-based character offset into the source SQL


def _lex(sql: str) -> List[Tok]:
    out: List[Tok] = []
    i = 0
    while i < len(sql):
        m = _TOKEN_RE.match(sql, i)
        if not m:
            raise ParseError(f"cannot tokenize at: {sql[i:i+30]!r}",
                             pos=i, token=sql[i:i + 1], source=sql)
        start = m.start()
        i = m.end()
        kind = m.lastgroup
        if kind == "ws":
            continue
        v = m.group()
        if kind == "ident" and v.upper() in _KEYWORDS:
            out.append(Tok("kw", v.upper(), start))
        else:
            out.append(Tok(kind, v, start))
    out.append(Tok("eof", "", len(sql)))
    return out


@dataclasses.dataclass
class TableRef:
    table: str
    alias: str


@dataclasses.dataclass
class JoinClause:
    ref: TableRef
    on: E.Expr


@dataclasses.dataclass
class OrderItem:
    """One ORDER BY key: an expression plus sort direction."""
    expr: E.Expr
    desc: bool = False


@dataclasses.dataclass
class Query:
    select: List[E.SelectItem]
    table: TableRef
    joins: List[JoinClause]
    where: Optional[E.Expr]
    group_by: List[str]
    limit: Optional[int]
    order_by: List[OrderItem] = dataclasses.field(default_factory=list)


class Parser:
    def __init__(self, sql: str):
        self.sql = sql
        self.toks = _lex(sql)
        self.i = 0

    # ---- token helpers ----
    def peek(self) -> Tok:
        return self.toks[self.i]

    def next(self) -> Tok:
        t = self.toks[self.i]
        self.i += 1
        return t

    def error(self, message: str, tok: Optional[Tok] = None) -> ParseError:
        """A `ParseError` anchored at ``tok`` (default: the lookahead)."""
        tok = tok or self.peek()
        return ParseError(message,
                          pos=tok.pos if tok.pos >= 0 else None,
                          token=tok.value or tok.kind, source=self.sql)

    def accept(self, kind: str, value: Optional[str] = None) -> Optional[Tok]:
        t = self.peek()
        if t.kind == kind and (value is None or t.value == value):
            return self.next()
        return None

    def expect(self, kind: str, value: Optional[str] = None) -> Tok:
        t = self.accept(kind, value)
        if t is None:
            raise self.error(f"expected {value or kind}, got "
                             f"{self.peek().kind}:{self.peek().value!r}")
        return t

    # ---- grammar ----
    def parse(self) -> Query:
        self.expect("kw", "SELECT")
        items = [self.select_item()]
        while self.accept("op", ","):
            items.append(self.select_item())
        self.expect("kw", "FROM")
        table = self.table_ref()
        joins = []
        while True:
            if self.accept("kw", "INNER"):
                self.expect("kw", "JOIN")
            elif not self.accept("kw", "JOIN"):
                break
            ref = self.table_ref()
            self.expect("kw", "ON")
            joins.append(JoinClause(ref, self.expr()))
        where = None
        if self.accept("kw", "WHERE"):
            where = self.expr()
        group_by: List[str] = []
        if self.accept("kw", "GROUP"):
            self.expect("kw", "BY")
            group_by.append(self.qualified_name())
            while self.accept("op", ","):
                group_by.append(self.qualified_name())
        order_by: List[OrderItem] = []
        if self.accept("kw", "ORDER"):
            self.expect("kw", "BY")
            order_by.append(self.order_item())
            while self.accept("op", ","):
                order_by.append(self.order_item())
        limit = None
        if self.accept("kw", "LIMIT"):
            tok = self.expect("num")
            if "." in tok.value:
                raise self.error(
                    f"LIMIT must be an integer, got {tok.value}", tok)
            limit = int(tok.value)
        self.expect("eof")
        return Query(items, table, joins, where, group_by, limit, order_by)

    def order_item(self) -> OrderItem:
        t = self.peek()
        if t.kind in ("eof",) or (t.kind == "op" and t.value == ","):
            raise self.error("ORDER BY requires an expression", t)
        ex = self.expr()
        desc = False
        if self.accept("kw", "DESC"):
            desc = True
        else:
            self.accept("kw", "ASC")
        return OrderItem(ex, desc)

    def select_item(self) -> E.SelectItem:
        if self.accept("op", "*"):
            return E.SelectItem(E.Star())
        ex = self.expr()
        alias = None
        if self.accept("kw", "AS"):
            alias = self.expect("ident").value
        elif self.peek().kind == "ident":
            alias = self.next().value
        return E.SelectItem(ex, alias)

    def table_ref(self) -> TableRef:
        name = self.expect("ident").value
        alias = name
        if self.accept("kw", "AS"):
            alias = self.expect("ident").value
        elif self.peek().kind == "ident":
            alias = self.next().value
        return TableRef(name, alias)

    def qualified_name(self) -> str:
        name = self.expect("ident").value
        while self.accept("op", "."):
            name += "." + self.expect("ident").value
        return name

    # expressions (precedence: OR < AND < NOT < cmp < add < mul < atom)
    def expr(self) -> E.Expr:
        return self.or_expr()

    def or_expr(self) -> E.Expr:
        parts = [self.and_expr()]
        while self.accept("kw", "OR"):
            parts.append(self.and_expr())
        return parts[0] if len(parts) == 1 else E.BoolOp("or", tuple(parts))

    def and_expr(self) -> E.Expr:
        parts = [self.not_expr()]
        while self.accept("kw", "AND"):
            parts.append(self.not_expr())
        return parts[0] if len(parts) == 1 else E.BoolOp("and", tuple(parts))

    def not_expr(self) -> E.Expr:
        if self.accept("kw", "NOT"):
            return E.Not(self.not_expr())
        return self.cmp_expr()

    def cmp_expr(self) -> E.Expr:
        left = self.add_expr()
        t = self.peek()
        if t.kind == "op" and t.value in ("=", "!=", "<>", "<", "<=", ">", ">="):
            self.next()
            op = "!=" if t.value == "<>" else t.value
            return E.BinOp(op, left, self.add_expr())
        if t.kind == "kw" and t.value == "BETWEEN":
            self.next()
            lo = self.add_expr()
            self.expect("kw", "AND")
            hi = self.add_expr()
            return E.Between(left, lo, hi)
        if t.kind == "kw" and t.value == "IN":
            self.next()
            self.expect("op", "(")
            vals = [self.literal_value()]
            while self.accept("op", ","):
                vals.append(self.literal_value())
            self.expect("op", ")")
            return E.InList(left, tuple(vals))
        return left

    def add_expr(self) -> E.Expr:
        left = self.mul_expr()
        while True:
            t = self.peek()
            if t.kind == "op" and t.value in ("+", "-"):
                self.next()
                left = E.BinOp(t.value, left, self.mul_expr())
            else:
                return left

    def mul_expr(self) -> E.Expr:
        left = self.atom()
        while True:
            t = self.peek()
            if t.kind == "op" and t.value in ("*", "/"):
                self.next()
                left = E.BinOp(t.value, left, self.atom())
            else:
                return left

    def literal_value(self) -> Any:
        t = self.next()
        if t.kind == "num":
            return float(t.value) if "." in t.value else int(t.value)
        if t.kind == "str":
            return t.value[1:-1].replace("''", "'")
        if t.kind == "kw" and t.value in ("TRUE", "FALSE"):
            return t.value == "TRUE"
        raise self.error(f"expected literal, got {t.value!r}", t)

    def atom(self) -> E.Expr:
        t = self.peek()
        if t.kind == "op" and t.value == "(":
            self.next()
            ex = self.expr()
            self.expect("op", ")")
            return ex
        if t.kind == "num":
            self.next()
            return E.Literal(float(t.value) if "." in t.value else int(t.value))
        if t.kind == "str":
            self.next()
            return E.Literal(t.value[1:-1].replace("''", "'"))
        if t.kind == "kw" and t.value in ("TRUE", "FALSE"):
            self.next()
            return E.Literal(t.value == "TRUE")
        if t.kind == "op" and t.value == "[":
            return E.Literal(self.array_literal())
        if t.kind == "ident":
            name = self.next().value
            if self.peek().kind == "op" and self.peek().value == "(":
                return self.call(name, t)
            full = name
            while self.accept("op", "."):
                full += "." + self.expect("ident").value
            return E.Column(full)
        if t.kind == "op" and t.value == "*":
            self.next()
            return E.Star()
        raise self.error(f"unexpected token {t.value!r}", t)

    def array_literal(self) -> Tuple[str, ...]:
        self.expect("op", "[")
        vals = [self.literal_value()]
        while self.accept("op", ","):
            vals.append(self.literal_value())
        self.expect("op", "]")
        return tuple(str(v) for v in vals)

    # ---- calls ----
    def call(self, name: str, tok: Optional[Tok] = None) -> E.Expr:
        uname = name.upper()
        self.expect("op", "(")
        if uname == "COUNT" and self.accept("op", "*"):
            self.expect("op", ")")
            return E.AggCall("COUNT", (E.Star(),))
        args: List[E.Expr] = []
        kwargs = {}
        if not (self.peek().kind == "op" and self.peek().value == ")"):
            while True:
                if (self.peek().kind == "ident"
                        and self.toks[self.i + 1].kind == "arrow"):
                    kw = self.next().value.lower()
                    self.next()  # =>
                    kwargs[kw] = self.literal_value()
                else:
                    args.append(self.expr())
                if not self.accept("op", ","):
                    break
        self.expect("op", ")")
        return self.build_call(uname, args, kwargs, tok)

    def build_call(self, uname, args, kwargs,
                   tok: Optional[Tok] = None) -> E.Expr:
        model = kwargs.get("model")
        if uname == "PROMPT":
            tpl = self._lit_str(args[0], "PROMPT template", tok)
            return E.Prompt(tpl, tuple(args[1:]))
        if uname == "AI_FILTER":
            p = args[0]
            if not isinstance(p, E.Prompt):
                if isinstance(p, E.Literal):
                    p = E.Prompt(str(p.value), tuple(args[1:]))
                else:
                    p = E.Prompt("{0}", (p,))
            return E.AIFilter(p, model=model)
        if uname == "AI_SCORE":
            p = args[0]
            if not isinstance(p, E.Prompt):
                if isinstance(p, E.Literal):
                    p = E.Prompt(str(p.value), tuple(args[1:]))
                else:
                    p = E.Prompt("{0}", (p,))
            return E.AIScore(p, model=model)
        if uname == "AI_EMBED":
            if len(args) != 1:
                raise self.error("AI_EMBED takes exactly one argument", tok)
            return E.AIEmbed(args[0], model=model)
        if uname == "AI_SIMILARITY":
            if len(args) != 2:
                raise self.error("AI_SIMILARITY takes exactly two "
                                 "arguments", tok)
            return E.AISimilarity(args[0], args[1], model=model)
        if uname == "AI_CLASSIFY":
            text = args[0]
            if not isinstance(text, E.Prompt):
                text = (E.Prompt(str(text.value), ())
                        if isinstance(text, E.Literal)
                        else E.Prompt("{0}", (text,)))
            labels: Tuple[str, ...] = ()
            labels_expr = None
            if len(args) > 1:
                second = args[1]
                if isinstance(second, E.Literal) and isinstance(second.value,
                                                                tuple):
                    labels = second.value
                else:
                    labels_expr = second
            return E.AIClassify(text, labels=labels, labels_expr=labels_expr,
                                multi_label=bool(kwargs.get("multi_label",
                                                            False)),
                                model=model)
        if uname == "AI_COMPLETE":
            p = args[0]
            if not isinstance(p, E.Prompt):
                p = (E.Prompt(str(p.value), tuple(args[1:]))
                     if isinstance(p, E.Literal) else E.Prompt("{0}", (p,)))
            return E.AIComplete(p, model=model,
                                max_tokens=int(kwargs.get("max_tokens", 48)))
        if uname == "AI_AGG":
            instr = (self._lit_str(args[1], "AI_AGG instruction", tok)
                     if len(args) > 1 else None)
            return E.AggCall("AI_AGG", (args[0],), instruction=instr)
        if uname == "AI_SUMMARIZE_AGG":
            return E.AggCall("AI_SUMMARIZE_AGG", (args[0],))
        if uname in ("COUNT", "SUM", "AVG", "MIN", "MAX"):
            return E.AggCall(uname, tuple(args))
        return E.FuncCall(uname, tuple(args))

    def _lit_str(self, e: E.Expr, what: str,
                 tok: Optional[Tok] = None) -> str:
        # A bare assert here disappears under ``python -O`` and lets a
        # non-literal template flow into execution; raise a real error.
        if not (isinstance(e, E.Literal) and isinstance(e.value, str)):
            raise self.error(f"{what} must be a string literal", tok)
        return e.value


def parse(sql: str) -> Query:
    return Parser(sql).parse()
