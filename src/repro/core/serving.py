"""Concurrent multi-tenant query serving — the production front of the
engine (paper §2's "heavy traffic" premise made concrete).

`AisqlEngine.sql()` is a blocking single-query call; this module turns a
catalog + scheduler into a **serving runtime** that keeps N queries in
flight at once while sharing the expensive state across all of them:

  * one `RequestPipeline` (thread-safe, single-dispatcher) shared by
    every session, so coalescing, dedup and the TTL'd LRU result cache
    work **across** concurrent queries and tenants — the repeated
    predicates of a production workload are answered once and served
    from cache everywhere else (`PipelineStats.cross_query_hits`);
  * one `StatsStore`, so every session plans with the statistics every
    other session has already learned;
  * one `Scheduler` + backend pool, with the pipeline's bounded
    retry-with-backoff riding the scheduler's replica retries — an
    injected transient fault re-dispatches, it never drops a request or
    bills it twice.

Admission is **per-tenant fair share**: each tenant has a `TenantPolicy`
with a credit budget (hard spend ceiling, checked at admission) and a
token bucket (``queries_per_s`` + ``burst``) that rate-limits how fast
its queries may start.  Billing is exact: the shared pipeline routes
each dispatched result to the owning session's meter (registered per
owner at dispatch time), so the sum of per-tenant credit meters always
equals the pipeline's dispatch spend — dedup/cache hits cost the hitting
tenant nothing, exactly the §4 accounting the paper surfaces.

Lifecycle: ``submit(tenant, sql)`` returns a `QueryTicket` immediately;
a pool of worker threads admits and executes tickets on per-tenant
`QuerySession`s (checked out per query, so one tenant may have several
queries in flight, each on its own executor).  ``drain()`` waits for all
submitted work; ``report()`` distils per-tenant spend, queue waits and
latency percentiles plus the shared pipeline/scheduler fault and cache
telemetry into a `ServingReport`.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import queue
import threading
import time
from typing import Dict, List, Optional, Tuple

from repro.core.cost import Catalog
from repro.core.engine import AisqlEngine, QueryReport
from repro.core.executor import ExecConfig
from repro.core.optimizer import OptimizerConfig
from repro.core.stats import PredObservation, StatsStore, \
    predicate_fingerprint
from repro.inference.api import CortexClient
from repro.inference.pipeline import PipelineConfig, RequestPipeline
from repro.inference.scheduler import Scheduler
from repro.obs import Observability
from repro.obs.metrics import MetricsRegistry, _HistChild
from repro.tables.table import Table


class AdmissionError(RuntimeError):
    """A query was refused at admission (tenant exhausted its credit
    budget); raised by ``QueryTicket.result()``."""


# ---------------------------------------------------------------------------
# tenants: policy, token bucket, meter
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TenantPolicy:
    """Fair-share admission knobs for one tenant.

    ``credit_budget``: hard ceiling on the tenant's dispatched AI-credit
    spend; a query arriving after the meter reaches it is rejected with
    `AdmissionError` (None = unlimited).  ``queries_per_s`` / ``burst``
    parameterize a token bucket: each admitted query consumes one token,
    tokens refill at ``queries_per_s`` up to ``burst`` — a tenant may
    burst, then settles to its fair rate while other tenants' queries
    interleave.
    """
    credit_budget: Optional[float] = None
    queries_per_s: float = math.inf
    burst: int = 8


class TokenBucket:
    """Thread-safe token bucket; ``acquire`` blocks until a token is
    available and returns the seconds waited."""

    def __init__(self, rate: float, burst: int):
        self.rate = float(rate)
        self.capacity = max(int(burst), 1)
        self._tokens = float(self.capacity)
        self._updated = time.monotonic()
        self._lock = threading.Lock()

    def try_acquire(self) -> Tuple[bool, float]:
        """Non-blocking: ``(True, 0.0)`` and one token consumed, or
        ``(False, seconds_until_next_token)``."""
        with self._lock:
            now = time.monotonic()
            if self.rate != math.inf:
                self._tokens = min(
                    self.capacity,
                    self._tokens + (now - self._updated) * self.rate)
            self._updated = now
            if self.rate == math.inf or self._tokens >= 1.0:
                if self.rate != math.inf:
                    self._tokens -= 1.0
                return True, 0.0
            return False, (1.0 - self._tokens) / max(self.rate, 1e-9)

    def peek(self) -> Tuple[bool, float]:
        """Like ``try_acquire`` but non-consuming: would a token be
        available right now, and if not, how long until one refills?
        (The HTTP front door sheds load with this — a 429 with
        Retry-After — without stealing the token an admitted query
        will consume.)"""
        with self._lock:
            now = time.monotonic()
            tokens = self._tokens
            if self.rate != math.inf:
                tokens = min(self.capacity,
                             tokens + (now - self._updated) * self.rate)
            if self.rate == math.inf or tokens >= 1.0:
                return True, 0.0
            return False, (1.0 - tokens) / max(self.rate, 1e-9)

    def acquire(self) -> float:
        t0 = time.perf_counter()
        while True:
            ok, shortfall = self.try_acquire()
            if ok:
                return time.perf_counter() - t0
            time.sleep(min(shortfall, 0.05))


class TenantMeter:
    """Per-tenant serving accounting, held as a *view* over the metrics
    registry: credits, call counts and query outcomes are registry
    counter children, queue-wait/latency are exponential-bucket
    histogram children — so ``ServingReport``, ``/v1/metrics`` and the
    tenant meter can never disagree, and percentiles cover the whole
    run instead of a bounded last-N sample window whose tail silently
    vanished on long runs (the old ``MAX_SAMPLES`` deques)."""

    def __init__(self, name: str, policy: TenantPolicy,
                 registry: Optional[MetricsRegistry] = None):
        self.name = name
        self.policy = policy
        self.bucket = TokenBucket(policy.queries_per_s, policy.burst)
        self.lock = threading.Lock()
        reg = registry if registry is not None else MetricsRegistry()
        self.registry = reg
        self._queries = reg.counter("aisql_queries_total")
        self._credits = reg.counter("aisql_credits_total").labels(
            tenant=name)
        self._calls = reg.counter(
            "aisql_dispatched_calls_total").labels(tenant=name)
        self.queue_hist = reg.histogram(
            "aisql_queue_wait_seconds").labels(tenant=name)
        self.latency_hist = reg.histogram(
            "aisql_query_latency_seconds").labels(tenant=name)
        self._status = {
            s: self._queries.labels(tenant=name, status=s)
            for s in ("submitted", "completed", "failed", "rejected")}

    def mark(self, status: str, n: int = 1) -> None:
        """Count a query lifecycle transition
        (submitted/completed/failed/rejected)."""
        with self.lock:
            self._status[status].value += n

    def record(self, queue_wait_s: float, latency_s: float) -> None:
        with self.lock:
            self._status["completed"].value += 1
            self.queue_hist.observe(queue_wait_s)
            self.latency_hist.observe(latency_s)

    def bill(self, results) -> None:
        """Dispatch-time hook: exact spend attribution (conservation:
        summing this over tenants gives the pipeline's dispatch spend)."""
        with self.lock:
            self._calls.value += len(results)
            for r in results:
                self._credits.value += r.credits

    # registry-backed reads (the report and admission control use these)
    @property
    def credits(self) -> float:
        return self._credits.value

    @property
    def dispatched_calls(self) -> int:
        return int(self._calls.value)

    @property
    def submitted(self) -> int:
        return int(self._status["submitted"].value)

    @property
    def completed(self) -> int:
        return int(self._status["completed"].value)

    @property
    def failed(self) -> int:
        return int(self._status["failed"].value)

    @property
    def rejected(self) -> int:
        return int(self._status["rejected"].value)

    @property
    def over_budget(self) -> bool:
        b = self.policy.credit_budget
        return b is not None and self.credits >= b


# ---------------------------------------------------------------------------
# cross-tenant statistics sharing
# ---------------------------------------------------------------------------


class TenantStatsStore(StatsStore):
    """Per-tenant statistics with cross-tenant *prior* sharing.

    The ``"priors"`` stat-sharing mode gives each tenant its own store
    (its ground truth: every observation its queries produce) while all
    writes are additionally folded into one shared pool.  Reads prefer
    the tenant's own evidence; when the tenant is cold for a fingerprint
    the pool answers instead — as a **capped copy** (at most
    ``prior_rows`` evidence rows, every counter scaled down
    proportionally) flagged ``shared_prior``, which the cost model
    surfaces as the ``"transferred"`` estimate tier and keeps blended
    rather than trusted raw.  Isolation properties:

      * another tenant's history can never outweigh this tenant's own
        fresh observations (the cap bounds borrowed confidence);
      * billing and per-tenant telemetry are untouched — sharing moves
        selectivity/cost *priors*, never credits or results.
    """

    def __init__(self, shared: StatsStore, *, prior_rows: int = 48):
        # set before super().__init__: the version property reads it
        self.shared = shared
        self._version = 0
        super().__init__()
        self.prior_rows = max(int(prior_rows), 1)

    # -- version: own writes and *other tenants'* pool writes must both
    # invalidate this tenant's transferred-prior cache
    @property
    def version(self) -> int:                       # type: ignore[override]
        return self._version + self.shared.version

    @version.setter
    def version(self, value: int) -> None:
        self._version = value - self.shared.version

    # -- writes: own ground truth AND the shared pool -------------------
    def observe_predicate(self, key, **kw):
        self.shared.observe_predicate(key, **kw)
        return super().observe_predicate(key, **kw)

    def note_query(self, keys) -> None:
        self.shared.note_query(keys)
        super().note_query(keys)

    def observe_cascade(self, key, **kw):
        self.shared.observe_cascade(key, **kw)
        return super().observe_cascade(key, **kw)

    def observe_index(self, key, **kw):
        self.shared.observe_index(key, **kw)
        return super().observe_index(key, **kw)

    def observe_pipeline(self, **kw):
        self.shared.observe_pipeline(**kw)
        return super().observe_pipeline(**kw)

    def register_prompt(self, key: str, text: str) -> None:
        self.shared.register_prompt(key, text)
        super().register_prompt(key, text)

    # -- reads: own evidence first, capped pool prior second ------------
    def _shared_view(self, key: str) -> Optional[PredObservation]:
        src = self.shared.get(key)
        if src is None:
            return None
        view = PredObservation.from_dict(src.to_dict())
        if view.evaluated > self.prior_rows:
            f = self.prior_rows / view.evaluated
            for fld in dataclasses.fields(view):
                v = getattr(view, fld.name)
                scaled = v * f
                setattr(view, fld.name,
                        int(round(scaled)) if isinstance(v, int)
                        else scaled)
        # dynamic attribute, NOT a dataclass field: merge()/to_dict()
        # must never treat provenance as an additive counter
        view.shared_prior = True
        return view

    def get(self, key: str) -> Optional[PredObservation]:
        own = super().get(key)
        if own is not None and own.evaluated > 0:
            return own
        return self._shared_view(key) or own

    def for_pred(self, pred) -> Optional[PredObservation]:
        return self.get(predicate_fingerprint(pred))

    def confident(self, key: str, *, min_rows: int = 32) -> bool:
        if super().confident(key, min_rows=min_rows):
            return True
        view = self._shared_view(key)
        return view is not None and view.evaluated >= min_rows

    def items(self):
        merged: Dict[str, Optional[PredObservation]] = {
            k: self._shared_view(k) for k, _ in self.shared.items()}
        for k, o in super().items():
            if o.evaluated > 0:
                merged[k] = o
        return iter([(k, o) for k, o in merged.items() if o is not None])

    def prompt_text(self, key: str) -> Optional[str]:
        return (super().prompt_text(key)
                or self.shared.prompt_text(key))

    def prompt_texts(self) -> Dict[str, str]:
        out = self.shared.prompt_texts()
        out.update(super().prompt_texts())
        return out


# ---------------------------------------------------------------------------
# tickets and sessions
# ---------------------------------------------------------------------------


class QueryTicket:
    """Handle for one submitted query; resolves to a `Table` (or raises
    the query's error) on ``result()``.  Tickets submitted with
    ``stream=True`` additionally expose ``batches()``: an iterator of
    partition-incremental `Table` batches, available while the query is
    still executing (the HTTP front-end turns these into NDJSON lines).
    """

    def __init__(self, tenant: str, sql: str, *, stream: bool = False,
                 query_id: str = ""):
        self.tenant = tenant
        self.sql = sql
        self.stream = stream
        self.query_id = query_id    # serving-assigned ("q000001", ...)
        self.submitted_at = time.perf_counter()
        self.queue_wait_s = 0.0     # submit -> execution start
        self.wall_s = 0.0           # execution only
        self.report: Optional[QueryReport] = None
        self._done = threading.Event()
        self._table: Optional[Table] = None
        self._error: Optional[Exception] = None
        # None-terminated batch stream; only populated for stream=True
        self._batchq: Optional["queue.Queue[Optional[Table]]"] = (
            queue.Queue() if stream else None)

    def done(self) -> bool:
        return self._done.is_set()

    def exception(self) -> Optional[Exception]:
        return self._error

    def result(self, timeout: Optional[float] = None) -> Table:
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"query not finished after {timeout}s: {self.sql[:60]!r}")
        if self._error is not None:
            raise self._error
        assert self._table is not None
        return self._table

    def batches(self, timeout: Optional[float] = None):
        """Yield result batches as the executor produces them; raises the
        query's error (if any) after the stream ends.  Only valid for
        tickets submitted with ``stream=True``."""
        if self._batchq is None:
            raise ValueError("ticket was not submitted with stream=True")
        while True:
            try:
                batch = self._batchq.get(timeout=timeout)
            except queue.Empty:
                raise TimeoutError(
                    f"no batch after {timeout}s: {self.sql[:60]!r}")
            if batch is None:
                break
            yield batch
        if self._error is not None:
            raise self._error

    def _finish(self) -> None:
        """Mark terminal (worker-side): wake ``result()`` waiters and
        terminate the batch stream exactly once."""
        self._done.set()
        if self._batchq is not None:
            self._batchq.put(None)


class QuerySession:
    """One tenant's execution context: a private `AisqlEngine` (its own
    executor/optimizer state) over a `CortexClient` that shares the
    serving runtime's pipeline, scheduler and stats store.  Sessions are
    single-threaded by construction — the serving engine checks one out
    per in-flight query and returns it afterwards."""

    def __init__(self, owner: str, tenant: str, meter: TenantMeter,
                 catalog: Catalog, scheduler: Scheduler,
                 pipeline: RequestPipeline, stats: StatsStore,
                 cfg: "ServingConfig", semindex=None, obs=None):
        self.owner = owner
        self.tenant = tenant
        # tenant billing chains onto the client meter in one registered
        # hook: the pipeline calls exactly one hook per dispatched
        # result, so spend lands on both the client (QueryReport) and
        # the tenant (ServingReport) exactly once
        self.client = CortexClient(
            scheduler, default_model=cfg.default_model,
            proxy_model=cfg.proxy_model, pipeline=pipeline, owner=owner,
            on_dispatch_extra=meter.bill)
        # ``semindex`` is the serving engine's *shared* manager: one
        # embedding store and one set of ANN indexes across every
        # session and tenant (an index built for tenant A's query
        # answers tenant B's for free; the manager is lock-protected)
        self.engine = AisqlEngine(
            catalog, self.client, optimizer=cfg.optimizer,
            executor=cfg.executor, stats=stats, semindex=semindex,
            obs=obs)

    def run(self, sql: str,
            on_batch=None) -> Tuple[Table, Optional[QueryReport]]:
        out = self.engine.sql(sql, on_batch=on_batch)
        return out, self.engine.last_report


# ---------------------------------------------------------------------------
# reports
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TenantReport:
    """One tenant's slice of a `ServingReport`.

    Percentiles come from the registry's exponential-bucket histograms
    (no raw samples kept): each is a bucket midpoint, with relative
    error at most ``repro.obs.metrics.QUANTILE_REL_ERROR`` (≈17% for
    the √2 buckets) — in exchange the estimate covers **every** query
    of the run, not a bounded last-N window."""
    tenant: str
    queries: int                    # submitted
    completed: int
    failed: int
    rejected: int                   # refused at admission (budget)
    credits_spent: float            # dispatch-billed AI credits
    credit_budget: Optional[float]
    dispatched_calls: int           # LLM requests billed to this tenant
    queue_wait_p50_s: float
    queue_wait_p95_s: float
    latency_p50_s: float
    latency_p95_s: float


@dataclasses.dataclass
class ServingReport:
    """Everything the serving runtime observed: per-tenant accounting
    plus the shared pipeline/scheduler/backend telemetry."""
    tenants: Dict[str, TenantReport]
    queries: int                    # total submitted
    total_credits: float            # sum of tenant meters (== dispatch spend)
    backend_credits: Optional[float]  # backends' own meter (conservation)
    submitted_requests: int         # requests entering the shared pipeline
    dispatched_requests: int        # requests actually sent to engines
    dedup_hits: int                 # in-flight + cache hits
    cache_hits: int                 # memoized-result hits
    cross_query_hits: int           # hits served across sessions/tenants
    cache_expired: int              # TTL evictions
    cancelled_requests: int         # withdrawn pre-dispatch (never billed)
    retries: int                    # pipeline batch re-dispatches
    scheduler_retries: int          # scheduler-level replica retries
    scheduler_timeouts: int         # of those, engine timeouts
    failed_requests: int            # requests that exhausted all retries
    queue_wait_p50_s: float         # across all completed queries
    queue_wait_p95_s: float
    latency_p50_s: float
    latency_p95_s: float
    # aggregated spill-manager counters (chunked catalog tables + the
    # embedding store); None when nothing spillable is attached
    storage: Optional[Dict[str, int]] = None

    def render(self) -> str:
        lines = [
            f"-- serving: {self.queries} queries, "
            f"{self.total_credits:.6g} credits "
            f"({self.dispatched_requests}/{self.submitted_requests} "
            f"requests dispatched, {self.dedup_hits} dedup hits, "
            f"{self.cross_query_hits} cross-query)",
            f"-- faults: {self.retries} pipeline retries, "
            f"{self.scheduler_retries} scheduler retries "
            f"({self.scheduler_timeouts} timeouts), "
            f"{self.failed_requests} permanent failures, "
            f"{self.cancelled_requests} cancelled",
            f"-- latency: queue p50/p95 {self.queue_wait_p50_s:.3f}/"
            f"{self.queue_wait_p95_s:.3f}s, exec p50/p95 "
            f"{self.latency_p50_s:.3f}/{self.latency_p95_s:.3f}s",
        ]
        if self.storage is not None:
            s = self.storage
            lines.append(
                f"-- storage: peak {s['peak_bytes']} tracked bytes "
                f"({s['tracked_bytes']} resident), "
                f"{s['spill_events']} spills / "
                f"{s['reload_events']} reloads")
        for t in self.tenants.values():
            budget = ("∞" if t.credit_budget is None
                      else f"{t.credit_budget:.4g}")
            lines.append(
                f"--   tenant {t.tenant}: {t.completed}/{t.queries} ok "
                f"({t.rejected} rejected, {t.failed} failed), "
                f"{t.credits_spent:.6g}/{budget} credits, "
                f"{t.dispatched_calls} calls")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# the serving engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServingConfig:
    """Policy for a `ServingEngine`."""
    workers: int = 4
    # shared-pipeline policy; the 300s TTL ages cross-query answers out
    pipeline: PipelineConfig = dataclasses.field(
        default_factory=lambda: PipelineConfig(cache_ttl_s=300.0))
    executor: Optional[ExecConfig] = None
    optimizer: Optional[OptimizerConfig] = None
    default_policy: TenantPolicy = dataclasses.field(
        default_factory=TenantPolicy)
    default_model: str = "oracle-70b"
    proxy_model: str = "proxy-8b"
    # cross-tenant statistics sharing:
    #   "full"   — one store; every session reads and writes the same
    #              observations (the historical single-store behaviour);
    #   "priors" — per-tenant ground-truth stores; every write also feeds
    #              a shared pool whose evidence other tenants read back
    #              as capped `shared_prior` copies, surfaced by the cost
    #              model as the "transferred" estimate tier;
    #   "none"   — fully private per-tenant stores, no sharing at all.
    stat_sharing: str = "full"
    # "priors" mode: max evidence rows a tenant may borrow from the pool
    # per fingerprint — another tenant's long history can never outweigh
    # this tenant's own fresh observations
    shared_prior_rows: int = 48
    # observability: tracing + metrics.  None builds a default
    # `Observability` (tracing on, wall-clock, 64-trace ring); pass
    # ``Observability(enabled=False)`` to skip span recording, or one
    # with ``clock=TickClock`` for byte-stable replay traces.
    obs: Optional[Observability] = None


class ServingEngine:
    """Multi-tenant concurrent front door: ``submit`` queries, ``drain``,
    inspect the `ServingReport`.  Usable as a context manager."""

    def __init__(self, catalog: Catalog, scheduler: Scheduler, *,
                 cfg: Optional[ServingConfig] = None,
                 stats: Optional[StatsStore] = None,
                 tenants: Optional[Dict[str, TenantPolicy]] = None,
                 semindex=None):
        from repro.semindex import SemanticIndexManager, SemIndexConfig
        self.catalog = catalog
        self.scheduler = scheduler
        self.cfg = cfg or ServingConfig()
        if self.cfg.stat_sharing not in ("full", "priors", "none"):
            raise ValueError(
                f"ServingConfig.stat_sharing must be 'full', 'priors' or "
                f"'none', got {self.cfg.stat_sharing!r}")
        self.stats = stats if stats is not None else StatsStore()
        # "priors"/"none": lazily-built per-tenant stores ("full" mode
        # hands every session self.stats directly)
        self._tenant_stats: Dict[str, StatsStore] = {}
        if semindex is True:
            semindex = SemanticIndexManager()
        elif isinstance(semindex, SemIndexConfig):
            semindex = SemanticIndexManager(semindex)
        # one manager for the whole serving engine: embedding store and
        # ANN indexes are cross-tenant shared state, like the pipeline
        self.semindex = semindex or None
        self.pipeline = RequestPipeline(scheduler, self.cfg.pipeline)
        # observability: one registry + trace ring for the process; the
        # scheduler and pipeline record their per-dispatch families into
        # the same registry the tenant meters live in
        self.obs = self.cfg.obs if self.cfg.obs is not None \
            else Observability()
        self.scheduler.registry = self.obs.registry
        self.pipeline.registry = self.obs.registry
        self._register_collectors()
        self._lock = threading.Lock()
        self._qids = itertools.count(1)
        self.tenants: Dict[str, TenantMeter] = {
            name: TenantMeter(name, pol, registry=self.obs.registry)
            for name, pol in (tenants or {}).items()}
        self._idle_sessions: Dict[str, List[QuerySession]] = {}
        self._session_ids = itertools.count(1)
        self.sessions_created = 0
        # counter, not a ticket list: retaining tickets would pin every
        # completed query's result table for the engine's lifetime
        self._submitted = 0
        self._queue: "queue.Queue[Optional[QueryTicket]]" = queue.Queue()
        self._closed = False
        self._shutdown_done = threading.Event()
        self._workers = [
            threading.Thread(target=self._worker, daemon=True,
                             name=f"aisql-serve-{i}")
            for i in range(max(self.cfg.workers, 1))]
        for w in self._workers:
            w.start()

    def _register_collectors(self) -> None:
        """Expose the pipeline/scheduler/storage counters as scrape-time
        registry samples.  Collectors read the same locked snapshots the
        `ServingReport` reads, so ``/v1/metrics`` and ``report()`` can
        never disagree about these numbers."""
        def pipeline_events():
            # scalar counters only — batch_size_hist is covered by the
            # aisql_pipeline_batch_size histogram the pipeline records
            snap = self.pipeline.stats_snapshot()
            return [("aisql_pipeline_events_total", {"event": k}, float(v))
                    for k, v in snap.items()
                    if isinstance(v, (int, float))]

        def scheduler_events():
            snap = self.scheduler.stats_snapshot()
            return [("aisql_scheduler_events_total", {"event": k}, float(v))
                    for k, v in snap.items()]

        def storage():
            stats = self.storage_stats()
            if stats is None:
                return []
            return [
                ("aisql_storage_events_total", {"event": "spill"},
                 float(stats["spill_events"])),
                ("aisql_storage_events_total", {"event": "reload"},
                 float(stats["reload_events"])),
                ("aisql_storage_bytes", {"state": "resident"},
                 float(stats["tracked_bytes"])),
                ("aisql_storage_bytes", {"state": "peak"},
                 float(stats["peak_bytes"])),
                ("aisql_storage_bytes", {"state": "spilled"},
                 float(stats["spilled_bytes"])),
            ]

        reg = self.obs.registry
        reg.register_collector(pipeline_events)
        reg.register_collector(scheduler_events)
        reg.register_collector(storage)

    @classmethod
    def simulated(cls, catalog: Catalog, *, seed: int = 0,
                  fault_rate: float = 0.0, timeout_rate: float = 0.0,
                  fault_burst_every: int = 0, fault_burst_len: int = 0,
                  replicas: int = 1, **kw) -> "ServingEngine":
        """Convenience: a serving engine over the calibrated simulator
        (optionally with injected transient faults/timeouts; burst
        parameters cluster those faults in attempt-time)."""
        from repro.inference.simulator import SimulatedBackend
        sched = Scheduler()
        for rep in range(max(replicas, 1)):
            sched.register(SimulatedBackend(
                seed=seed, fault_rate=fault_rate, timeout_rate=timeout_rate,
                fault_seed=seed + 101 * rep,
                fault_burst_every=fault_burst_every,
                fault_burst_len=fault_burst_len))
        return cls(catalog, sched, **kw)

    # -- context manager ----------------------------------------------
    def __enter__(self) -> "ServingEngine":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- tenants and sessions -----------------------------------------
    def tenant(self, name: str) -> TenantMeter:
        with self._lock:
            meter = self.tenants.get(name)
            if meter is None:
                meter = TenantMeter(
                    name, dataclasses.replace(self.cfg.default_policy),
                    registry=self.obs.registry)
                self.tenants[name] = meter
            return meter

    def tenant_stats(self, tenant: str) -> StatsStore:
        """The statistics store ``tenant``'s sessions plan with: the one
        shared store ("full"), a `TenantStatsStore` over the shared pool
        ("priors"), or a fully private store ("none")."""
        if self.cfg.stat_sharing == "full":
            return self.stats
        with self._lock:
            store = self._tenant_stats.get(tenant)
            if store is None:
                if self.cfg.stat_sharing == "priors":
                    store = TenantStatsStore(
                        self.stats, prior_rows=self.cfg.shared_prior_rows)
                else:
                    store = StatsStore()
                self._tenant_stats[tenant] = store
            return store

    def _checkout(self, tenant: str) -> QuerySession:
        meter = self.tenant(tenant)
        stats = self.tenant_stats(tenant)
        with self._lock:
            pool = self._idle_sessions.setdefault(tenant, [])
            if pool:
                return pool.pop()
            owner = f"{tenant}#{next(self._session_ids)}"
            self.sessions_created += 1
        return QuerySession(owner, tenant, meter, self.catalog,
                            self.scheduler, self.pipeline, stats,
                            self.cfg, semindex=self.semindex,
                            obs=self.obs)

    def _checkin(self, tenant: str, session: QuerySession) -> None:
        with self._lock:
            self._idle_sessions.setdefault(tenant, []).append(session)

    # -- submission / draining ----------------------------------------
    def submit(self, tenant: str, sql: str, *,
               stream: bool = False) -> QueryTicket:
        """Enqueue one query for ``tenant``; returns immediately.  With
        ``stream=True`` the ticket's ``batches()`` iterator yields result
        batches while the query executes."""
        ticket = QueryTicket(tenant, sql, stream=stream)
        meter = self.tenant(tenant)
        # closed-check and enqueue are one atomic step: a racing close()
        # (which flips _closed under the same lock) can therefore never
        # drain *between* our check and our put, which would strand the
        # ticket unserved and hang its result() forever
        with self._lock:
            if self._closed:
                raise RuntimeError("ServingEngine is closed")
            self._submitted += 1
            ticket.query_id = f"q{next(self._qids):06d}"
            self._queue.put(ticket)
        meter.mark("submitted")
        return ticket

    def run_all(self, workload: List[Tuple[str, str]]) -> List[QueryTicket]:
        """Submit a ``[(tenant, sql), ...]`` workload and drain it."""
        tickets = [self.submit(tenant, sql) for tenant, sql in workload]
        self.drain()
        return tickets

    def drain(self) -> None:
        """Block until every submitted ticket has finished."""
        self._queue.join()

    def close(self) -> None:
        """Drain, then stop the worker threads.  Idempotent and safe
        under concurrency: the first caller performs the shutdown, every
        later (or concurrent) caller blocks until it completes; tickets
        in flight at the moment of the call all finish normally."""
        with self._lock:
            first = not self._closed
            self._closed = True
        if not first:
            self._shutdown_done.wait()
            return
        try:
            self.drain()
            for _ in self._workers:
                self._queue.put(None)
            for w in self._workers:
                if w is not threading.current_thread():
                    w.join(timeout=30.0)
        finally:
            self._shutdown_done.set()

    # -- the worker loop ----------------------------------------------
    def _worker(self) -> None:
        while True:
            ticket = self._queue.get()
            if ticket is None:
                self._queue.task_done()
                return
            requeued = False
            try:
                requeued = self._serve(ticket)
            finally:
                if not requeued:
                    ticket._finish()
                self._queue.task_done()

    def _serve(self, ticket: QueryTicket) -> bool:
        """Admit + execute one ticket.  Returns True when the ticket was
        re-enqueued (rate-limited, token not yet available) — a worker
        must never sleep on one tenant's bucket while other tenants'
        queries are runnable (head-of-line blocking)."""
        meter = self.tenant(ticket.tenant)
        try:
            if meter.over_budget:
                meter.mark("rejected")
                raise AdmissionError(
                    f"tenant {ticket.tenant!r} exhausted its credit "
                    f"budget ({meter.credits:.6g} >= "
                    f"{meter.policy.credit_budget:.6g})")
            admitted, shortfall = meter.bucket.try_acquire()
            if not admitted:            # fair-share rate limiting
                if meter.bucket.rate <= 0.0:
                    # a zero-rate (paused) tenant's bucket never refills:
                    # requeueing would spin forever and hang drain()
                    meter.mark("rejected")
                    raise AdmissionError(
                        f"tenant {ticket.tenant!r} is paused "
                        f"(queries_per_s=0) and its burst is exhausted")
                # brief bounded pause (spin guard when only this
                # tenant's work remains), then back of the queue
                time.sleep(min(shortfall, 0.02))
                self._queue.put(ticket)
                return True
            ticket.queue_wait_s = time.perf_counter() - ticket.submitted_at
            session = self._checkout(ticket.tenant)
            try:
                t0 = time.perf_counter()
                on_batch = (ticket._batchq.put
                            if ticket._batchq is not None else None)
                table, report = session.run(ticket.sql, on_batch=on_batch)
                ticket.wall_s = time.perf_counter() - t0
                ticket.report = report
                ticket._table = table
                if report is not None and report.trace is not None:
                    self.obs.ring.put(ticket.query_id, report.trace)
            finally:
                self._checkin(ticket.tenant, session)
            meter.record(ticket.queue_wait_s, ticket.wall_s)
        except AdmissionError as e:
            ticket._error = e
        except Exception as e:          # the query's own failure
            ticket._error = e
            meter.mark("failed")
        return False

    # -- reporting -----------------------------------------------------
    def storage_stats(self) -> Optional[Dict[str, int]]:
        """Aggregate spill-manager counters across every chunk-backed
        catalog table and the embedding store (managers deduplicated:
        tables sharing one manager are counted once)."""
        managers = {}
        for t in self.catalog.tables.values():
            mgr = getattr(t, "spill", None)
            if mgr is not None:
                managers[id(mgr)] = mgr
        if self.semindex is not None:
            mgr = getattr(self.semindex.store, "spill", None)
            if mgr is not None:
                managers[id(mgr)] = mgr
        if not managers:
            return None
        agg: Dict[str, int] = {}
        for mgr in managers.values():
            for k, v in mgr.stats().items():
                agg[k] = agg.get(k, 0) + v
        return agg

    def backend_credits(self) -> Optional[float]:
        """Sum of the backends' own credit meters (independent source
        for the conservation check); None if no backend exposes one."""
        total, seen, found = 0.0, set(), False
        for reps in self.scheduler._replicas.values():
            for e in reps:
                if id(e) not in seen and hasattr(e, "total_credits"):
                    total += e.total_credits
                    seen.add(id(e))
                    found = True
        return total if found else None

    def report(self) -> ServingReport:
        """Distil the run so far.  Exact cross-field invariants (e.g.
        ``total_credits == backend_credits``, submitted == dispatched +
        dedup + cancelled + failed) hold for a report taken after
        ``drain()``; a report taken mid-flight is a best-effort sample
        (the pipeline counters themselves are snapshotted atomically)."""
        with self._lock:
            meters = list(self.tenants.values())
            n_tickets = self._submitted
        tenant_reports: Dict[str, TenantReport] = {}
        total_credits = 0.0
        all_waits = _HistChild()
        all_lats = _HistChild()
        for m in meters:
            with m.lock:
                waits, lats = m.queue_hist, m.latency_hist
                tenant_reports[m.name] = TenantReport(
                    tenant=m.name, queries=m.submitted,
                    completed=m.completed, failed=m.failed,
                    rejected=m.rejected, credits_spent=m.credits,
                    credit_budget=m.policy.credit_budget,
                    dispatched_calls=m.dispatched_calls,
                    queue_wait_p50_s=waits.quantile(0.50),
                    queue_wait_p95_s=waits.quantile(0.95),
                    latency_p50_s=lats.quantile(0.50),
                    latency_p95_s=lats.quantile(0.95))
                total_credits += m.credits
                all_waits.merge(waits)
                all_lats.merge(lats)
        ps = self.pipeline.stats_snapshot()   # atomic under pipeline lock
        ss = self.scheduler.stats_snapshot()  # atomic under scheduler lock
        return ServingReport(
            tenants=tenant_reports, queries=n_tickets,
            total_credits=total_credits,
            backend_credits=self.backend_credits(),
            submitted_requests=ps["submitted"],
            dispatched_requests=ps["dispatched"],
            dedup_hits=ps["dedup_hits"], cache_hits=ps["cache_hits"],
            cross_query_hits=ps["cross_query_hits"],
            cache_expired=ps["cache_expired"],
            cancelled_requests=ps["cancelled"],
            retries=ps["retries"],
            scheduler_retries=ss["retries"],
            scheduler_timeouts=ss["timeouts"],
            failed_requests=ps["failures"],
            queue_wait_p50_s=all_waits.quantile(0.50),
            queue_wait_p95_s=all_waits.quantile(0.95),
            latency_p50_s=all_lats.quantile(0.50),
            latency_p95_s=all_lats.quantile(0.95),
            storage=self.storage_stats())
