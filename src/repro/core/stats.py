"""Learned statistics store — the feedback half of adaptive re-optimization.

The paper's core planning problem is that semantic operators' *cost and
selectivity are unknown during query compilation*.  This module closes the
loop: every AI-predicate evaluation (pilot samples, full passes, cascade
routing, pipeline dedup) folds observations into a `StatsStore`, keyed by a
**predicate fingerprint** that is stable across queries and across
syntactically-different-but-equivalent predicates (table aliases are
stripped, prompt templates and models are canonical).  The `CostModel`
consults the store *before* its static defaults, so the second query — or
the post-pilot remainder of the first — plans with real numbers.

Recorded per fingerprint (`PredObservation`):

  * **selectivity** — passed / evaluated rows, with a Wilson-score
    confidence interval (`selectivity_ci`) so the planner can tell a
    confident estimate from noise;
  * **cost per row** — observed credits / evaluated row (dispatch-metered,
    so dedup savings show up) plus wall seconds;
  * **cascade delegation rate** — oracle escalations / rows routed through
    a SUPG-IT cascade for this predicate (drives the cascade-bypass
    re-decision);
  * **dedup hit rate** — pipeline-level, stored under the reserved
    ``__pipeline__`` key.

Persistence is plain JSON (`save` / `load` round-trip) so learned stats
survive across engine instances — the production pattern of a statistics
service shared by all queries over a workload.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import threading
import warnings
from typing import Any, Dict, Iterator, Optional, Tuple

from repro.core import expr as E

PIPELINE_KEY = "__pipeline__"      # reserved fingerprint for global stats


# ---------------------------------------------------------------------------
# predicate fingerprinting
# ---------------------------------------------------------------------------


def _leaf(col: str) -> str:
    """Strip the table alias: ``a.body`` and ``articles.body`` -> ``body``."""
    return col.rsplit(".", 1)[-1]


def _canon(e: E.Expr) -> str:
    """Alias-free canonical form of a non-AI expression."""
    if isinstance(e, E.Column):
        return _leaf(e.name)
    if isinstance(e, E.Literal):
        return repr(e.value)
    if isinstance(e, E.BinOp):
        return f"({_canon(e.left)}{e.op}{_canon(e.right)})"
    if isinstance(e, E.Between):
        return f"between({_canon(e.expr)},{_canon(e.lo)},{_canon(e.hi)})"
    if isinstance(e, E.InList):
        return f"in({_canon(e.expr)},{sorted(map(repr, e.values))})"
    if isinstance(e, E.Not):
        return f"not({_canon(e.arg)})"
    if isinstance(e, E.BoolOp):
        return f"{e.op}({','.join(_canon(a) for a in e.args)})"
    if isinstance(e, E.FuncCall):
        return f"{e.name.upper()}({','.join(_canon(a) for a in e.args)})"
    if isinstance(e, E.AISimilarity):
        # cosine similarity is symmetric: canonicalize the side order so
        # AI_SIMILARITY(a, b) and AI_SIMILARITY(b, a) share an identity
        lo, hi = sorted((_canon(e.left), _canon(e.right)))
        return f"ai_similarity({lo},{hi},{e.model or ''})"
    if isinstance(e, E.AIEmbed):
        return f"ai_embed({_canon(e.arg)},{e.model or ''})"
    if isinstance(e, E.Prompt):
        return f"prompt({e.template!r},{','.join(_canon(a) for a in e.args)})"
    return type(e).__name__


def predicate_fingerprint(pred: E.Expr) -> str:
    """Stable cross-query identity of a predicate.

    Two predicates share a fingerprint iff an engine would answer them
    identically per row: same operator kind, same prompt template, same
    model, same *unaliased* argument columns.  ``WHERE AI_FILTER(
    PROMPT('x {0}', a.body))`` and the same filter written against alias
    ``b`` therefore share learned statistics.
    """
    if isinstance(pred, E.AIFilter):
        return (f"AI_FILTER|{pred.prompt.template}|{pred.model or ''}|"
                f"{','.join(_canon(a) for a in pred.prompt.args)}")
    if isinstance(pred, E.AIScore):
        # the model is part of the identity: proxy-prefilter scores and
        # oracle scores of the same prompt are distinct cost populations
        return (f"AI_SCORE|{pred.prompt.template}|{pred.model or ''}|"
                f"{','.join(_canon(a) for a in pred.prompt.args)}")
    if isinstance(pred, E.AIClassify):
        return (f"AI_CLASSIFY|{pred.text.template}|{pred.model or ''}|"
                f"{','.join(sorted(pred.labels))}|"
                f"{','.join(_canon(a) for a in pred.text.args)}")
    if isinstance(pred, E.AISimilarity):
        # symmetric operator, symmetric key: sort the canonical sides so
        # learned stats never split across the two argument orders
        lo, hi = sorted((_canon(pred.left), _canon(pred.right)))
        return f"AI_SIMILARITY|{pred.model or ''}|{lo}|{hi}"
    if isinstance(pred, E.AIEmbed):
        return f"AI_EMBED|{pred.model or ''}|{_canon(pred.arg)}"
    return f"REL|{_canon(pred)}"


def index_join_fingerprint(template: str, model, left_arg: str,
                           label_col: str) -> str:
    """Identity of one index-assisted semantic-join blocking site: the
    `StatsStore` accumulates probe/candidate volume under it, giving the
    cost model a learned candidate rate for the next race."""
    return (f"INDEX_JOIN|{template}|{model or ''}|"
            f"{_leaf(left_arg)}|{_leaf(label_col)}")


def predicate_prompt_text(pred: E.Expr) -> Optional[str]:
    """Natural-language text embedding a predicate's *meaning* — the
    kNN-transfer key (cost model v2).  The prompt template carries the
    semantic content; the unaliased argument columns disambiguate
    same-template predicates over different data.  None for operators
    whose statistics are not transferable by meaning (similarity /
    embed produce values, relational predicates are priced statically).
    """
    if isinstance(pred, (E.AIFilter, E.AIScore)):
        args = " ".join(_canon(a) for a in pred.prompt.args)
        return f"{pred.prompt.template} {args}".strip()
    if isinstance(pred, E.AIClassify):
        args = " ".join(_canon(a) for a in pred.text.args)
        return f"{pred.text.template} {args}".strip()
    return None


# ---------------------------------------------------------------------------
# observations
# ---------------------------------------------------------------------------


def wilson_interval(passed: int, evaluated: int, *, z: float = 1.96
                    ) -> Tuple[float, float]:
    """Wilson-score ``(lo, hi)`` confidence interval on a pass rate.

    Used instead of the normal approximation because pilot samples are
    small (tens of rows) and AI selectivities are often near 0 or 1,
    exactly where the normal interval degenerates.
    """
    if evaluated <= 0:
        return 0.0, 1.0
    p = passed / evaluated
    denom = 1.0 + z * z / evaluated
    centre = p + z * z / (2 * evaluated)
    margin = z * math.sqrt((p * (1 - p) + z * z / (4 * evaluated))
                           / evaluated)
    return (max(0.0, (centre - margin) / denom),
            min(1.0, (centre + margin) / denom))


@dataclasses.dataclass
class PredObservation:
    """Accumulated execution-time evidence for one predicate fingerprint.

    Counters are additive across queries; all derived quantities
    (selectivity, cost per row, delegation rate) are recomputed from the
    raw counts so merging two stores is exact.
    """
    evaluated: int = 0            # rows the predicate was evaluated on
    passed: int = 0               # rows where it returned true
    credits: float = 0.0          # LLM credits spent on those rows
    seconds: float = 0.0          # wall seconds spent on those rows
    queries: int = 0              # distinct queries that contributed
    cascade_rows: int = 0         # rows routed through a cascade
    cascade_oracle: int = 0      # of those, rows escalated to the oracle
    dedup_submitted: int = 0      # pipeline: requests submitted
    dedup_hits: int = 0           # pipeline: requests served by dedup
    index_probes: int = 0         # semantic index: kNN probe rows issued
    index_candidates: int = 0     # of those, candidates surfaced in total

    # -- derived -------------------------------------------------------
    @property
    def selectivity(self) -> float:
        return self.passed / self.evaluated if self.evaluated else 0.5

    def selectivity_ci(self, z: float = 1.96) -> Tuple[float, float]:
        return wilson_interval(self.passed, self.evaluated, z=z)

    @property
    def cost_per_row(self) -> float:
        """Observed credits per evaluated row (0.0 when unobserved)."""
        return self.credits / self.evaluated if self.evaluated else 0.0

    @property
    def seconds_per_row(self) -> float:
        return self.seconds / self.evaluated if self.evaluated else 0.0

    @property
    def delegation_rate(self) -> float:
        """Cascade escalation rate: oracle calls / cascaded rows."""
        return (self.cascade_oracle / self.cascade_rows
                if self.cascade_rows else 0.0)

    @property
    def dedup_hit_rate(self) -> float:
        return (self.dedup_hits / self.dedup_submitted
                if self.dedup_submitted else 0.0)

    @property
    def candidates_per_probe(self) -> float:
        """Semantic index: observed mean kNN candidates surfaced per
        probe row (0.0 when unobserved) — the learned candidate rate
        behind the index-vs-rewrite cost race."""
        return (self.index_candidates / self.index_probes
                if self.index_probes else 0.0)

    # -- (de)serialisation --------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PredObservation":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})

    def merge(self, other: "PredObservation") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))


# ---------------------------------------------------------------------------
# the store
# ---------------------------------------------------------------------------


class StatsStore:
    """Persistent map: predicate fingerprint -> `PredObservation`.

    One instance is shared by the `CostModel` (reads), the `Executor`
    (writes, during pilot sampling and full evaluation) and the
    `AisqlEngine` (cascade / pipeline roll-ups after each query).  With a
    ``path`` the store loads existing stats on construction and `save`
    writes them back as JSON — no other I/O happens implicitly.

    Thread-safe: under the serving runtime one store is written by every
    concurrent query session, so all recording, merging and persistence
    happens under a reentrant lock — two writers folding observations
    into the same fingerprint lose nothing.  Readers get live
    `PredObservation` objects; their counters are plain ints/floats
    updated only under the lock, so a read sees a consistent-enough
    snapshot for planning purposes.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._lock = threading.RLock()
        self._obs: Dict[str, PredObservation] = {}
        # fingerprint -> natural-language prompt text (kNN-transfer key);
        # only fingerprints with a registered text can donate priors
        self._prompts: Dict[str, str] = {}
        # bumped on every write — cheap cache-invalidation handle for
        # derived state (the cost model's transferred-prior cache)
        self.version = 0
        if path is not None and os.path.exists(path):
            self.load(path)

    # -- access --------------------------------------------------------
    def get(self, key: str) -> Optional[PredObservation]:
        return self._obs.get(key)

    def for_pred(self, pred: E.Expr) -> Optional[PredObservation]:
        return self._obs.get(predicate_fingerprint(pred))

    def confident(self, key: str, *, min_rows: int = 32) -> bool:
        """True when the fingerprint has at least ``min_rows`` observed
        row evaluations — the planner's trust threshold."""
        o = self._obs.get(key)
        return o is not None and o.evaluated >= min_rows

    def __len__(self) -> int:
        return len(self._obs)

    def keys(self):
        return self._obs.keys()

    def items(self) -> Iterator[Tuple[str, PredObservation]]:
        """Snapshot of ``(fingerprint, observation)`` pairs (taken under
        the lock, so concurrent writers never corrupt the iteration)."""
        with self._lock:
            return iter(list(self._obs.items()))

    def prompt_text(self, key: str) -> Optional[str]:
        return self._prompts.get(key)

    def prompt_texts(self) -> Dict[str, str]:
        with self._lock:
            return dict(self._prompts)

    def register_prompt(self, key: str, text: str) -> None:
        """Associate a fingerprint with its natural-language prompt text
        so it can donate (and receive) kNN-transferred priors."""
        if not text:
            return
        with self._lock:
            if self._prompts.get(key) != text:
                self._prompts[key] = text
                self.version += 1

    # -- recording -----------------------------------------------------
    def _entry(self, key: str) -> PredObservation:
        return self._obs.setdefault(key, PredObservation())

    def observe_predicate(self, key: str, *, evaluated: int, passed: int,
                          credits: float = 0.0, seconds: float = 0.0,
                          new_query: bool = False) -> PredObservation:
        """Fold one evaluation batch (rows, outcomes, spend) into ``key``."""
        with self._lock:
            o = self._entry(key)
            o.evaluated += int(evaluated)
            o.passed += int(passed)
            o.credits += float(credits)
            o.seconds += float(seconds)
            if new_query:
                o.queries += 1
            self.version += 1
            return o

    def note_query(self, keys) -> None:
        """Count one contributing query for each (already observed)
        fingerprint — called once per executed query by the executor."""
        with self._lock:
            for key in keys:
                o = self._obs.get(key)
                if o is not None:
                    o.queries += 1
            self.version += 1

    def observe_cascade(self, key: str, *, rows: int, oracle_calls: int
                        ) -> PredObservation:
        """Record SUPG-IT routing volume for a cascaded predicate."""
        with self._lock:
            o = self._entry(key)
            o.cascade_rows += int(rows)
            o.cascade_oracle += int(oracle_calls)
            self.version += 1
            return o

    def observe_index(self, key: str, *, probes: int, candidates: int
                      ) -> PredObservation:
        """Record semantic-index blocking volume (probe rows issued and
        candidates surfaced) for an `index_join_fingerprint` — the
        learned candidate-rate feedback the next cost race reads."""
        with self._lock:
            o = self._entry(key)
            o.index_probes += int(probes)
            o.index_candidates += int(candidates)
            self.version += 1
            return o

    def observe_pipeline(self, *, submitted: int, dedup_hits: int
                         ) -> PredObservation:
        """Record the request pipeline's dedup effectiveness (global)."""
        with self._lock:
            o = self._entry(PIPELINE_KEY)
            o.dedup_submitted += int(submitted)
            o.dedup_hits += int(dedup_hits)
            self.version += 1
            return o

    # -- persistence ---------------------------------------------------
    def save(self, path: Optional[str] = None) -> str:
        """Atomically persist the store as JSON.

        The payload is written to a same-directory temp file and moved
        into place with ``os.replace`` — a crash mid-write (power loss,
        kill -9) leaves either the previous complete file or the new
        complete file, never a truncated one that would poison the next
        engine's ``__init__``.
        """
        path = path or self.path
        if path is None:
            raise ValueError("StatsStore.save: no path configured")
        with self._lock:
            payload = {
                "format": 2,
                "observations": {k: o.to_dict()
                                 for k, o in self._obs.items()},
                "prompts": dict(self._prompts),
            }
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = f"{path}.tmp.{os.getpid()}"
        try:
            with open(tmp, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)
        return path

    @staticmethod
    def _canonical_key(key: str) -> str:
        """Map a legacy (pre-symmetry) fingerprint to its canonical form:
        old stores may hold ``AI_SIMILARITY|model|b|a`` twins whose
        evidence belongs under the sorted-side key."""
        if key.startswith("AI_SIMILARITY|"):
            parts = key.split("|")
            if len(parts) == 4:       # templates with '|' are not ours
                return "|".join(parts[:2] + sorted(parts[2:]))
        return key

    def load(self, path: Optional[str] = None) -> None:
        """Merge a persisted store into this one.

        Corrupt or partially-written files (the pre-atomic-save failure
        mode) warn and contribute nothing instead of raising — learned
        statistics are an optimization, never a reason a query engine
        fails to construct.  Legacy asymmetric ``AI_SIMILARITY`` twin
        keys are folded into their canonical (sorted-side) key.
        """
        path = path or self.path
        try:
            with open(path) as f:
                payload = json.load(f)
            if not isinstance(payload, dict):
                raise ValueError("stats payload is not an object")
        except (json.JSONDecodeError, ValueError, OSError) as exc:
            warnings.warn(
                f"StatsStore: ignoring unreadable stats file {path!r} "
                f"({exc}); starting from empty statistics", stacklevel=2)
            return
        if "observations" in payload:       # format 2
            observations = payload.get("observations", {})
            prompts = payload.get("prompts", {})
        else:                               # legacy flat format
            observations, prompts = payload, {}
        with self._lock:
            for k, d in observations.items():
                try:
                    obs = PredObservation.from_dict(d)
                except (TypeError, AttributeError):
                    warnings.warn(f"StatsStore: skipping malformed entry "
                                  f"{k!r} in {path!r}", stacklevel=2)
                    continue
                k = self._canonical_key(k)
                if k in self._obs:
                    self._obs[k].merge(obs)
                else:
                    self._obs[k] = obs
            for k, text in prompts.items():
                self._prompts.setdefault(self._canonical_key(k), str(text))
            self.version += 1

    def clear(self) -> None:
        with self._lock:
            self._obs.clear()
            self._prompts.clear()
            self.version += 1

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            return {k: o.to_dict() for k, o in self._obs.items()}
