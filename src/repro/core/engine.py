"""AisqlEngine — the public entry point: SQL text in, Table out.

Wires the full paper pipeline:

    parse (§3 dialect) -> build_plan -> AI-aware optimize (§5.1/§5.3)
        -> execute (§5.2 cascades, runtime adaptation) -> Table

plus the adaptive re-optimization loop: a `StatsStore` shared by the
cost model (reads) and the executor (writes) lets each query plan with
the previous queries' — and its own pilot sample's — observed
selectivity and cost numbers.  Per-query estimated-vs-actual accounting
is surfaced as `QueryReport.operators` and rendered by
`QueryReport.explain_analyze` (the paper's §4 instrumentation turned
into an EXPLAIN ANALYZE).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

from repro.core import expr as E
from repro.core import plan as P
from repro.core import sqlparse
from repro.core.cost import Catalog, CostModel
from repro.core.executor import ExecConfig, Executor
from repro.core.optimizer import Optimizer, OptimizerConfig
from repro.core.stats import StatsStore
from repro.inference.api import CortexClient
from repro.obs.trace import NOOP, activate, critical_path
from repro.tables.table import Table


@dataclasses.dataclass
class OperatorReport:
    """Estimated-vs-actual accounting for one AI/relational predicate.

    ``est_*`` fields are captured at plan time (before execution, so a
    warm `StatsStore` shows up as better estimates, not as hindsight);
    ``actual_*`` fields come from the executor's per-predicate telemetry
    and are None when the operator never ran (e.g. short-circuited).
    Units: selectivities are fractions, costs are credits per row.
    """
    operator: str                       # executor predicate key
    est_rows_in: float                  # rows the planner expected to see
    est_selectivity: float
    # Wilson interval on the observed selectivity; (0.0, 1.0) — maximum
    # uncertainty — when the store has no evidence (cold start)
    est_selectivity_ci: tuple = (0.0, 1.0)
    est_cost_per_row: float = 0.0
    # "observed" | "blended" | "transferred" | "default"
    est_source: str = "default"
    actual_rows_in: Optional[int] = None
    actual_selectivity: Optional[float] = None
    actual_cost_per_row: Optional[float] = None
    actual_credits: Optional[float] = None


@dataclasses.dataclass
class QueryReport:
    """Everything the engine observed about one ``sql()`` call."""
    sql: str
    plan: str                  # optimized plan, pretty-printed
    optimizer_trace: list      # one line per plan rewrite decision
    est_llm_cost: float        # planner's credit estimate (pre-execution)
    wall_seconds: float
    ai_calls: int              # LLM requests dispatched (post-dedup)
    ai_credits: float          # credits actually spent
    ai_seconds: float          # modelled model-serving seconds
    rows_out: int
    # semantic-operator runtime telemetry (None on an eager client):
    # batch-size histogram, dedup hit counts/rate, queue-wait seconds,
    # submitted vs dispatched request counts, flush causes
    pipeline: Optional[Dict[str, Any]] = None
    # estimated-vs-actual per predicate (EXPLAIN ANALYZE source data)
    operators: List[OperatorReport] = dataclasses.field(default_factory=list)
    # mid-query re-optimization events: pilot reorders, cascade bypasses
    reoptimizations: List[str] = dataclasses.field(default_factory=list)
    # pilot-sample telemetry: sampled_rows, cold/warm predicate counts,
    # reordered flag, per-predicate observed selectivity (+ Wilson CI)
    # and cost_per_row; None when no Filter was piloted
    pilot: Optional[Dict[str, Any]] = None
    # partition-pull telemetry (partitioned mode only): partitions
    # total/executed/cancelled, rows scanned/emitted, early_terminated,
    # cancelled (never-dispatched) request count; None otherwise
    partitions: Optional[Dict[str, Any]] = None
    # semantic-index telemetry: index joins / top-k prunes run, kNN
    # probes and candidates, verification calls, texts embedded and the
    # EMBED requests actually dispatched for them (store hits cost
    # none); None when no query operator touched the index subsystem
    semindex: Optional[Dict[str, Any]] = None
    # plan-memo telemetry: hit flag, optimizer cost races actually run
    # (zero on a hit), memo entry count; None when the memo is disabled
    memo: Optional[Dict[str, Any]] = None
    # span-tree dict (parse/optimize/execute/dispatch hierarchy with
    # per-span rows/tokens/credits attributes); None unless the engine
    # was built with a tracing-enabled Observability — see docs/
    # observability.md for the span taxonomy and export formats
    trace: Optional[Dict[str, Any]] = None

    def explain_analyze(self) -> str:
        """EXPLAIN ANALYZE-style rendering: the optimized plan followed
        by a per-operator estimated-vs-actual table, re-optimization
        events and the pilot summary."""
        lines = [self.plan,
                 f"-- est LLM cost: {self.est_llm_cost:.6g} credits; "
                 f"actual: {self.ai_credits:.6g} credits "
                 f"({self.ai_calls} calls)"]
        if self.operators:
            hdr = (f"{'operator':<44} {'est.sel':>8} {'act.sel':>8} "
                   f"{'est.c/row':>10} {'act.c/row':>10} {'rows':>7} "
                   f"{'source':>9}")
            lines += ["-- operators (estimated vs actual):", hdr,
                      "-" * len(hdr)]
            for op in self.operators:
                act_sel = ("-" if op.actual_selectivity is None
                           else f"{op.actual_selectivity:.3f}")
                act_c = ("-" if op.actual_cost_per_row is None
                         else f"{op.actual_cost_per_row:.2e}")
                rows = ("-" if op.actual_rows_in is None
                        else str(op.actual_rows_in))
                lines.append(
                    f"{op.operator[:44]:<44} {op.est_selectivity:>8.3f} "
                    f"{act_sel:>8} {op.est_cost_per_row:>10.2e} "
                    f"{act_c:>10} {rows:>7} {op.est_source:>9}")
        for ev in self.reoptimizations:
            lines.append(f"-- reoptimized: {ev}")
        if self.pilot:
            lines.append(
                f"-- pilot: {self.pilot['sampled_rows']} rows sampled, "
                f"{self.pilot['cold_predicates']} cold / "
                f"{self.pilot['warm_predicates']} warm predicate(s), "
                f"reordered={self.pilot['reordered']}")
        if self.partitions:
            p = self.partitions
            suffix = " (early termination)" if p["early_terminated"] else ""
            lines.append(
                f"-- partitions: {p['partitions_executed']}/"
                f"{p['partitions_total']} executed, "
                f"{p['partitions_cancelled']} cancelled, "
                f"{p['rows_scanned']} rows scanned -> "
                f"{p['rows_emitted']} emitted, "
                f"{p['cancelled_requests']} queued request(s) "
                f"withdrawn{suffix}")
        if self.semindex:
            s = self.semindex
            lines.append(
                f"-- semindex: {s['index_joins']} join(s) / "
                f"{s['index_topk']} top-k via index, {s['probes']} probes "
                f"-> {s['candidates']} candidates, "
                f"{s['verify_calls']} verification call(s), "
                f"{s['embed_texts']} texts embedded "
                f"({s['embed_llm_calls']} EMBED requests)")
        if self.memo:
            m = self.memo
            lines.append(
                f"-- plan-memo: {'hit' if m['hit'] else 'miss'}, "
                f"{m['cost_races']} cost race(s) run, "
                f"{m['entries']} plan(s) memoized")
        if self.trace:
            lines.append("-- " + critical_path(self.trace))
        return "\n".join(lines)


class AisqlEngine:
    """SQL front door.

    Args:
        catalog: table registry.
        client: a `CortexClient` (eager or pipelined).
        optimizer: planner policy (`OptimizerConfig`).
        executor: runtime policy (`ExecConfig`) — cascades, chunking,
            pilot sampling, cascade bypass.
        llm_judge: optional §5.3 rewrite-oracle veto hook.
        stats: a `StatsStore` to share across engines/queries; by default
            a fresh in-memory store is created (adaptivity within the
            engine's lifetime, nothing persisted).
        stats_path: convenience — build the store from this JSON file
            and save back after every query (ignored when ``stats`` is
            passed explicitly; call ``stats.save(path)`` yourself then).
        semindex: the semantic index subsystem — ``True`` for a fresh
            default `SemanticIndexManager`, a `SemIndexConfig` to
            configure one, or a manager instance to *share* (the serving
            runtime passes one manager to every tenant session).  None
            (default) disables index-assisted plans entirely: the
            optimizer never races `SemanticJoinIndex` and top-k
            similarity queries embed through the client directly.
        semindex_path: persistence prefix for the embedding store
            (``<path>.json`` + ``<path>.npz``), used when the manager is
            built here; saved after every query like ``stats_path``.
    """

    def __init__(self, catalog: Catalog, client: CortexClient, *,
                 optimizer: Optional[OptimizerConfig] = None,
                 executor: Optional[ExecConfig] = None,
                 llm_judge=None,
                 stats: Optional[StatsStore] = None,
                 stats_path: Optional[str] = None,
                 semindex=None,
                 semindex_path: Optional[str] = None,
                 obs=None):
        from repro.semindex import SemanticIndexManager, SemIndexConfig
        self.catalog = catalog
        self.client = client
        opt_cfg = optimizer or OptimizerConfig()
        self.stats_path = stats_path if stats is None else None
        self.stats = stats if stats is not None else StatsStore(stats_path)
        self.semindex_path = None
        if semindex is True:
            semindex = SemanticIndexManager(path=semindex_path)
            self.semindex_path = semindex_path
        elif isinstance(semindex, SemIndexConfig):
            semindex = SemanticIndexManager(semindex, path=semindex_path)
            self.semindex_path = semindex_path
        elif semindex is None and semindex_path is not None:
            semindex = SemanticIndexManager(path=semindex_path)
            self.semindex_path = semindex_path
        self.semindex = semindex or None
        self.cost = CostModel(catalog, default_model=client.default_model,
                              proxy_model=client.proxy_model,
                              embed_model=client.embed_model,
                              defaults=opt_cfg.cost_defaults,
                              stats=self.stats)
        self.cost.semindex = self.semindex
        # unlocks kNN prior transfer: with a semindex attached the cost
        # model can embed predicate prompts through this client
        self.cost.embed_client = client
        self.opt = Optimizer(catalog, cfg=opt_cfg, cost=self.cost,
                             llm_judge=llm_judge)
        self.exec = Executor(catalog, client, cfg=executor, cost=self.cost,
                             stats=self.stats, semindex=self.semindex)
        # keep the planner's TopK pricing on the path the runtime takes
        self.cost.topk_prefilter = self.exec.cfg.topk_prefilter
        # an `Observability` (repro.obs): span tracing for every sql()
        # call plus the metrics registry the executor records into.
        # None (default) keeps the no-op fast path everywhere.
        self.obs = obs
        self.exec.obs = obs
        self.last_report: Optional[QueryReport] = None

    # ------------------------------------------------------------------
    def plan(self, sql: str) -> P.PlanNode:
        """Parse + optimize; returns the plan without executing it."""
        return self.opt.optimize(P.build_plan(sqlparse.parse(sql)))

    def explain(self, sql: str) -> str:
        """Optimized plan + per-node estimated rows + optimizer trace."""
        node = self.plan(sql)
        lines = [node.pretty(annotate=self._annotate_est),
                 f"-- est LLM cost: {self.cost.est_llm_cost(node):.6g} credits"]
        lines += [f"-- {t}" for t in self.opt.trace]
        return "\n".join(lines)

    def _annotate_est(self, node: P.PlanNode) -> str:
        try:
            return f"[est {self.cost.est_rows(node):.0f} rows]"
        except (TypeError, KeyError):
            return ""

    # ------------------------------------------------------------------
    # estimated-vs-actual accounting
    # ------------------------------------------------------------------

    def _collect_estimates(self, node: P.PlanNode) -> List[OperatorReport]:
        """Capture the planner's per-predicate numbers *before* execution
        (a warm store changes these — that is the adaptive loop)."""
        out: List[OperatorReport] = []

        def visit(n: P.PlanNode):
            for c in n.children():
                visit(c)
            if isinstance(n, P.Filter):
                rows = self.cost.est_rows(n.child)
                for p in n.predicates:
                    out.append(self._op_estimate(p, rows))
                    rows *= self.cost.predicate_selectivity(p)
            elif isinstance(n, P.Join) and n.residual:
                pairs = self.cost.est_rows(
                    P.Join(n.left, n.right, n.equi, ()))
                for p in n.residual:
                    out.append(self._op_estimate(p, pairs))
                    pairs *= self.cost.predicate_selectivity(p)
            elif isinstance(n, P.SemanticJoinClassify):
                import math
                l = self.cost.est_rows(n.left)
                r = self.cost.est_rows(n.right)
                calls = l * max(1.0, math.ceil(r / n.max_labels_per_call))
                fake = E.AIClassify(n.prompt, labels=(), model=n.model)
                out.append(self._op_estimate(fake, calls))
            elif isinstance(n, P.SemanticJoinIndex):
                import math
                l = self.cost.est_rows(n.left)
                r = self.cost.est_rows(n.right)
                cand = self.cost.index_candidates_per_probe(n, r)
                calls = l * max(1.0, math.ceil(
                    cand / max(n.max_labels_per_call, 1)))
                out.append(self._op_estimate(
                    self.cost.index_verify_surrogate(n), calls))
            elif isinstance(n, (P.Sort, P.TopK)):
                rows = self.cost.est_rows(n.child)
                cand = (self.cost.topk_candidates(rows, n.n)
                        if isinstance(n, P.TopK) else rows)
                prefilters = (isinstance(n, P.TopK)
                              and self.cost.topk_prefilter_applies(n, rows))
                for i, sk in enumerate(n.keys):
                    if isinstance(sk.expr, E.AISimilarity):
                        # embedding-based: every row embeds once (store
                        # coverage already discounts the warm fraction)
                        out.append(self._op_estimate(
                            self.cost.resolved_similarity(sk.expr), rows))
                        continue
                    if not isinstance(sk.expr, E.AIScore):
                        continue
                    prefilter = prefilters and i == 0
                    if prefilter:
                        # proxy scores the full input, the ordering
                        # model only the escalated candidates
                        out.append(self._op_estimate(
                            self.cost.resolved_score(
                                sk.expr, self.cost.proxy_model), rows))
                        out.append(self._op_estimate(
                            self.cost.resolved_score(sk.expr), cand))
                    else:
                        # without the prefilter every key scores the
                        # full input; with it, secondary keys score
                        # only the escalated candidates
                        out.append(self._op_estimate(
                            self.cost.resolved_score(sk.expr),
                            cand if prefilters else rows))
        visit(node)
        return out

    def _op_estimate(self, pred: E.Expr, rows_in: float) -> OperatorReport:
        lo, hi = self.cost.selectivity_interval(pred)
        return OperatorReport(
            operator=self.exec._pred_key(pred),
            est_rows_in=rows_in,
            est_selectivity=self.cost.predicate_selectivity(pred),
            est_selectivity_ci=(round(lo, 4), round(hi, 4)),
            est_cost_per_row=self.cost.predicate_cost_per_row(pred),
            est_source=self.cost.estimate_source(pred))

    def _fill_actuals(self, ops: List[OperatorReport]) -> None:
        for op in ops:
            st = self.exec.pred_stats.get(op.operator)
            if st is None or not st.evaluated:
                continue
            op.actual_rows_in = st.evaluated
            op.actual_selectivity = st.selectivity
            op.actual_cost_per_row = st.credits / st.evaluated
            op.actual_credits = st.credits

    # ------------------------------------------------------------------
    def sql(self, sql: str, on_batch=None) -> Table:
        """Execute ``sql`` end to end; telemetry lands on
        ``self.last_report`` and feedback in the shared `StatsStore`.
        With ``on_batch`` (a callable taking a `Table`), incremental
        result batches are delivered as the executor produces them —
        the returned table and all telemetry are unchanged."""
        obs = self.obs
        tr = obs.tracer() if obs is not None and obs.enabled else NOOP
        before = self.client.snapshot()
        t0 = time.perf_counter()
        with activate(tr), tr.span("query", kind="query") as qsp:
            with tr.span("parse", kind="parse"):
                ast = P.build_plan(sqlparse.parse(sql))
            with tr.span("optimize", kind="optimize") as osp:
                node = self.opt.optimize(ast)
                if tr.enabled:
                    for line in self.opt.trace:
                        tr.event("optimize.rewrite", decision=line)
                    osp.set(memo_hit=getattr(self.opt, "memo_hit", False),
                            cost_races=getattr(self.opt, "cost_races", 0),
                            rewrites=len(self.opt.trace))
            # estimates are frozen pre-execution so est-vs-actual is
            # honest
            est_cost = self.cost.est_llm_cost(node)
            operators = self._collect_estimates(node)
            with tr.span("execute", kind="execute") as esp:
                try:
                    if on_batch is not None:
                        out = self.exec.execute_stream(node, on_batch)
                    else:
                        out = self.exec.execute(node)
                except Exception:
                    # a failed query must not leave queued requests
                    # behind: a later barrier (possibly another
                    # session's) would dispatch and bill them on behalf
                    # of a query that produced nothing
                    if self.client.pipeline is not None:
                        self.client.cancel_queued()
                    raise
                # drain any still-queued pipeline work
                self.client.flush()
                esp.set(rows_out=out.num_rows)
            delta = self.client.meter_delta(before)
            if tr.enabled:
                qsp.set(rows_out=out.num_rows, ai_calls=delta["ai_calls"],
                        credits=delta["ai_credits"])
        dt = time.perf_counter() - t0
        self._fill_actuals(operators)
        pipe = delta.get("pipeline")
        if pipe and pipe.get("submitted"):
            self.stats.observe_pipeline(submitted=pipe["submitted"],
                                        dedup_hits=pipe["dedup_hits"])
        memo_info = None
        if self.opt.cfg.enable_plan_memo and self.opt.cfg.mode != "none":
            memo_info = {"hit": self.opt.memo_hit,
                         "cost_races": self.opt.cost_races,
                         "entries": len(self.opt.memo)}
        self.last_report = QueryReport(
            sql=sql, plan=node.pretty(), optimizer_trace=list(self.opt.trace),
            est_llm_cost=est_cost, wall_seconds=dt,
            ai_calls=delta["ai_calls"], ai_credits=delta["ai_credits"],
            ai_seconds=delta["ai_seconds"], rows_out=out.num_rows,
            pipeline=pipe, operators=operators,
            reoptimizations=list(self.exec.reoptimizations),
            pilot=self.exec.pilot_telemetry,
            partitions=self.exec.partition_telemetry,
            semindex=self.exec.index_telemetry,
            memo=memo_info,
            trace=tr.to_dict() if tr.enabled else None)
        if self.stats_path is not None:
            self.stats.save(self.stats_path)
        if self.semindex_path is not None and self.semindex is not None:
            self.semindex.save(self.semindex_path)
        return out

    # telemetry passthroughs ------------------------------------------------
    @property
    def pred_stats(self):
        return self.exec.pred_stats

    @property
    def cascades(self):
        return self.exec.cascades
