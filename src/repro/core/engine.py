"""AisqlEngine — the public entry point: SQL text in, Table out.

Wires the full paper pipeline:

    parse (§3 dialect) -> build_plan -> AI-aware optimize (§5.1/§5.3)
        -> execute (§5.2 cascades, runtime adaptation) -> Table

Also exposes ``explain`` (optimized plan + optimizer trace + cost
estimates) and per-query telemetry (LLM calls / credits / seconds — the
paper's §4 instrumentation).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional  # noqa: F401 (QueryReport fields)

from repro.core import plan as P
from repro.core import sqlparse
from repro.core.cost import Catalog, CostModel
from repro.core.executor import ExecConfig, Executor
from repro.core.optimizer import Optimizer, OptimizerConfig
from repro.inference.api import CortexClient
from repro.tables.table import Table


@dataclasses.dataclass
class QueryReport:
    sql: str
    plan: str
    optimizer_trace: list
    est_llm_cost: float
    wall_seconds: float
    ai_calls: int
    ai_credits: float
    ai_seconds: float
    rows_out: int
    # semantic-operator runtime telemetry (None on an eager client):
    # batch-size histogram, dedup hit counts/rate, queue-wait seconds,
    # submitted vs dispatched request counts, flush causes
    pipeline: Optional[Dict[str, Any]] = None


class AisqlEngine:
    def __init__(self, catalog: Catalog, client: CortexClient, *,
                 optimizer: Optional[OptimizerConfig] = None,
                 executor: Optional[ExecConfig] = None,
                 llm_judge=None):
        self.catalog = catalog
        self.client = client
        self.cost = CostModel(catalog, default_model=client.default_model)
        self.opt = Optimizer(catalog, cfg=optimizer, cost=self.cost,
                             llm_judge=llm_judge)
        self.exec = Executor(catalog, client, cfg=executor, cost=self.cost)
        self.last_report: Optional[QueryReport] = None

    # ------------------------------------------------------------------
    def plan(self, sql: str) -> P.PlanNode:
        return self.opt.optimize(P.build_plan(sqlparse.parse(sql)))

    def explain(self, sql: str) -> str:
        node = self.plan(sql)
        lines = [node.pretty(),
                 f"-- est LLM cost: {self.cost.est_llm_cost(node):.6g} credits"]
        lines += [f"-- {t}" for t in self.opt.trace]
        return "\n".join(lines)

    def sql(self, sql: str) -> Table:
        before = self.client.snapshot()
        t0 = time.perf_counter()
        node = self.plan(sql)
        out = self.exec.execute(node)
        self.client.flush()        # drain any still-queued pipeline work
        dt = time.perf_counter() - t0
        delta = self.client.meter_delta(before)
        self.last_report = QueryReport(
            sql=sql, plan=node.pretty(), optimizer_trace=list(self.opt.trace),
            est_llm_cost=self.cost.est_llm_cost(node), wall_seconds=dt,
            ai_calls=delta["ai_calls"], ai_credits=delta["ai_credits"],
            ai_seconds=delta["ai_seconds"], rows_out=out.num_rows,
            pipeline=delta.get("pipeline"))
        return out

    # telemetry passthroughs ------------------------------------------------
    @property
    def pred_stats(self):
        return self.exec.pred_stats

    @property
    def cascades(self):
        return self.exec.cascades
