"""Expression AST for the AISQL dialect (paper §3).

Relational scalar expressions evaluate vectorised over a Table; the AI
operators (AI_FILTER / AI_CLASSIFY / AI_COMPLETE) are *not* evaluated here —
the executor owns them (batching, cascades, cost metering).  This module
only provides structure, column-reference analysis and prompt rendering.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.tables.table import FileRef, Table, fl_is_audio, fl_is_image


class Expr:
    def refs(self) -> Set[str]:
        raise NotImplementedError

    def is_ai(self) -> bool:
        return bool(ai_calls_in(self))


@dataclasses.dataclass(frozen=True)
class Column(Expr):
    name: str                      # possibly qualified: "p.abstract"

    def refs(self):
        return {self.name}


@dataclasses.dataclass(frozen=True)
class Literal(Expr):
    value: Any

    def refs(self):
        return set()


@dataclasses.dataclass(frozen=True)
class Star(Expr):
    def refs(self):
        return set()


@dataclasses.dataclass(frozen=True)
class BinOp(Expr):
    op: str                        # = != < <= > >= + - * /
    left: Expr
    right: Expr

    def refs(self):
        return self.left.refs() | self.right.refs()


@dataclasses.dataclass(frozen=True)
class Between(Expr):
    expr: Expr
    lo: Expr
    hi: Expr

    def refs(self):
        return self.expr.refs() | self.lo.refs() | self.hi.refs()


@dataclasses.dataclass(frozen=True)
class InList(Expr):
    expr: Expr
    values: Tuple[Any, ...]

    def refs(self):
        return self.expr.refs()


@dataclasses.dataclass(frozen=True)
class BoolOp(Expr):
    op: str                        # "and" | "or"
    args: Tuple[Expr, ...]

    def refs(self):
        out: Set[str] = set()
        for a in self.args:
            out |= a.refs()
        return out


@dataclasses.dataclass(frozen=True)
class Not(Expr):
    arg: Expr

    def refs(self):
        return self.arg.refs()


@dataclasses.dataclass(frozen=True)
class FuncCall(Expr):
    """Scalar builtin (FL_IS_IMAGE, FL_IS_AUDIO, LENGTH, ...)."""
    name: str
    args: Tuple[Expr, ...]

    def refs(self):
        out: Set[str] = set()
        for a in self.args:
            out |= a.refs()
        return out


@dataclasses.dataclass(frozen=True)
class Prompt(Expr):
    """PROMPT('template with {0} {1}', arg0, arg1) — §3.1/3.3."""
    template: str
    args: Tuple[Expr, ...]

    def refs(self):
        out: Set[str] = set()
        for a in self.args:
            out |= a.refs()
        return out

    def render(self, table: Table, rows: Optional[np.ndarray] = None
               ) -> List[str]:
        cols = [eval_expr(a, table, rows) for a in self.args]
        n = len(cols[0]) if cols else (
            len(rows) if rows is not None else table.num_rows)
        out = []
        for i in range(n):
            vals = [c[i] for c in cols]
            out.append(self.template.format(*vals))
        return out


@dataclasses.dataclass(frozen=True)
class AIFilter(Expr):
    """AI_FILTER(PROMPT(...)) or AI_FILTER('predicate', col) — §3.2."""
    prompt: Prompt
    model: Optional[str] = None

    def refs(self):
        return self.prompt.refs()

    @property
    def multimodal(self) -> bool:
        # heuristic mirror of the compiler: FILE-typed args => multimodal
        return any(isinstance(a, FuncCall) and a.name.startswith("FL_")
                   for a in self.prompt.args)


@dataclasses.dataclass(frozen=True)
class AIScore(Expr):
    """AI_SCORE(PROMPT(...)) — the model's confidence in [0, 1] that the
    prompt's statement holds for the row.  The semantic ORDER BY key:
    ``ORDER BY AI_SCORE(...) DESC LIMIT k`` is the paper's top-k search
    workload.  Reuses the SCORE request kind of AI_FILTER but returns the
    raw score instead of thresholding it."""
    prompt: Prompt
    model: Optional[str] = None

    def refs(self):
        return self.prompt.refs()


@dataclasses.dataclass(frozen=True)
class AIEmbed(Expr):
    """AI_EMBED(col) — the column's embedding vector (EMBED request
    kind, priced per input token on the embedding tier).  A projection
    item; also the building block AI_SIMILARITY reduces to."""
    arg: Expr
    model: Optional[str] = None

    def refs(self):
        return self.arg.refs()


@dataclasses.dataclass(frozen=True)
class AISimilarity(Expr):
    """AI_SIMILARITY(a, b) — cosine similarity of the two sides'
    embeddings, in [-1, 1].  Embedding-based by definition (no
    generative model): each side costs one EMBED request per distinct
    text, so a literal side embeds exactly once per query and the
    semantic index can answer ``ORDER BY AI_SIMILARITY(...) LIMIT k``
    without touching the inference tier at all."""
    left: Expr
    right: Expr
    model: Optional[str] = None

    def refs(self):
        return self.left.refs() | self.right.refs()


@dataclasses.dataclass(frozen=True)
class AIClassify(Expr):
    """AI_CLASSIFY(text, [labels...]) — §3.4."""
    text: Prompt
    labels: Tuple[str, ...] = ()
    labels_expr: Optional[Expr] = None   # label list from a column (rewrite)
    multi_label: bool = False
    model: Optional[str] = None

    def refs(self):
        out = self.text.refs()
        if self.labels_expr is not None:
            out |= self.labels_expr.refs()
        return out


@dataclasses.dataclass(frozen=True)
class AIComplete(Expr):
    prompt: Prompt
    model: Optional[str] = None
    max_tokens: int = 48

    def refs(self):
        return self.prompt.refs()


@dataclasses.dataclass(frozen=True)
class AggCall(Expr):
    """Aggregate in a SELECT list: COUNT/SUM/AVG/MIN/MAX or
    AI_AGG(col, instruction) / AI_SUMMARIZE_AGG(col)."""
    name: str
    args: Tuple[Expr, ...]
    instruction: Optional[str] = None

    def refs(self):
        out: Set[str] = set()
        for a in self.args:
            out |= a.refs()
        return out

    @property
    def is_ai(self) -> bool:  # type: ignore[override]
        return self.name in ("AI_AGG", "AI_SUMMARIZE_AGG")


@dataclasses.dataclass(frozen=True)
class SelectItem:
    expr: Expr
    alias: Optional[str] = None


# ---------------------------------------------------------------------------
# analysis helpers
# ---------------------------------------------------------------------------


def ai_calls_in(e: Expr) -> List[Expr]:
    out: List[Expr] = []

    def walk(x):
        if isinstance(x, (AIFilter, AIScore, AIClassify, AIComplete,
                          AIEmbed, AISimilarity)):
            out.append(x)
        if isinstance(x, AggCall) and x.name in ("AI_AGG", "AI_SUMMARIZE_AGG"):
            out.append(x)
        for f in dataclasses.fields(x) if dataclasses.is_dataclass(x) else []:
            v = getattr(x, f.name)
            if isinstance(v, Expr):
                walk(v)
            elif isinstance(v, tuple):
                for item in v:
                    if isinstance(item, Expr):
                        walk(item)
    walk(e)
    return out


def split_conjuncts(e: Optional[Expr]) -> List[Expr]:
    if e is None:
        return []
    if isinstance(e, BoolOp) and e.op == "and":
        out: List[Expr] = []
        for a in e.args:
            out.extend(split_conjuncts(a))
        return out
    return [e]


def conjoin(preds: Sequence[Expr]) -> Optional[Expr]:
    preds = list(preds)
    if not preds:
        return None
    if len(preds) == 1:
        return preds[0]
    return BoolOp("and", tuple(preds))


# ---------------------------------------------------------------------------
# vectorised evaluation of NON-AI expressions
# ---------------------------------------------------------------------------


def resolve_column(table: Table, name: str) -> str:
    if name in table:
        return name
    # unqualified reference: unique suffix match on "alias.col"
    matches = [c for c in table.column_names
               if c.endswith("." + name) or c == name]
    if len(matches) == 1:
        return matches[0]
    raise KeyError(f"column {name!r} not found (or ambiguous) in "
                   f"{table.column_names}")


_SCALAR_FUNCS = {
    "FL_IS_IMAGE": lambda col: np.asarray([fl_is_image(v) for v in col]),
    "FL_IS_AUDIO": lambda col: np.asarray([fl_is_audio(v) for v in col]),
    "LENGTH": lambda col: np.asarray([len(str(v)) for v in col]),
    "LOWER": lambda col: np.asarray([str(v).lower() for v in col], object),
    "UPPER": lambda col: np.asarray([str(v).upper() for v in col], object),
}


def eval_expr(e: Expr, table: Table, rows: Optional[np.ndarray] = None
              ) -> np.ndarray:
    """Evaluate a non-AI expression over (a subset of) a table."""
    n = table.num_rows if rows is None else len(rows)

    def col(name):
        resolved = resolve_column(table, name)
        if rows is None:
            return table.column(resolved)
        # segment-wise on chunked tables: touches only the chunks that
        # hold `rows`, never the whole column
        return table.gather(resolved, rows)

    if isinstance(e, Column):
        return col(e.name)
    if isinstance(e, Literal):
        return np.full(n, e.value, dtype=object if isinstance(e.value, str)
                       else None)
    if isinstance(e, BinOp):
        l = eval_expr(e.left, table, rows)
        r = eval_expr(e.right, table, rows)
        ops = {"=": lambda a, b: a == b, "!=": lambda a, b: a != b,
               "<": lambda a, b: a < b, "<=": lambda a, b: a <= b,
               ">": lambda a, b: a > b, ">=": lambda a, b: a >= b,
               "+": lambda a, b: a + b, "-": lambda a, b: a - b,
               "*": lambda a, b: a * b, "/": lambda a, b: a / b}
        return ops[e.op](l, r)
    if isinstance(e, Between):
        v = eval_expr(e.expr, table, rows)
        lo = eval_expr(e.lo, table, rows)
        hi = eval_expr(e.hi, table, rows)
        return (v >= lo) & (v <= hi)
    if isinstance(e, InList):
        v = eval_expr(e.expr, table, rows)
        allowed = set(e.values)
        return np.asarray([x in allowed for x in v])
    if isinstance(e, BoolOp):
        parts = [eval_expr(a, table, rows) for a in e.args]
        out = parts[0].astype(bool)
        for p in parts[1:]:
            out = (out & p.astype(bool)) if e.op == "and" else (out | p.astype(bool))
        return out
    if isinstance(e, Not):
        return ~eval_expr(e.arg, table, rows).astype(bool)
    if isinstance(e, FuncCall):
        fn = _SCALAR_FUNCS.get(e.name.upper())
        if fn is None:
            raise KeyError(f"unknown function {e.name}")
        return fn(eval_expr(e.args[0], table, rows))
    if isinstance(e, (AIFilter, AIScore, AIClassify, AIComplete, AIEmbed,
                      AISimilarity, AggCall)):
        raise RuntimeError(f"AI/aggregate expression reached eval_expr: {e}; "
                           "the executor must handle it")
    raise TypeError(f"cannot evaluate {type(e).__name__}")
