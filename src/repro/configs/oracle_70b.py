"""Cascade oracle model: Llama-3.3-70B-class dense GQA (paper §5.2)."""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="oracle-70b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500000.0,
    period=(ATTN,),
    grad_accum_steps=4,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="oracle-smoke",
        family="dense",
        num_layers=3,
        d_model=96,
        num_heads=6,
        num_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=512,
        period=(ATTN,),
    )
