"""Whisper-base: audio encoder-decoder [arXiv:2212.04356].

The conv frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings of shape [B, encoder_seq, d_model].
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,                 # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    use_layernorm=True,
    use_bias=True,
    use_rope=False,
    learned_pos_embed=True,
    tie_embeddings=True,
    is_encoder_decoder=True,
    max_pos_embed=33024,
    encoder_layers=6,
    encoder_seq=1500,
    frontend="frames",
    period=(ATTN,),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        family="audio",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        use_layernorm=True,
        use_bias=True,
        tie_embeddings=True,
        is_encoder_decoder=True,
        max_pos_embed=128,
        encoder_layers=2,
        encoder_seq=32,
        frontend="frames",
        period=(ATTN,),
    )
