"""Phi-3.5-MoE 42B (6.6B active): 16 experts, top-2
[hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.configs.base import ATTN, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,                  # unused for MoE layers; kept for reference
    vocab_size=32064,
    use_layernorm=True,
    rope_theta=10000.0,
    period=(ATTN,),
    moe=MoEConfig(
        num_experts=16,
        num_experts_per_tok=2,
        expert_d_ff=6400,
    ),
    grad_accum_steps=2,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi35-moe-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab_size=512,
        use_layernorm=True,
        period=(ATTN,),
        moe=MoEConfig(num_experts=4, num_experts_per_tok=2, expert_d_ff=96),
    )
