"""Qwen3-32B: dense GQA with qk-norm [hf:Qwen/Qwen3 family]."""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    period=(ATTN,),
    grad_accum_steps=4,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=160,
        vocab_size=512,
        qk_norm=True,
        period=(ATTN,),
    )
