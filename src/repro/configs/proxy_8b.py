"""Cascade proxy model: Llama-3.1-8B-class dense GQA (paper §5.2)."""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="proxy-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500000.0,
    period=(ATTN,),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="proxy-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        period=(ATTN,),
    )
