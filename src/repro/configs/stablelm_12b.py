"""StableLM-2-12B: dense GQA, parallel block, LayerNorm
[hf:stabilityai/stablelm-2-12b]."""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100352,
    use_layernorm=True,
    parallel_block=True,
    rope_theta=10000.0,
    period=(ATTN,),
    grad_accum_steps=2,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        use_layernorm=True,
        parallel_block=True,
        period=(ATTN,),
    )
