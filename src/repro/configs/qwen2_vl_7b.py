"""Qwen2-VL-7B backbone: GQA + M-RoPE [arXiv:2409.12191].

The vision tower is a STUB per the assignment: ``input_specs`` provides
precomputed patch embeddings; M-RoPE positions [B, S, 3] (t/h/w streams)
arrive as model input.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    use_bias=False,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),   # rotary half-dims per (t, h, w); sums to 64
    frontend="patches",
    num_patches=1024,
    period=(ATTN,),
    grad_accum_steps=2,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        mrope_sections=(4, 2, 2),
        frontend="patches",
        num_patches=16,
        period=(ATTN,),
    )
