"""Minitron-8B: width-pruned Nemotron-4 [arXiv:2407.14679]."""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    rope_theta=10000.0,
    period=(ATTN,),
    grad_accum_steps=2,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minitron-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=512,
        period=(ATTN,),
    )
