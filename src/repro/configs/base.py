"""Model/shape configuration system for the AISQL model zoo.

Every hosted architecture is described by a :class:`ModelConfig`.  Configs are
plain data (no jax imports) so they can be loaded by launchers before jax
device initialisation (important: the dry-run must set XLA_FLAGS before any
jax import).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Sequence, Tuple

# Block type tags used by the generic LM assembly (models/lm.py).
ATTN = "attn"          # global causal self-attention
LOCAL_ATTN = "local"   # sliding-window self-attention
RGLRU = "rglru"        # RG-LRU recurrent block (recurrentgemma)
RWKV = "rwkv6"         # RWKV-6 "Finch" time-mix block


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int                # routed experts (pre-padding)
    num_experts_per_tok: int        # top-k
    expert_d_ff: int                # per-expert hidden dim
    num_shared_experts: int = 0     # always-on shared experts
    shared_d_ff: int = 0            # hidden dim of the fused shared expert
    router_aux_loss: float = 0.001  # load-balance loss weight
    capacity_factor: float = 1.25   # per-expert token capacity multiplier
    padded_num_experts: int = 0     # experts padded up for even EP sharding

    def __post_init__(self):
        if self.padded_num_experts == 0:
            object.__setattr__(self, "padded_num_experts", self.num_experts)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    # --- attention options ---------------------------------------------
    qk_norm: bool = False
    use_bias: bool = False
    rope_theta: float = 10000.0
    attention_window: int = 0       # sliding window size for LOCAL_ATTN
    mrope_sections: Tuple[int, ...] = ()   # M-RoPE (qwen2-vl): rotary dims per (t,h,w)
    # --- norms / embeddings ---------------------------------------------
    use_rope: bool = True
    learned_pos_embed: bool = False # additive learned positions (whisper)
    max_pos_embed: int = 4096       # rows of the learned position table
    norm_eps: float = 1e-6
    use_layernorm: bool = False     # LayerNorm instead of RMSNorm (whisper, stablelm)
    parallel_block: bool = False    # attn+mlp in parallel (command-r, stablelm)
    tie_embeddings: bool = False
    scale_embedding: bool = False   # multiply embeddings by sqrt(d_model) (gemma)
    logit_softcap: float = 0.0
    # --- block pattern ----------------------------------------------------
    # The model is `num_periods` repetitions of `period` followed by `tail`.
    # Homogeneous models: period=("attn",), num_periods=num_layers, tail=().
    period: Tuple[str, ...] = (ATTN,)
    tail: Tuple[str, ...] = ()
    # --- MoE ---------------------------------------------------------------
    moe: Optional[MoEConfig] = None
    # --- recurrent families -------------------------------------------------
    lru_width: int = 0              # RG-LRU recurrence width (0 -> d_model)
    conv1d_width: int = 4           # temporal conv in RG-LRU block
    rwkv_head_size: int = 64        # RWKV6 per-head state size
    # --- encoder/decoder (whisper) -------------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500         # audio frames after the (stubbed) conv frontend
    # --- modality frontend stub ----------------------------------------------
    # "none": token ids. "frames": precomputed frame embeddings (audio).
    # "patches": precomputed patch embeddings prepended to token stream (vlm).
    frontend: str = "none"
    num_patches: int = 0            # vlm: patch positions prepended to the stream
    # --- numerics -------------------------------------------------------------
    dtype: str = "bfloat16"
    # --- training memory lever (per-arch default, overridable per run) -------
    grad_accum_steps: int = 1

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.lru_width == 0 and RGLRU in self.period + self.tail:
            object.__setattr__(self, "lru_width", self.d_model)

    # ---- derived ----
    @property
    def num_periods(self) -> int:
        body = self.num_layers - len(self.tail)
        assert body % len(self.period) == 0, (
            f"{self.name}: {self.num_layers} layers does not decompose into "
            f"{self.period} * k + {self.tail}")
        return body // len(self.period)

    @property
    def block_pattern(self) -> Tuple[str, ...]:
        return self.period * self.num_periods + self.tail

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def attention_free(self) -> bool:
        return not any(b in (ATTN, LOCAL_ATTN) for b in self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if sequence mixing cost is sub-quadratic in seq_len (may run
        the long_500k shape)."""
        return not any(b == ATTN for b in self.block_pattern)

    def param_count(self) -> int:
        """Analytic parameter count (matches init to within ties/padding)."""
        d, v = self.d_model, self.vocab_size
        n = v * d                                     # embedding
        if not self.tie_embeddings:
            n += d * v                                # lm_head
        n += d                                        # final norm
        for blk in self.block_pattern:
            n += self._block_params(blk)
        if self.is_encoder_decoder:
            n += self.encoder_layers * self._block_params(ATTN)
            # cross attention per decoder layer
            n += self.num_layers * (2 * d * self.q_dim + 2 * d * self.kv_dim + d)
            n += d                                    # encoder final norm
        return n

    def _block_params(self, blk: str) -> int:
        d = self.d_model
        n = 2 * d                                     # two pre-norms
        if blk in (ATTN, LOCAL_ATTN):
            n += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qk_norm:
                n += 2 * self.head_dim
            n += self._mlp_params()
        elif blk == RGLRU:
            w = self.lru_width
            n += 2 * d * w + w * d                    # x/gate in-proj, out-proj
            n += self.conv1d_width * w                # temporal conv
            n += 3 * w                                # a_param, input_gate, a_gate (diag)
            n += self._mlp_params()
        elif blk == RWKV:
            # time-mix: r,k,v,g,w projections + out; small lora-ish decay nets folded in
            n += 5 * d * d + d * d
            n += 6 * d                                # per-channel mix/decay/bonus params
            # channel-mix
            n += d * self.d_ff + self.d_ff * d + 2 * d
        else:
            raise ValueError(blk)
        return n

    def _mlp_params(self) -> int:
        d = self.d_model
        if self.moe is not None:
            m = self.moe
            n = d * m.num_experts                     # router
            n += m.num_experts * (3 * d * m.expert_d_ff)
            if m.num_shared_experts:
                n += 3 * d * m.shared_d_ff
            return n
        return 3 * d * self.d_ff                      # gated mlp (wi, wg, wo)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        full_expert = 3 * self.d_model * m.expert_d_ff
        inactive = (m.num_experts - m.num_experts_per_tok) * full_expert
        n_moe_layers = sum(1 for b in self.block_pattern if b in (ATTN, LOCAL_ATTN))
        return self.param_count() - inactive * n_moe_layers


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
)

SHAPE_BY_NAME = {s.name: s for s in SHAPES}

ARCH_IDS = (
    "recurrentgemma-9b",
    "command-r-35b",
    "qwen3-32b",
    "stablelm-12b",
    "minitron-8b",
    "whisper-base",
    "phi3.5-moe-42b-a6.6b",
    "qwen2-moe-a2.7b",
    "qwen2-vl-7b",
    "rwkv6-1.6b",
)

# extra configs used by the paper reproduction (cascade proxy/oracle pair)
EXTRA_IDS = ("proxy-8b", "oracle-70b")

_MODULE_FOR = {
    "recurrentgemma-9b": "recurrentgemma_9b",
    "command-r-35b": "command_r_35b",
    "qwen3-32b": "qwen3_32b",
    "stablelm-12b": "stablelm_12b",
    "minitron-8b": "minitron_8b",
    "whisper-base": "whisper_base",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "qwen2-moe-a2.7b": "qwen2_moe_a27b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "rwkv6-1.6b": "rwkv6_16b",
    "proxy-8b": "proxy_8b",
    "oracle-70b": "oracle_70b",
}


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULE_FOR:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULE_FOR)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    """Reduced config of the same family for CPU smoke tests."""
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch]}")
    return mod.smoke_config()


def all_configs() -> Sequence[ModelConfig]:
    return [get_config(a) for a in ARCH_IDS]


def cells(arch: str) -> Sequence[ShapeSpec]:
    """The shape cells that apply to an arch (with assignment-mandated skips)."""
    cfg = get_config(arch)
    out = []
    for s in SHAPES:
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue  # pure full-attention arch: skip per assignment
        out.append(s)
    return out


def skipped_cells(arch: str):
    cfg = get_config(arch)
    return [(s, "skip(full-attn)") for s in SHAPES
            if s.name == "long_500k" and not cfg.sub_quadratic]
