"""RWKV-6 "Finch" 1.6B: attention-free, data-dependent decay
[arXiv:2404.05892]."""
from repro.configs.base import RWKV, ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,                # wkv heads: d_model / rwkv_head_size
    num_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    use_layernorm=True,
    period=(RWKV,),
    rwkv_head_size=64,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        use_layernorm=True,
        period=(RWKV,),
        rwkv_head_size=16,
    )
