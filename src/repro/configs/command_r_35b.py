"""Command-R 35B: dense GQA, parallel attn+mlp block, no bias
[hf:CohereForAI/c4ai-command-r-v01]."""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    use_layernorm=True,
    parallel_block=True,
    tie_embeddings=True,
    rope_theta=8_000_000.0,
    period=(ATTN,),
    grad_accum_steps=4,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="command-r-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=8,
        num_kv_heads=2,
        head_dim=8,
        d_ff=160,
        vocab_size=512,
        use_layernorm=True,
        parallel_block=True,
        tie_embeddings=True,
        period=(ATTN,),
    )
