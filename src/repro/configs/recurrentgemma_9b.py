"""RecurrentGemma-9B: RG-LRU + local attention, 1:2 pattern [arXiv:2402.19427].

38 layers decompose as 12 × (rglru, rglru, attn) + (rglru, rglru) tail,
preserving the 1:2 attention:recurrence ratio at exactly 38 layers.
"""
from repro.configs.base import ATTN, LOCAL_ATTN, RGLRU, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    attention_window=2048,
    rope_theta=10000.0,
    scale_embedding=True,
    tie_embeddings=True,
    logit_softcap=30.0,
    period=(RGLRU, RGLRU, LOCAL_ATTN),
    tail=(RGLRU, RGLRU),
    lru_width=4096,
    grad_accum_steps=2,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke",
        family="hybrid",
        num_layers=5,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        attention_window=32,
        scale_embedding=True,
        tie_embeddings=True,
        logit_softcap=30.0,
        period=(RGLRU, RGLRU, LOCAL_ATTN),
        tail=(RGLRU, RGLRU),
        lru_width=64,
    )
