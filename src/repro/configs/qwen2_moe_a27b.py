"""Qwen1.5/2-MoE-A2.7B: 60 routed experts top-4 + 4 shared experts
[hf:Qwen/Qwen1.5-MoE-A2.7B].

Routed experts are padded 60 -> 64 so expert-parallelism divides the 16-way
``model`` mesh axis evenly; the 4 pad experts are masked out of routing.
"""
from repro.configs.base import ATTN, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151936,
    use_bias=False,
    rope_theta=1_000_000.0,
    period=(ATTN,),
    moe=MoEConfig(
        num_experts=60,
        num_experts_per_tok=4,
        expert_d_ff=1408,
        num_shared_experts=4,
        shared_d_ff=5632,       # 4 shared experts fused: 4 * 1408
        padded_num_experts=64,
    ),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=64,
        vocab_size=512,
        period=(ATTN,),
        moe=MoEConfig(
            num_experts=6, num_experts_per_tok=2, expert_d_ff=64,
            num_shared_experts=1, shared_d_ff=128, padded_num_experts=8,
        ),
    )
