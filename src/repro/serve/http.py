"""HTTP/JSON wire protocol over the `ServingEngine` (stdlib only).

The paper's engine is a *service*: clients reach it over a REST
surface.  This module is that surface for the reproduction — a
dependency-free, threaded ``http.server`` front door that maps the
library's exceptions onto a structured error contract and the
executor's partition-incremental results onto chunked NDJSON streams.

Endpoints (all JSON):

  * ``GET  /v1/healthz``         — liveness probe
  * ``GET  /v1/report``          — the live `ServingReport`
  * ``GET  /v1/metrics``         — the engine's metrics registry in
    Prometheus text exposition format (the one non-JSON endpoint)
  * ``GET  /v1/trace/<query_id>`` — the span tree of a recent query
    (bounded ring; 404 once evicted or for an unknown id)
  * ``GET  /v1/semantic-model``  — the attached `SemanticModel` (404
    when the server has none)
  * ``POST /v1/query``           — ``{"sql": ..., "stream": bool}``;
    buffered JSON result, or NDJSON lines (``schema`` / ``row`` /
    ``summary`` / ``error`` kinds) streamed as partitions complete
  * ``POST /v1/nl2sql``          — ``{"question": ..., "execute":
    bool}``; compiles via the `NL2SQLOperator` validation loop

Authentication is per-tenant bearer tokens: ``HttpConfig.tokens`` maps
token → tenant name, and the resolved tenant is the one whose
`TenantPolicy` admits (and is billed for) the query.  With no tokens
configured the server is open and the tenant comes from the request
body (``"tenant"``, default ``"default"``).

The error contract (rendered in docs/http-api.md and validated by
``tests/test_docs.py`` against `ERROR_CONTRACT`): every failure is
``{"error": {"code", "message", ...}}`` with the HTTP status
determined by the mapped exception — `ParseError` → 400 with character
position and caret, `AdmissionError` → 429, a token-bucket rejection →
429 with ``Retry-After``, `RequestFailed` → 503.
"""
from __future__ import annotations

import dataclasses
import http.client
import json
import math
import socket
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.cost import UnknownTableError
from repro.core.serving import AdmissionError, ServingEngine
from repro.core.sqlparse import ParseError
from repro.inference.pipeline import RequestFailed
from repro.serve.semantic_model import (NL2SQLError, NL2SQLOperator,
                                        SemanticModel,
                                        SemanticValidationError)
from repro.tables.table import Table

# code -> (HTTP status, meaning); docs/http-api.md renders this table
# and tests/test_docs.py asserts the two stay in sync
ERROR_CONTRACT: Dict[str, Tuple[int, str]] = {
    "unauthorized": (401, "missing or unknown bearer token"),
    "not_found": (404, "unknown endpoint"),
    "bad_request": (400, "malformed JSON body or missing field"),
    "invalid_sql": (400, "SQL failed to parse or validate; the body "
                         "carries pos, token and a caret snippet"),
    "unknown_table": (400, "query references a table the catalog does "
                           "not have"),
    "nl2sql_rejected": (422, "no compilation attempt survived the "
                             "parse/optimize/semantic validation loop"),
    "throttled": (429, "tenant token bucket is empty; Retry-After "
                       "gives seconds until a token refills"),
    "budget_exhausted": (429, "tenant credit budget exhausted, or the "
                              "tenant is paused"),
    "backend_unavailable": (503, "an inference request exhausted its "
                                 "retries"),
    "shutting_down": (503, "the server (or its engine) is closed"),
    "timeout": (504, "query did not finish within the configured "
                     "timeout"),
    "internal": (500, "unexpected server error"),
}


class HttpError(Exception):
    """A failure with a wire representation (status + code + body)."""

    def __init__(self, code: str, message: str, *,
                 retry_after_s: Optional[float] = None,
                 extra: Optional[Dict[str, Any]] = None):
        if code not in ERROR_CONTRACT:
            raise ValueError(f"unknown error code {code!r}")
        self.status = ERROR_CONTRACT[code][0]
        self.code = code
        self.message = message
        self.retry_after_s = retry_after_s
        self.extra = extra or {}
        super().__init__(f"{self.status} {code}: {message}")

    def body(self) -> Dict[str, Any]:
        err: Dict[str, Any] = {"code": self.code, "message": self.message}
        err.update(self.extra)
        return {"error": err}


def error_for(exc: Exception, *,
              default_retry_s: float = 1.0) -> HttpError:
    """Map a library exception onto the wire error contract."""
    if isinstance(exc, HttpError):
        return exc
    if isinstance(exc, ParseError):
        return HttpError("invalid_sql", exc.message, extra={
            "pos": exc.pos, "token": exc.token, "caret": exc.caret()})
    if isinstance(exc, NL2SQLError):
        return HttpError("nl2sql_rejected", str(exc),
                         extra={"rejected_sql": exc.last_sql})
    if isinstance(exc, SemanticValidationError):
        return HttpError("invalid_sql", str(exc))
    if isinstance(exc, AdmissionError):
        return HttpError("budget_exhausted", str(exc),
                         retry_after_s=default_retry_s)
    if isinstance(exc, RequestFailed):
        return HttpError("backend_unavailable", str(exc),
                         retry_after_s=default_retry_s)
    if isinstance(exc, UnknownTableError):
        # only the catalog's own miss is client error; a bare KeyError
        # from anywhere else is a server bug and falls through to 500
        return HttpError("unknown_table", str(exc))
    if isinstance(exc, TimeoutError):
        return HttpError("timeout", str(exc))
    if isinstance(exc, RuntimeError) and "closed" in str(exc):
        return HttpError("shutting_down", str(exc))
    return HttpError("internal", f"{type(exc).__name__}: {exc}")


# ---------------------------------------------------------------------------
# JSON rendering
# ---------------------------------------------------------------------------


def _py(v: Any) -> Any:
    """A JSON-safe Python value for one table cell."""
    if isinstance(v, np.integer):
        return int(v)
    if isinstance(v, np.floating):
        return float(v)
    if isinstance(v, (np.bool_, bool)):
        return bool(v)
    if isinstance(v, np.ndarray):
        return [_py(x) for x in v]
    if isinstance(v, (int, float, str)) or v is None:
        return v
    return str(v)


def table_rows(table: Table) -> Tuple[List[str], List[List[Any]]]:
    """``(column names, row-major JSON-safe values)`` for a result."""
    cols = list(table.column_names)
    rows = [[_py(table.column(c)[i]) for c in cols]
            for i in range(table.num_rows)]
    return cols, rows


def _dumps(obj: Any) -> bytes:
    return json.dumps(obj, default=_py).encode("utf-8")


# ---------------------------------------------------------------------------
# server
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class HttpConfig:
    """Wire-level policy for an `AisqlHttpServer`."""
    host: str = "127.0.0.1"
    port: int = 0               # 0 = ephemeral (read server.port after start)
    # bearer token -> tenant name; empty = open server (tenant from the
    # request body, default "default")
    tokens: Dict[str, str] = dataclasses.field(default_factory=dict)
    # shed load instead of queueing when the tenant's token bucket is
    # empty: 429 + Retry-After, the wire-correct overload behaviour
    throttle: bool = True
    # Retry-After for 429/503 responses without a better number
    default_retry_after_s: float = 1.0
    # server-side cap on one query's wall time
    request_timeout_s: float = 120.0


class _Server(ThreadingHTTPServer):
    daemon_threads = True
    allow_reuse_address = True
    app: "AisqlHttpServer"


class AisqlHttpServer:
    """The HTTP front door: wraps a `ServingEngine` (and optionally an
    `NL2SQLOperator`) behind the endpoints above.  Usable as a context
    manager; ``stop()`` shuts the listener down and leaves the engine
    to its owner (`ServingEngine.close` is idempotent, so closing both
    in either order is safe)."""

    def __init__(self, engine: ServingEngine, *,
                 nl2sql: Optional[NL2SQLOperator] = None,
                 semantic_model: Optional[SemanticModel] = None,
                 cfg: Optional[HttpConfig] = None):
        self.engine = engine
        self.cfg = cfg or HttpConfig()
        self.nl2sql = nl2sql
        self.semantic_model = semantic_model or (
            nl2sql.model if nl2sql is not None else None)
        # the operator's client is not a per-session object; serialize
        self._nl_lock = threading.Lock()
        self._httpd = _Server((self.cfg.host, self.cfg.port), _Handler)
        self._httpd.app = self
        self.host, self.port = self._httpd.server_address[:2]
        self._thread: Optional[threading.Thread] = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "AisqlHttpServer":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._httpd.serve_forever,
                name="aisql-http", daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is not None:
            self._httpd.shutdown()
            self._thread.join(timeout=10.0)
            self._thread = None
        self._httpd.server_close()

    def __enter__(self) -> "AisqlHttpServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- request-level logic (called from handler threads) -------------
    def resolve_tenant(self, auth_header: Optional[str],
                       body: Dict[str, Any]) -> str:
        if self.cfg.tokens:
            token = None
            if auth_header and auth_header.startswith("Bearer "):
                token = auth_header[len("Bearer "):].strip()
            tenant = self.cfg.tokens.get(token) if token else None
            if tenant is None:
                raise HttpError("unauthorized",
                                "missing or unknown bearer token")
            return tenant
        return str(body.get("tenant", "default"))

    def check_throttle(self, tenant: str) -> None:
        """Load shedding: when the tenant's bucket is empty, answer 429
        + Retry-After instead of queueing (the library path would
        requeue the ticket until a token refills)."""
        if not self.cfg.throttle:
            return
        meter = self.engine.tenant(tenant)
        ok, shortfall = meter.bucket.peek()
        if not ok and meter.bucket.rate > 0.0:
            raise HttpError(
                "throttled",
                f"tenant {tenant!r} is over its query rate "
                f"({meter.bucket.rate:.4g}/s)",
                retry_after_s=shortfall)


# ---------------------------------------------------------------------------
# the request handler
# ---------------------------------------------------------------------------


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    # keep-alive + small JSON responses interact badly with Nagle /
    # delayed-ACK (a flat ~40ms stall per request on loopback)
    disable_nagle_algorithm = True
    server: _Server

    # silence the default stderr request log
    def log_message(self, fmt, *args):  # noqa: D102
        pass

    @property
    def app(self) -> AisqlHttpServer:
        return self.server.app

    # -- plumbing ------------------------------------------------------
    def _send_json(self, status: int, obj: Any,
                   headers: Optional[Dict[str, str]] = None) -> None:
        data = _dumps(obj)
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(data)))
        for k, v in (headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(data)

    def _send_text(self, status: int, text: str,
                   content_type: str) -> None:
        data = text.encode("utf-8")
        self.send_response(status)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def _send_error_obj(self, err: HttpError) -> None:
        headers = {}
        if err.retry_after_s is not None:
            headers["Retry-After"] = str(
                max(int(math.ceil(err.retry_after_s)), 1))
        self._send_json(err.status, err.body(), headers)

    def _body(self) -> Dict[str, Any]:
        length = int(self.headers.get("Content-Length") or 0)
        raw = self.rfile.read(length) if length else b""
        if not raw:
            return {}
        try:
            body = json.loads(raw)
        except ValueError:
            raise HttpError("bad_request", "request body is not JSON")
        if not isinstance(body, dict):
            raise HttpError("bad_request",
                            "request body must be a JSON object")
        return body

    # -- chunked NDJSON ------------------------------------------------
    def _begin_stream(self) -> None:
        # past this point the status line is on the wire: failures must
        # become a terminal {"kind": "error"} chunk, never a second
        # send_response (see do_POST)
        self._streaming = True
        self.send_response(200)
        self.send_header("Content-Type", "application/x-ndjson")
        self.send_header("Transfer-Encoding", "chunked")
        self.end_headers()

    def _chunk(self, obj: Any) -> None:
        data = _dumps(obj) + b"\n"
        self.wfile.write(f"{len(data):X}\r\n".encode("ascii")
                         + data + b"\r\n")
        self.wfile.flush()

    def _end_stream(self) -> None:
        self.wfile.write(b"0\r\n\r\n")
        self.wfile.flush()

    # -- routing -------------------------------------------------------
    def do_GET(self) -> None:  # noqa: N802
        try:
            if self.path == "/v1/healthz":
                self._send_json(200, {"status": "ok"})
            elif self.path == "/v1/report":
                report = self.app.engine.report()
                self._send_json(200, dataclasses.asdict(report))
            elif self.path == "/v1/metrics":
                text = self.app.engine.obs.registry.render_prometheus()
                self._send_text(200, text,
                                "text/plain; version=0.0.4; charset=utf-8")
            elif self.path.startswith("/v1/trace/"):
                qid = self.path[len("/v1/trace/"):]
                tree = self.app.engine.obs.ring.get(qid)
                if tree is None:
                    raise HttpError(
                        "not_found",
                        f"no trace for query {qid!r} (never traced, "
                        f"or evicted from the ring)")
                self._send_json(200, {"query_id": qid, "trace": tree})
            elif self.path == "/v1/semantic-model":
                model = self.app.semantic_model
                if model is None:
                    raise HttpError("not_found",
                                    "no semantic model attached")
                self._send_json(200, model.to_dict())
            else:
                raise HttpError("not_found",
                                f"unknown endpoint {self.path!r}")
        except Exception as e:
            self._send_error_obj(error_for(
                e, default_retry_s=self.app.cfg.default_retry_after_s))

    def do_POST(self) -> None:  # noqa: N802
        self._streaming = False
        try:
            body = self._body()
            tenant = self.app.resolve_tenant(
                self.headers.get("Authorization"), body)
            if self.path == "/v1/query":
                self._handle_query(tenant, body)
            elif self.path == "/v1/nl2sql":
                self._handle_nl2sql(tenant, body)
            else:
                raise HttpError("not_found",
                                f"unknown endpoint {self.path!r}")
        except Exception as e:
            err = error_for(
                e, default_retry_s=self.app.cfg.default_retry_after_s)
            if not self._streaming:
                self._send_error_obj(err)
                return
            # the chunked response already started: a second status line
            # would corrupt the keep-alive framing, so finish the body
            # with a terminal error event instead — and if even that
            # write fails, drop the connection
            try:
                self._chunk({"kind": "error", **err.body()["error"]})
                self._end_stream()
            except Exception:
                self.close_connection = True

    # -- endpoints -----------------------------------------------------
    def _handle_query(self, tenant: str, body: Dict[str, Any]) -> None:
        sql = body.get("sql")
        if not isinstance(sql, str) or not sql.strip():
            raise HttpError("bad_request", 'missing "sql" string field')
        self.app.check_throttle(tenant)
        if body.get("stream"):
            self._stream_query(tenant, sql)
        else:
            self._buffered_query(tenant, sql)

    def _buffered_query(self, tenant: str, sql: str) -> None:
        app = self.app
        ticket = app.engine.submit(tenant, sql)
        table = ticket.result(timeout=app.cfg.request_timeout_s)
        cols, rows = table_rows(table)
        payload: Dict[str, Any] = {
            "columns": cols, "rows": rows, "row_count": len(rows),
            "tenant": tenant, "query_id": ticket.query_id,
        }
        if ticket.report is not None:
            payload["stats"] = {
                "wall_s": ticket.wall_s,
                "queue_wait_s": ticket.queue_wait_s,
                "ai_calls": ticket.report.ai_calls,
                "ai_credits": ticket.report.ai_credits,
            }
        self._send_json(200, payload)

    def _stream_query(self, tenant: str, sql: str) -> None:
        """NDJSON streaming: the first failure (parse error, admission,
        backend) surfaces as a proper HTTP status — the stream only
        starts once the first batch exists; failures after that become
        a terminal ``{"kind": "error"}`` line."""
        app = self.app
        ticket = app.engine.submit(tenant, sql, stream=True)
        gen = ticket.batches(timeout=app.cfg.request_timeout_s)
        try:
            first = next(gen, None)
        except Exception:
            # error before any batch: the ticket is done; re-raise the
            # query's error for the normal status mapping
            raise
        if first is None:
            # no batches at all: either an empty result or nothing
            # streamed — fall back to the final table (also surfaces
            # errors with a proper status)
            table = ticket.result(timeout=app.cfg.request_timeout_s)
            cols, rows = table_rows(table)
            self._begin_stream()
            self._chunk({"kind": "schema", "columns": cols,
                         "tenant": tenant})
            for row in rows:
                self._chunk({"kind": "row", "values": row})
            self._emit_summary(ticket, len(rows))
            self._end_stream()
            return
        cols, rows = table_rows(first)
        self._begin_stream()
        self._chunk({"kind": "schema", "columns": cols, "tenant": tenant})
        count = 0
        for row in rows:
            self._chunk({"kind": "row", "values": row})
            count += 1
        # failures from here on (batch iteration, chunk writes, the
        # summary) propagate to do_POST, which sees the started stream
        # and emits a terminal {"kind": "error"} chunk
        for batch in gen:
            _, rows = table_rows(batch)
            for row in rows:
                self._chunk({"kind": "row", "values": row})
                count += 1
        self._emit_summary(ticket, count)
        self._end_stream()

    def _emit_summary(self, ticket, count: int) -> None:
        summary: Dict[str, Any] = {"kind": "summary", "row_count": count,
                                   "wall_s": ticket.wall_s,
                                   "query_id": ticket.query_id}
        if ticket.report is not None:
            summary["ai_calls"] = ticket.report.ai_calls
            summary["ai_credits"] = ticket.report.ai_credits
        self._chunk(summary)

    def _handle_nl2sql(self, tenant: str, body: Dict[str, Any]) -> None:
        app = self.app
        if app.nl2sql is None:
            raise HttpError("not_found", "no NL2SQL operator attached")
        question = body.get("question")
        if not isinstance(question, str) or not question.strip():
            raise HttpError("bad_request",
                            'missing "question" string field')
        with app._nl_lock:
            sql = app.nl2sql.compile(question)
        if not body.get("execute"):
            self._send_json(200, {"sql": sql, "tenant": tenant})
            return
        app.check_throttle(tenant)
        ticket = app.engine.submit(tenant, sql)
        table = ticket.result(timeout=app.cfg.request_timeout_s)
        cols, rows = table_rows(table)
        self._send_json(200, {
            "sql": sql, "columns": cols, "rows": rows,
            "row_count": len(rows), "tenant": tenant})


# ---------------------------------------------------------------------------
# client
# ---------------------------------------------------------------------------


class HttpStatusError(RuntimeError):
    """A non-2xx response the client did not retry away."""

    def __init__(self, status: int, body: Dict[str, Any]):
        self.status = status
        self.body = body
        err = body.get("error", {}) if isinstance(body, dict) else {}
        self.code = err.get("code", "unknown")
        super().__init__(f"HTTP {status} {self.code}: "
                         f"{err.get('message', body)}")


class AisqlHttpClient:
    """Minimal stdlib client for the server above.

    One `http.client.HTTPConnection` per client instance (use one
    client per thread).  429 responses are retried up to
    ``max_retries`` times honouring ``Retry-After``; connection
    failures are retried for GETs only (the server may already have
    executed a POST whose response was lost); everything else non-2xx
    raises `HttpStatusError`."""

    def __init__(self, host: str, port: int, *,
                 token: Optional[str] = None, tenant: Optional[str] = None,
                 timeout: float = 60.0, max_retries: int = 4,
                 max_retry_wait_s: float = 2.0):
        self.host, self.port = host, port
        self.token = token
        self.tenant = tenant
        self.timeout = timeout
        self.max_retries = max_retries
        self.max_retry_wait_s = max_retry_wait_s
        self.throttled_retries = 0      # 429s absorbed by waiting
        self._conn: Optional[http.client.HTTPConnection] = None

    # -- plumbing ------------------------------------------------------
    def _connection(self) -> http.client.HTTPConnection:
        if self._conn is None:
            conn = http.client.HTTPConnection(
                self.host, self.port, timeout=self.timeout)
            conn.connect()
            # mirror the server's TCP_NODELAY: without it each pipelined
            # request eats a Nagle/delayed-ACK round trip
            conn.sock.setsockopt(
                socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._conn = conn
        return self._conn

    def close(self) -> None:
        if self._conn is not None:
            self._conn.close()
            self._conn = None

    def __enter__(self) -> "AisqlHttpClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def _headers(self) -> Dict[str, str]:
        h = {"Content-Type": "application/json"}
        if self.token:
            h["Authorization"] = f"Bearer {self.token}"
        return h

    def _request(self, method: str, path: str,
                 body: Optional[Dict[str, Any]] = None):
        """One exchange with bounded 429 retries; returns the open
        response (2xx) for the caller to consume fully."""
        payload = dict(body or {})
        if self.tenant is not None and "tenant" not in payload:
            payload["tenant"] = self.tenant
        data = json.dumps(payload).encode() if method == "POST" else None
        for attempt in range(self.max_retries + 1):
            conn = self._connection()
            try:
                conn.request(method, path, body=data,
                             headers=self._headers())
                resp = conn.getresponse()
            except (ConnectionError, http.client.HTTPException, OSError):
                self.close()
                # only GETs are safe to resend: a POST the server may
                # already have executed (response lost on the wire)
                # would double-run the query and double-bill the tenant
                if method != "GET" or attempt >= self.max_retries:
                    raise
                continue
            if resp.status == 429 and attempt < self.max_retries:
                retry_after = float(resp.getheader("Retry-After") or 1.0)
                resp.read()             # drain; keep the connection
                self.throttled_retries += 1
                time.sleep(min(retry_after, self.max_retry_wait_s))
                continue
            if resp.status >= 300:
                raw = resp.read()
                try:
                    parsed = json.loads(raw)
                except ValueError:
                    parsed = {"error": {"message": raw.decode("utf-8",
                                                              "replace")}}
                raise HttpStatusError(resp.status, parsed)
            return resp
        raise HttpStatusError(429, {"error": {
            "code": "throttled",
            "message": f"still throttled after {self.max_retries} "
                       f"retries"}})

    # -- endpoints -----------------------------------------------------
    def healthz(self) -> Dict[str, Any]:
        return json.loads(self._request("GET", "/v1/healthz").read())

    def report(self) -> Dict[str, Any]:
        return json.loads(self._request("GET", "/v1/report").read())

    def metrics(self) -> str:
        """The raw Prometheus text exposition (parse it with
        ``repro.obs.parse_prometheus_text``)."""
        return self._request("GET", "/v1/metrics").read().decode("utf-8")

    def trace(self, query_id: str) -> Dict[str, Any]:
        """``{"query_id", "trace"}`` for a recent query (404 →
        `HttpStatusError` once the ring evicted it)."""
        return json.loads(
            self._request("GET", f"/v1/trace/{query_id}").read())

    def semantic_model(self) -> Dict[str, Any]:
        return json.loads(
            self._request("GET", "/v1/semantic-model").read())

    def query(self, sql: str) -> Dict[str, Any]:
        resp = self._request("POST", "/v1/query", {"sql": sql})
        return json.loads(resp.read())

    def query_stream(self, sql: str) -> Iterator[Dict[str, Any]]:
        """Yield parsed NDJSON events (``schema``/``row``/``summary``);
        a terminal ``error`` event raises `HttpStatusError`."""
        resp = self._request("POST", "/v1/query",
                             {"sql": sql, "stream": True})
        while True:
            line = resp.readline()
            if not line:
                break
            line = line.strip()
            if not line:
                continue
            event = json.loads(line)
            if event.get("kind") == "error":
                resp.read()
                raise HttpStatusError(
                    ERROR_CONTRACT.get(event.get("code", "internal"),
                                       (500, ""))[0],
                    {"error": event})
            yield event

    def nl2sql(self, question: str, *,
               execute: bool = False) -> Dict[str, Any]:
        resp = self._request("POST", "/v1/nl2sql",
                             {"question": question, "execute": execute})
        return json.loads(resp.read())
