"""Semantic-model catalog + NL→AISQL compilation.

The paper's chat front-ends do not speak SQL: they POST a natural-
language question plus a *semantic model* — a curated description of the
tables (business meaning per column, synonyms, verified example
queries) — and the service compiles the question into AISQL against
that model.  This module provides both halves:

  * `SemanticModel`: the curated catalog description, validated against
    the live `Catalog` (every described table/column must exist; every
    verified example query must parse and resolve).  Serializable to a
    plain dict/JSON structure (YAML-compatible; loading YAML works when
    the interpreter has ``pyyaml``, but nothing here requires it).
  * `NL2SQLOperator`: compiles a question to AISQL via the existing
    `CortexClient` COMPLETE path.  Every generated query is round-
    tripped through ``sqlparse.parse`` → plan → `Optimizer` **and**
    validated against the semantic model before it may execute; a
    query that fails validation is retried with the error appended to
    the prompt, and exhaustion surfaces the last validation error as
    `NL2SQLError` — a rejected query never reaches the engine.

Grounding for tests/benchmarks: the `SimulatedBackend` understands a
``"nl2sql"`` metadata block (question + examples) and answers with the
semantically matching verified query — sometimes corrupted, so the
validation loop is exercised end to end (see
``repro.inference.simulator``).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import expr as E
from repro.core import plan as P
from repro.core import sqlparse
from repro.core.cost import Catalog
from repro.core.optimizer import Optimizer, OptimizerConfig
from repro.core.sqlparse import ParseError, Query
from repro.inference.api import CortexClient


class SemanticValidationError(ValueError):
    """A query (or the model itself) failed semantic-model validation:
    unknown table, unresolvable column, or a verified example that no
    longer matches the live catalog."""


class NL2SQLError(RuntimeError):
    """NL→AISQL compilation failed for a question: every attempt was
    rejected by the parse/optimize/semantic validation loop.  Carries
    the last rejected SQL and its validation error."""

    def __init__(self, question: str, attempts: int,
                 last_sql: Optional[str], last_error: Exception):
        self.question = question
        self.attempts = attempts
        self.last_sql = last_sql
        self.last_error = last_error
        super().__init__(
            f"could not compile question {question!r} after {attempts} "
            f"attempt(s); last error: {last_error}")


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ColumnSpec:
    """One described column: business meaning + NL synonyms."""
    name: str
    description: str = ""
    synonyms: Tuple[str, ...] = ()


@dataclasses.dataclass
class TableSpec:
    """One described table."""
    name: str
    description: str = ""
    columns: List[ColumnSpec] = dataclasses.field(default_factory=list)

    def column(self, name: str) -> Optional[ColumnSpec]:
        for c in self.columns:
            if c.name == name:
                return c
        return None


@dataclasses.dataclass
class VerifiedQuery:
    """A curated (question, AISQL) pair: few-shot grounding for the
    compiler and a regression anchor for the model itself."""
    name: str
    question: str
    sql: str


@dataclasses.dataclass
class SemanticModel:
    """The curated catalog description a chat front-end queries against."""
    name: str = "default"
    description: str = ""
    tables: List[TableSpec] = dataclasses.field(default_factory=list)
    verified: List[VerifiedQuery] = dataclasses.field(default_factory=list)

    # -- construction --------------------------------------------------
    @classmethod
    def from_catalog(cls, catalog: Catalog, *, name: str = "default",
                     description: str = "") -> "SemanticModel":
        """Skeleton model over a live catalog: every table and every
        non-hidden column, with empty descriptions to be curated."""
        tables = []
        for tname, t in catalog.tables.items():
            cols = [ColumnSpec(c) for c in t.column_names
                    if not c.rsplit(".", 1)[-1].startswith("_")]
            tables.append(TableSpec(tname, columns=cols))
        return cls(name=name, description=description, tables=tables)

    def table(self, name: str) -> Optional[TableSpec]:
        for t in self.tables:
            if t.name == name:
                return t
        return None

    # -- validation ----------------------------------------------------
    def validate(self, catalog: Catalog) -> None:
        """The model must agree with the live catalog: every described
        table and column exists, and every verified query parses and
        resolves.  Raises `SemanticValidationError` on the first
        mismatch (`ParseError` propagates for unparsable examples)."""
        if not self.tables:
            raise SemanticValidationError(
                "semantic model describes no tables")
        for spec in self.tables:
            if spec.name not in catalog.tables:
                raise SemanticValidationError(
                    f"semantic model table {spec.name!r} does not exist "
                    f"in the catalog")
            live = catalog.tables[spec.name]
            for col in spec.columns:
                if col.name not in live.column_names:
                    raise SemanticValidationError(
                        f"semantic model column "
                        f"{spec.name}.{col.name} does not exist "
                        f"(live columns: {sorted(live.column_names)})")
        for vq in self.verified:
            q = sqlparse.parse(vq.sql)
            try:
                self.validate_query(q, catalog)
            except SemanticValidationError as e:
                raise SemanticValidationError(
                    f"verified query {vq.name!r} is invalid: {e}") from e

    def validate_query(self, q: Query, catalog: Catalog) -> None:
        """A parsed query must resolve entirely inside the model: every
        table referenced is described, every column reference names a
        live column of a referenced table."""
        refs = [q.table] + [j.ref for j in q.joins]
        alias_to_table: Dict[str, str] = {}
        for ref in refs:
            if self.table(ref.table) is None:
                raise SemanticValidationError(
                    f"unknown table {ref.table!r} (semantic model knows: "
                    f"{sorted(t.name for t in self.tables)})")
            alias_to_table[ref.alias] = ref.table
        for col in self._column_refs(q):
            self._resolve_column(col, alias_to_table, catalog)

    def _resolve_column(self, col: str, alias_to_table: Dict[str, str],
                        catalog: Catalog) -> None:
        if "." in col:
            alias, bare = col.split(".", 1)
            table = alias_to_table.get(alias)
            if table is None:
                raise SemanticValidationError(
                    f"column {col!r} references unknown alias {alias!r} "
                    f"(in scope: {sorted(alias_to_table)})")
            candidates = [table]
        else:
            bare, candidates = col, list(alias_to_table.values())
        for table in candidates:
            live = catalog.tables.get(table)
            if live is not None and bare in live.column_names:
                return
        raise SemanticValidationError(
            f"column {col!r} does not resolve against "
            f"{sorted(set(candidates))}")

    @staticmethod
    def _column_refs(q: Query) -> List[str]:
        exprs: List[E.Expr] = [it.expr for it in q.select]
        exprs += [j.on for j in q.joins]
        if q.where is not None:
            exprs.append(q.where)
        exprs += [o.expr for o in q.order_by]
        refs: List[str] = []
        for e in exprs:
            refs.extend(sorted(e.refs()))
        refs.extend(q.group_by)
        return refs

    # -- (de)serialization: plain dicts, JSON, YAML-compatible ---------
    def to_dict(self) -> Dict:
        return {
            "name": self.name,
            "description": self.description,
            "tables": [
                {"name": t.name, "description": t.description,
                 "columns": [
                     {"name": c.name, "description": c.description,
                      "synonyms": list(c.synonyms)}
                     for c in t.columns]}
                for t in self.tables],
            "verified_queries": [
                {"name": v.name, "question": v.question, "sql": v.sql}
                for v in self.verified],
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "SemanticModel":
        return cls(
            name=d.get("name", "default"),
            description=d.get("description", ""),
            tables=[
                TableSpec(
                    t["name"], t.get("description", ""),
                    [ColumnSpec(c["name"], c.get("description", ""),
                                tuple(c.get("synonyms", ())))
                     for c in t.get("columns", ())])
                for t in d.get("tables", ())],
            verified=[
                VerifiedQuery(v["name"], v["question"], v["sql"])
                for v in d.get("verified_queries", ())])

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "SemanticModel":
        return cls.from_dict(json.loads(text))

    @classmethod
    def from_yaml(cls, text: str) -> "SemanticModel":
        """The on-disk schema is YAML-compatible; parsing YAML needs
        ``pyyaml``, which is optional — JSON always works."""
        try:
            import yaml
        except ImportError as e:       # pragma: no cover - env dependent
            raise RuntimeError(
                "pyyaml is not installed; use from_json()") from e
        return cls.from_dict(yaml.safe_load(text))

    # -- prompt rendering ----------------------------------------------
    def prompt_context(self) -> str:
        """The model rendered as grounding text for the compiler LLM."""
        lines: List[str] = []
        if self.description:
            lines.append(self.description)
        for t in self.tables:
            desc = f" -- {t.description}" if t.description else ""
            lines.append(f"table {t.name}{desc}")
            for c in t.columns:
                extra = []
                if c.description:
                    extra.append(c.description)
                if c.synonyms:
                    extra.append("synonyms: " + ", ".join(c.synonyms))
                suffix = (" -- " + "; ".join(extra)) if extra else ""
                lines.append(f"  column {c.name}{suffix}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# NL -> AISQL
# ---------------------------------------------------------------------------

_SQL_FENCE_RE = re.compile(r"```(?:sql)?\s*(.+?)\s*```", re.DOTALL)


def extract_sql(text: str) -> str:
    """The SQL from a completion: the fenced block when present, else
    the text from the first SELECT onward, else the raw text."""
    m = _SQL_FENCE_RE.search(text)
    if m:
        return m.group(1).strip()
    low = text.upper()
    i = low.find("SELECT")
    return text[i:].strip() if i >= 0 else text.strip()


class NL2SQLOperator:
    """Compile natural-language questions to validated AISQL.

    The validation loop is the contract: *every* candidate the LLM
    produces is (1) parsed (`ParseError` on malformed SQL), (2) checked
    against the semantic model (unknown tables/columns), and (3) built
    into a plan and run through the `Optimizer` — only a query that
    survives all three may execute.  A rejected candidate's error is
    appended to the next attempt's prompt; after ``max_attempts`` the
    last error surfaces as `NL2SQLError`.
    """

    def __init__(self, model: SemanticModel, catalog: Catalog,
                 client: CortexClient, *, llm_model: Optional[str] = None,
                 max_attempts: int = 2,
                 optimizer: Optional[OptimizerConfig] = None,
                 validate_model: bool = True):
        if validate_model:
            model.validate(catalog)
        self.model = model
        self.catalog = catalog
        self.client = client
        self.llm_model = llm_model
        self.max_attempts = max(int(max_attempts), 1)
        self.optimizer = Optimizer(catalog, cfg=optimizer)
        # compilation telemetry
        self.compiled = 0
        self.rejected_attempts = 0
        self.failed = 0

    # -- prompt assembly ----------------------------------------------
    def _prompt(self, question: str, feedback: Optional[str]) -> str:
        parts = [
            "Translate the question into one AISQL query.",
            "Schema:", self.model.prompt_context(),
        ]
        if self.model.verified:
            parts.append("Examples:")
            for vq in self.model.verified:
                parts.append(f"Q: {vq.question}\nSQL: {vq.sql}")
        if feedback:
            parts.append(f"The previous attempt was rejected: {feedback}\n"
                         f"Produce a corrected query.")
        parts.append(f"Q: {question}\nSQL:")
        return "\n\n".join(parts)

    def _metadata(self, question: str) -> Dict:
        # grounding block the deterministic simulator keys on; a real
        # backend simply ignores it
        return {"nl2sql": {
            "question": question,
            "examples": [{"question": vq.question, "sql": vq.sql}
                         for vq in self.model.verified],
        }}

    # -- validation ----------------------------------------------------
    def validate_sql(self, sql: str) -> P.PlanNode:
        """Parse → semantic-model check → plan → optimize; returns the
        optimized plan, raises `ParseError` / `SemanticValidationError`."""
        q = sqlparse.parse(sql)
        self.model.validate_query(q, self.catalog)
        return self.optimizer.optimize(P.build_plan(q))

    # -- compilation ---------------------------------------------------
    def compile(self, question: str) -> str:
        """The validated AISQL for ``question`` (the compiled SQL text;
        call `validate_sql` again for the plan).  Raises `NL2SQLError`
        when every attempt is rejected."""
        feedback: Optional[str] = None
        last_sql: Optional[str] = None
        last_err: Optional[Exception] = None
        for _ in range(self.max_attempts):
            prompt = self._prompt(question, feedback)
            [completion] = self.client.complete(
                [prompt], model=self.llm_model,
                max_tokens=128, metadata=[self._metadata(question)])
            sql = extract_sql(completion)
            last_sql = sql
            try:
                self.validate_sql(sql)
            except (ParseError, SemanticValidationError) as e:
                self.rejected_attempts += 1
                feedback = f"{sql!r}: {e}"
                last_err = e
                continue
            self.compiled += 1
            return sql
        self.failed += 1
        assert last_err is not None
        raise NL2SQLError(question, self.max_attempts, last_sql, last_err)


# ---------------------------------------------------------------------------
# seeded question corpus (benchmark/test grounding)
# ---------------------------------------------------------------------------

_PARAPHRASES = (
    "{q}",
    "please {q}",
    "show me: {q}",
    "{q} thanks",
    "i need to {q}",
    "could you {q}",
)


def question_corpus(model: SemanticModel, n: int, *, seed: int = 0
                    ) -> List[Tuple[str, VerifiedQuery]]:
    """``n`` (question, grounding) pairs: deterministic paraphrases of
    the model's verified questions — the NL→AISQL acceptance gate
    compiles these and checks the result against the verified query's
    rows."""
    if not model.verified:
        raise ValueError("semantic model has no verified queries")
    out: List[Tuple[str, VerifiedQuery]] = []
    for i in range(n):
        vq = model.verified[(seed + i) % len(model.verified)]
        tpl = _PARAPHRASES[(seed + i) % len(_PARAPHRASES)]
        out.append((tpl.format(q=vq.question), vq))
    return out
