"""Network serving front-end: HTTP/JSON wire protocol over the
`ServingEngine` plus the semantic-model catalog and NL→AISQL
compilation layer (the paper's REST/chat entry points, §2)."""
from repro.serve.semantic_model import (ColumnSpec,        # noqa: F401
                                        NL2SQLError,
                                        NL2SQLOperator,
                                        SemanticModel,
                                        SemanticValidationError,
                                        TableSpec, VerifiedQuery,
                                        question_corpus)
from repro.serve.http import (AisqlHttpClient,             # noqa: F401
                              AisqlHttpServer, HttpConfig,
                              HttpError, ERROR_CONTRACT)
