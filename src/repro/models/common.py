"""Shared model-building primitives: norms, projections, RoPE / M-RoPE.

All modules are pure functions over explicit param pytrees (dicts of
jnp arrays) — no framework dependency.  Matmuls run in the config dtype
(bf16 by default) with fp32 softmax/norm statistics.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def layer_norm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def norm_init(d: int, dtype, use_layernorm: bool):
    return layer_norm_init(d, dtype) if use_layernorm else rms_norm_init(d, dtype)


def apply_norm(params, x, eps: float = 1e-6):
    """RMSNorm or LayerNorm depending on whether a bias is present."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if "bias" in params:
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps)
        y = y * params["scale"].astype(jnp.float32)
    return y.astype(dtype)


def group_norm_heads(x, scale, bias, eps: float = 64e-5):
    """Per-head group norm used by RWKV6 (x: [..., H, hd])."""
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    sh = y.shape[:-2] + (y.shape[-2] * y.shape[-1],)
    y = y.reshape(sh) * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# linear / mlp
# ---------------------------------------------------------------------------


def linear(x, w, b=None):
    y = jnp.einsum("...i,io->...o", x, w)
    if b is not None:
        y = y + b
    return y


def mlp_init(key, d: int, f: int, dtype, use_bias: bool):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "wi": dense_init(k1, d, f, dtype),
        "wg": dense_init(k2, d, f, dtype),
        "wo": dense_init(k3, f, d, dtype),
    }
    if use_bias:
        p["bi"] = jnp.zeros((f,), dtype)
        p["bg"] = jnp.zeros((f,), dtype)
        p["bo"] = jnp.zeros((d,), dtype)
    return p


def apply_mlp(params, x):
    """Gated (SwiGLU-style) MLP."""
    h = linear(x, params["wi"], params.get("bi"))
    g = linear(x, params["wg"], params.get("bg"))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * h
    return linear(h, params["wo"], params.get("bo"))


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------


def rope_inv_freq(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float,
               mrope_sections: Tuple[int, ...] = ()):
    """Rotate x: [..., S, H, hd].  positions: [..., S] or [..., S, 3] (M-RoPE).

    Half-split (llama) convention.  For M-RoPE, rotary dim i uses the
    position stream of its section (t/h/w), per Qwen2-VL.
    """
    hd = x.shape[-1]
    half = hd // 2
    inv = rope_inv_freq(hd, theta)                      # [half]
    if mrope_sections:
        assert sum(mrope_sections) == half, (mrope_sections, half)
        # section id for each rotary dim
        sec = jnp.concatenate([
            jnp.full((n,), i, jnp.int32) for i, n in enumerate(mrope_sections)
        ])                                              # [half]
        pos = positions.astype(jnp.float32)             # [..., S, 3]
        pos_per_dim = pos[..., sec]                     # [..., S, half]
        angles = pos_per_dim * inv                      # [..., S, half]
    else:
        angles = positions.astype(jnp.float32)[..., None] * inv   # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]                 # [..., S, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def softcap(logits, cap: float):
    if cap and cap > 0:
        lf = logits.astype(jnp.float32)
        return (jnp.tanh(lf / cap) * cap).astype(logits.dtype)
    return logits


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]
