"""Whisper-style audio encoder-decoder backbone.

Per the assignment, the conv/mel frontend is a STUB: the model consumes
precomputed frame embeddings [B, encoder_seq, d_model] (``batch["frames"]``).
Decoder = causal self-attention (cached) + cross-attention over the encoder
output (K/V precomputed at prefill) + gated MLP.  Learned positions, no RoPE.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import base as cfgs
from repro.models import attention as attn
from repro.models import blocks
from repro.models.common import (apply_mlp, apply_norm, dtype_of, embed_init,
                                 mlp_init, norm_init)


def init_params(cfg: cfgs.ModelConfig, key, dtype=None) -> Dict[str, Any]:
    dtype = dtype or dtype_of(cfg.dtype)
    keys = jax.random.split(key, 8)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": norm_init(cfg.d_model, dtype, True),
                "attn": blocks.attn_init(k1, cfg, dtype),
                "ln2": norm_init(cfg.d_model, dtype, True),
                "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype, cfg.use_bias)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": norm_init(cfg.d_model, dtype, True),
                "attn": blocks.attn_init(k1, cfg, dtype),
                "lnx": norm_init(cfg.d_model, dtype, True),
                "xattn": blocks.attn_init(k2, cfg, dtype),
                "ln2": norm_init(cfg.d_model, dtype, True),
                "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, dtype, cfg.use_bias)}

    enc_keys = jax.random.split(keys[0], cfg.encoder_layers)
    dec_keys = jax.random.split(keys[1], cfg.num_layers)
    return {
        "embed": {"w": embed_init(keys[2], cfg.vocab_size, cfg.d_model, dtype)},
        "pos_embed": {"w": embed_init(keys[3], cfg.max_pos_embed, cfg.d_model,
                                      dtype)},
        "enc_pos": {"w": embed_init(keys[4], cfg.encoder_seq, cfg.d_model,
                                    dtype)},
        "enc_layers": jax.vmap(enc_layer)(enc_keys),
        "enc_norm": norm_init(cfg.d_model, dtype, True),
        "dec_layers": jax.vmap(dec_layer)(dec_keys),
        "final_norm": norm_init(cfg.d_model, dtype, True),
    }


def init_cache(cfg: cfgs.ModelConfig, batch: int, smax: int,
               dtype=None) -> Dict[str, Any]:
    dtype = dtype or dtype_of(cfg.dtype)
    L = cfg.num_layers
    kv = (batch, smax, cfg.num_kv_heads, cfg.head_dim)
    xkv = (batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim)
    zero = lambda s: jnp.zeros((L,) + s, dtype)
    return {"k": zero(kv), "v": zero(kv), "xk": zero(xkv), "xv": zero(xkv),
            "len": jnp.zeros((batch,), jnp.int32)}


def _self_qkv(p, x, cfg):
    B, S, _ = x.shape
    q = (x @ p["wq"] + p.get("bq", 0)).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = (x @ p["wk"] + p.get("bk", 0)).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = (x @ p["wv"] + p.get("bv", 0)).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def encode(cfg, params, frames):
    """frames: [B, Senc, D] -> encoder output [B, Senc, D]."""
    x = frames.astype(dtype_of(cfg.dtype)) + params["enc_pos"]["w"][None]

    def body(h, lp):
        a_in = apply_norm(lp["ln1"], h, cfg.norm_eps)
        q, k, v = _self_qkv(lp["attn"], a_in, cfg)
        o = attn.causal_attention(q, k, v, causal=False)
        o = o.reshape(h.shape[0], h.shape[1], cfg.q_dim)
        h = h + (o @ lp["attn"]["wo"] + lp["attn"].get("bo", 0))
        m_in = apply_norm(lp["ln2"], h, cfg.norm_eps)
        h = h + apply_mlp(lp["mlp"], m_in)
        return h, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return apply_norm(params["enc_norm"], x, cfg.norm_eps)


def apply(cfg: cfgs.ModelConfig, params, batch, *, mode: str, cache=None,
          mesh_axes=None, remat: bool = True):
    """Whisper forward.  batch: {"frames": [B,Senc,D] (train/prefill),
    "tokens": [B,S], optional "lengths"}."""
    assert mode in ("train", "prefill", "decode")
    B = batch["tokens"].shape[0]
    S = batch["tokens"].shape[1]

    if mode in ("train", "prefill"):
        enc_out = encode(cfg, params, batch["frames"])
        lengths = batch.get("lengths")
        if lengths is None:
            lengths = jnp.full((B,), S, jnp.int32)
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    else:
        enc_out = None
        lengths = cache["len"] + S
        positions = (lengths - 1)[:, None]

    x = params["embed"]["w"][batch["tokens"]]
    x = x + params["pos_embed"]["w"][positions]

    smax = cache["k"].shape[2] if cache is not None else 0

    def layer(h, lp, layer_cache):
        new_lc = {}
        # --- causal self attention ---
        a_in = apply_norm(lp["ln1"], h, cfg.norm_eps)
        q, k, v = _self_qkv(lp["attn"], a_in, cfg)
        if mode == "decode":
            if S == 1 and attn.seq_sharded_decode_ready(layer_cache["k"]):
                o, ck, cv = attn.sharded_cache_decode(
                    q, layer_cache["k"], layer_cache["v"], k, v, lengths)
            else:
                start = lengths - S
                ck, cv = attn.write_kv(layer_cache["k"], layer_cache["v"],
                                       k, v, start)
                o = attn.decode_attention(q, ck, cv, lengths)
            new_lc["k"], new_lc["v"] = ck, cv
        else:
            o = attn.causal_attention(q, k, v)
            if mode == "prefill":
                ck = jnp.zeros((B, smax) + k.shape[2:], k.dtype)
                cv = jnp.zeros((B, smax) + v.shape[2:], v.dtype)
                new_lc["k"] = jax.lax.dynamic_update_slice_in_dim(ck, k, 0, 1)
                new_lc["v"] = jax.lax.dynamic_update_slice_in_dim(cv, v, 0, 1)
        o = o.reshape(B, S, cfg.q_dim)
        h = h + (o @ lp["attn"]["wo"] + lp["attn"].get("bo", 0))
        # --- cross attention ---
        x_in = apply_norm(lp["lnx"], h, cfg.norm_eps)
        xp = lp["xattn"]
        qx = (x_in @ xp["wq"] + xp.get("bq", 0)).reshape(
            B, S, cfg.num_heads, cfg.head_dim)
        if mode == "decode":
            xk, xv = layer_cache["xk"], layer_cache["xv"]
            new_lc["xk"], new_lc["xv"] = xk, xv
        else:
            xk = (enc_out @ xp["wk"] + xp.get("bk", 0)).reshape(
                B, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim)
            xv = (enc_out @ xp["wv"] + xp.get("bv", 0)).reshape(
                B, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim)
            if mode == "prefill":
                new_lc["xk"], new_lc["xv"] = xk, xv
        ox = attn.causal_attention(qx, xk, xv, causal=False)
        ox = ox.reshape(B, S, cfg.q_dim)
        h = h + (ox @ xp["wo"] + xp.get("bo", 0))
        # --- mlp ---
        m_in = apply_norm(lp["ln2"], h, cfg.norm_eps)
        h = h + apply_mlp(lp["mlp"], m_in)
        return h, new_lc

    if mode == "train":
        def body(h, lp):
            h, _ = layer(h, lp, None)
            return h, None
        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, _ = jax.lax.scan(body, x, params["dec_layers"])
        new_cache = None
    else:
        def body(h, xs):
            lp, lc = xs
            h, new_lc = layer(h, lp, lc)
            return h, new_lc
        per_layer_cache = {k: cache[k] for k in ("k", "v", "xk", "xv")}
        x, new_lcs = jax.lax.scan(body, x, (params["dec_layers"],
                                            per_layer_cache))
        new_cache = dict(new_lcs)
        new_cache["len"] = lengths

    x = apply_norm(params["final_norm"], x, cfg.norm_eps)
    out = {"aux": jnp.float32(0.0)}
    if mode == "train":
        out["hidden"] = x
    elif mode == "prefill":
        bidx = jnp.arange(B)
        out["last_hidden"] = x[bidx, jnp.clip(lengths - 1, 0, S - 1)]
        out["cache"] = new_cache
    else:
        out["hidden"] = x
        out["cache"] = new_cache
    return out
