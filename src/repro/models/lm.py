"""Generic decoder-only language model assembled from a block pattern.

The model is ``num_periods`` repetitions of ``cfg.period`` (scanned, with
stacked params — keeps HLO small for 64-layer models) followed by ``cfg.tail``
(unrolled).  Supports three modes:

  * train:   full sequence, no cache, returns hidden states (+ aux loss)
  * prefill: full (right-padded) sequence, writes decode caches, returns the
             hidden state of the *last valid* token per sequence
  * decode:  single-token step against the cache

Logits / loss are computed by the callers (:func:`logits_last`,
:func:`ce_loss_chunked`) so that the [*, vocab] tensor is never materialised
for a full 4k sequence at once.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import base as cfgs
from repro.models import blocks, shardctx
from repro.models.common import dtype_of, embed_init, norm_init, apply_norm, softcap


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(cfg: cfgs.ModelConfig, key, dtype=None) -> Dict[str, Any]:
    dtype = dtype or dtype_of(cfg.dtype)
    keys = jax.random.split(key, 6)
    params: Dict[str, Any] = {
        "embed": {"w": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype)},
        "final_norm": norm_init(cfg.d_model, dtype, cfg.use_layernorm),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": embed_init(keys[1], cfg.d_model,
                                             cfg.vocab_size, dtype)}
    if cfg.learned_pos_embed:
        params["pos_embed"] = {"w": embed_init(keys[2], cfg.max_pos_embed,
                                               cfg.d_model, dtype)}
    # stacked period params
    period_keys = jax.random.split(keys[3], len(cfg.period))
    periods = {}
    for i, blk in enumerate(cfg.period):
        ks = jax.random.split(period_keys[i], cfg.num_periods)
        periods[f"b{i}"] = jax.vmap(
            lambda k, blk=blk: blocks.block_init(blk, k, cfg, dtype))(ks)
    params["periods"] = periods
    # tail params (unrolled)
    if cfg.tail:
        tail_keys = jax.random.split(keys[4], len(cfg.tail))
        params["tail"] = {
            f"t{i}": blocks.block_init(blk, tail_keys[i], cfg, dtype)
            for i, blk in enumerate(cfg.tail)}
    return params


def init_cache(cfg: cfgs.ModelConfig, batch: int, smax: int,
               dtype=None) -> Dict[str, Any]:
    dtype = dtype or dtype_of(cfg.dtype)
    one = lambda blk: blocks.block_cache_init(blk, cfg, batch, smax, dtype)
    periods = {}
    for i, blk in enumerate(cfg.period):
        c = one(blk)
        periods[f"b{i}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.num_periods,) + x.shape), c)
    cache: Dict[str, Any] = {"periods": periods,
                             "len": jnp.zeros((batch,), jnp.int32)}
    if cfg.tail:
        cache["tail"] = {f"t{i}": one(blk) for i, blk in enumerate(cfg.tail)}
    return cache


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _embed(cfg, params, batch, mode):
    tokens = batch["tokens"]
    x = params["embed"]["w"][tokens]
    if cfg.scale_embedding:
        x = x * jnp.asarray(jnp.sqrt(jnp.float32(cfg.d_model)), x.dtype)
    if cfg.frontend == "patches" and mode != "decode":
        # VLM: precomputed patch embeddings prepended to the token stream.
        patches = batch["patches"].astype(x.dtype)     # [B, P, D]
        x = jnp.concatenate([patches, x], axis=1)
    if cfg.learned_pos_embed:
        pos = batch["positions"]
        if pos.ndim == 3:
            pos = pos[..., 0]
        x = x + params["pos_embed"]["w"][pos]
    return x


def _default_positions(cfg, batch, x, mode, lengths):
    if "positions" in batch and batch["positions"] is not None:
        return batch["positions"]
    B, S = x.shape[0], x.shape[1]
    if mode == "decode":
        pos = (lengths - 1)[:, None]                   # [B,1]
    else:
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(pos[..., None], pos.shape + (3,))
    return pos


def apply(cfg: cfgs.ModelConfig, params, batch, *, mode: str,
          cache=None, mesh_axes=None, remat: bool = True):
    """Run the backbone.  Returns dict with:
       hidden [B,S,D] (train) or last_hidden [B,D] (prefill) or
       hidden [B,1,D] (decode); new cache (prefill/decode); aux loss.
    """
    assert mode in ("train", "prefill", "decode")
    if mesh_axes is None and shardctx.enabled():
        # launcher context: explicit expert parallelism for MoE layers
        m, dp, tp = shardctx.mesh_info()
        mesh_axes = (m, dp, tp)
    x = _embed(cfg, params, batch, mode)
    x = shardctx.constrain(x, "dp", "sp" if mode == "train" else None, None)
    B, S = x.shape[0], x.shape[1]
    lengths = None
    valid = None
    if mode in ("prefill", "decode"):
        if mode == "prefill":
            lengths = batch.get("lengths")
            if lengths is None:
                lengths = jnp.full((B,), S, jnp.int32)
            valid = jnp.arange(S, dtype=jnp.int32)[None] < lengths[:, None]
        else:
            lengths = cache["len"] + S                 # S new tokens
    positions = _default_positions(cfg, batch, x, mode, lengths)
    ctx = blocks.Ctx(cfg=cfg, mode=mode, positions=positions, lengths=lengths,
                     valid=valid, smax=cache_capacity(cache),
                     mesh_axes=mesh_axes)

    def period_body(carry, xs):
        h = carry
        p_params, p_cache = xs
        new_caches = {}
        aux = jnp.float32(0.0)
        for i, blk in enumerate(cfg.period):
            c = None if p_cache is None else p_cache[f"b{i}"]
            h, nc, a = blocks.block_apply(blk, p_params[f"b{i}"], h,
                                          ctx.replace(cache=c))
            new_caches[f"b{i}"] = nc
            aux = aux + a
        # pin the layer-to-layer carry: batch on dp; with sequence
        # parallelism on, activations are also sharded over `model` on S
        h = shardctx.constrain(h, "dp", "sp", None)
        return h, (new_caches, aux)

    if mode == "train":
        body = lambda h, p: period_body(h, (p, None))
        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, (_, auxs) = jax.lax.scan(body, x, params["periods"])
        new_cache = None
    else:
        body = period_body
        x, (new_period_caches, auxs) = jax.lax.scan(
            body, x, (params["periods"], cache["periods"]))
        new_cache = dict(cache)
        new_cache["periods"] = new_period_caches
    aux = jnp.sum(auxs)

    if cfg.tail:
        if new_cache is not None:
            new_cache["tail"] = dict(cache["tail"])
        for i, blk in enumerate(cfg.tail):
            c = None if new_cache is None else cache["tail"][f"t{i}"]
            x, nc, a = blocks.block_apply(blk, params["tail"][f"t{i}"], x,
                                          ctx.replace(cache=c))
            aux = aux + a
            if new_cache is not None:
                new_cache["tail"][f"t{i}"] = nc

    x = apply_norm(params["final_norm"], x, cfg.norm_eps)

    out = {"aux": aux}
    if mode == "train":
        out["hidden"] = x
    elif mode == "prefill":
        bidx = jnp.arange(B)
        out["last_hidden"] = x[bidx, jnp.clip(lengths - 1, 0, S - 1)]
        new_cache["len"] = lengths
        out["cache"] = new_cache
    else:
        out["hidden"] = x
        new_cache["len"] = lengths
        out["cache"] = new_cache
    return out


def cache_capacity(cache) -> int:
    if cache is None:
        return 0
    for k in cache.get("periods", {}).values():
        if "k" in k:
            return k["k"].shape[2]  # [P, B, Smax, KV, hd]
    for k in cache.get("tail", {}).values():
        if "k" in k:
            return k["k"].shape[1]
    return 0


# ---------------------------------------------------------------------------
# logits & loss
# ---------------------------------------------------------------------------


def unembed_w(cfg, params):
    if cfg.tie_embeddings:
        return params["embed"]["w"].T      # [D, V]
    return params["lm_head"]["w"]


def logits_of(cfg, params, hidden):
    """hidden [..., D] -> logits [..., V] (fp32)."""
    w = unembed_w(cfg, params)
    logits = jnp.einsum("...d,dv->...v", hidden, w,
                        preferred_element_type=jnp.float32)
    if cfg.logit_softcap:
        logits = softcap(logits, cfg.logit_softcap)
    return logits


def ce_loss_chunked(cfg, params, hidden, labels, loss_mask=None,
                    chunk: int = 512):
    """Causal LM loss without materialising [B,S,V].

    hidden: [B,S,D]; labels: [B,S] (already shifted by the caller: labels[t]
    is the target for hidden[t]).  Returns (mean_loss, token_count).
    """
    B, S, D = hidden.shape
    w = unembed_w(cfg, params)
    if loss_mask is None:
        loss_mask = jnp.ones((B, S), jnp.float32)
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        loss_mask = jnp.pad(loss_mask, ((0, 0), (0, pad)))
    nc = (S + pad) // chunk
    hs = jnp.moveaxis(hidden.reshape(B, nc, chunk, D), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0)
    ms = jnp.moveaxis(loss_mask.reshape(B, nc, chunk), 1, 0)

    def body(acc, xs):
        hc, lc, mc = xs
        logits = jnp.einsum("bcd,dv->bcv", hc, w,
                            preferred_element_type=jnp.float32)
        # vocab-sharded logits; never replicate the [B,chunk,V] tensor
        logits = shardctx.constrain(logits, "dp", None, "tp")
        if cfg.logit_softcap:
            logits = softcap(logits, cfg.logit_softcap)
        logz = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (logz - tgt) * mc
        return (acc[0] + jnp.sum(nll), acc[1] + jnp.sum(mc)), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (total, count), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (hs, ls, ms))
    return total / jnp.maximum(count, 1.0), count
