"""Attention cores (pure jnp).

These are the *reference* sequence-mixing implementations used for training,
prefill, decode and the dry-run.  They are written to be:
  * memory-safe at 32k context (chunked over query blocks, online per-block
    softmax peak of [B, H, C, S_kv] instead of [B, H, S, S]);
  * GQA-native (keys/values never repeated to q heads — grouped einsum);
  * SPMD-friendly (batch on the `data` axis, q-heads on `model` for
    train/prefill; KV-sequence on `model` for decode).

Pallas TPU kernels in ``repro.kernels`` implement the same contracts and are
swapped in via ``attention_impl='pallas'`` on real TPUs.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import shardctx

NEG_INF = -1e30

# Single-token decode attention implementation. "dense" is the pure-jnp
# reference below; anything else routes through the kernels/decode_attention
# flash path ("auto" = Pallas on TPU, the bitwise-equal reference off-TPU).
# Trace-time state: the continuous-batching backend arms it around its
# jitted decode step, so the choice is baked into each compiled step fn.
_DECODE_IMPL = "dense"


@contextlib.contextmanager
def use_decode_impl(impl: str):
    """Route single-token :func:`decode_attention` calls traced inside this
    context through ``kernels/decode_attention`` (``impl`` in {"dense",
    "auto", "reference", "interpret", "pallas"})."""
    global _DECODE_IMPL
    prev, _DECODE_IMPL = _DECODE_IMPL, impl
    try:
        yield
    finally:
        _DECODE_IMPL = prev


def _grouped_logits(q, k):
    """q: [B,Sq,KV,G,hd], k: [B,Skv,KV,hd] -> [B,KV,G,Sq,Skv] (fp32)."""
    return jnp.einsum("bqcgd,bscd->bcgqs", q, k,
                      preferred_element_type=jnp.float32)


def _grouped_out(p, v, dtype):
    """p: [B,KV,G,Sq,Skv], v: [B,Skv,KV,hd] -> [B,Sq,KV,G,hd]."""
    return jnp.einsum("bcgqs,bscd->bqcgd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(dtype)


def _attn_block(q, k, v, q_pos, kv_pos, *, causal: bool, window: int = 0,
                kv_valid=None):
    """One dense attention block.

    q: [B,Sq,H,hd]; k,v: [B,Skv,KV,hd]; q_pos: [Sq] or [B,Sq];
    kv_pos: [Skv] or [B,Skv]; kv_valid: optional bool [B,Skv].
    Returns [B,Sq,H,hd].
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    # pin the batch sharding through the GQA head-split (propagation can
    # otherwise drop it while it resolves the KV×G factorisation)
    qg = shardctx.constrain(qg, "dp", None, None, None, None)
    k = shardctx.constrain(k, "dp", None, None, None)
    v = shardctx.constrain(v, "dp", None, None, None)
    logits = _grouped_logits(qg, k) / jnp.sqrt(jnp.float32(hd))
    # mask construction ([b?, Sq, Skv], broadcastable to [B,KV,G,Sq,Skv])
    qp = q_pos if q_pos.ndim == 2 else q_pos[None]           # [B?,Sq]
    kp = kv_pos if kv_pos.ndim == 2 else kv_pos[None]        # [B?,Skv]
    mask = None
    if causal:
        mask = kp[:, None, :] <= qp[:, :, None]
    if window:
        w = kp[:, None, :] > qp[:, :, None] - window
        mask = w if mask is None else mask & w
    if kv_valid is not None:
        v_ = kv_valid[:, None, :]
        mask = v_ if mask is None else mask & v_
    if mask is not None:
        logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = _grouped_out(p, v, q.dtype)
    out = out.reshape(B, Sq, H, hd)
    return shardctx.constrain(out, "dp", None, "tp", None)


def causal_attention(q, k, v, *, q_offset=0, window: int = 0,
                     chunk: int = 1024, causal: bool = True):
    """Chunked (flash-style memory profile) attention over full sequences.

    q: [B,Sq,H,hd]; k,v: [B,Skv,KV,hd]. ``q_offset`` is the absolute position
    of q[0] relative to kv[0] (q_offset=Skv-Sq for incremental prefill).
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    kv_pos = jnp.arange(Skv, dtype=jnp.int32)
    if Sq <= chunk:
        q_pos = jnp.arange(Sq, dtype=jnp.int32) + q_offset
        return _attn_block(q, k, v, q_pos, kv_pos, causal=causal, window=window)
    # pad Sq to a chunk multiple, scan over query chunks
    pad = (-Sq) % chunk
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (Sq + pad) // chunk
    q_chunks = jnp.moveaxis(qp.reshape(B, nc, chunk, H, hd), 1, 0)
    pos_chunks = (jnp.arange(nc * chunk, dtype=jnp.int32)
                  .reshape(nc, chunk) + q_offset)

    def one(args):
        qc, pc = args
        return _attn_block(qc, k, v, pc, kv_pos, causal=causal, window=window)

    out = jax.lax.map(one, (q_chunks, pos_chunks))
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq + pad, H, hd)
    return out[:, :Sq]


def local_attention(q, k, v, *, window: int):
    """Exact sliding-window causal attention (token t sees [t-window+1, t]).

    Implemented as chunked banded attention: query chunk i (chunk size =
    window) attends to kv chunks i-1 and i only.  Peak logits:
    [B, H, W, 2W] per chunk step.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    W = window
    if S <= W:
        return causal_attention(q, k, v, window=W, chunk=max(W, 256))
    pad = (-S) % W
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nc = Sp // W
    qc = jnp.moveaxis(qp.reshape(B, nc, W, H, hd), 1, 0)      # [nc,B,W,H,hd]
    kc = jnp.moveaxis(kp.reshape(B, nc, W, KV, hd), 1, 0)
    vc = jnp.moveaxis(vp.reshape(B, nc, W, KV, hd), 1, 0)
    # previous chunk (zeros for the first)
    kprev = jnp.concatenate([jnp.zeros_like(kc[:1]), kc[:-1]], axis=0)
    vprev = jnp.concatenate([jnp.zeros_like(vc[:1]), vc[:-1]], axis=0)
    idx = jnp.arange(nc, dtype=jnp.int32)

    def one(args):
        i, qi, ki, vi, kpi, vpi = args
        kv_k = jnp.concatenate([kpi, ki], axis=1)             # [B,2W,KV,hd]
        kv_v = jnp.concatenate([vpi, vi], axis=1)
        q_pos = i * W + jnp.arange(W, dtype=jnp.int32)
        kv_pos = (i - 1) * W + jnp.arange(2 * W, dtype=jnp.int32)
        # kv positions < 0 are the zero-padding of chunk -1
        valid = (kv_pos >= 0)[None]
        return _attn_block(qi, kv_k, kv_v, q_pos, kv_pos, causal=True,
                           window=W, kv_valid=valid)

    out = jax.lax.map(one, (idx, qc, kc, vc, kprev, vprev))
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sp, H, hd)
    return out[:, :S]


def decode_attention(q, k_cache, v_cache, lengths):
    """Decode-step attention against a full KV cache.

    q: [B,T,H,hd] (T new tokens, already at positions lengths..lengths+T-1);
    k_cache,v_cache: [B,Smax,KV,hd] with the new tokens already written;
    lengths: [B] — number of valid tokens *including* the new ones.

    Sharding contract: the Smax axis may be sharded over the `model` mesh
    axis; the softmax reduction then induces collectives, which the Pallas
    flash-decode kernel replaces with a logsumexp-combine on TPU.
    """
    B, T, H, hd = q.shape
    if T == 1 and _DECODE_IMPL != "dense":
        from repro.kernels.decode_attention import ops as dec_ops
        return dec_ops.flash_decode(q, k_cache, v_cache, lengths,
                                    impl=_DECODE_IMPL)
    Smax = k_cache.shape[1]
    kv_pos = jnp.arange(Smax, dtype=jnp.int32)[None]           # [1,Smax]
    q_pos = (lengths[:, None] - T) + jnp.arange(T, dtype=jnp.int32)[None]
    valid = kv_pos < lengths[:, None]                          # [B,Smax]
    return _attn_block(q, k_cache, v_cache, q_pos,
                       jnp.broadcast_to(kv_pos, (B, Smax)),
                       causal=True, kv_valid=valid)


def ring_decode_attention(q, k_ring, v_ring, pos, window: int):
    """Sliding-window decode against a ring buffer of the last W tokens.

    q: [B,1,H,hd]; k_ring,v_ring: [B,W,KV,hd]; pos: [B] absolute position of
    the new token (already written to slot pos % W).
    """
    B, T, H, hd = q.shape
    assert T == 1, "ring decode is single-token"
    W = window
    j = jnp.arange(W, dtype=jnp.int32)[None]                   # [1,W]
    p = pos[:, None]                                           # [B,1]
    slot_pos = p - jnp.mod(p - j, W)                           # [B,W]
    valid = slot_pos >= 0
    return _attn_block(q, k_ring, v_ring, p, slot_pos, causal=True,
                       window=W, kv_valid=valid)


def seq_sharded_decode_ready(cache_k) -> bool:
    """True when the shard context is armed and the cache's sequence axis
    divides the model axis (the sharded decode fast path applies)."""
    if not shardctx.enabled():
        return False
    mesh, _, tp = shardctx.mesh_info()
    return cache_k.shape[1] % mesh.shape[tp] == 0


def sharded_cache_decode(q, cache_k, cache_v, k_new, v_new, lengths):
    """Decode against a sequence-sharded KV cache: shard-local ring write +
    flash-decode with psum-of-partials (see kernels/decode_attention)."""
    from repro.kernels.decode_attention import ops as dec_ops
    mesh, dp, tp = shardctx.mesh_info()
    dp = shardctx.dp_for(q.shape[0])
    start = lengths - 1
    ck, cv = dec_ops.write_kv_sharded(cache_k, cache_v, k_new, v_new, start,
                                      mesh=mesh, seq_axis=tp, dp_axes=dp)
    out = dec_ops.flash_decode_sharded(q, ck, cv, lengths, mesh=mesh,
                                       seq_axis=tp, dp_axes=dp)
    return out, ck, cv


def write_kv(cache_k, cache_v, k_new, v_new, start):
    """Write k_new [B,T,KV,hd] into cache at per-batch offsets start [B]."""
    B, T = k_new.shape[:2]
    idx = start[:, None] + jnp.arange(T, dtype=jnp.int32)[None]   # [B,T]
    bidx = jnp.arange(B, dtype=jnp.int32)[:, None]
    ck = cache_k.at[bidx, idx].set(k_new)
    cv = cache_v.at[bidx, idx].set(v_new)
    return ck, cv


def write_kv_ring(cache_k, cache_v, k_new, v_new, pos, window: int):
    """Write single-token k/v [B,1,KV,hd] at ring slot pos % window."""
    B = k_new.shape[0]
    slot = jnp.mod(pos, window)
    bidx = jnp.arange(B, dtype=jnp.int32)
    ck = cache_k.at[bidx, slot].set(k_new[:, 0])
    cv = cache_v.at[bidx, slot].set(v_new[:, 0])
    return ck, cv
