"""Unified model interface over the 10 assigned architectures (+ extras).

``build(arch)`` returns a :class:`Model` with init/apply/cache entry points;
``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of an assigned (arch × shape) cell — weak-type-correct,
shardable, and allocation-free (used by the multi-pod dry-run).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Union

import jax
import jax.numpy as jnp

from repro.configs import base as cfgs
from repro.models import lm, whisper
from repro.models.common import dtype_of


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: cfgs.ModelConfig
    init_params: Callable[..., Any]
    init_cache: Callable[..., Any]
    apply: Callable[..., Any]
    logits_of: Callable[..., Any]
    ce_loss: Callable[..., Any]

    @property
    def name(self) -> str:
        return self.cfg.name


def build(arch: Union[str, cfgs.ModelConfig], *, smoke: bool = False) -> Model:
    if isinstance(arch, str):
        cfg = cfgs.get_smoke_config(arch) if smoke else cfgs.get_config(arch)
    else:
        cfg = arch
    if cfg.is_encoder_decoder:
        return Model(
            cfg=cfg,
            init_params=functools.partial(whisper.init_params, cfg),
            init_cache=functools.partial(whisper.init_cache, cfg),
            apply=functools.partial(whisper.apply, cfg),
            logits_of=functools.partial(lm.logits_of, cfg),
            ce_loss=functools.partial(lm.ce_loss_chunked, cfg),
        )
    return Model(
        cfg=cfg,
        init_params=functools.partial(lm.init_params, cfg),
        init_cache=functools.partial(lm.init_cache, cfg),
        apply=functools.partial(lm.apply, cfg),
        logits_of=functools.partial(lm.logits_of, cfg),
        ce_loss=functools.partial(lm.ce_loss_chunked, cfg),
    )


# ---------------------------------------------------------------------------
# input specs (dry-run stand-ins; no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: cfgs.ModelConfig, shape: cfgs.ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct batch for one (arch × shape) cell.

    train  -> {"tokens","labels","loss_mask"} (+ modality extras)
    prefill-> {"tokens","lengths"} (+ extras)
    decode -> {"tokens"} (+ positions for M-RoPE); KV cache comes from
              :func:`cache_specs`.
    """
    B, S = shape.global_batch, shape.seq_len
    act = dtype_of(cfg.dtype)
    batch: Dict[str, Any] = {}
    if shape.kind == "train":
        n_tok = S
        if cfg.frontend == "patches":
            n_tok = S - cfg.num_patches
        batch["tokens"] = _sds((B, n_tok), jnp.int32)
        batch["labels"] = _sds((B, S), jnp.int32)
        batch["loss_mask"] = _sds((B, S), jnp.float32)
        if cfg.frontend == "patches":
            batch["patches"] = _sds((B, cfg.num_patches, cfg.d_model), act)
            batch["positions"] = _sds((B, S, 3), jnp.int32)
        if cfg.frontend == "frames":
            batch["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model), act)
    elif shape.kind == "prefill":
        n_tok = S
        if cfg.frontend == "patches":
            n_tok = S - cfg.num_patches
        batch["tokens"] = _sds((B, n_tok), jnp.int32)
        batch["lengths"] = _sds((B,), jnp.int32)
        if cfg.frontend == "patches":
            batch["patches"] = _sds((B, cfg.num_patches, cfg.d_model), act)
            batch["positions"] = _sds((B, S, 3), jnp.int32)
        if cfg.frontend == "frames":
            batch["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model), act)
    else:  # decode: one new token against a cache of S tokens
        batch["tokens"] = _sds((B, 1), jnp.int32)
        if cfg.mrope_sections:
            batch["positions"] = _sds((B, 1, 3), jnp.int32)
    return batch


def cache_specs(cfg: cfgs.ModelConfig, shape: cfgs.ShapeSpec):
    """Abstract KV/state-cache pytree for a decode cell (no allocation)."""
    model = build(cfg)
    return jax.eval_shape(
        functools.partial(model.init_cache, shape.global_batch, shape.seq_len))


# ---------------------------------------------------------------------------
# analytic FLOPs (roofline MODEL_FLOPS term)
# ---------------------------------------------------------------------------


def model_flops(cfg: cfgs.ModelConfig, shape: cfgs.ShapeSpec) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference fwd), N = active params.

    D counts processed tokens: B·S for train/prefill, B·1 for decode.
    Attention is *not* included (the ratio HLO/MODEL in the roofline table
    surfaces attention + routing + remat overheads explicitly).
    """
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n_active * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n_active * toks
    toks = shape.global_batch * 1
    return 2.0 * n_active * toks


def attention_flops(cfg: cfgs.ModelConfig, shape: cfgs.ShapeSpec) -> float:
    """Analytic attention matmul FLOPs (qk^T + pv), forward only."""
    n_attn = sum(1 for b in cfg.block_pattern if b == cfgs.ATTN)
    n_local = sum(1 for b in cfg.block_pattern if b == cfgs.LOCAL_ATTN)
    B, S = shape.global_batch, shape.seq_len
    H, hd = cfg.num_heads, cfg.head_dim
    if shape.kind == "decode":
        per_q = 4.0 * H * hd
        f = B * (n_attn * per_q * S + n_local * per_q * min(S, cfg.attention_window))
        return f
    # full-sequence: 2*S^2*H*hd per matmul pair (x2), /2 causal
    full = 2.0 * S * S * H * hd
    local = 2.0 * S * min(2 * cfg.attention_window, S) * H * hd
    f = B * (n_attn * full + n_local * local)
    if shape.kind == "train":
        f *= 3.0  # fwd + bwd
    return f
