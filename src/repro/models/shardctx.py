"""Sharding-constraint context for model internals.

XLA's sharding propagation handles most of the graph, but two spots need
pinning on the production mesh:

  * the GQA head-split reshape [B,S,H,hd] -> [B,S,KV,G,hd] — neither KV nor
    G alone divides the 16-way `model` axis, and propagation can drop the
    *batch* sharding while deciding, replicating multi-GiB attention
    logits (observed: f32[4,128,1,2,1024,4096] per device);
  * the scan-over-layers carry, whose sharding otherwise re-derives per
    layer.

``enable(mesh, ...)`` arms the context (launchers only — smoke tests and
the CPU serving engine never enable it, so ``constrain`` is a no-op there).
Dims that don't divide their axis are dropped per-dim, so one rule set
serves every architecture.

Tokens understood in a constraint spec: "dp" (all data-parallel axes),
"tp" (the model axis), "sp" (sequence: tp when sequence-parallelism is on,
else unsharded), None.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_STATE = {"mesh": None, "dp": ("data",), "tp": "model", "sp": False}


def enable(mesh, *, dp: Optional[Tuple[str, ...]] = None, tp: str = "model",
           sp: bool = False) -> None:
    _STATE["mesh"] = mesh
    _STATE["dp"] = dp or tuple(a for a in mesh.axis_names if a != tp)
    _STATE["tp"] = tp
    _STATE["sp"] = sp


def disable() -> None:
    _STATE["mesh"] = None


def enabled() -> bool:
    return _STATE["mesh"] is not None


def mesh_info():
    """(mesh, dp_axes, tp_axis) or (None, None, None)."""
    return _STATE["mesh"], _STATE["dp"], _STATE["tp"]


def dp_for(batch: int):
    """The subset of dp axes usable for a batch dim of this size."""
    mesh = _STATE["mesh"]
    if mesh is None:
        return ()
    dp = _STATE["dp"]
    size = 1
    for a in dp:
        size *= mesh.shape[a]
    return dp if batch % size == 0 else ()


def _axis_size(mesh, axis) -> int:
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def constrain(x, *dims: Any):
    """Apply with_sharding_constraint(x, P(resolved dims)); no-op unless
    a launcher enabled the context.  Drops non-dividing axes per-dim."""
    mesh = _STATE["mesh"]
    if mesh is None or not hasattr(x, "shape"):
        return x
    if len(dims) != len(x.shape):
        return x
    spec = []
    for size, d in zip(x.shape, dims):
        axis = {"dp": _STATE["dp"], "tp": _STATE["tp"],
                "sp": (_STATE["tp"] if _STATE["sp"] else None)}.get(d, d) \
            if isinstance(d, str) else d
        if axis is None:
            spec.append(None)
        elif size % _axis_size(mesh, axis) == 0:
            spec.append(axis)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
