"""Transformer / recurrent block implementations.

Every block implements three entry points behind one interface:

    block_init(blk, key, cfg, dtype)            -> params
    block_cache_init(blk, cfg, batch, smax)     -> cache (decode state)
    block_apply(blk, params, x, ctx)            -> (y, new_cache, aux_loss)

``ctx.mode`` is one of "train" (no cache), "prefill" (full sequence, writes
cache), "decode" (single-token step against cache).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import base as cfgs
from repro.models import attention as attn
from repro.models import shardctx
from repro.models.common import (apply_mlp, apply_norm, apply_rope, dense_init,
                                 group_norm_heads, linear, mlp_init, norm_init)


@dataclasses.dataclass
class Ctx:
    cfg: cfgs.ModelConfig
    mode: str                       # train | prefill | decode
    positions: Any                  # [B,S] int32 or [B,S,3] (M-RoPE)
    lengths: Optional[Any] = None   # [B] valid tokens incl. current step
    valid: Optional[Any] = None     # [B,S] bool — pad mask for prefill
    cache: Any = None               # this block's cache slice
    smax: int = 0                   # KV-cache capacity
    mesh_axes: Any = None           # (dp_axes, tp_axis) names or None

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


# ===========================================================================
# Attention block (global or sliding-window), GQA + RoPE (+ qk-norm, M-RoPE)
# ===========================================================================


def attn_init(key, cfg: cfgs.ModelConfig, dtype):
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p = {
        "wq": dense_init(ks[0], d, cfg.q_dim, dtype),
        "wk": dense_init(ks[1], d, cfg.kv_dim, dtype),
        "wv": dense_init(ks[2], d, cfg.kv_dim, dtype),
        "wo": dense_init(ks[3], cfg.q_dim, d, dtype),
    }
    if cfg.use_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dtype)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dtype)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dtype)
        p["bo"] = jnp.zeros((d,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = {"scale": jnp.ones((cfg.head_dim,), dtype)}
        p["k_norm"] = {"scale": jnp.ones((cfg.head_dim,), dtype)}
    return p


def _qkv(params, x, cfg: cfgs.ModelConfig, positions):
    B, S, _ = x.shape
    q = linear(x, params["wq"], params.get("bq")).reshape(
        B, S, cfg.num_heads, cfg.head_dim)
    k = linear(x, params["wk"], params.get("bk")).reshape(
        B, S, cfg.num_kv_heads, cfg.head_dim)
    v = linear(x, params["wv"], params.get("bv")).reshape(
        B, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = apply_norm(params["q_norm"], q, cfg.norm_eps)
        k = apply_norm(params["k_norm"], k, cfg.norm_eps)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    q = shardctx.constrain(q, "dp", None, "tp", None)
    k = shardctx.constrain(k, "dp", None, None, None)
    v = shardctx.constrain(v, "dp", None, None, None)
    return q, k, v


def _padded_heads(cfg: cfgs.ModelConfig) -> int:
    """Zero-pad q heads up to a multiple of the TP axis when H doesn't
    divide it (qwen2-vl: 28 heads on a 16-way axis -> 32).  Padding is
    activation-level: the extra heads' wo rows are zero, so the output is
    exact; the win is 16-way head sharding instead of fully replicated
    attention (observed 16x redundant attention traffic otherwise)."""
    from repro.models import shardctx as _sc
    if not _sc.enabled():
        return cfg.num_heads
    mesh, _, tp = _sc.mesh_info()
    t = mesh.shape[tp]
    H = cfg.num_heads
    if H % t == 0:
        return H
    Hp = ((H + t - 1) // t) * t
    if Hp % cfg.num_kv_heads != 0:     # GQA grouping must survive
        return H
    return Hp


def attn_apply(params, x, ctx: Ctx, *, window: int):
    cfg = ctx.cfg
    B, S, _ = x.shape
    q, k, v = _qkv(params, x, cfg, ctx.positions)
    Hp = _padded_heads(cfg)
    if Hp != cfg.num_heads:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, Hp - cfg.num_heads), (0, 0)))
        q = shardctx.constrain(q, "dp", None, "tp", None)
    new_cache = ctx.cache
    if ctx.mode == "decode":
        if window:
            pos = ctx.lengths - 1                       # absolute position
            ck, cv = attn.write_kv_ring(ctx.cache["k"], ctx.cache["v"],
                                        k, v, pos, window)
            out = attn.ring_decode_attention(q, ck, cv, pos, window)
        elif S == 1 and attn.seq_sharded_decode_ready(ctx.cache["k"]):
            # seq-sharded cache: shard-local write + logsumexp-combined
            # partial attention (kills the scatter-induced cache all-gather)
            out, ck, cv = attn.sharded_cache_decode(
                q, ctx.cache["k"], ctx.cache["v"], k, v, ctx.lengths)
        else:
            start = ctx.lengths - S
            ck, cv = attn.write_kv(ctx.cache["k"], ctx.cache["v"], k, v, start)
            out = attn.decode_attention(q, ck, cv, ctx.lengths)
        new_cache = {"k": ck, "v": cv}
    else:
        if window:
            out = attn.local_attention(q, k, v, window=window)
        else:
            out = attn.causal_attention(q, k, v)
        if ctx.mode == "prefill":
            if window:
                # Ring buffer = the last W *valid* tokens; slot j holds the
                # largest valid position congruent to j (mod W).  Gather
                # formulation keeps the scatter deterministic under padding.
                W = window
                lens = (ctx.lengths if ctx.lengths is not None
                        else jnp.full((B,), S, jnp.int32))
                q_last = lens[:, None] - 1                       # [B,1]
                j = jnp.arange(W, dtype=jnp.int32)[None]         # [1,W]
                src = q_last - jnp.mod(q_last - j, W)            # [B,W]
                ok = (src >= 0)[..., None, None]
                srcc = jnp.clip(src, 0, S - 1)
                bidx = jnp.arange(B, dtype=jnp.int32)[:, None]
                ring_k = jnp.where(ok, k[bidx, srcc], 0).astype(k.dtype)
                ring_v = jnp.where(ok, v[bidx, srcc], 0).astype(v.dtype)
                new_cache = {"k": ring_k, "v": ring_v}
            else:
                ck = jnp.zeros((B, ctx.smax) + k.shape[2:], k.dtype)
                cv = jnp.zeros((B, ctx.smax) + v.shape[2:], v.dtype)
                ck = jax.lax.dynamic_update_slice_in_dim(ck, k, 0, axis=1)
                cv = jax.lax.dynamic_update_slice_in_dim(cv, v, 0, axis=1)
                new_cache = {"k": ck, "v": cv}
    out = out.reshape(B, S, Hp * cfg.head_dim)
    wo = params["wo"]
    if Hp != cfg.num_heads:                 # zero rows for padded heads
        wo = jnp.pad(wo, ((0, (Hp - cfg.num_heads) * cfg.head_dim), (0, 0)))
    return linear(out, wo, params.get("bo")), new_cache


def attn_cache_init(cfg: cfgs.ModelConfig, batch: int, smax: int, *,
                    window: int, dtype):
    cap = window if window else smax
    shape = (batch, cap, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ===========================================================================
# MoE MLP (token-choice top-k, capacity-based, EP over the `model` mesh axis)
# ===========================================================================


def moe_init(key, cfg: cfgs.ModelConfig, dtype):
    m = cfg.moe
    ks = jax.random.split(key, 5)
    E = m.padded_num_experts
    d, f = cfg.d_model, m.expert_d_ff

    def stack(k, din, dout):
        keys = jax.random.split(k, E)
        return jnp.stack([dense_init(kk, din, dout, dtype) for kk in keys])

    p = {
        "router": {"w": dense_init(ks[0], d, E, jnp.float32)},
        "experts": {
            "wi": stack(ks[1], d, f),
            "wg": stack(ks[2], d, f),
            "wo": stack(ks[3], f, d),
        },
    }
    if m.num_shared_experts:
        p["shared"] = mlp_init(ks[4], d, m.shared_d_ff, dtype, cfg.use_bias)
    return p


def _route(router_w, x2d, m: cfgs.MoEConfig):
    """x2d: [T,D] -> normalized top-k gates scattered to [T,E] (fp32)."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), router_w)
    E = m.padded_num_experts
    if E > m.num_experts:                     # mask padding experts
        pad_mask = jnp.arange(E) < m.num_experts
        logits = jnp.where(pad_mask[None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, m.num_experts_per_tok)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)
    gates = jnp.zeros_like(probs)
    gates = gates.at[jnp.arange(x2d.shape[0])[:, None], topi].set(topv)
    # Switch-style load balance aux loss (over true experts only)
    frac_tokens = jnp.mean((gates > 0).astype(jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = m.num_experts * jnp.sum(frac_tokens * frac_probs)
    return gates, aux * m.router_aux_loss


def _moe_local(x2d, gates_loc, wi, wg, wo, capacity: int):
    """Run tokens through a local slice of experts.

    x2d: [T,D]; gates_loc: [T,E_loc]; wi/wg: [E_loc,D,F]; wo: [E_loc,F,D].
    Per expert, up to ``capacity`` tokens are selected by gate priority
    (overflow dropped, matching capacity-factor semantics).
    Returns [T,D] contribution of the local experts.
    """
    T, D = x2d.shape
    E_loc = wi.shape[0]
    sel = (gates_loc > 0).astype(jnp.float32)
    # top-capacity tokens per expert, priority = gate weight (stable ties)
    prio = jnp.swapaxes(gates_loc, 0, 1)                   # [E_loc, T]
    _, idx = jax.lax.top_k(prio, min(capacity, T))         # [E_loc, C]
    tok = x2d[idx]                                         # [E_loc, C, D]
    g = jnp.take_along_axis(jnp.swapaxes(gates_loc, 0, 1), idx, axis=1)
    valid = g > 0                                          # [E_loc, C]
    h = jnp.einsum("ecd,edf->ecf", tok, wi)
    gate = jnp.einsum("ecd,edf->ecf", tok, wg)
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(h.dtype) * h
    out = jnp.einsum("ecf,efd->ecd", h, wo)                # [E_loc, C, D]
    out = out * (g * valid)[..., None].astype(out.dtype)
    y = jnp.zeros((T, D), out.dtype)
    y = y.at[idx.reshape(-1)].add(out.reshape(-1, D), mode="drop")
    return y


def _capacity(m: cfgs.MoEConfig, t_loc: int, mode: str) -> int:
    """Per-expert token capacity.  Decode is dropless (tiny T); train/prefill
    use the capacity factor (overflow dropped by gate priority)."""
    if mode == "decode":
        return t_loc
    import math
    return min(t_loc, max(1, math.ceil(
        m.num_experts_per_tok * t_loc * m.capacity_factor / m.num_experts)))


def moe_apply(params, x, ctx: Ctx):
    cfg = ctx.cfg
    m = cfg.moe
    B, S, D = x.shape
    x2d = x.reshape(B * S, D)
    gates, aux = _route(params["router"]["w"], x2d, m)
    E = m.padded_num_experts
    mesh_axes = ctx.mesh_axes
    if mesh_axes is not None:
        # gate: tokens must divide the dp axes and experts the tp axis
        _mesh, _dp, _tp = mesh_axes
        _dp = _dp if isinstance(_dp, tuple) else (_dp,)
        dp_size = 1
        for a in _dp:
            dp_size *= _mesh.shape[a]
        if (B * S) % dp_size != 0 or E % _mesh.shape[_tp] != 0:
            mesh_axes = None
    if mesh_axes is not None:
        mesh, dp_axes, tp_axis = mesh_axes
        tp = mesh.shape[tp_axis]
        E_loc = E // tp
        P = jax.sharding.PartitionSpec

        def local_fn(xl, gl, wi, wg, wo):
            axis_idx = jax.lax.axis_index(tp_axis)
            off = axis_idx * E_loc
            g_slice = jax.lax.dynamic_slice_in_dim(gl, off, E_loc, axis=1)
            cap = _capacity(m, xl.shape[0], ctx.mode)
            y = _moe_local(xl, g_slice, wi, wg, wo, cap)
            return jax.lax.psum(y, tp_axis)

        dp = dp_axes if isinstance(dp_axes, tuple) else (dp_axes,)
        y2d = jax.shard_map(
            local_fn,
            mesh=mesh,
            in_specs=(P(dp, None), P(dp, None),
                      P(tp_axis, None, None), P(tp_axis, None, None),
                      P(tp_axis, None, None)),
            out_specs=P(dp, None),
            check_vma=False,
        )(x2d, gates, params["experts"]["wi"], params["experts"]["wg"],
          params["experts"]["wo"])
    else:
        cap = _capacity(m, x2d.shape[0], ctx.mode)
        y2d = _moe_local(x2d, gates, params["experts"]["wi"],
                         params["experts"]["wg"], params["experts"]["wo"], cap)
    y = y2d.reshape(B, S, D).astype(x.dtype)
    if m.num_shared_experts:
        y = y + apply_mlp(params["shared"], x)
    return y, aux


# ===========================================================================
# RG-LRU recurrent block (RecurrentGemma / Griffin)
# ===========================================================================

_RG_C = 8.0  # decay sharpness constant from the Griffin paper


def rglru_init(key, cfg: cfgs.ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    d, w = cfg.d_model, cfg.lru_width
    # a_param initialised so that a = sigmoid(a_param)^c is in (0.9, 0.999)
    a0 = jnp.linspace(2.0, 6.0, w).astype(jnp.float32)
    return {
        "in_x": dense_init(ks[0], d, w, dtype),
        "in_gate": dense_init(ks[1], d, w, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv1d_width, w), jnp.float32)
                   * 0.02).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "a_param": a0,
        "i_gate_w": jnp.ones((w,), jnp.float32),
        "i_gate_b": jnp.zeros((w,), jnp.float32),
        "r_gate_w": jnp.ones((w,), jnp.float32),
        "r_gate_b": jnp.zeros((w,), jnp.float32),
        "out": dense_init(ks[3], w, d, dtype),
    }


def _rglru_coeffs(params, u):
    """u: [...,W] conv output -> (a, b) of h_t = a*h + b (fp32)."""
    uf = u.astype(jnp.float32)
    i_gate = jax.nn.sigmoid(uf * params["i_gate_w"] + params["i_gate_b"])
    r_gate = jax.nn.sigmoid(uf * params["r_gate_w"] + params["r_gate_b"])
    log_a_base = jax.nn.log_sigmoid(params["a_param"])       # [W]
    log_a = _RG_C * r_gate * log_a_base                      # [...,W] (<0)
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * (i_gate * uf)
    return a, b


def _causal_conv(params, x, prev):
    """Depthwise causal conv1d. x: [B,S,W]; prev: [B,cw-1,W] history."""
    cw = params["conv_w"].shape[0]
    xp = jnp.concatenate([prev.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * params["conv_w"][cw - 1 - i]
            for i in range(cw))
    return y + params["conv_b"]


def rglru_apply(params, x, ctx: Ctx):
    cfg = ctx.cfg
    B, S, _ = x.shape
    u = linear(x, params["in_x"])                            # [B,S,W]
    gate = linear(x, params["in_gate"])
    cache = ctx.cache
    if ctx.mode == "decode":
        prev = cache["conv"]
        h0 = cache["h"]
    else:
        cw = params["conv_w"].shape[0]
        prev = jnp.zeros((B, cw - 1, u.shape[-1]), u.dtype)
        h0 = jnp.zeros((B, u.shape[-1]), jnp.float32)
    uc = _causal_conv(params, u, prev)
    a, b = _rglru_coeffs(params, uc)
    if ctx.mode == "prefill" and ctx.valid is not None:
        # pad positions perform an identity state update (a=1, b=0) so the
        # final carried state equals the state at the last valid token
        vm = ctx.valid[..., None]
        a = jnp.where(vm, a, 1.0)
        b = jnp.where(vm, b, 0.0)

    if ctx.mode == "decode":
        assert S == 1
        h = a[:, 0] * h0 + b[:, 0]                           # [B,W]
        hs = h[:, None]
        new_cache = {"h": h,
                     "conv": jnp.concatenate([prev, u], axis=1)[:, 1:]}
    else:
        def step(h, ab):
            a_t, b_t = ab
            h = a_t * h + b_t
            return h, h
        hT, hs = jax.lax.scan(step, h0,
                              (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
        hs = jnp.moveaxis(hs, 0, 1)                          # [B,S,W]
        new_cache = ctx.cache
        if ctx.mode == "prefill":
            # conv history = the last (cw-1) *valid* inputs per sequence
            cw = params["conv_w"].shape[0]
            lens = (ctx.lengths if ctx.lengths is not None
                    else jnp.full((B,), S, jnp.int32))
            idx = lens[:, None] - (cw - 1) + jnp.arange(cw - 1)[None]   # [B,cw-1]
            ok = (idx >= 0)[..., None]
            idxc = jnp.clip(idx, 0, S - 1)
            bidx = jnp.arange(B)[:, None]
            conv_hist = jnp.where(ok, u[bidx, idxc], 0).astype(u.dtype)
            new_cache = {"h": hT, "conv": conv_hist}
    y = hs.astype(x.dtype) * jax.nn.gelu(gate.astype(jnp.float32)).astype(x.dtype)
    return linear(y, params["out"]), new_cache


def rglru_cache_init(cfg: cfgs.ModelConfig, batch: int, dtype):
    w = cfg.lru_width
    return {"h": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv1d_width - 1, w), dtype)}


# ===========================================================================
# RWKV-6 "Finch" block (time-mix + channel-mix)
# ===========================================================================


def rwkv_init(key, cfg: cfgs.ModelConfig, dtype):
    ks = jax.random.split(key, 10)
    d, f = cfg.d_model, cfg.d_ff
    H = d // cfg.rwkv_head_size
    hd = cfg.rwkv_head_size
    p = {
        "tmix": {
            "mu_r": jnp.full((d,), 0.5, dtype), "mu_k": jnp.full((d,), 0.5, dtype),
            "mu_v": jnp.full((d,), 0.5, dtype), "mu_g": jnp.full((d,), 0.5, dtype),
            "mu_w": jnp.full((d,), 0.5, dtype),
            "wr": dense_init(ks[0], d, d, dtype), "wk": dense_init(ks[1], d, d, dtype),
            "wv": dense_init(ks[2], d, d, dtype), "wg": dense_init(ks[3], d, d, dtype),
            "ww": dense_init(ks[4], d, d, dtype, scale=0.1),
            "wo": dense_init(ks[5], d, d, dtype),
            "w0": jnp.linspace(-6.0, -1.0, d).astype(jnp.float32),
            "u": (jax.random.normal(ks[6], (H, hd), jnp.float32) * 0.1),
            "gn_scale": jnp.ones((d,), jnp.float32),
            "gn_bias": jnp.zeros((d,), jnp.float32),
        },
        "cmix": {
            "mu_k": jnp.full((d,), 0.5, dtype),
            "wk": dense_init(ks[7], d, f, dtype),
            "wv": dense_init(ks[8], f, d, dtype),
        },
    }
    return p


def _token_shift(x, prev):
    """x: [B,S,D]; prev: [B,D] last token of the previous segment."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _wkv_scan(r, k, v, w, u, s0):
    """RWKV6 recurrence.  r,k,v,w: [B,S,H,hd] (w in (0,1)); u: [H,hd];
    s0: [B,H,hd,hd] fp32.  Returns (o: [B,S,H,hd], sT)."""
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))

    def step(s, rkvw):
        r_t, k_t, v_t, w_t = rkvw                       # [B,H,hd]
        kv = k_t[..., :, None] * v_t[..., None, :]      # [B,H,hd,hd]
        s_att = s + u[None, :, :, None] * kv
        o_t = jnp.einsum("bhi,bhij->bhj", r_t, s_att)
        s = w_t[..., :, None] * s + kv
        return s, o_t

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, wf))
    sT, o = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(o, 0, 1), sT


def rwkv_apply(params, x, ctx: Ctx):
    """Full RWKV block: x + tmix(ln1(x)), then + cmix(ln2(.)).

    ``params`` is the whole block param dict (needs ln1/ln2).  Token-shift
    states are the last *normed* tokens of each stream (so that decode
    continues exactly where prefill left off).
    """
    cfg = ctx.cfg
    B, S, D = x.shape
    H = D // cfg.rwkv_head_size
    hd = cfg.rwkv_head_size
    tm = params["rwkv"]["tmix"]
    cm = params["rwkv"]["cmix"]
    cache = ctx.cache
    if ctx.mode == "decode":
        prev_t, prev_c, s0 = cache["shift_t"], cache["shift_c"], cache["s"]
    else:
        prev_t = jnp.zeros((B, D), x.dtype)
        prev_c = jnp.zeros((B, D), x.dtype)
        s0 = jnp.zeros((B, H, hd, hd), jnp.float32)

    # ---- time-mix ----
    h1 = apply_norm(params["ln1"], x, cfg.norm_eps)
    xx = _token_shift(h1, prev_t)

    def mix(mu):
        return h1 + mu * (xx - h1)

    r = linear(mix(tm["mu_r"]), tm["wr"]).reshape(B, S, H, hd)
    k = linear(mix(tm["mu_k"]), tm["wk"]).reshape(B, S, H, hd)
    v = linear(mix(tm["mu_v"]), tm["wv"]).reshape(B, S, H, hd)
    g = linear(mix(tm["mu_g"]), tm["wg"])
    decay_raw = tm["w0"] + linear(mix(tm["mu_w"]), tm["ww"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(decay_raw)).reshape(B, S, H, hd)
    if ctx.mode == "prefill" and ctx.valid is not None:
        # pads: decay 1, no kv injection -> state frozen at last valid token
        vm = ctx.valid[:, :, None, None]
        w = jnp.where(vm, w, 1.0)
        k = jnp.where(vm, k, 0.0).astype(k.dtype)

    o, sT = _wkv_scan(r, k, v, w, tm["u"], s0)
    o = group_norm_heads(o, tm["gn_scale"], tm["gn_bias"]).astype(x.dtype)
    o = o * jax.nn.silu(g.astype(jnp.float32)).astype(o.dtype)
    x2 = x + linear(o, tm["wo"])

    # ---- channel-mix ----
    h2 = apply_norm(params["ln2"], x2, cfg.norm_eps)
    xx2 = _token_shift(h2, prev_c)
    zk = h2 + cm["mu_k"] * (xx2 - h2)
    h = jnp.square(jax.nn.relu(linear(zk, cm["wk"]).astype(jnp.float32)))
    y2 = linear(h.astype(x.dtype), cm["wv"])
    out = x2 + y2

    new_cache = ctx.cache
    if ctx.mode in ("prefill", "decode"):
        if ctx.mode == "prefill" and ctx.lengths is not None:
            bidx = jnp.arange(B)
            last = jnp.clip(ctx.lengths - 1, 0, S - 1)
            st, sc = h1[bidx, last], h2[bidx, last]
        else:
            st, sc = h1[:, -1], h2[:, -1]
        new_cache = {"s": sT, "shift_t": st, "shift_c": sc}
    return out, new_cache


def rwkv_cache_init(cfg: cfgs.ModelConfig, batch: int, dtype):
    D = cfg.d_model
    H = D // cfg.rwkv_head_size
    hd = cfg.rwkv_head_size
    return {"s": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "shift_t": jnp.zeros((batch, D), dtype),
            "shift_c": jnp.zeros((batch, D), dtype)}


# ===========================================================================
# dispatch table
# ===========================================================================


def block_init(blk: str, key, cfg: cfgs.ModelConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    if blk in (cfgs.ATTN, cfgs.LOCAL_ATTN):
        p = {"ln1": norm_init(cfg.d_model, dtype, cfg.use_layernorm),
             "ln2": norm_init(cfg.d_model, dtype, cfg.use_layernorm),
             "attn": attn_init(k1, cfg, dtype)}
        if cfg.moe is not None:
            p["moe"] = moe_init(k2, cfg, dtype)
        else:
            p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype, cfg.use_bias)
        return p
    if blk == cfgs.RGLRU:
        return {"ln1": norm_init(cfg.d_model, dtype, cfg.use_layernorm),
                "ln2": norm_init(cfg.d_model, dtype, cfg.use_layernorm),
                "rec": rglru_init(k1, cfg, dtype),
                "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype, cfg.use_bias)}
    if blk == cfgs.RWKV:
        return {"ln1": norm_init(cfg.d_model, dtype, True),
                "ln2": norm_init(cfg.d_model, dtype, True),
                "rwkv": rwkv_init(k1, cfg, dtype)}
    raise ValueError(blk)


def block_cache_init(blk: str, cfg: cfgs.ModelConfig, batch: int, smax: int,
                     dtype):
    if blk == cfgs.ATTN:
        return attn_cache_init(cfg, batch, smax, window=0, dtype=dtype)
    if blk == cfgs.LOCAL_ATTN:
        return attn_cache_init(cfg, batch, smax,
                               window=cfg.attention_window, dtype=dtype)
    if blk == cfgs.RGLRU:
        return rglru_cache_init(cfg, batch, dtype)
    if blk == cfgs.RWKV:
        return rwkv_cache_init(cfg, batch, dtype)
    raise ValueError(blk)


def block_apply(blk: str, params, x, ctx: Ctx):
    cfg = ctx.cfg
    aux = jnp.float32(0.0)
    if blk in (cfgs.ATTN, cfgs.LOCAL_ATTN):
        window = cfg.attention_window if blk == cfgs.LOCAL_ATTN else 0
        h1 = apply_norm(params["ln1"], x, cfg.norm_eps)
        a_out, new_cache = attn_apply(params["attn"], h1, ctx, window=window)
        if cfg.parallel_block:
            if cfg.moe is not None:
                m_out, aux = moe_apply(params["moe"], h1, ctx)
            else:
                m_out = apply_mlp(params["mlp"], h1)
            y = x + a_out + m_out
        else:
            x = x + a_out
            h2 = apply_norm(params["ln2"], x, cfg.norm_eps)
            if cfg.moe is not None:
                m_out, aux = moe_apply(params["moe"], h2, ctx)
            else:
                m_out = apply_mlp(params["mlp"], h2)
            y = x + m_out
        return y, new_cache, aux
    if blk == cfgs.RGLRU:
        h1 = apply_norm(params["ln1"], x, cfg.norm_eps)
        r_out, new_cache = rglru_apply(params["rec"], h1, ctx)
        x = x + r_out
        h2 = apply_norm(params["ln2"], x, cfg.norm_eps)
        y = x + apply_mlp(params["mlp"], h2)
        return y, new_cache, aux
    if blk == cfgs.RWKV:
        # rwkv_apply handles norms, residuals and token-shift state itself
        out, new_cache = rwkv_apply(params, x, ctx)
        return out, new_cache, aux
    raise ValueError(blk)
