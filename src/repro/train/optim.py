"""AdamW in pure pytree form (ZeRO-style sharding comes from the pjit
specs in repro.launch.sharding — the moments inherit the param specs, so
on the production mesh every moment tensor is FSDP-sharded over `data`).

Moments are fp32 regardless of the (bf16) param dtype; the update math runs
in fp32 and casts back, the standard mixed-precision recipe.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay (fp32 scalar)."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def adamw_init(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, grads, opt_state, params
                 ) -> Tuple[Any, dict, dict]:
    """-> (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1c
        nhat = nu / b2c
        step = mhat / (jnp.sqrt(nhat) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return newp.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(opt_state["mu"])
    flat_nu = jax.tree.leaves(opt_state["nu"])
    out = [upd(p, g, mu, nu)
           for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "mu": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "nu": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "count": count,
    }
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
