"""The jit-able train step for every zoo architecture.

Structure (bottom to top): ce_loss_chunked (never materialises [B,S,V]) →
loss_fn (+ MoE aux) → grad → microbatch accumulation (lax.scan over
microbatches when cfg.grad_accum_steps > 1) → AdamW update.

The same function lowers on 1 CPU device (smoke tests) and on the 512-chip
production mesh (dry-run) — sharding comes entirely from in/out shardings
supplied by the launcher.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import base as cfgs
from repro.models import model_zoo
from repro.train.optim import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jnp.ndarray        # int32 scalar

    def tree(self):
        return {"params": self.params, "opt_state": self.opt_state,
                "step": self.step}

    @classmethod
    def from_tree(cls, t):
        return cls(t["params"], t["opt_state"], t["step"])


def init_state(model: model_zoo.Model, key,
               opt_cfg: Optional[AdamWConfig] = None) -> TrainState:
    params = model.init_params(key)
    return TrainState(params, adamw_init(params), jnp.zeros((), jnp.int32))


def loss_fn(model: model_zoo.Model, params, batch, *, remat: bool = True):
    out = model.apply(params, batch, mode="train", remat=remat)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    loss, count = model.ce_loss(params, out["hidden"], labels, mask)
    return loss + out["aux"], {"ce": loss, "aux": out["aux"], "tokens": count}


def _microbatches(batch: Dict[str, Any], n: int):
    """Split the leading (batch) axis into n microbatches: [n, B/n, ...]."""
    def split(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape((n, b // n) + x.shape[1:])
    return jax.tree.map(split, batch)


def make_train_step(model: model_zoo.Model,
                    opt_cfg: Optional[AdamWConfig] = None, *,
                    grad_accum: Optional[int] = None, remat: bool = True):
    """Returns train_step(state_tree, batch) -> (state_tree, metrics).

    state is passed as a plain pytree (dict) so jit in/out shardings can be
    expressed uniformly for the dry-run.
    """
    opt_cfg = opt_cfg or AdamWConfig()
    accum = grad_accum or model.cfg.grad_accum_steps

    def forward_backward(params, batch):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: loss_fn(model, p, batch, remat=remat), has_aux=True
        )(params)
        return loss, aux, grads

    def train_step(state: Dict[str, Any], batch: Dict[str, Any]):
        params = state["params"]
        if accum <= 1:
            loss, aux, grads = forward_backward(params, batch)
        else:
            micro = _microbatches(batch, accum)

            def body(carry, mb):
                acc, loss_sum = carry
                loss, _aux, grads = forward_backward(params, mb)
                acc = jax.tree.map(jnp.add, acc, grads)
                return (acc, loss_sum + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, loss_sum), _ = jax.lax.scan(body, (zeros, 0.0), micro)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = loss_sum / accum
            aux = {"ce": loss, "aux": jnp.float32(0.0),
                   "tokens": jnp.float32(0.0)}
        new_params, new_opt, om = adamw_update(opt_cfg, grads, state["opt_state"],
                                               params)
        metrics = {"loss": loss, **{k: v for k, v in aux.items()}, **om}
        new_state = {"params": new_params, "opt_state": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return train_step


def make_eval_step(model: model_zoo.Model, *, remat: bool = False):
    def eval_step(params, batch):
        loss, aux = loss_fn(model, params, batch, remat=remat)
        return {"loss": loss, **aux}
    return eval_step
