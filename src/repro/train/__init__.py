"""Training substrate: optimizer, train step, data pipeline, checkpoints."""
from repro.train.optim import AdamWConfig, adamw_init, adamw_update  # noqa: F401
from repro.train.train_step import TrainState, make_train_step, init_state  # noqa: F401
from repro.train.checkpoint import CheckpointManager                 # noqa: F401
from repro.train.data import TokenPipeline                           # noqa: F401
