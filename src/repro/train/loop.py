"""Training loop with the fleet-operations envelope:

  * checkpoint every N steps + auto-resume from the newest valid one;
  * injected failures (``FailureInjector``) exercise the crash-restart
    path end-to-end in tests;
  * straggler watchdog — per-step wall-time EWMA; steps slower than
    ``straggler_factor``× the EWMA are counted and surfaced (on a real
    fleet this triggers hot-spare swap; here it feeds telemetry/tests);
  * deterministic data — resuming at step k replays exactly batch k.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model_zoo
from repro.train.checkpoint import CheckpointManager
from repro.train.data import TokenPipeline
from repro.train.optim import AdamWConfig
from repro.train.train_step import init_state, make_train_step


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Deterministically fail at the given global steps (before commit)."""
    fail_at_steps: tuple = ()
    fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise InjectedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 20
    checkpoint_every: int = 5
    straggler_factor: float = 3.0
    log_every: int = 5


class Trainer:
    def __init__(self, model: model_zoo.Model, pipeline: TokenPipeline,
                 ckpt: CheckpointManager, *,
                 loop: Optional[LoopConfig] = None,
                 opt: Optional[AdamWConfig] = None,
                 injector: Optional[FailureInjector] = None,
                 seed: int = 0):
        self.model = model
        self.pipeline = pipeline
        self.ckpt = ckpt
        self.loop_cfg = loop or LoopConfig()
        self.opt_cfg = opt or AdamWConfig()
        self.injector = injector
        self.seed = seed
        self.step_fn = jax.jit(make_train_step(model, self.opt_cfg))
        self.history: List[Dict[str, float]] = []
        self.straggler_steps = 0
        self.resumed_from: Optional[int] = None

    # ------------------------------------------------------------------
    def _fresh_state(self):
        return init_state(self.model, jax.random.PRNGKey(self.seed)).tree()

    def run(self) -> Dict[str, Any]:
        state = self._fresh_state()
        latest = self.ckpt.restore_latest(state)
        start = 0
        if latest is not None:
            start, state = latest
            self.resumed_from = start
        ewma = None
        for step in range(start, self.loop_cfg.total_steps):
            batch = {k: jnp.asarray(v)
                     for k, v in self.pipeline.batch_at(step).items()}
            t0 = time.perf_counter()
            if self.injector is not None:
                self.injector.maybe_fail(step)
            state, metrics = self.step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            if ewma is None:
                ewma = dt
            elif dt > self.loop_cfg.straggler_factor * ewma:
                self.straggler_steps += 1
            ewma = 0.9 * (ewma or dt) + 0.1 * dt
            self.history.append({"step": step + 1,
                                 "loss": float(metrics["loss"]),
                                 "grad_norm": float(metrics["grad_norm"]),
                                 "seconds": dt})
            done = step + 1
            if (done % self.loop_cfg.checkpoint_every == 0
                    or done == self.loop_cfg.total_steps):
                self.ckpt.save(done, state)
        return {"state": state, "history": self.history,
                "straggler_steps": self.straggler_steps,
                "resumed_from": self.resumed_from}


def run_with_restarts(make_trainer: Callable[[], Trainer],
                      max_restarts: int = 4) -> Dict[str, Any]:
    """Supervisor: restart the trainer on failure (the fleet controller)."""
    restarts = 0
    while True:
        trainer = make_trainer()
        try:
            out = trainer.run()
            out["restarts"] = restarts
            return out
        except InjectedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
