"""Fault-tolerant checkpointing.

Production posture scaled down to a single host:

  * atomic publish — write to ``step_K.tmp/``, fsync, rename to ``step_K/``
    (a crash mid-write can never corrupt the latest checkpoint);
  * integrity — every array shard gets a sha256 recorded in ``MANIFEST.json``;
    restore verifies and rejects corrupt checkpoints;
  * auto-resume — ``restore_latest`` walks checkpoints newest-first and
    returns the first one that verifies, so a torn write or bit-rot falls
    back to the previous step (the node-failure recovery path);
  * retention — keeps the newest ``keep`` checkpoints.

Arrays are stored leaf-per-file (`.npy`) with the pytree structure in the
manifest, which is exactly the layout a multi-host fleet writes per shard.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

# numpy's .npy format doesn't know ml_dtypes (bfloat16 etc.) — store a
# same-width integer view and record the logical dtype in the manifest.
_VIEW_FOR = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
             "float8_e5m2": np.uint8}


def _leafpaths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


class CheckpointCorrupt(RuntimeError):
    pass


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any) -> str:
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest: Dict[str, Any] = {"step": step, "leaves": {}}
        for key, leaf in _leafpaths(tree):
            arr = np.asarray(leaf)
            fname = key.replace("/", "__") + ".npy"
            fpath = os.path.join(tmp, fname)
            store = arr
            if str(arr.dtype) in _VIEW_FOR:
                store = arr.view(_VIEW_FOR[str(arr.dtype)])
            np.save(fpath, store, allow_pickle=False)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype), "sha256": _sha256(fpath)}
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def restore(self, step: int, like: Any) -> Any:
        """Restore into the structure of ``like`` (verifying hashes)."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "MANIFEST.json")) as f:
            manifest = json.load(f)
        leaves = {}
        for key, meta in manifest["leaves"].items():
            fpath = os.path.join(path, meta["file"])
            if _sha256(fpath) != meta["sha256"]:
                raise CheckpointCorrupt(f"{path}: bad hash for {key}")
            arr = np.load(fpath, allow_pickle=False)
            if meta["dtype"] in _VIEW_FOR:
                arr = arr.view(ml_dtypes.bfloat16 if meta["dtype"] ==
                               "bfloat16" else meta["dtype"])
            leaves[key] = arr
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        restored = []
        for pathk, leaf in flat:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in pathk)
            if key not in leaves:
                raise CheckpointCorrupt(f"{path}: missing leaf {key}")
            arr = leaves[key]
            restored.append(np.asarray(arr, dtype=leaf.dtype)
                            if hasattr(leaf, "dtype") else arr)
        return jax.tree_util.tree_unflatten(treedef, restored)

    def restore_latest(self, like: Any) -> Optional[Tuple[int, Any]]:
        """Newest checkpoint that verifies; corrupt ones are skipped."""
        for step in reversed(self.all_steps()):
            try:
                return step, self.restore(step, like)
            except (CheckpointCorrupt, FileNotFoundError, json.JSONDecodeError):
                continue
        return None
