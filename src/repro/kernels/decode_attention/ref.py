"""Pure-jnp oracle for the flash-decode kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """q: [B,H,hd] (single new token, already at position lengths-1);
    k_cache,v_cache: [B,KV,Smax,hd]; lengths: [B] valid tokens.
    Returns [B,H,hd]."""
    B, H, hd = q.shape
    KV, Smax = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    logits = jnp.einsum("bcgd,bcsd->bcgs", qg, k_cache,
                        preferred_element_type=jnp.float32) / jnp.sqrt(
                            jnp.float32(hd))
    valid = jnp.arange(Smax)[None] < lengths[:, None]          # [B,Smax]
    logits = jnp.where(valid[:, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bcgs,bcsd->bcgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, hd).astype(q.dtype)


def decode_attention_with_lse_ref(q, k_cache, v_cache, lengths):
    """Like :func:`decode_attention_ref` but also returns the logsumexp
    over the (local) sequence — the shard-combine statistic.

    q: [B,H,hd]; k_cache,v_cache: [B,KV,Smax,hd]; lengths: [B].
    Returns (out [B,H,hd], lse [B,H,1] fp32).
    """
    B, H, hd = q.shape
    KV, Smax = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    logits = jnp.einsum("bcgd,bcsd->bcgs", qg, k_cache,
                        preferred_element_type=jnp.float32) / jnp.sqrt(
                            jnp.float32(hd))
    valid = jnp.arange(Smax)[None] < lengths[:, None]
    logits = jnp.where(valid[:, None, None], logits, -jnp.inf)
    m = jnp.max(logits, axis=-1, keepdims=True)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.exp(logits - m_safe)
    e = jnp.where(jnp.isfinite(logits), e, 0.0)
    l = jnp.sum(e, axis=-1, keepdims=True)
    out = jnp.einsum("bcgs,bcsd->bcgd", e.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    out = out / jnp.maximum(l, 1e-30)
    lse = m_safe + jnp.log(jnp.maximum(l, 1e-30))
    lse = jnp.where(l > 0, lse, -jnp.inf)
    return (out.reshape(B, H, hd).astype(q.dtype),
            lse.reshape(B, H, 1).astype(jnp.float32))
