"""Pallas TPU flash-decode kernel.

One new token per sequence attends to a (possibly very long) KV cache.
Grid: (B, KV, num_kv_blocks) — kv blocks innermost/sequential with the
online-softmax state in VMEM scratch; the q block is the [G, hd] group of
query heads sharing one kv head (GQA), so the matmul shape is
[G, hd] x [hd, block_k] -> MXU-friendly after sublane padding.

KV blocks entirely beyond ``length`` are skipped (``pl.when``) — this is the
structural analogue of not reading evicted pages on GPU serving stacks, and
what makes the 500k-context decode cell latency proportional to the *valid*
prefix, not the allocated capacity.

The sequence axis may be sharded over the `model` mesh axis; each shard then
runs this kernel over its chunk and the partial (acc, m, l) triples are
combined with a logsumexp reduction (see ops.flash_decode_sharded).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, lse_ref,
                   acc_ref, m_ref, l_ref,
                   *, sm_scale: float, block_k: int, seq_kv: int):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    k_start = ki * block_k
    length = len_ref[0]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(k_start < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)                   # [G, hd]
        k = k_ref[0, 0].astype(jnp.float32)                   # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale     # [G, bk]
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, 1)
        mask = jnp.logical_and(k_pos < length, k_pos < seq_kv)
        logits = jnp.where(mask, logits, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(logits - m_cur)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_cur
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        lsafe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / lsafe).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_ref[...] + jnp.log(lsafe)).astype(jnp.float32)


def decode_attention_kernel(q, k_cache, v_cache, lengths, *,
                            block_k: int = 512, interpret: bool = True,
                            return_lse: bool = False):
    """q: [B,H,hd]; k_cache,v_cache: [B,KV,Smax,hd]; lengths: [B].
    Returns [B,H,hd] (and optionally the per-head logsumexp [B,H,1] for
    cross-shard combination)."""
    B, H, hd = q.shape
    KV, Smax = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = hd ** -0.5
    block_k = min(block_k, Smax)
    pad_k = (-Smax) % block_k
    if pad_k:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nk = (Smax + pad_k) // block_k
    qg = q.reshape(B, KV, G, hd)

    kernel = functools.partial(
        _decode_kernel, sm_scale=scale, block_k=block_k, seq_kv=Smax)

    out_shapes = [jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
                  jax.ShapeDtypeStruct((B, KV, G, 1), jnp.float32)]
    out_specs = [pl.BlockSpec((1, 1, G, hd), lambda b, c, j: (b, c, 0, 0)),
                 pl.BlockSpec((1, 1, G, 1), lambda b, c, j: (b, c, 0, 0))]

    res = pl.pallas_call(
        kernel,
        grid=(B, KV, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, c, j: (b,),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, hd), lambda b, c, j: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, c, j: (b, c, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, c, j: (b, c, j, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shapes,
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(lengths, qg, k_cache, v_cache)
    out = res[0].reshape(B, H, hd)
    if return_lse:
        return out, res[1].reshape(B, H, 1)
    return out
