"""Public jit'd wrappers for flash-decode (model layout caches).

``flash_decode`` runs on a single device / replicated cache.
``flash_decode_sharded`` shard_maps over a mesh axis holding KV-sequence
chunks and combines per-shard partial attention with a logsumexp reduction —
the TPU-native replacement for paged attention at 32k-500k contexts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.decode_attention.kernel import decode_attention_kernel
from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                decode_attention_with_lse_ref)

# jax.shard_map only exists from 0.6; on the pinned 0.4.x it lives in
# jax.experimental and spells the replication-check kwarg "check_rep".
if hasattr(jax, "shard_map"):
    def _shard_map(fn, *, mesh, in_specs, out_specs):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def _shard_map(fn, *, mesh, in_specs, out_specs):
        return _exp_shard_map(fn, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("impl", "block_k"))
def flash_decode(q, k_cache, v_cache, lengths, *, impl: str = "auto",
                 block_k: int = 512):
    """q: [B,1,H,hd]; k_cache,v_cache: [B,Smax,KV,hd]; lengths: [B].
    Returns [B,1,H,hd]."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    q3 = q[:, 0]                                   # [B,H,hd]
    kc = jnp.swapaxes(k_cache, 1, 2)               # [B,KV,Smax,hd]
    vc = jnp.swapaxes(v_cache, 1, 2)
    if impl == "reference":
        out = decode_attention_ref(q3, kc, vc, lengths)
    else:
        out = decode_attention_kernel(
            q3, kc, vc, lengths, block_k=block_k,
            interpret=(impl == "interpret"))
    return out[:, None]


def gather_kv_blocks(pool, tables):
    """Dense cache view of a paged KV pool.

    pool: [NB, bs, ...] fixed-size blocks; tables: int32 [B, nb] per-
    sequence block tables.  Returns [B, nb*bs, ...] — sequence ``b``'s
    tokens contiguous at positions ``0..len_b-1`` (table order).
    """
    g = jnp.take(pool, tables.reshape(-1), axis=0)     # [B*nb, bs, ...]
    B, nb = tables.shape
    return g.reshape((B, nb * pool.shape[1]) + pool.shape[2:])


@functools.partial(jax.jit, static_argnames=("impl", "block_k"))
def flash_decode_paged(q, k_pool, v_pool, tables, lengths, *,
                       impl: str = "auto", block_k: int = 512):
    """Flash-decode against paged KV pools via block-table gather.

    q: [B,1,H,hd]; k_pool,v_pool: [NB,bs,KV,hd]; tables: int32 [B,nb];
    lengths: [B] valid tokens per sequence.  Returns [B,1,H,hd], bitwise
    equal to ``flash_decode`` over the equivalent dense [B,nb*bs] cache.
    """
    kc = gather_kv_blocks(k_pool, tables)
    vc = gather_kv_blocks(v_pool, tables)
    return flash_decode(q, kc, vc, lengths, impl=impl, block_k=block_k)


def flash_decode_sharded(q, k_cache, v_cache, lengths, *, mesh, seq_axis: str,
                         dp_axes, impl: str = "auto", block_k: int = 512):
    """Flash-decode with the cache sequence axis sharded over ``seq_axis``.

    Each shard computes partial attention over its chunk plus the local
    logsumexp; partials are combined exactly:
        out = Σ_s out_s · softmax_weight_s,   w_s = exp(lse_s - lse_max)·l_s
    Collective cost: one psum of [B,H,hd] + [B,H,1] over seq_axis (vs the
    naive all-gather of the full [B,H,S] logits row).
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    B, _, H, hd = q.shape
    dp = dp_axes if isinstance(dp_axes, tuple) else (dp_axes,)
    dp = dp if dp else None   # B=1 cells: batch replicated

    def local(q_, kc_, vc_, lengths_):
        idx = jax.lax.axis_index(seq_axis)
        chunk = kc_.shape[1]
        # local valid length within this shard's chunk
        loc_len = jnp.clip(lengths_ - idx * chunk, 0, chunk)
        q3 = q_[:, 0]
        kc = jnp.swapaxes(kc_, 1, 2)
        vc = jnp.swapaxes(vc_, 1, 2)
        if impl == "reference":
            # pure-jnp local pass: what the dry-run/roofline analyses see
            # (the pallas path is the TPU-native implementation)
            out, lse = decode_attention_with_lse_ref(q3, kc, vc, loc_len)
        else:
            out, lse = decode_attention_kernel(
                q3, kc, vc, loc_len, block_k=min(block_k, chunk),
                interpret=(impl != "pallas"), return_lse=True)
            # lse of an empty chunk is 0 from the kernel init path; mask it
            empty = (loc_len == 0)[:, None, None]
            lse = jnp.where(empty, -jnp.inf, lse)
        lse_max = jax.lax.pmax(lse, seq_axis)
        wgt = jnp.exp(lse - lse_max)
        wgt = jnp.where(jnp.isfinite(wgt), wgt, 0.0)
        num = jax.lax.psum(out.astype(jnp.float32) * wgt, seq_axis)
        den = jax.lax.psum(wgt, seq_axis)
        return (num / jnp.maximum(den, 1e-30)).astype(q_.dtype)[:, None]

    return _shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, None, None, None), P(dp, seq_axis, None, None),
                  P(dp, seq_axis, None, None), P(dp)),
        out_specs=P(dp, None, None, None),
    )(q, k_cache, v_cache, lengths)


def write_kv_sharded(cache_k, cache_v, k_new, v_new, start, *, mesh,
                     seq_axis: str, dp_axes):
    """Write single-token k/v into a cache whose sequence axis is sharded.

    The naive scatter forces XLA to all-gather the whole cache (observed:
    ~80 GB of collective traffic per decode step on a 35B/32k cell).  Under
    shard_map the write lands entirely in the shard owning position
    ``start``; every other shard is a masked no-op — zero collectives.

    cache_k/v: [B, Smax, KV, hd] (Smax sharded over seq_axis);
    k_new/v_new: [B, 1, KV, hd]; start: [B] global write positions.
    """
    dp = tuple(dp_axes) if isinstance(dp_axes, (tuple, list)) else (dp_axes,)
    bspec = dp if dp else None

    def local(ck, cv, kn, vn, st):
        idx = jax.lax.axis_index(seq_axis)
        chunk = ck.shape[1]
        loc = st - idx * chunk                      # [B] local position
        ok = (loc >= 0) & (loc < chunk)
        locc = jnp.clip(loc, 0, chunk - 1)
        b = jnp.arange(ck.shape[0])
        cur_k = ck[b, locc]
        cur_v = cv[b, locc]
        m = ok[:, None, None]
        new_k = jnp.where(m, kn[:, 0], cur_k)
        new_v = jnp.where(m, vn[:, 0], cur_v)
        return ck.at[b, locc].set(new_k), cv.at[b, locc].set(new_v)

    return _shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec, seq_axis, None, None),
                  P(bspec, seq_axis, None, None),
                  P(bspec, None, None, None),
                  P(bspec, None, None, None), P(bspec)),
        out_specs=(P(bspec, seq_axis, None, None),
                   P(bspec, seq_axis, None, None)),
    )(cache_k, cache_v, k_new, v_new, start)
