"""Pure-jnp oracle for the RWKV-6 wkv recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_scan_ref(r, k, v, w, u, s0):
    """RWKV6: o_t = r_t · (S_{t-1} + (u ⊙ k_t) v_tᵀ);  S_t = diag(w_t) S + k_t v_tᵀ.

    r,k,v,w: [B,S,H,hd]  (w ∈ (0,1) decay);  u: [H,hd];  s0: [B,H,hd,hd] fp32.
    Returns (o [B,S,H,hd] fp32, sT [B,H,hd,hd] fp32).
    """
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))

    def step(s, rkvw):
        r_t, k_t, v_t, w_t = rkvw
        kv = k_t[..., :, None] * v_t[..., None, :]
        s_att = s + u[None, :, :, None] * kv
        o_t = jnp.einsum("bhi,bhij->bhj", r_t, s_att)
        s = w_t[..., :, None] * s + kv
        return s, o_t

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, wf))
    sT, o = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return jnp.moveaxis(o, 0, 1), sT
