"""Pallas TPU kernel for the RWKV-6 wkv recurrence.

Grid: (B, H, num_time_blocks) — time innermost/sequential with the [hd, hd]
state matrix carried in VMEM scratch (hd = 64 for the zoo; the state tile is
64x64 fp32 = 16 KiB, far under VMEM).  Within a time block the per-step
update is rank-1 (outer product k_t v_tᵀ) plus a diagonal decay — VPU work —
while the readout r_t·S is a [1,hd]x[hd,hd] matvec.  This is the
TPU-native adaptation of the CUDA wkv kernel: instead of one thread per
channel, the state lives in vector registers/VMEM and the time loop is the
only sequential dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref,
                 s_ref, *, block_t: int):
    ti = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(ti == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)      # [bt, hd]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)         # [hd]

    def step(t, s):
        kt = k[t][:, None]                   # [hd,1]
        vt = v[t][None, :]                   # [1,hd]
        kv = kt * vt                         # [hd,hd]
        s_att = s + u[:, None] * kv
        o_t = jnp.einsum("i,ij->j", r[t], s_att)
        o_ref[0, 0, t] = o_t.astype(o_ref.dtype)
        s = w[t][:, None] * s + kv
        return s

    s = jax.lax.fori_loop(0, block_t, step, s_ref[...])
    s_ref[...] = s

    @pl.when(ti == nt - 1)
    def _fin():
        sT_ref[0, 0] = s_ref[...].astype(sT_ref.dtype)


def rwkv6_scan_kernel(r, k, v, w, u, s0, *, block_t: int = 64,
                      interpret: bool = True):
    """r,k,v,w: [B,S,H,hd]; u: [H,hd]; s0: [B,H,hd,hd].
    Returns (o [B,S,H,hd] fp32, sT [B,H,hd,hd] fp32)."""
    B, S, H, hd = r.shape
    block_t = min(block_t, S)
    pad_t = (-S) % block_t
    # layout: [B,H,S,hd] so the time axis is blockable per (b,h)
    def to_bhsd(x):
        x = jnp.moveaxis(x, 2, 1)
        if pad_t:
            x = jnp.pad(x, ((0, 0), (0, 0), (0, pad_t), (0, 0)))
        return x
    rr, kk, vv = to_bhsd(r), to_bhsd(k), to_bhsd(v)
    # padded decay must be 1.0 (identity update) so sT is unaffected
    ww = to_bhsd(w)
    if pad_t:
        tmask = (jnp.arange(S + pad_t) < S)[None, None, :, None]
        ww = jnp.where(tmask, ww, 1.0)
        kk = jnp.where(tmask, kk, 0.0)
    Sp = S + pad_t
    nt = Sp // block_t

    kernel = functools.partial(_rwkv_kernel, block_t=block_t)
    o, sT = pl.pallas_call(
        kernel,
        grid=(B, H, nt),
        in_specs=[
            pl.BlockSpec((1, 1, block_t, hd), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, block_t, hd), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, block_t, hd), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, block_t, hd), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, hd), lambda b, h, t: (h, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_t, hd), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sp, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rr, kk, vv, ww, u, s0)
    o = jnp.moveaxis(o[:, :, :S], 1, 2)      # back to [B,S,H,hd]
    return o, sT
