"""Public jit'd wrapper for the RWKV-6 wkv recurrence."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rwkv6_scan.kernel import rwkv6_scan_kernel
from repro.kernels.rwkv6_scan.ref import rwkv6_scan_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("impl", "block_t"))
def rwkv6_scan(r, k, v, w, u, s0, *, impl: str = "auto", block_t: int = 64):
    """r,k,v,w: [B,S,H,hd]; u: [H,hd]; s0: [B,H,hd,hd] ->
    (o [B,S,H,hd] fp32, sT [B,H,hd,hd] fp32)."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    if impl == "reference":
        return rwkv6_scan_ref(r, k, v, w, u, s0)
    return rwkv6_scan_kernel(r, k, v, w, u, s0, block_t=block_t,
                             interpret=(impl == "interpret"))
