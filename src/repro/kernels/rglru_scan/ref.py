"""Pure-jnp oracle for the RG-LRU linear-recurrence scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a, b, h0):
    """h_t = a_t * h_{t-1} + b_t.  a,b: [B,S,W] fp32; h0: [B,W].
    Returns (hs [B,S,W], hT [B,W])."""
    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h
    hT, hs = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (jnp.moveaxis(a.astype(jnp.float32), 1, 0),
         jnp.moveaxis(b.astype(jnp.float32), 1, 0)))
    return jnp.moveaxis(hs, 0, 1), hT
