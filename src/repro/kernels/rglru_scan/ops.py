"""Public jit'd wrapper for the RG-LRU scan."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rglru_scan.kernel import rglru_scan_kernel
from repro.kernels.rglru_scan.ref import rglru_scan_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("impl", "block_w", "block_t"))
def rglru_scan(a, b, h0, *, impl: str = "auto", block_w: int = 512,
               block_t: int = 128):
    """h_t = a_t*h_{t-1} + b_t.  a,b: [B,S,W]; h0: [B,W] ->
    (hs [B,S,W], hT [B,W]) in fp32."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    if impl == "reference":
        return rglru_scan_ref(a, b, h0)
    return rglru_scan_kernel(a, b, h0, block_w=block_w, block_t=block_t,
                             interpret=(impl == "interpret"))
