"""Pallas TPU kernel for the RG-LRU diagonal linear recurrence.

h_t = a_t ⊙ h_{t-1} + b_t  over the time axis, vectorised across a channel
block.  Grid: (B, num_channel_blocks, num_time_blocks) — time innermost and
sequential, the running state h carried in VMEM scratch.  Within a time
block the recurrence is an unavoidable loop-carried dependence, but each
step is a [block_w]-wide VPU op, so the kernel is bandwidth-bound exactly
like the roofline predicts for a diagonal RNN: bytes(a)+bytes(b)+bytes(out)
per step, zero MXU work.  block_w is lane-aligned (multiples of 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, hT_ref, h_ref, *,
                  block_t: int):
    ti = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)       # [bt, bw]
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        o_ref[0, t] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ti == nt - 1)
    def _fin():
        hT_ref[0] = h_ref[...].astype(hT_ref.dtype)


def rglru_scan_kernel(a, b, h0, *, block_w: int = 512, block_t: int = 128,
                      interpret: bool = True):
    """a,b: [B,S,W]; h0: [B,W] -> (hs [B,S,W] fp32, hT [B,W] fp32)."""
    B, S, W = a.shape
    block_w = min(block_w, W)
    block_t = min(block_t, S)
    pad_w = (-W) % block_w
    pad_t = (-S) % block_t
    if pad_w or pad_t:
        a = jnp.pad(a, ((0, 0), (0, pad_t), (0, pad_w)))
        b = jnp.pad(b, ((0, 0), (0, pad_t), (0, pad_w)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_w)))
    if pad_t:
        # padded time steps must be identity updates (a=1, b=0) so the
        # carried-out final state hT is the state at the last real step
        tmask = (jnp.arange(S + pad_t) < S)[None, :, None]
        a = jnp.where(tmask, a, 1.0)
    Sp, Wp = S + pad_t, W + pad_w
    nw, nt = Wp // block_w, Sp // block_t

    kernel = functools.partial(_rglru_kernel, block_t=block_t)
    hs, hT = pl.pallas_call(
        kernel,
        grid=(B, nw, nt),
        in_specs=[
            pl.BlockSpec((1, block_t, block_w), lambda bb, wi, ti: (bb, ti, wi)),
            pl.BlockSpec((1, block_t, block_w), lambda bb, wi, ti: (bb, ti, wi)),
            pl.BlockSpec((1, block_w), lambda bb, wi, ti: (bb, wi)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_w), lambda bb, wi, ti: (bb, ti, wi)),
            pl.BlockSpec((1, block_w), lambda bb, wi, ti: (bb, wi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Sp, Wp), jnp.float32),
            jax.ShapeDtypeStruct((B, Wp), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return hs[:, :S, :W], hT[:, :W]
