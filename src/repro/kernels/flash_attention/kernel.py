"""Pallas TPU flash-attention forward kernel (causal / sliding window, GQA).

Grid: (B, H, num_q_blocks, num_kv_blocks) — kv innermost (sequential), with
the online-softmax running max / sum / accumulator carried in VMEM scratch
across kv steps.  Causally-masked-out kv blocks are skipped entirely
(``pl.when``), so the kernel does ~half the FLOPs of the dense reference for
causal attention.  Block shapes are (block_q, head_dim) / (block_k, head_dim)
— head_dim is MXU-lane aligned for the zoo (128/256) and block_q/block_k
default to 128 (sublane-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  sm_scale: float, block_q: int, block_k: int,
                  q_offset: int, seq_kv: int, causal: bool, window: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    q_start = qi * block_q
    k_start = ki * block_k
    q_off = q_offset  # absolute offset of q positions relative to kv

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Skip kv blocks fully above the causal diagonal or below the window band.
    q_max = q_start + block_q - 1 + q_off          # largest q position
    q_min = q_start + q_off                        # smallest q position
    run = k_start >= 0                             # trivially-true traced bool
    if causal:
        run = jnp.logical_and(run, k_start <= q_max)
    if window:
        run = jnp.logical_and(run, k_start + block_k - 1 > q_min - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)                  # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)                  # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)                  # [bk, hd]
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale    # [bq, bk]
        q_pos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0) + q_off
        k_pos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = k_pos < seq_kv
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_ref[...]                                  # [bq,1]
        l_prev = l_ref[...]
        m_cur = jnp.maximum(m_prev, jnp.max(logits, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_cur)                      # [bq,1]
        p = jnp.exp(logits - m_cur)                          # [bq, bk]
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_cur
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True, window: int = 0,
                           sm_scale: float | None = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True):
    """q: [B,H,Sq,hd]; k,v: [B,KV,Skv,hd] -> [B,H,Sq,hd]."""
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    scale = float(sm_scale) if sm_scale is not None else hd ** -0.5
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = (Sq + pad_q) // block_q
    nk = (Skv + pad_k) // block_k
    grid = (B, H, nq, nk)

    kernel = functools.partial(
        _flash_kernel, sm_scale=scale, block_q=block_q, block_k=block_k,
        q_offset=Skv - Sq, seq_kv=Skv, causal=causal, window=window)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq + pad_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    if pad_q:
        out = out[:, :, :Sq]
    return out
