"""Pure-jnp oracle for the flash attention kernel (GQA causal attention)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        sm_scale: float | None = None):
    """q: [B,H,Sq,hd]; k,v: [B,KV,Skv,hd] -> [B,H,Sq,hd].

    GQA: q head h uses kv head h // (H // KV).  Optional sliding window.
    """
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    scale = sm_scale if sm_scale is not None else 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qg = q.reshape(B, KV, G, Sq, hd)
    logits = jnp.einsum("bcgqd,bckd->bcgqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    q_pos = jnp.arange(Sq)[:, None] + (Skv - Sq)
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window:
        mask = mask & (k_pos > q_pos - window)
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bcgqk,bckd->bcgqd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, Sq, hd).astype(q.dtype)
