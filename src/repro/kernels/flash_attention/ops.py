"""Public jit'd wrapper for flash attention (model layout [B,S,H,hd])."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "impl",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    impl: str = "auto", block_q: int = 128,
                    block_k: int = 128):
    """q: [B,Sq,H,hd]; k,v: [B,Skv,KV,hd] -> [B,Sq,H,hd].

    impl: "pallas" (compiled TPU kernel), "interpret" (kernel body traced on
    CPU — used by the test suite), "reference" (jnp oracle), "auto"
    (pallas on TPU else reference).
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    qt = jnp.swapaxes(q, 1, 2)            # [B,H,S,hd]
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    if impl == "reference":
        out = flash_attention_ref(qt, kt, vt, causal=causal, window=window)
    else:
        out = flash_attention_kernel(
            qt, kt, vt, causal=causal, window=window, block_q=block_q,
            block_k=block_k, interpret=(impl == "interpret"))
    return jnp.swapaxes(out, 1, 2)
