"""Public jit'd wrapper for batched cosine-similarity top-k."""
from __future__ import annotations

import functools

import jax

from repro.kernels.similarity_topk.kernel import similarity_topk_kernel
from repro.kernels.similarity_topk.ref import (l2_normalize,
                                               similarity_topk_ref)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit,
                   static_argnames=("k", "impl", "block_q", "block_n"))
def similarity_topk(queries, corpus, k: int, *, impl: str = "auto",
                    block_q: int = 128, block_n: int = 512):
    """Top-k corpus rows per query by cosine similarity.

    queries: [Q, D], corpus: [N, D] (any float dtype; normalized here).
    Returns ``(vals [Q, k] fp32 descending, idx [Q, k] int32)``; with
    ``k > N`` the tail holds ``-inf`` / ``-1``.
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "reference"
    if impl == "reference":
        return similarity_topk_ref(queries, corpus, k)
    return similarity_topk_kernel(
        l2_normalize(queries), l2_normalize(corpus), k,
        block_q=block_q, block_n=block_n, interpret=(impl == "interpret"))
