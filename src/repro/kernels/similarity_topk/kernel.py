"""Pallas TPU kernel: tiled batched cosine-similarity + top-k retrieval.

The semantic index's scoring path: queries [Q, D] against a corpus
[N, D] (both pre-normalized by the wrapper, so the MXU matmul *is* the
cosine similarity), returning the k best corpus rows per query.

Grid: (num_q_blocks, num_n_blocks) — corpus blocks innermost and
sequential.  Each step computes one [block_q, block_n] similarity tile
on the MXU, then merges it into a running per-query top-k held in VMEM
scratch via k rounds of select-max-and-mask (k is small; the rounds are
VPU work over [block_q, k + block_n] candidates).  The final corpus
block writes the running winners out.  Ties break toward the lower
corpus index — identical to the reference's stable argsort — because
earlier blocks (and earlier selections) sit first in the candidate
concatenation and ``argmax`` returns the first occurrence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _sim_topk_kernel(q_ref, c_ref, vals_ref, idx_ref, sv_ref, si_ref, *,
                     k: int, block_n: int, n_real: int):
    ni = pl.program_id(1)
    nn = pl.num_programs(1)

    @pl.when(ni == 0)
    def _init():
        sv_ref[...] = jnp.full_like(sv_ref, NEG_INF)
        si_ref[...] = jnp.full_like(si_ref, -1)

    q = q_ref[...].astype(jnp.float32)          # [bq, d]
    c = c_ref[...].astype(jnp.float32)          # [bn, d]
    s = jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bn]
    bq = s.shape[0]
    gidx = (jax.lax.broadcasted_iota(jnp.int32, (bq, block_n), 1)
            + ni * block_n)
    s = jnp.where(gidx < n_real, s, NEG_INF)    # mask padded corpus rows

    cand_v = jnp.concatenate([sv_ref[...], s], axis=1)        # [bq, k+bn]
    cand_i = jnp.concatenate([si_ref[...], gidx], axis=1)
    pos = jax.lax.broadcasted_iota(jnp.int32, cand_v.shape, 1)
    vals, idxs = [], []
    for _ in range(k):                          # unrolled: k is small
        am = jnp.argmax(cand_v, axis=1)         # first max -> lowest index
        hit = pos == am[:, None]
        vals.append(jnp.sum(jnp.where(hit, cand_v, 0.0), axis=1))
        idxs.append(jnp.sum(jnp.where(hit, cand_i, 0), axis=1))
        cand_v = jnp.where(hit, NEG_INF, cand_v)
    sv_ref[...] = jnp.stack(vals, axis=1)
    si_ref[...] = jnp.stack(idxs, axis=1).astype(jnp.int32)

    @pl.when(ni == nn - 1)
    def _fin():
        # selections that only ever saw -inf (k > N) report index -1
        out_v = sv_ref[...]
        vals_ref[...] = out_v
        idx_ref[...] = jnp.where(out_v == NEG_INF, -1, si_ref[...])


def similarity_topk_kernel(q, c, k: int, *, block_q: int = 128,
                           block_n: int = 512, interpret: bool = True):
    """q: [Q, D], c: [N, D] — unit-normalized fp32 rows.
    Returns ``(vals [Q, k] fp32 descending, idx [Q, k] int32)``."""
    Q, D = q.shape
    N = c.shape[0]
    block_q = max(min(block_q, Q), 1)
    block_n = max(min(block_n, N), 1)
    pad_q = (-Q) % block_q
    pad_n = (-N) % block_n
    if pad_q:
        q = jnp.pad(q, ((0, pad_q), (0, 0)))
    if pad_n:
        c = jnp.pad(c, ((0, pad_n), (0, 0)))
    nq = (Q + pad_q) // block_q
    nn = (N + pad_n) // block_n

    kernel = functools.partial(_sim_topk_kernel, k=k, block_n=block_n,
                               n_real=N)
    vals, idx = pl.pallas_call(
        kernel,
        grid=(nq, nn),
        in_specs=[
            pl.BlockSpec((block_q, D), lambda qi, ni: (qi, 0)),
            pl.BlockSpec((block_n, D), lambda qi, ni: (ni, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, k), lambda qi, ni: (qi, 0)),
            pl.BlockSpec((block_q, k), lambda qi, ni: (qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q + pad_q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q + pad_q, k), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, k), jnp.float32),
            pltpu.VMEM((block_q, k), jnp.int32),
        ],
        interpret=interpret,
    )(q.astype(jnp.float32), c.astype(jnp.float32))
    return vals[:Q], idx[:Q]
