"""Pure-jnp oracle for batched cosine-similarity top-k retrieval."""
from __future__ import annotations

import jax.numpy as jnp


def l2_normalize(x, eps: float = 1e-12):
    """Row-normalize to unit L2 norm (zero rows stay zero)."""
    x = x.astype(jnp.float32)
    n = jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True))
    return x / jnp.maximum(n, eps)


def similarity_topk_ref(queries, corpus, k: int):
    """Exact top-k by cosine similarity.

    queries: [Q, D]; corpus: [N, D] (any float dtype; normalized
    internally).  Returns ``(vals [Q, k] fp32 descending, idx [Q, k]
    int32)``.  Ties broken by the lower corpus index (argmax-first
    semantics, matching the Pallas kernel's running merge).  With
    ``k > N`` the tail is padded with ``-inf`` values and index ``-1``.
    """
    q = l2_normalize(queries)
    c = l2_normalize(corpus)
    n = c.shape[0]
    sims = q @ c.T                                    # [Q, N]
    kk = min(k, n)
    # argsort on (-sim, idx) gives descending values, ascending index ties
    order = jnp.argsort(-sims, axis=1, stable=True)[:, :kk]
    vals = jnp.take_along_axis(sims, order, axis=1)
    idx = order.astype(jnp.int32)
    if kk < k:
        pad_v = jnp.full((q.shape[0], k - kk), -jnp.inf, jnp.float32)
        pad_i = jnp.full((q.shape[0], k - kk), -1, jnp.int32)
        vals = jnp.concatenate([vals, pad_v], axis=1)
        idx = jnp.concatenate([idx, pad_i], axis=1)
    return vals, idx
