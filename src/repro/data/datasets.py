"""Synthetic recreations of the paper's public benchmark datasets.

No network access is available, so each HuggingFace dataset used in §6 is
regenerated with the paper's cardinalities and a *difficulty* parameter
calibrated so the simulated proxy/oracle models land near the paper's
quality numbers.  Hidden columns (``_truth``, ``_difficulty``, ``_labels``)
carry ground truth to the calibrated simulator; ``SELECT *`` never
returns them.

Provided datasets:

  * cascade suite (§6.2 / Table 2 / Fig 11): NQ, BOOLQ, IMDB, SST2,
    QUORA, FARL — boolean-filter tables;
  * semantic-join suite (§6.3 / Tables 3–4 / Fig 12): NASDAQ, EURLEX,
    BIODEX, ABTBUY, AG NEWS (100/200), ARXIV, NYT, CNN — (left, right)
    table pairs with true pair sets;
  * NYT articles (Fig 9/10): single articles table with a category column
    whose IN-selectivity is adjustable;
  * the arXiv example of §5.1 (papers / paper_images with FILE columns).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.tables.table import FileRef, Table

_WORDS = ("data systems query model learning neural market stock product "
          "review energy database cloud index storage scan vector language "
          "policy health climate film music soccer election science space "
          "biology drug protein court law finance tax art travel food").split()


def _rng(seed) -> np.random.Generator:
    return np.random.default_rng(seed)


def _name_key(name: str) -> int:
    """Stable 16-bit key for a dataset name.  ``hash()`` is salted per
    process (PYTHONHASHSEED), which silently regenerated a *different*
    corpus every run — benchmark gates need bit-stable data."""
    import hashlib
    return int.from_bytes(hashlib.sha256(name.encode()).digest()[:2],
                          "little")


def _sentence(rng, n=12) -> str:
    return " ".join(rng.choice(_WORDS, size=n))


# ---------------------------------------------------------------------------
# cascade suite (§6.2)
# ---------------------------------------------------------------------------

# name -> (rows, difficulty, positive_rate).  Difficulty calibrates the Beta
# mixture in the simulator: higher = weaker proxy separation (lower speedup),
# mirroring the per-dataset spread in Fig 11 (NQ easy .. BOOLQ/QUORA hard).
CASCADE_DATASETS: Dict[str, Tuple[int, float, float]] = {
    "NQ":    (4000, 0.10, 0.45),
    "BOOLQ": (3500, 0.42, 0.60),
    "IMDB":  (5000, 0.22, 0.50),
    "SST2":  (4000, 0.25, 0.52),
    "QUORA": (6000, 0.40, 0.37),
    "FARL":  (4000, 0.38, 0.50),
}

CASCADE_PREDICATES: Dict[str, str] = {
    "NQ":    "Does the passage answer the question? {0}",
    "BOOLQ": "Is the answer to this yes/no question true? {0}",
    "IMDB":  "Does this movie review express positive sentiment? {0}",
    "SST2":  "Is the sentiment of this sentence positive? {0}",
    "QUORA": "Are these two questions duplicates? {0}",
    "FARL":  "Is this news headline reliable (not fake)? {0}",
}


def cascade_table(name: str, *, rows: Optional[int] = None, seed: int = 0
                  ) -> Table:
    n, difficulty, pos_rate = CASCADE_DATASETS[name]
    n = rows or n
    rng = _rng((seed, _name_key(name)))
    truth = rng.random(n) < pos_rate
    text = [f"[{name}:{i}] " + _sentence(rng, 18) for i in range(n)]
    return Table({
        "id": np.arange(n),
        "text": text,
        "_truth": truth,
        "_difficulty": np.full(n, difficulty),
    }, name=name.lower())


# ---------------------------------------------------------------------------
# semantic-join suite (§6.3, Table 4 cardinalities)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class JoinSpec:
    """Per-dataset calibration.  The four error knobs are fit to the paper's
    Table 4 per-dataset precision/recall (baseline cross-join AI_FILTER
    vs AI_CLASSIFY rewrite):

      fp_bias / fn_bias — pairwise AI_FILTER flip rates (the systematic
        yes-bias of isolated binary decisions drives the baseline's poor
        precision on NASDAQ/NYT and the no-bias drives ARXIV's low recall);
      cls_drop — per-true-label drop prob of the multi-label rewrite
        (conservative selection: the EURLEX/BIODEX recall loss);
      cls_adds — expected *count* of false labels added per left row
        (comparative reasoning keeps it ~constant, not per-candidate).
    """
    name: str
    left_rows: int
    right_rows: int
    kind: str                 # "entity" (1:1 matching) | "category" (n:few)
    labels_per_left: float    # mean true labels per left row
    doc_words: int            # left-document length (drives per-call tokens)
    label_words: int          # label verbosity (EuroVoc/MedDRA are phrases)
    fp_bias: float
    fn_bias: float
    cls_drop: float
    cls_adds: float


JOIN_DATASETS: Dict[str, JoinSpec] = {
    #                          name        L    R   kind      lpl  words lw  fp      fn     drop   adds
    "NASDAQ":     JoinSpec("NASDAQ",     100, 100, "entity",   1.0, 120, 2, 0.35,   0.04,  0.27,  0.13),
    "EURLEX":     JoinSpec("EURLEX",      50, 194, "category", 4.0, 160, 5, 0.10,   0.17,  0.79,  0.14),
    "BIODEX":     JoinSpec("BIODEX",      50, 197, "category", 3.5, 160, 3, 0.135,  0.415, 0.80,  1.01),
    "ABTBUY":     JoinSpec("ABTBUY",     100, 100, "entity",   1.0, 100, 2, 0.0004, 0.033, 0.032, 0.032),
    "AGNEWS_100": JoinSpec("AGNEWS_100", 100, 100, "category", 1.2,  80, 2, 0.0081, 0.13,  0.39,  0.072),
    "AGNEWS_200": JoinSpec("AGNEWS_200", 200, 200, "category", 1.2,  80, 2, 0.0048, 0.20,  0.39,  0.10),
    "ARXIV":      JoinSpec("ARXIV",      500, 500, "category", 2.0, 100, 6, 0.0006, 0.82,  0.80,  0.33),
    "NYT":        JoinSpec("NYT",        500, 500, "category", 1.5, 100, 2, 0.066,  0.225, 0.586, 0.40),
    "CNN":        JoinSpec("CNN",        500, 500, "category", 1.3, 220, 2, 0.001,  0.01,  0.016, 0.31),
}

JOIN_PROMPTS: Dict[str, str] = {
    "NASDAQ": "Company record {0} refers to the same company as ticker "
              "entry {1}",
    "EURLEX": "Legal document {0} falls under EuroVoc descriptor {1}",
    "BIODEX": "Patient report {0} mentions adverse reaction {1}",
    "ABTBUY": "Product listing {0} is the same product as listing {1}",
    "AGNEWS_100": "News article {0} belongs to topic {1}",
    "AGNEWS_200": "News article {0} belongs to topic {1}",
    "ARXIV": "Paper abstract {0} belongs to arXiv category {1}",
    "NYT": "Article {0} belongs to NYT section {1}",
    "CNN": "CNN story {0} is about category {1}",
}


def join_tables(name: Optional[str] = None, *, seed: int = 0,
                spec: Optional[JoinSpec] = None
                ) -> Tuple[Table, Table, JoinSpec]:
    """Returns (left, right, spec).  left.label_names carries truth as a
    hidden ``_labels`` tuple column; right is the label/category side.
    Pass ``spec`` to generate a custom corpus (e.g. the index-blocking
    benchmark's large-label-universe workload) with the same machinery.
    """
    spec = spec or JOIN_DATASETS[name]
    name = spec.name
    rng = _rng((seed, _name_key(name)))
    L, R = spec.left_rows, spec.right_rows
    if spec.kind == "entity":
        # R unique entities; left rows each match exactly one
        labels = [f"{name.lower()}-entity-{j:03d} "
                  + _sentence(rng, spec.label_words) for j in range(R)]
        match = rng.permutation(R)[:L] if R >= L else rng.integers(0, R, L)
        truth = [(labels[match[i]],) for i in range(L)]
    else:
        # category style: a modest label universe, several true per row
        labels = [f"{name.lower()}-cat-{j:03d} "
                  + _sentence(rng, spec.label_words) for j in range(R)]
        truth = []
        for i in range(L):
            k = max(1, int(rng.poisson(spec.labels_per_left)))
            k = min(k, R)
            truth.append(tuple(labels[j] for j in
                               sorted(rng.choice(R, size=k, replace=False))))
    left = Table({
        "id": np.arange(L),
        "content": [f"[{name}:{i}] " + _sentence(rng, spec.doc_words)
                    for i in range(L)],
        "_labels": [t for t in truth],
        "_fp_bias": np.full(L, spec.fp_bias),
        "_fn_bias": np.full(L, spec.fn_bias),
        "_drop_prob": np.full(L, spec.cls_drop),
        "_add_frac": np.full(L, spec.cls_adds / R),
    }, name=name.lower() + "_l")
    right = Table({
        "rid": np.arange(R),
        "label": labels,
    }, name=name.lower() + "_r")
    return left, right, spec


# ---------------------------------------------------------------------------
# NYT articles (Fig 9 / Fig 10)
# ---------------------------------------------------------------------------

NYT_CATEGORIES = ("politics", "business", "technology", "science", "health",
                  "sports", "arts", "travel", "food", "opinion")


def nyt_articles(n: int = 1000, *, seed: int = 0,
                 ai_selectivity: float = 0.30) -> Table:
    """1000-article table.  ``category`` is uniform over 10 values so an
    ``IN`` list of k categories has selectivity k/10 (the Fig 9 sweep);
    ``_truth`` grounds the AI_FILTER predicate at the given selectivity."""
    rng = _rng((seed, 42))
    cat = rng.choice(NYT_CATEGORIES, size=n)
    truth = rng.random(n) < ai_selectivity
    return Table({
        "id": np.arange(n),
        "category": cat,
        "body": [f"[nyt:{i}] " + _sentence(rng, 40) for i in range(n)],
        "_truth": truth,
        "_difficulty": np.full(n, 0.15),
    }, name="ny_articles")


def skewed_articles(n: int = 2000, *, seed: int = 0,
                    sel_broad: float = 0.95, sel_narrow: float = 0.05
                    ) -> Table:
    """Adaptive-reoptimization workload: two equal-length text columns
    whose AI predicates have wildly different true selectivities.

    ``headline`` and ``summary`` have the same average length (so static
    token-based cost estimates cannot tell the predicates apart), but the
    column-scoped ground truth ``_truth__headline`` passes ``sel_broad``
    of rows while ``_truth__summary`` passes ``sel_narrow`` — the
    skewed-selectivity case where the static default (0.5 for every AI
    predicate) is badly wrong in both directions."""
    rng = _rng((seed, 909))
    return Table({
        "id": np.arange(n),
        "headline": [f"[hl:{i}] " + _sentence(rng, 12) for i in range(n)],
        "summary": [f"[sm:{i}] " + _sentence(rng, 12) for i in range(n)],
        "_truth__headline": rng.random(n) < sel_broad,
        "_truth__summary": rng.random(n) < sel_narrow,
        "_difficulty": np.full(n, 0.05),
    }, name="articles")


def nyt_join_pair(n_left: int = 400, *, out_in_ratio: float = 1.0,
                  seed: int = 0, ai_selectivity: float = 0.3
                  ) -> Tuple[Table, Table]:
    """Two tables whose equi-join emits ``out_in_ratio * n_left`` rows
    (the Fig 10 sweep): every left row joins ~ratio right rows."""
    rng = _rng((seed, 77))
    left = Table({
        "key": np.arange(n_left),
        "body": [f"[nyt:{i}] " + _sentence(rng, 30) for i in range(n_left)],
        "_truth": rng.random(n_left) < ai_selectivity,
        "_difficulty": np.full(n_left, 0.15),
    }, name="ny_articles_v1")
    n_pairs = int(round(out_in_ratio * n_left))
    keys = rng.integers(0, n_left, size=max(n_pairs, 1))
    right = Table({
        "key": keys,
        "meta": [f"meta-{i}" for i in range(len(keys))],
    }, name="ny_meta")
    return left, right


# ---------------------------------------------------------------------------
# §5.1 arXiv example schema (papers / paper_images with FILE columns)
# ---------------------------------------------------------------------------


def papers_tables(n_papers: int = 1000, images_per_paper: int = 10, *,
                  seed: int = 0, date_sel: float = 0.10,
                  abstract_sel: float = 0.10, image_sel: float = 0.30
                  ) -> Tuple[Table, Table]:
    rng = _rng((seed, 5151))
    n = n_papers
    dates = rng.integers(2000, 2026, size=n)
    papers = Table({
        "id": np.arange(n),
        "title": [f"Paper {i}: " + _sentence(rng, 6) for i in range(n)],
        "date": dates,
        "abstract": [f"[abs:{i}] " + _sentence(rng, 50) for i in range(n)],
        "pdf": [FileRef(f"s3://papers/{i}.pdf", "application/pdf")
                for i in range(n)],
        "_truth": rng.random(n) < abstract_sel,
        "_difficulty": np.full(n, 0.12),
    }, name="papers")
    m = n * images_per_paper
    images = Table({
        "id": np.repeat(np.arange(n), images_per_paper),
        "image_file": [FileRef(f"s3://papers/img/{i}.png", "image/png")
                       for i in range(m)],
        "_truth": rng.random(m) < image_sel,
        "_difficulty": np.full(m, 0.2),
    }, name="paper_images")
    return papers, images


# ---------------------------------------------------------------------------
# quality metrics shared by benchmarks
# ---------------------------------------------------------------------------


def prf1(tp: int, fp: int, fn: int) -> Tuple[float, float, float]:
    p = tp / (tp + fp) if tp + fp else 0.0
    r = tp / (tp + fn) if tp + fn else 0.0
    f1 = 2 * p * r / (p + r) if p + r else 0.0
    return p, r, f1


def binary_metrics(pred: np.ndarray, truth: np.ndarray) -> Dict[str, float]:
    pred = np.asarray(pred, bool)
    truth = np.asarray(truth, bool)
    tp = int((pred & truth).sum())
    fp = int((pred & ~truth).sum())
    fn = int((~pred & truth).sum())
    tn = int((~pred & ~truth).sum())
    p, r, f1 = prf1(tp, fp, fn)
    return {"accuracy": (tp + tn) / max(len(pred), 1), "precision": p,
            "recall": r, "f1": f1}


def pair_metrics(pred_pairs: set, true_pairs: set) -> Dict[str, float]:
    tp = len(pred_pairs & true_pairs)
    fp = len(pred_pairs - true_pairs)
    fn = len(true_pairs - pred_pairs)
    p, r, f1 = prf1(tp, fp, fn)
    return {"precision": p, "recall": r, "f1": f1}


def true_pairs_of(left: Table, right: Table) -> set:
    """(left_id, right_label) truth set from the hidden ``_labels`` column."""
    out = set()
    lbl = left.column("_labels")
    ids = left.column("id")
    for i in range(left.num_rows):
        for lb in lbl[i]:
            out.add((int(ids[i]), str(lb)))
    return out
