"""Process-wide metrics registry: counters, gauges, exponential-bucket
histograms, Prometheus text exposition.

Every family must be declared in :data:`METRIC_FAMILIES` — the registry
rejects unknown names, and ``test_docs`` checks the docs table against
the same catalog, so code, docs and the wire format cannot drift apart.

Histograms use exponential buckets (start 100µs, factor √2, 48 bounds)
and retain **no raw samples**: quantiles come from cumulative bucket
counts with a bounded relative error of at most √2−1 ≈ 41% at a bucket
edge (≈ ±19% returning the bucket midpoint, as we do).  That replaces
the bounded last-N sample windows the serving report used to keep,
whose tail silently vanished on long runs.
"""
from __future__ import annotations

import re
import threading
from bisect import bisect_left

# ---------------------------------------------------------------------------
# Family catalog: name -> (type, help text, label names)

METRIC_FAMILIES = {
    "aisql_queries_total": (
        "counter", "queries by tenant and lifecycle status "
        "(submitted/completed/failed/rejected)", ("tenant", "status")),
    "aisql_credits_total": (
        "counter", "credits billed to each tenant's meter", ("tenant",)),
    "aisql_dispatched_calls_total": (
        "counter", "backend calls attributed to each tenant", ("tenant",)),
    "aisql_queue_wait_seconds": (
        "histogram", "admission-queue wait per query", ("tenant",)),
    "aisql_query_latency_seconds": (
        "histogram", "end-to-end query wall time", ("tenant",)),
    "aisql_ai_calls_total": (
        "counter", "inference results by model and request kind",
        ("model", "kind")),
    "aisql_ai_tokens_total": (
        "counter", "tokens by model and direction (in/out)",
        ("model", "direction")),
    "aisql_backend_credits_total": (
        "counter", "credits charged by backends, by model", ("model",)),
    "aisql_dispatch_latency_seconds": (
        "histogram", "one batch attempt on one replica", ("model",)),
    "aisql_pipeline_events_total": (
        "counter", "request-pipeline events (dispatch/cache_hit/"
        "inflight_hit/retry/failure/coalesced)", ("event",)),
    "aisql_pipeline_batch_size": (
        "histogram", "requests per dispatched pipeline batch", ()),
    "aisql_scheduler_events_total": (
        "counter", "scheduler telemetry (submits/dispatches/retries/"
        "timeouts/redispatches/splits)", ("event",)),
    "aisql_operator_seconds": (
        "histogram", "AI-operator evaluation time per batch", ("operator",)),
    "aisql_storage_events_total": (
        "counter", "chunk spills and reloads", ("event",)),
    "aisql_storage_bytes": (
        "gauge", "bytes resident in memory vs spilled", ("state",)),
}

BUCKET_START = 1e-4
BUCKET_FACTOR = 2.0 ** 0.5
BUCKET_COUNT = 48
BUCKET_BOUNDS = tuple(BUCKET_START * BUCKET_FACTOR ** i
                      for i in range(BUCKET_COUNT))
# relative quantile error returning bucket midpoints (documented bound)
QUANTILE_REL_ERROR = (BUCKET_FACTOR - 1.0) / (BUCKET_FACTOR + 1.0)


def locked_snapshot(lock, fn):
    """Run ``fn`` under ``lock`` and return its result.

    The one sanctioned way to read counters a dispatcher mutates —
    `Scheduler.stats_snapshot()` and `PipelineStats` reads both route
    through here so no snapshot ever sees a torn update.
    """
    with lock:
        return fn()


class _Child:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0


class _HistChild:
    __slots__ = ("counts", "sum", "count")

    def __init__(self):
        self.counts = [0] * (BUCKET_COUNT + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v):
        self.counts[bisect_left(BUCKET_BOUNDS, v)] += 1
        self.sum += v
        self.count += 1

    def quantile(self, q):
        """Quantile estimate from bucket midpoints; 0.0 when empty.
        Monotone in q (cumulative counts), so p95 >= p50 always holds."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= target and cum > 0 and c > 0 or cum >= self.count:
                lower = BUCKET_BOUNDS[i - 1] if i > 0 else 0.0
                upper = (BUCKET_BOUNDS[i] if i < BUCKET_COUNT
                         else BUCKET_BOUNDS[-1] * BUCKET_FACTOR)
                return (lower + upper) / 2.0
        return BUCKET_BOUNDS[-1] * BUCKET_FACTOR

    def merge(self, other):
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.sum += other.sum
        self.count += other.count
        return self


class Family:
    def __init__(self, registry, name, mtype, help_text, label_names):
        self.registry = registry
        self.name = name
        self.type = mtype
        self.help = help_text
        self.label_names = tuple(label_names)
        self._children = {}

    def labels(self, *values, **kv):
        if kv:
            values = tuple(kv.get(n, "") for n in self.label_names)
        else:
            values = tuple(str(v) for v in values)
        if len(values) != len(self.label_names):
            raise ValueError(
                "family %r takes labels %r, got %r"
                % (self.name, self.label_names, values))
        with self.registry._lock:
            child = self._children.get(values)
            if child is None:
                child = (_HistChild() if self.type == "histogram"
                         else _Child())
                self._children[values] = child
            return child

    # counter / gauge convenience on the family itself (label-less or
    # label-forwarding)
    def inc(self, amount=1.0, **labels):
        child = self.labels(**labels)
        with self.registry._lock:
            child.value += amount

    def set(self, value, **labels):
        child = self.labels(**labels)
        with self.registry._lock:
            child.value = value

    def observe(self, value, **labels):
        child = self.labels(**labels)
        with self.registry._lock:
            child.observe(value)

    def merged(self):
        """All children merged into one (histograms only)."""
        out = _HistChild()
        with self.registry._lock:
            for child in self._children.values():
                out.merge(child)
        return out


class MetricsRegistry:
    """Registry of labeled metric families plus scrape-time collectors.

    Collectors are callables returning ``(family_name, labels_dict,
    value)`` samples; components that already keep their own locked
    counters (pipeline, scheduler, spill manager, backends) register a
    collector so the registry exposes the *same* numbers their report
    objects read — the two can never disagree.
    """

    def __init__(self):
        self._lock = threading.RLock()
        self._families = {}
        self._collectors = []

    def _family(self, name, mtype):
        spec = METRIC_FAMILIES.get(name)
        if spec is None:
            raise ValueError("unknown metric family %r — declare it in "
                             "repro.obs.metrics.METRIC_FAMILIES" % (name,))
        if spec[0] != mtype:
            raise ValueError("family %r is a %s, not a %s"
                             % (name, spec[0], mtype))
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = Family(self, name, spec[0], spec[1], spec[2])
                self._families[name] = fam
            return fam

    def counter(self, name):
        return self._family(name, "counter")

    def gauge(self, name):
        return self._family(name, "gauge")

    def histogram(self, name):
        return self._family(name, "histogram")

    def register_collector(self, fn):
        with self._lock:
            self._collectors.append(fn)

    # -- snapshot / exposition --------------------------------------------

    def _collector_samples(self):
        samples = []
        with self._lock:
            collectors = list(self._collectors)
        for fn in collectors:
            for name, labels, value in fn():
                if name not in METRIC_FAMILIES:
                    raise ValueError("collector produced unknown family %r"
                                     % (name,))
                samples.append((name, labels, value))
        return samples

    def snapshot(self):
        """Plain-dict snapshot of every family (JSON-serializable)."""
        out = {}
        with self._lock:
            fams = list(self._families.items())
        for name, fam in fams:
            series = []
            with self._lock:
                children = list(fam._children.items())
            for values, child in children:
                labels = dict(zip(fam.label_names, values))
                if fam.type == "histogram":
                    series.append({"labels": labels, "sum": child.sum,
                                   "count": child.count,
                                   "buckets": list(child.counts)})
                else:
                    series.append({"labels": labels, "value": child.value})
            out[name] = {"type": fam.type, "help": fam.help,
                         "labels": list(fam.label_names), "series": series}
        for name, labels, value in self._collector_samples():
            spec = METRIC_FAMILIES[name]
            entry = out.setdefault(
                name, {"type": spec[0], "help": spec[1],
                       "labels": list(spec[2]), "series": []})
            entry["series"].append({"labels": dict(labels), "value": value})
        return out

    def render_prometheus(self):
        """Prometheus text exposition format (version 0.0.4)."""
        lines = []
        snap = self.snapshot()
        for name in sorted(snap):
            fam = snap[name]
            lines.append("# HELP %s %s" % (name, fam["help"]))
            lines.append("# TYPE %s %s" % (name, fam["type"]))
            for s in fam["series"]:
                lbl = _fmt_labels(s["labels"])
                if fam["type"] == "histogram":
                    cum = 0
                    for i, c in enumerate(s["buckets"]):
                        cum += c
                        le = ("+Inf" if i >= BUCKET_COUNT
                              else _fmt_num(BUCKET_BOUNDS[i]))
                        bl = dict(s["labels"])
                        bl["le"] = le
                        lines.append("%s_bucket%s %d"
                                     % (name, _fmt_labels(bl), cum))
                    lines.append("%s_sum%s %s"
                                 % (name, lbl, _fmt_num(s["sum"])))
                    lines.append("%s_count%s %d" % (name, lbl, s["count"]))
                else:
                    lines.append("%s%s %s" % (name, lbl,
                                              _fmt_num(s["value"])))
        return "\n".join(lines) + "\n"


def _fmt_labels(labels):
    if not labels:
        return ""
    parts = ["%s=\"%s\"" % (k, str(v).replace("\\", "\\\\")
                            .replace('"', '\\"').replace("\n", "\\n"))
             for k, v in sorted(labels.items())]
    return "{" + ",".join(parts) + "}"


def _fmt_num(v):
    if v == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


_LINE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{[^}]*\})?\s+(\S+)$")
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_prometheus_text(text):
    """Minimal Prometheus text-format parser.

    Returns ``{metric_name: [(labels_dict, value), ...]}``.  Raises
    ``ValueError`` on a malformed sample line — CI's bench-smoke job
    uses this to assert ``/v1/metrics`` stays parseable.
    """
    out = {}
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        m = _LINE_RE.match(line)
        if m is None:
            raise ValueError("malformed metric line: %r" % (raw,))
        name, labelpart, value = m.group(1), m.group(2), m.group(3)
        labels = {}
        if labelpart:
            for lm in _LABEL_RE.finditer(labelpart):
                labels[lm.group(1)] = (lm.group(2)
                                       .replace('\\"', '"')
                                       .replace("\\n", "\n")
                                       .replace("\\\\", "\\"))
        try:
            val = float(value)
        except ValueError:
            raise ValueError("malformed metric value: %r" % (raw,))
        out.setdefault(name, []).append((labels, val))
    return out
