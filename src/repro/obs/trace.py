"""Hierarchical span tracing with deterministic IDs and injectable clocks.

A :class:`Tracer` records one query's execution as a tree of spans
(parse -> optimize -> execute -> partition -> predicate -> dispatch).
Span IDs are a per-tracer counter and the clock is injectable, so the
serialized tree is byte-identical across runs of a seeded workload
(``TickClock``) while still carrying real wall-clock timings in
production (``time.perf_counter``).

The tracer is activated per query on the executing thread via the
``activate`` context manager; deep call sites (pipeline, scheduler,
spill manager) fetch it with ``active_tracer()`` — which returns the
shared no-op tracer when tracing is off, so the disabled path costs a
thread-local read and an attribute check.
"""
from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager

# ---------------------------------------------------------------------------
# Taxonomy — the single source of truth the docs (and test_docs) check
# against.  Adding an instrumentation site means adding its kind here.

SPAN_KINDS = {
    "query": "root span for one SQL statement; wall time of the whole call",
    "parse": "SQL text to AST",
    "optimize": "logical plan to physical plan (cost races, memo, rewrites)",
    "execute": "physical plan execution incl. pipeline flush",
    "pilot": "cold-predicate pilot sampling pass",
    "partition": "one partition-pull morsel (streaming executor)",
    "predicate": "one AI predicate evaluated over a row batch",
    "cascade": "proxy/oracle cascade run for one predicate batch",
    "pipeline.dispatch": "one coalesced batch leaving the request pipeline",
    "dispatch.replica": "one batch attempt on one backend replica",
}

EVENT_KINDS = {
    "optimize.memo_hit": "plan memo returned a cached physical plan",
    "optimize.cost_race": "cost race between candidate rewrites",
    "optimize.rewrite": "a rewrite decision recorded by the optimizer",
    "pipeline.dedup_hit": "request matched cache or an in-flight duplicate",
    "pipeline.coalesce": "submissions coalesced into one dispatch batch",
    "pipeline.retry": "pipeline-level retry after a dispatch failure",
    "scheduler.retry": "scheduler retried a batch on another replica",
    "cascade.proxy": "cascade scored a batch with the proxy model",
    "cascade.escalate": "cascade escalated rows to the oracle model",
    "partition.early_stop": "LIMIT satisfied; remaining partitions skipped",
    "partition.reorder": "adaptive predicate reorder between partitions",
    "storage.spill": "a column chunk was spilled to disk",
    "storage.reload": "a spilled chunk was reloaded into memory",
}


class TickClock:
    """Deterministic clock: call n returns ``n * step`` seconds.

    Injected into a tracer so span timings (and therefore the serialized
    span tree) are byte-stable across runs of the same seeded workload.
    """

    def __init__(self, step: float = 0.001):
        self.step = step
        self._n = 0

    def __call__(self) -> float:
        t = self._n * self.step
        self._n += 1
        return t


class Span:
    __slots__ = ("name", "kind", "span_id", "parent_id", "t0", "t1",
                 "attrs", "events", "children")

    def __init__(self, name, kind, span_id, parent_id, t0):
        self.name = name
        self.kind = kind
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0 = t0
        self.t1 = t0
        self.attrs = {}
        self.events = []
        self.children = []

    def set(self, **attrs):
        """Attach attributes (rows in/out, tokens, credits, model, ...)."""
        self.attrs.update(attrs)
        return self

    def to_dict(self):
        return {
            "name": self.name,
            "kind": self.kind,
            "id": self.span_id,
            "parent": self.parent_id,
            "t0": self.t0,
            "t1": self.t1,
            "attrs": self.attrs,
            "events": self.events,
            "children": [c.to_dict() for c in self.children],
        }


class _NoopSpan:
    __slots__ = ()

    def set(self, **attrs):
        return self


_NOOP_SPAN = _NoopSpan()


class _NoopCtx:
    """Reusable context manager yielding the shared no-op span."""
    __slots__ = ()

    def __enter__(self):
        return _NOOP_SPAN

    def __exit__(self, *exc):
        return False


_NOOP_CTX = _NoopCtx()


class _NoopTracer:
    """Shared disabled tracer: every operation is a constant-time no-op."""
    enabled = False

    def span(self, name, kind="span", **attrs):
        return _NOOP_CTX

    def event(self, name, **attrs):
        pass

    def now(self):
        return 0.0

    def to_dict(self):
        return None


NOOP = _NoopTracer()


class Tracer:
    """Per-query span recorder.

    Single-threaded by construction: one tracer belongs to the one
    thread executing its query (serving workers run whole sessions), so
    no locking is needed on the span stack.
    """
    enabled = True

    def __init__(self, clock=None):
        self.clock = clock if clock is not None else time.perf_counter
        self._next = 0
        self._stack = []
        self.roots = []

    def now(self) -> float:
        return self.clock()

    def _new_span(self, name, kind):
        self._next += 1
        parent = self._stack[-1] if self._stack else None
        sp = Span(name, kind, self._next,
                  parent.span_id if parent is not None else 0,
                  self.now())
        if parent is not None:
            parent.children.append(sp)
        else:
            self.roots.append(sp)
        return sp

    @contextmanager
    def span(self, name, kind="span", **attrs):
        sp = self._new_span(name, kind)
        if attrs:
            sp.attrs.update(attrs)
        self._stack.append(sp)
        try:
            yield sp
        finally:
            self._stack.pop()
            sp.t1 = self.now()

    def event(self, name, **attrs):
        """Point-in-time event attached to the innermost open span."""
        if not self._stack:
            return
        ev = {"name": name, "t": self.now()}
        if attrs:
            ev["attrs"] = attrs
        self._stack[-1].events.append(ev)

    # -- export ------------------------------------------------------------

    def root(self):
        return self.roots[0] if self.roots else None

    def to_dict(self):
        r = self.root()
        return r.to_dict() if r is not None else None


def to_json(tree) -> str:
    """Canonical JSON for a span tree dict — the byte-stable form the
    determinism tests compare."""
    return json.dumps(tree, sort_keys=True, separators=(",", ":"))


def to_chrome(tree, pid=1, tid=1):
    """Span tree dict -> Chrome-trace (chrome://tracing / Perfetto) JSON
    object with complete ("X") events and instant ("i") events."""
    out = []

    def walk(node):
        args = dict(node.get("attrs") or {})
        out.append({
            "name": node["name"], "cat": node["kind"], "ph": "X",
            "ts": node["t0"] * 1e6,
            "dur": max(0.0, (node["t1"] - node["t0"])) * 1e6,
            "pid": pid, "tid": tid, "args": args,
        })
        for ev in node.get("events") or []:
            out.append({
                "name": ev["name"], "cat": "event", "ph": "i", "s": "t",
                "ts": ev["t"] * 1e6, "pid": pid, "tid": tid,
                "args": dict(ev.get("attrs") or {}),
            })
        for c in node.get("children") or []:
            walk(c)

    if tree is not None:
        walk(tree)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def critical_path(tree):
    """The chain of longest-duration child spans from the root down.

    Returns a one-line summary of where wall time went, e.g.
    ``query > execute > partition[2] > predicate(f0) 1.234s (87% of query)``.
    """
    if not tree:
        return ""
    total = max(tree["t1"] - tree["t0"], 0.0)
    path = [tree]
    node = tree
    while node.get("children"):
        node = max(node["children"], key=lambda c: c["t1"] - c["t0"])
        path.append(node)
    leaf_dur = max(node["t1"] - node["t0"], 0.0)
    pct = 100.0 * leaf_dur / total if total > 0 else 0.0
    chain = " > ".join(p["name"] for p in path)
    return "critical path: %s  %.4fs (%.0f%% of query)" % (chain, leaf_dur, pct)


def walk_spans(tree):
    """Yield every span dict in a tree, depth-first."""
    if not tree:
        return
    stack = [tree]
    while stack:
        node = stack.pop()
        yield node
        stack.extend(node.get("children") or [])


# ---------------------------------------------------------------------------
# Thread-local activation

_tls = threading.local()


def active_tracer():
    """The tracer bound to this thread, or the shared no-op tracer."""
    return getattr(_tls, "tracer", None) or NOOP


@contextmanager
def activate(tracer):
    """Bind ``tracer`` to the current thread for the duration."""
    prev = getattr(_tls, "tracer", None)
    _tls.tracer = tracer
    try:
        yield tracer
    finally:
        _tls.tracer = prev


class TraceRing:
    """Bounded ring of recent span trees keyed by query id (serving's
    ``/v1/trace/<query_id>`` backing store)."""

    def __init__(self, capacity: int = 64):
        self.capacity = capacity
        self._lock = threading.Lock()
        self._items = {}
        self._order = []

    def put(self, query_id, tree):
        with self._lock:
            if query_id in self._items:
                self._order.remove(query_id)
            self._items[query_id] = tree
            self._order.append(query_id)
            while len(self._order) > self.capacity:
                evict = self._order.pop(0)
                del self._items[evict]

    def get(self, query_id):
        with self._lock:
            return self._items.get(query_id)

    def ids(self):
        with self._lock:
            return list(self._order)

    def __len__(self):
        with self._lock:
            return len(self._order)
