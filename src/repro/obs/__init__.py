"""Unified observability: span tracing + metrics registry.

One :class:`Observability` object bundles what a serving process needs:

- a tracer factory (deterministic IDs, injectable clock) producing one
  span tree per query, stored on ``QueryReport.trace`` and in a bounded
  :class:`TraceRing` served at ``/v1/trace/<query_id>``;
- a :class:`MetricsRegistry` of labeled counter/gauge/histogram
  families, exposed at ``/v1/metrics`` in Prometheus text format.

``enabled`` gates *tracing* only — metrics are always recorded once an
Observability object is attached, because they are cheap (a dict lookup
and a locked increment) while span trees allocate per call site.
"""
from .metrics import (BUCKET_BOUNDS, BUCKET_COUNT, BUCKET_FACTOR,
                      BUCKET_START, METRIC_FAMILIES, QUANTILE_REL_ERROR,
                      MetricsRegistry, locked_snapshot,
                      parse_prometheus_text)
from .trace import (EVENT_KINDS, NOOP, SPAN_KINDS, Span, TickClock, TraceRing,
                    Tracer, activate, active_tracer, critical_path, to_chrome,
                    to_json, walk_spans)

__all__ = [
    "Observability", "Tracer", "Span", "TickClock", "TraceRing", "NOOP",
    "activate", "active_tracer", "critical_path", "to_chrome", "to_json",
    "walk_spans", "SPAN_KINDS", "EVENT_KINDS", "MetricsRegistry",
    "METRIC_FAMILIES", "locked_snapshot", "parse_prometheus_text",
    "QUANTILE_REL_ERROR", "BUCKET_BOUNDS", "BUCKET_COUNT", "BUCKET_FACTOR",
    "BUCKET_START",
]


class Observability:
    """Tracing + metrics for one engine or serving process.

    ``clock`` is a *factory* of clock callables — pass ``TickClock`` to
    give every query tracer a fresh deterministic clock (byte-stable
    span trees under ``tools/replay.py``); the default is wall time.
    """

    def __init__(self, enabled=True, clock=None, ring_size=64,
                 registry=None):
        self.enabled = enabled
        self.clock = clock
        self.registry = registry if registry is not None else MetricsRegistry()
        self.ring = TraceRing(ring_size)

    def tracer(self):
        """A fresh per-query tracer, or the shared no-op when disabled."""
        if not self.enabled:
            return NOOP
        return Tracer(clock=self.clock() if self.clock is not None else None)
