"""Cortex Platform Scheduler (paper §2): routes requests to engines.

Responsibilities mirrored from the paper:
  * model-affinity routing — a request for model M goes to an engine that
    already hosts M (round-robin across replicas);
  * fault tolerance — EngineFailure triggers bounded retry on another
    replica (or the same one if it is the only replica);
  * straggler mitigation — per-batch deadline; a batch that exceeds it is
    re-dispatched to the fastest healthy replica;
  * elastic scaling hooks — replicas can be registered/deregistered at any
    time (the autoscaler in api.py uses queue depth).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

from repro.inference.backend import (EngineFailure, InferenceBackend, Request,
                                     Result)


class SchedulerError(RuntimeError):
    pass


class Scheduler:
    def __init__(self, *, max_retries: int = 2,
                 straggler_deadline_s: Optional[float] = None):
        self._replicas: Dict[str, List[InferenceBackend]] = {}
        self._rr: Dict[str, int] = {}
        self.max_retries = max_retries
        self.straggler_deadline_s = straggler_deadline_s
        # telemetry
        self.retries = 0
        self.redispatches = 0

    # ---- registry / elasticity ----
    def register(self, engine: InferenceBackend) -> None:
        for m in engine.hosted_models():
            self._replicas.setdefault(m, []).append(engine)

    def deregister(self, engine: InferenceBackend) -> None:
        for m in list(self._replicas):
            self._replicas[m] = [e for e in self._replicas[m] if e is not engine]

    def replicas(self, model: str) -> List[InferenceBackend]:
        return list(self._replicas.get(model, ()))

    def hosted_models(self) -> List[str]:
        return list(self._replicas)

    # ---- routing ----
    def _pick(self, model: str, exclude=None) -> InferenceBackend:
        reps = self._replicas.get(model)
        if not reps:
            raise SchedulerError(f"no engine hosts model {model!r}; "
                                 f"hosted: {self.hosted_models()}")
        candidates = [e for e in reps if e is not exclude] or reps
        i = self._rr.get(model, 0) % len(candidates)
        self._rr[model] = i + 1
        return candidates[i]

    def submit(self, requests: Sequence[Request]) -> List[Result]:
        """Route a mixed-model batch; preserves input order."""
        by_model: Dict[str, List[Request]] = {}
        for r in requests:
            by_model.setdefault(r.model, []).append(r)
        results: Dict[int, Result] = {}
        for model, reqs in by_model.items():
            for res in self._submit_one_model(model, reqs):
                results[res.request_id] = res
        return [results[r.request_id] for r in requests]

    def _submit_one_model(self, model: str, reqs: Sequence[Request]
                          ) -> List[Result]:
        last_exc: Optional[Exception] = None
        engine = self._pick(model)
        for attempt in range(self.max_retries + 1):
            try:
                t0 = time.perf_counter()
                out = engine.submit_batch(reqs)
                dt = time.perf_counter() - t0
                if (self.straggler_deadline_s is not None
                        and dt > self.straggler_deadline_s
                        and len(self._replicas.get(model, ())) > 1
                        and attempt < self.max_retries):
                    # straggler: result arrived but too late — re-dispatch
                    # the NEXT batches elsewhere by rotating this replica out
                    self.redispatches += 1
                    engine = self._pick(model, exclude=engine)
                return out
            except EngineFailure as e:
                last_exc = e
                self.retries += 1
                engine = self._pick(model, exclude=engine)
        raise SchedulerError(
            f"model {model}: exhausted {self.max_retries} retries") from last_exc
