"""Cortex Platform Scheduler (paper §2): routes requests to engines.

Responsibilities mirrored from the paper:
  * model-affinity routing — a request for model M goes to an engine that
    already hosts M, picked **least-loaded first**: replicas are ranked by
    accumulated busy-seconds plus queued work, so a slow or straggling
    replica naturally receives less traffic than pure round-robin would
    give it (round-robin order breaks ties);
  * batch right-sizing — a batch larger than a replica's capacity hint is
    split across healthy replicas and the partial results are merged in
    request order;
  * fault tolerance — EngineFailure triggers bounded retry on another
    replica (or the same one if it is the only replica);
  * straggler mitigation — per-batch deadline; a batch that exceeds it
    adds a load penalty to the offending replica so subsequent picks
    prefer its peers;
  * elastic scaling hooks — replicas can be registered/deregistered at any
    time (the autoscaler in api.py uses queue depth).

Request ids must be unique within one ``submit`` call; colliding ids
(e.g. the all-zero default) are transparently re-assigned for the
duration of the call and restored afterwards, instead of silently
dropping all but one result per id.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional, Sequence

from repro.inference.backend import (EngineFailure, EngineTimeout,
                                     InferenceBackend, Request, Result)
from repro.obs.metrics import locked_snapshot
from repro.obs.trace import active_tracer

_DEFAULT_CAPACITY = 32


def _capacity_of(engine: InferenceBackend) -> int:
    hint = getattr(engine, "capacity_hint", None)
    if callable(hint):
        hint = hint()
    if hint is None:
        hint = getattr(engine, "max_batch", None)
    return int(hint) if hint else _DEFAULT_CAPACITY


class SchedulerError(RuntimeError):
    pass


class Scheduler:
    def __init__(self, *, max_retries: int = 2,
                 straggler_deadline_s: Optional[float] = None,
                 straggler_penalty_s: float = 1.0):
        self._replicas: Dict[str, List[InferenceBackend]] = {}
        self._rr: Dict[str, int] = {}
        # per-engine load accounting for least-loaded routing
        self._busy_s: Dict[int, float] = {}
        self._depth: Dict[int, int] = {}
        self.max_retries = max_retries
        self.straggler_deadline_s = straggler_deadline_s
        self.straggler_penalty_s = straggler_penalty_s
        # one submit at a time: routing state (_busy_s/_depth/_rr), the
        # telemetry counters and the backends' own meters are all
        # mutated per call — concurrent querying threads serialize here
        # (the single-dispatcher half of the serving concurrency model)
        self._lock = threading.RLock()
        # telemetry
        self.retries = 0
        self.timeouts = 0          # of the retries, injected/engine timeouts
        self.redispatches = 0
        self.splits = 0
        self.submits = 0           # submit() calls (what the pipeline saves)
        self.dispatches = 0        # engine submit_batch calls
        # optional `MetricsRegistry` (set by the serving runtime): each
        # successful replica dispatch records per-model calls, tokens,
        # credits and latency families there
        self.registry = None

    # ---- registry / elasticity ----
    def register(self, engine: InferenceBackend) -> None:
        with self._lock:
            for m in engine.hosted_models():
                self._replicas.setdefault(m, []).append(engine)
            self._busy_s.setdefault(id(engine), 0.0)
            self._depth.setdefault(id(engine), 0)

    def deregister(self, engine: InferenceBackend) -> None:
        with self._lock:
            for m in list(self._replicas):
                self._replicas[m] = [e for e in self._replicas[m]
                                     if e is not engine]
            self._busy_s.pop(id(engine), None)
            self._depth.pop(id(engine), None)

    def replicas(self, model: str) -> List[InferenceBackend]:
        return list(self._replicas.get(model, ()))

    def hosted_models(self) -> List[str]:
        return list(self._replicas)

    def engine_load(self, engine: InferenceBackend) -> float:
        """Load score: accumulated busy seconds + queued request count."""
        return (self._busy_s.get(id(engine), 0.0)
                + float(self._depth.get(id(engine), 0)))

    def backend_stats(self) -> Dict[str, Dict]:
        """Decode-backend telemetry per registered engine (engines that
        expose ``backend_stats``), keyed by engine id — what the serving
        report surfaces for continuous-batching occupancy/step counts."""
        def read():
            out: Dict[str, Dict] = {}
            seen = set()
            for reps in self._replicas.values():
                for e in reps:
                    if id(e) in seen:
                        continue
                    seen.add(id(e))
                    fn = getattr(e, "backend_stats", None)
                    if callable(fn):
                        out[getattr(e, "engine_id",
                                    f"engine#{len(out)}")] = fn()
            return out
        return locked_snapshot(self._lock, read)

    def stats_snapshot(self) -> Dict[str, int]:
        """Atomic copy of the telemetry counters, taken under the same
        lock the dispatcher mutates them behind — the one sanctioned way
        to read them (`ServingEngine.report` and the registry collector
        both come through here, so their numbers agree)."""
        return locked_snapshot(self._lock, lambda: {
            "retries": self.retries,
            "timeouts": self.timeouts,
            "redispatches": self.redispatches,
            "splits": self.splits,
            "submits": self.submits,
            "dispatches": self.dispatches,
        })

    def atomic_batch(self, model: str) -> Optional[int]:
        """Largest single-model batch ``submit`` will never split across
        replicas (None = single replica, unbounded).  A caller that
        retries failed submits should stay within this bound: an
        unsplit submit is all-or-nothing — either results come back or
        nothing was served/billed — so a retry can never re-execute a
        partition that already succeeded."""
        with self._lock:
            reps = self._replicas.get(model, ())
            if len(reps) <= 1:
                return None
            return max(min(_capacity_of(e) for e in reps), 1)

    # ---- routing ----
    def _pick(self, model: str, exclude=None) -> InferenceBackend:
        reps = self._replicas.get(model)
        if not reps:
            raise SchedulerError(f"no engine hosts model {model!r}; "
                                 f"hosted: {self.hosted_models()}")
        candidates = [e for e in reps if e is not exclude] or reps
        lo = min(self.engine_load(e) for e in candidates)
        tied = [e for e in candidates if self.engine_load(e) <= lo + 1e-12]
        i = self._rr.get(model, 0) % len(tied)     # round-robin tie-break
        self._rr[model] = i + 1
        return tied[i]

    def submit(self, requests: Sequence[Request]) -> List[Result]:
        """Route a mixed-model batch; preserves input order.  Thread-safe
        (serialized on the scheduler lock)."""
        with self._lock:
            return self._submit_locked(requests)

    def _submit_locked(self, requests: Sequence[Request]) -> List[Result]:
        self.submits += 1
        originals = self._ensure_unique_ids(requests)
        try:
            by_model: Dict[str, List[Request]] = {}
            for r in requests:
                by_model.setdefault(r.model, []).append(r)
            results: Dict[int, Result] = {}
            for model, reqs in by_model.items():
                for part in self._partition(model, reqs):
                    for res in self._submit_one_model(model, part):
                        results[res.request_id] = res
            out = [results[r.request_id] for r in requests]
        finally:
            if originals is not None:
                for r, rid in zip(requests, originals):
                    r.request_id = rid
        if originals is not None:
            for res, r in zip(out, requests):
                res.request_id = r.request_id
        return out

    def _ensure_unique_ids(self, requests: Sequence[Request]
                           ) -> Optional[List[int]]:
        """Colliding request ids would silently drop results (the results
        map is id-keyed) — re-assign unique temporary ids when needed."""
        ids = [r.request_id for r in requests]
        if len(set(ids)) == len(requests):
            return None
        for i, r in enumerate(requests):
            r.request_id = i + 1
        return ids

    def _partition(self, model: str, reqs: List[Request]
                   ) -> List[List[Request]]:
        """Split an oversized batch across replicas (capacity hints)."""
        reps = self._replicas.get(model, ())
        if len(reps) <= 1 or not reqs:
            return [reqs]
        per_replica = max(min(_capacity_of(e) for e in reps), 1)
        n_parts = min(len(reps), -(-len(reqs) // per_replica))
        if n_parts <= 1:
            return [reqs]
        self.splits += n_parts - 1
        size = -(-len(reqs) // n_parts)
        return [reqs[i:i + size] for i in range(0, len(reqs), size)]

    def _replica_name(self, model: str, engine: InferenceBackend) -> str:
        name = getattr(engine, "engine_id", None)
        if name:
            return str(name)
        reps = self._replicas.get(model, ())
        try:
            i = reps.index(engine)
        except ValueError:
            i = -1
        return f"{type(engine).__name__}#{i}"

    def _record_dispatch(self, model: str, results: Sequence[Result],
                         seconds: float) -> None:
        reg = self.registry
        if reg is None or not results:
            return
        calls = reg.counter("aisql_ai_calls_total")
        by_kind: Dict[str, int] = {}
        tokens_in = tokens_out = 0
        credits = 0.0
        for r in results:
            by_kind[r.kind] = by_kind.get(r.kind, 0) + 1
            tokens_in += r.tokens_in
            tokens_out += r.tokens_out
            credits += r.credits
        for kind, n in by_kind.items():
            calls.inc(n, model=model, kind=kind)
        tok = reg.counter("aisql_ai_tokens_total")
        tok.inc(tokens_in, model=model, direction="in")
        tok.inc(tokens_out, model=model, direction="out")
        reg.counter("aisql_backend_credits_total").inc(credits, model=model)
        reg.histogram("aisql_dispatch_latency_seconds").observe(
            seconds, model=model)

    def _submit_one_model(self, model: str, reqs: Sequence[Request]
                          ) -> List[Result]:
        last_exc: Optional[Exception] = None
        tr = active_tracer()
        engine = self._pick(model)
        for attempt in range(self.max_retries + 1):
            eid = id(engine)
            self._depth[eid] = self._depth.get(eid, 0) + len(reqs)
            try:
                with tr.span("dispatch.replica", kind="dispatch.replica",
                             model=model,
                             replica=(self._replica_name(model, engine)
                                      if tr.enabled else ""),
                             attempt=attempt,
                             requests=len(reqs)) as sp:
                    t0 = time.perf_counter()
                    self.dispatches += 1
                    out = engine.submit_batch(reqs)
                    dt = time.perf_counter() - t0
                    if tr.enabled:
                        sp.set(credits=float(sum(r.credits for r in out)),
                               tokens_in=int(sum(r.tokens_in
                                                 for r in out)),
                               tokens_out=int(sum(r.tokens_out
                                                  for r in out)),
                               outcome="ok")
                self._record_dispatch(model, out, dt)
                self._busy_s[eid] = self._busy_s.get(eid, 0.0) + dt
                if (self.straggler_deadline_s is not None
                        and dt > self.straggler_deadline_s
                        and len(self._replicas.get(model, ())) > 1
                        and attempt < self.max_retries):
                    # straggler: result arrived but too late — penalize the
                    # slow replica so least-loaded picks route around it
                    self.redispatches += 1
                    self._busy_s[eid] += self.straggler_penalty_s
                return out
            except EngineFailure as e:
                last_exc = e
                self.retries += 1
                timeout = isinstance(e, EngineTimeout)
                if timeout:
                    self.timeouts += 1
                sp.set(outcome="timeout" if timeout else "fault")
                tr.event("scheduler.retry", attempt=attempt,
                         timeout=timeout)
                engine = self._pick(model, exclude=engine)
            finally:
                self._depth[eid] = max(self._depth.get(eid, 0) - len(reqs), 0)
        raise SchedulerError(
            f"model {model}: exhausted {self.max_retries} retries") from last_exc
