"""Continuous-batching decode backend (per-step admission, paged KV).

The static path in ``engine.py`` runs one blocking prefill+decode call per
batch: finished slots retire early from the *loop*, but freed capacity is
only refilled at batch boundaries, so a 4-token AI_FILTER score queues
behind a 128-token AI_COMPLETE generation that happens to share the batch.
This module is the backend the paper's serving layer actually wants:

  * **slots** — a fixed-width in-flight batch (XLA static shapes).  Every
    step, finished sequences retire (EOS or max_tokens), their KV blocks
    return to the pool, and queued requests are admitted into the freed
    slots — admission happens at *every* step, not at batch boundaries;
  * **paged KV** — each sequence owns a block table over a shared pool
    (``paged_kv.PagedKVCache``); a step gathers the dense view, runs the
    model, and scatters only the newly valid keys/values back;
  * **chunked prefill** — prompts enter the cache ``prefill_chunk`` tokens
    at a time, batched across every prefilling slot and interleaved with
    decode steps, so a long prompt never stalls in-flight decodes for its
    full length;
  * **flash decode** — single-token steps route ``decode_attention``
    through the ``kernels/decode_attention`` flash path
    (``attention.use_decode_impl``): Pallas on TPU, the bitwise-equal
    reference off-TPU.

Determinism contract: results are **bit-identical** to the static path.
Chunked decode-mode prefill equals one-shot prefill bitwise (same einsum
contractions over the same valid positions; masked tails contribute exact
float zeros), per-row outputs are independent of batch composition, and
the flash-decode reference is bitwise equal to the dense cache attention.
The parity tests in ``tests/test_backend.py`` pin all three.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfgs
from repro.inference import tokenizer as tok
from repro.inference.backend import (COMPLETE, SCORE, EngineFailure, Request,
                                     Result, credits_for)
from repro.inference.paged_kv import PagedKVCache
from repro.models import attention


def supports(cfg) -> bool:
    """Continuous batching serves pure global-attention decoders: every
    block's KV cache must be a flat per-layer [B, Smax] tensor for the
    paged pool to tile (ring buffers, recurrent states and encoder caches
    fall back to the static path)."""
    if cfg.is_encoder_decoder or cfg.frontend != "none":
        return False
    return all(b == cfgs.ATTN for b in cfg.block_pattern)


def _pow2(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass
class _Seq:
    """One in-flight sequence (a slot's occupant)."""
    req: Request
    index: int                 # position in the submitted request list
    enc: List[int]             # encoded prompt
    slot: int
    blocks: List[int]
    state: str = "prefill"     # "prefill" -> "decode" (COMPLETE only)
    filled: int = 0            # prompt tokens already in the paged cache
    cur: int = -1              # last sampled token (next decode input)
    out: List[int] = dataclasses.field(default_factory=list)


class ContinuousBatcher:
    """Step loop + paged-KV state for one :class:`JaxInferenceEngine`.

    Owns no model/params — it drives the engine's model through two jitted
    step functions (shared via ``engine._jit`` so compile counting and
    caching live in one place).
    """

    def __init__(self, engine, *, block_size: int = 32,
                 num_blocks: Optional[int] = None, prefill_chunk: int = 32,
                 decode_impl: str = "auto"):
        self.engine = engine
        self.model = engine.model
        self.slots = engine.max_batch
        self.block_size = int(block_size)
        self.prefill_chunk = int(prefill_chunk)
        self.decode_impl = decode_impl
        if num_blocks is None:
            # every slot can hold a full-length prompt plus a generous
            # generation budget; +1 for the sacrificial scratch block
            per_seq = -(-(engine.max_seq + 4 * self.prefill_chunk)
                        // self.block_size)
            num_blocks = self.slots * per_seq + 1
        self.kv = PagedKVCache(self.model, block_size=self.block_size,
                               num_blocks=num_blocks)
        width = self.kv.max_seq_blocks
        self.tables_np = np.zeros((self.slots, width), np.int32)
        self.lens_np = np.zeros((self.slots,), np.int32)
        # device mirror of (block tables, lengths, decode-active mask),
        # valid between slot mutations — see _device_state
        self._dev: Optional[Dict[str, Any]] = None
        # telemetry
        self.waves = 0             # serve() calls
        self.admitted = 0          # sequences admitted into slots
        self.retired = 0
        self.retired_eos = 0       # retired on EOS before max_tokens
        self.prefill_steps = 0
        self.decode_steps = 0
        self.prefill_tokens = 0    # prompt tokens written via chunked prefill
        self.decode_tokens = 0     # decode-step slot participations
        self.peak_blocks = 0
        # roofline: abstract args of each step key, for AOT lower/compile
        self._step_specs: Dict[Any, Tuple[str, int, Tuple[Any, ...]]] = {}

    # ------------------------------------------------------------------
    # serve loop
    # ------------------------------------------------------------------

    def serve(self, requests: Sequence[Request],
              t0: Optional[float] = None) -> List[Result]:
        """Serve SCORE/COMPLETE requests to completion; returns results in
        submission order with per-request completion-time latency."""
        t0 = time.perf_counter() if t0 is None else t0
        self.waves += 1
        pending: Deque[_Seq] = deque()
        for i, r in enumerate(requests):
            enc = tok.encode(r.prompt, max_len=self.engine.max_seq)
            pending.append(_Seq(req=r, index=i, enc=enc, slot=-1, blocks=[]))
        active: List[Optional[_Seq]] = [None] * self.slots
        results: List[Optional[Result]] = [None] * len(requests)
        while pending or any(s is not None for s in active):
            self._admit(pending, active)
            if any(s is not None and s.state == "prefill" for s in active):
                self._prefill_step(active, results, t0)
            if any(s is not None and s.state == "decode" for s in active):
                self._decode_step(active, results, t0)
        return results  # type: ignore[return-value]

    # ------------------------------------------------------------------

    def _blocks_needed(self, seq: _Seq) -> int:
        horizon = len(seq.enc)
        if seq.req.kind == COMPLETE:
            horizon += max(int(seq.req.max_tokens), 1)
        return self.kv.blocks_for(horizon)

    def _admit(self, pending: Deque[_Seq], active: List[Optional[_Seq]]
               ) -> int:
        """FIFO admission into free slots while KV blocks last.  Head-of-
        line order is kept deliberately: skipping ahead would make results
        depend on pool pressure, and the determinism contract forbids it
        (per-row results are batch-independent, so order alone is enough).
        """
        n = 0
        free_slots = [i for i, s in enumerate(active) if s is None]
        while pending and free_slots:
            seq = pending[0]
            need = self._blocks_needed(seq)
            if need > self.kv.max_seq_blocks:
                pending.popleft()
                raise EngineFailure(
                    f"{self.engine.engine_id}: request {seq.req.request_id} "
                    f"needs {need} KV blocks, pool holds "
                    f"{self.kv.max_seq_blocks} (raise kv_blocks)")
            if not self.kv.can_alloc(need):
                break
            pending.popleft()
            seq.slot = free_slots.pop(0)
            seq.blocks = self.kv.alloc(need)
            self.tables_np[seq.slot, :] = 0
            self.tables_np[seq.slot, :need] = seq.blocks
            self.lens_np[seq.slot] = 0
            active[seq.slot] = seq
            n += 1
        if n:
            self._dev = None
        self.admitted += n
        used = self.kv.num_blocks - 1 - self.kv.free_count
        self.peak_blocks = max(self.peak_blocks, used)
        return n

    def _device_state(self, active: List[Optional[_Seq]], nb: int
                      ) -> Dict[str, Any]:
        """Device mirror of the per-slot step state.

        Rebuilt from the host arrays only when a slot mutated (admission,
        retirement, prefill->decode flip) or the bucketed table width
        changed; across steady-state decode runs — the dominant phase —
        every step reuses it, so the only per-step host->device transfer
        is the sampled-token vector.  The ``.copy()`` calls matter:
        ``device_put`` of an aligned numpy array can be zero-copy on CPU
        and execution is asynchronous, so jit must never alias a host
        buffer the step loop later mutates.  ``lens`` is threaded through
        the step functions (each returns the advanced lengths), keeping
        it device-resident between rebuilds."""
        if self._dev is None or self._dev["nb"] != nb:
            act = np.asarray(
                [1 if (s is not None and s.state == "decode") else 0
                 for s in active], np.int32)
            self._dev = {
                "nb": nb,
                "tables": jnp.asarray(self.tables_np[:, :nb].copy()),
                "lens": jnp.asarray(self.lens_np.copy()),
                "act": jnp.asarray(act),
            }
        return self._dev

    def _gather_width(self, active: List[Optional[_Seq]], horizon: int
                      ) -> int:
        """Block-table width for this step: max blocks any live row needs
        to cover ``len + horizon`` tokens, bucketed to a power of two to
        bound jit keys."""
        nb = 1
        for s in active:
            if s is not None:
                h = horizon if s.state == "prefill" else 1
                nb = max(nb, self.kv.blocks_for(int(self.lens_np[s.slot]) + h))
        return min(_pow2(nb), self.kv.max_seq_blocks)

    # ------------------------------------------------------------------
    # batched chunked prefill
    # ------------------------------------------------------------------

    def _prefill_step(self, active, results, t0) -> None:
        C = self.prefill_chunk
        B = self.slots
        nb = self._gather_width(active, C)
        toks = np.zeros((B, C), np.int32)
        counts = np.zeros((B,), np.int32)
        pre = [s for s in active if s is not None and s.state == "prefill"]
        for s in pre:
            v = min(C, len(s.enc) - s.filled)
            toks[s.slot, :v] = s.enc[s.filled:s.filled + v]
            counts[s.slot] = v
        key = ("cb_prefill", B, C, nb, self.decode_impl)
        fn = self.engine._jit(key, self._prefill_fn, donate=(1,))
        dev = self._device_state(active, nb)
        args = (self.engine.params, self.kv.pool, dev["tables"], dev["lens"],
                jnp.asarray(counts), jnp.asarray(toks))
        self._record_spec(key, "prefill", B * C, args)
        self.kv.pool, logits, new_lens = fn(*args)
        self.prefill_steps += 1
        self.prefill_tokens += int(counts.sum())
        for s in pre:
            v = int(counts[s.slot])
            s.filled += v
            self.lens_np[s.slot] += v
        dev["lens"] = new_lens
        lf = None
        for s in pre:
            if s.filled >= len(s.enc):
                if lf is None:
                    lf = np.asarray(logits, np.float32)
                self._finish_prefill(s, lf[s.slot], active, results, t0)

    def _prefill_fn(self, params, pool, tables, lens, counts, toks):
        cache = self.kv.gather(pool, tables, lens)
        C = toks.shape[1]
        pos = lens[:, None] + jnp.arange(C, dtype=jnp.int32)[None]
        out = self.model.apply(params, {"tokens": toks, "positions": pos},
                               mode="decode", cache=cache)
        last = jnp.clip(counts - 1, 0, C - 1)
        h = out["hidden"][jnp.arange(toks.shape[0]), last]
        logits = self.model.logits_of(params, h)
        pool = self.kv.scatter(pool, out["cache"], tables, lens, counts, C)
        return pool, logits, lens + counts

    def _finish_prefill(self, s: _Seq, logits_row: np.ndarray, active,
                        results, t0) -> None:
        r = s.req
        if r.kind == SCORE:
            # identical arithmetic to the static _score_batch
            py = logits_row[tok.YES_ID]
            pn = logits_row[tok.NO_ID]
            score = 1.0 / (1.0 + np.exp(-(py - pn)))
            self._retire(s, active, results, t0, score=float(score))
            return
        s.cur = int(np.argmax(logits_row))
        s.state = "decode"
        self._dev = None        # slot joins the decode-active mask
        self._consume(s, active, results, t0)

    # ------------------------------------------------------------------
    # decode step
    # ------------------------------------------------------------------

    def _decode_step(self, active, results, t0) -> None:
        B = self.slots
        nb = self._gather_width(active, 1)
        cur = np.zeros((B, 1), np.int32)
        dec = [s for s in active if s is not None and s.state == "decode"]
        for s in dec:
            cur[s.slot, 0] = s.cur
        key = ("cb_decode", B, nb, self.decode_impl)
        fn = self.engine._jit(key, self._decode_fn, donate=(1,))
        dev = self._device_state(active, nb)
        args = (self.engine.params, self.kv.pool, dev["tables"], dev["lens"],
                dev["act"], jnp.asarray(cur))
        self._record_spec(key, "decode", B, args)
        self.kv.pool, nxt_dev, new_lens = fn(*args)
        self.decode_steps += 1
        self.decode_tokens += len(dec)
        nxt = np.asarray(nxt_dev, np.int32)
        for s in dec:
            self.lens_np[s.slot] += 1
        dev["lens"] = new_lens
        for s in dec:
            s.cur = int(nxt[s.slot])
            self._consume(s, active, results, t0)

    def _decode_fn(self, params, pool, tables, lens, act, cur):
        cache = self.kv.gather(pool, tables, lens)
        with attention.use_decode_impl(self.decode_impl):
            out = self.model.apply(params, {"tokens": cur}, mode="decode",
                                   cache=cache)
        logits = self.model.logits_of(params, out["hidden"][:, 0])
        pool = self.kv.scatter(pool, out["cache"], tables, lens, act, 1)
        return pool, jnp.argmax(logits, -1), lens + act

    def _consume(self, s: _Seq, active, results, t0) -> None:
        """Append the sampled token and retire on EOS / max_tokens —
        exactly the static loop's append-then-check chain."""
        s.out.append(s.cur)
        if s.cur == tok.EOS_ID or len(s.out) >= s.req.max_tokens:
            if s.cur == tok.EOS_ID and len(s.out) < s.req.max_tokens:
                self.retired_eos += 1
            self._retire(s, active, results, t0)

    # ------------------------------------------------------------------

    def _retire(self, s: _Seq, active, results, t0,
                score: Optional[float] = None) -> None:
        r = s.req
        eng = self.engine
        ti = len(s.enc)
        if r.kind == SCORE:
            res = Result(r.request_id, eng.arch, SCORE, score=score,
                         tokens_in=ti, credits=credits_for(eng.arch, ti),
                         engine_id=eng.engine_id)
        else:
            res = Result(r.request_id, eng.arch, COMPLETE,
                         text=tok.decode(s.out), tokens_in=ti,
                         tokens_out=len(s.out),
                         credits=credits_for(eng.arch, ti + len(s.out)),
                         engine_id=eng.engine_id)
        res.latency_s = time.perf_counter() - t0
        results[s.index] = res
        self.kv.free_blocks(s.blocks)
        active[s.slot] = None
        self.lens_np[s.slot] = 0
        self.tables_np[s.slot, :] = 0
        self._dev = None
        self.retired += 1

    # ------------------------------------------------------------------
    # telemetry / roofline
    # ------------------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        occ = (self.decode_tokens / (self.decode_steps * self.slots)
               if self.decode_steps else 0.0)
        return {
            "waves": self.waves, "admitted": self.admitted,
            "retired": self.retired, "retired_eos": self.retired_eos,
            "prefill_steps": self.prefill_steps,
            "decode_steps": self.decode_steps,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "decode_slot_occupancy": occ,
            "kv_blocks": self.kv.num_blocks,
            "kv_block_size": self.block_size,
            "kv_peak_blocks": self.peak_blocks,
        }

    def _record_spec(self, key, kind: str, tokens_per_step: int, args
                     ) -> None:
        if key not in self._step_specs:
            sds = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(jnp.shape(x),
                                               jnp.result_type(x)), args)
            self._step_specs[key] = (kind, tokens_per_step, sds)

    def roofline_report(self) -> Dict[str, Any]:
        """Roofline-derived utilization per step kind (prefill vs decode),
        from AOT-compiling the largest-shape step function of each kind
        (``launch/roofline.py`` does the HLO walk)."""
        from repro.launch import roofline
        n_params = sum(int(x.size) for x in jax.tree.leaves(self.engine.params))
        best: Dict[str, Tuple[Any, int, Tuple[Any, ...]]] = {}
        for key, (kind, tps, sds) in self._step_specs.items():
            if kind not in best or tps >= best[kind][1]:
                best[kind] = (key, tps, sds)
        out: Dict[str, Any] = {}
        for kind, (key, tps, sds) in best.items():
            fn = self.engine._jit_cache[key]
            r = roofline.analyze_jitted(
                fn, sds, arch=self.engine.arch,
                shape=f"{kind}-step B={self.slots}",
                model_flops=2.0 * n_params * tps)
            out[kind] = {"tokens_per_step": tps, **r.to_dict()}
        return out
